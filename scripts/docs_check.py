#!/usr/bin/env python
"""Docs lint: public-symbol docstrings and DESIGN.md section references.

Two checks, both hard CI failures (wired into scripts/smoke.sh):

1. **Docstring coverage** — every module, public module-level function,
   public class, and public method of a public class under
   ``src/repro/api``, ``src/repro/dist``, ``src/repro/core``, ``src/repro/kernels``,
   ``src/repro/serving``, ``src/repro/data``, and ``src/repro/index``
   (plus the ``src/repro/launch/serve.py`` front door) must carry a
   docstring.  Private names (leading underscore, including dunders) are
   exempt, and so is a method override whose base class (resolvable in the
   same module) documents the same method — the contract is documented
   once, at the declaration site (``PlanNode.label`` speaks for every node
   class's ``label``).
2. **DESIGN.md section references** — every ``DESIGN.md §N`` pointer in the
   tree (source comments, docstrings, markdown) must name a section that
   actually exists (``## N.`` heading in DESIGN.md), including both ends of
   ``§A–B`` ranges.  Stale pointers rot silently otherwise — section
   numbers are load-bearing across code comments here.

Exit codes: 0 clean, 1 violations (each printed as file:line).

Usage:  python scripts/docs_check.py
"""
from __future__ import annotations

import ast
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# packages (every .py in the dir) or single .py files
DOC_PACKAGES = ("src/repro/api", "src/repro/dist", "src/repro/core",
                "src/repro/kernels", "src/repro/serving", "src/repro/data",
                "src/repro/index", "src/repro/opt",
                "src/repro/launch/serve.py")
REF_SCAN_DIRS = ("src", "benchmarks", "scripts", "tests", "examples", "docs")
REF_SCAN_ROOT_MD = True       # also scan *.md at the repo root


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def _documented_methods(classes: dict, cls_name: str,
                        seen: set | None = None) -> set[str]:
    """Transitively collect method names documented on ``cls_name`` or any
    same-module base class (single-module MRO approximation)."""
    seen = set() if seen is None else seen
    if cls_name in seen or cls_name not in classes:
        return set()
    seen.add(cls_name)
    node = classes[cls_name]
    out = {sub.name for sub in node.body
           if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef))
           and ast.get_docstring(sub)}
    for base in node.bases:
        if isinstance(base, ast.Name):
            out |= _documented_methods(classes, base.id, seen)
    return out


def check_docstrings(failures: list[str]) -> int:
    """AST-walk the documented packages; append violations, return #symbols."""
    checked = 0
    for pkg in DOC_PACKAGES:
        full = os.path.join(REPO, pkg)
        if pkg.endswith(".py"):
            paths = [full]
        else:
            paths = [os.path.join(full, fname)
                     for fname in sorted(os.listdir(full))
                     if fname.endswith(".py")]
        for path in paths:
            rel = os.path.relpath(path, REPO)
            with open(path) as f:
                tree = ast.parse(f.read(), filename=rel)
            checked += 1
            if not ast.get_docstring(tree):
                failures.append(f"{rel}:1 module docstring missing")
            classes = {n.name: n for n in tree.body
                       if isinstance(n, ast.ClassDef)}
            for node in tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                    if not _is_public(node.name):
                        continue
                    checked += 1
                    if not ast.get_docstring(node):
                        kind = ("class" if isinstance(node, ast.ClassDef)
                                else "function")
                        failures.append(
                            f"{rel}:{node.lineno} public {kind} "
                            f"{node.name!r} missing docstring")
                    if isinstance(node, ast.ClassDef):
                        inherited = set()
                        for base in node.bases:
                            if isinstance(base, ast.Name):
                                inherited |= _documented_methods(
                                    classes, base.id)
                        for sub in node.body:
                            if not isinstance(sub, (ast.FunctionDef,
                                                    ast.AsyncFunctionDef)):
                                continue
                            if not _is_public(sub.name):
                                continue
                            checked += 1
                            if (not ast.get_docstring(sub)
                                    and sub.name not in inherited):
                                failures.append(
                                    f"{rel}:{sub.lineno} public method "
                                    f"{node.name}.{sub.name} missing "
                                    f"docstring")
    return checked


def _design_sections() -> set[int]:
    with open(os.path.join(REPO, "DESIGN.md")) as f:
        text = f.read()
    return {int(m) for m in re.findall(r"^## (\d+)\.", text, re.MULTILINE)}


def _ref_files() -> list[str]:
    out = []
    for d in REF_SCAN_DIRS:
        full = os.path.join(REPO, d)
        if not os.path.isdir(full):
            continue
        for root, _dirs, files in os.walk(full):
            for fname in files:
                if fname.endswith((".py", ".md", ".sh")):
                    out.append(os.path.join(root, fname))
    if REF_SCAN_ROOT_MD:
        for fname in os.listdir(REPO):
            if fname.endswith(".md"):
                out.append(os.path.join(REPO, fname))
    return sorted(out)


# a DESIGN.md mention, then every §N (and the B of a §A–B range) within the
# following few tokens: "DESIGN.md §5", "(DESIGN.md §5, §10)", "DESIGN.md §8–9"
_DESIGN_MENTION = re.compile(r"DESIGN(?:\.md)?\s*(§[^)\n]{0,24})")
_SECTION_NUM = re.compile(r"§\s*(\d+)(?:\s*[–-]\s*§?\s*(\d+))?")


def check_design_refs(failures: list[str]) -> int:
    """Validate every DESIGN.md §N pointer; return the number checked."""
    sections = _design_sections()
    checked = 0
    for path in _ref_files():
        rel = os.path.relpath(path, REPO)
        with open(path, errors="replace") as f:
            for lineno, line in enumerate(f, 1):
                for mention in _DESIGN_MENTION.finditer(line):
                    for m in _SECTION_NUM.finditer(mention.group(1)):
                        nums = [int(m.group(1))]
                        if m.group(2):
                            nums.append(int(m.group(2)))
                        for n in nums:
                            checked += 1
                            if n not in sections:
                                failures.append(
                                    f"{rel}:{lineno} references DESIGN.md "
                                    f"§{n}, which does not exist "
                                    f"(sections: {sorted(sections)})")
    return checked


def main() -> int:
    failures: list[str] = []
    n_docs = check_docstrings(failures)
    n_refs = check_design_refs(failures)
    if failures:
        print(f"docs_check: FAIL — {len(failures)} violation(s):")
        for f in failures:
            print("  " + f)
        return 1
    print(f"docs_check: OK — {n_docs} public symbols documented, "
          f"{n_refs} DESIGN.md section references valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())
