#!/usr/bin/env python
"""Silent-skip audit for the smoke run.

A skipped test that nobody registered is coverage rotting quietly: the
suite stays green while an entire subsystem stops executing (the failure
mode this repo hit when ``hypothesis``-gated property tests skipped
whole-module for years of CI time).  This script parses the junit XML the
smoke pytest run emits and fails unless EVERY skip carries a reason
matching the registry below — adding a new legitimate skip means adding
its reason here, in review, on purpose.

Usage:  python scripts/check_skips.py JUNIT_XML_PATH
"""
from __future__ import annotations

import re
import sys
import xml.etree.ElementTree as ET

# Every legitimate skip reason in this repo, as a regex.  A skip whose
# message matches none of these fails the smoke.
REGISTERED_REASONS = [
    r"hypothesis not installed in this container",
    r"no TPU backend attached",
]


def audit(path: str) -> int:
    """Return the number of UNREGISTERED skips in the junit file (printing
    each), after listing the registered ones."""
    root = ET.parse(path).getroot()
    bad = 0
    for case in root.iter("testcase"):
        skipped = case.find("skipped")
        if skipped is None:
            continue
        name = f"{case.get('classname')}::{case.get('name')}"
        reason = (skipped.get("message") or skipped.text or "").strip()
        if reason and any(re.search(p, reason) for p in REGISTERED_REASONS):
            print(f"[check_skips] ok   {name}: {reason}")
        else:
            bad += 1
            print(f"[check_skips] FAIL {name}: unregistered skip "
                  f"reason {reason!r}")
    return bad


def main() -> None:
    """CLI entry: exit non-zero when any silent/unregistered skip exists."""
    if len(sys.argv) != 2:
        raise SystemExit("usage: check_skips.py JUNIT_XML_PATH")
    bad = audit(sys.argv[1])
    if bad:
        raise SystemExit(
            f"[check_skips] {bad} test(s) skipped without a registered "
            f"reason — register the reason in scripts/check_skips.py or "
            f"fix the skip")
    print("[check_skips] no silent skips")


if __name__ == "__main__":
    main()
