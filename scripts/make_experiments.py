"""Generate EXPERIMENTS.md from dry-run JSONs + benchmark CSV + the §Perf
narrative (hand-written below, numbers from the measured hillclimb log)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.roofline.report import dryrun_table, load_records, roofline_table

ROOT = os.path.join(os.path.dirname(__file__), "..")


def bench_section() -> str:
    path = os.path.join(ROOT, "experiments", "bench_full.csv")
    if not os.path.exists(path):
        return "_bench_full.csv not found — run `python -m benchmarks.run`_"
    lines = open(path).read().strip().splitlines()
    out = ["| name | ms/call | derived |", "|---|---|---|"]
    for ln in lines[1:]:
        parts = ln.split(",", 2)
        if len(parts) != 3:
            continue
        name, us, derived = parts
        out.append(f"| {name} | {float(us)/1e3:.2f} | {derived} |")
    return "\n".join(out)


def main():
    base = load_records(os.path.join(ROOT, "experiments", "dryrun_baseline"))
    opt_dir = os.path.join(ROOT, "experiments", "dryrun")
    opt = load_records(opt_dir)

    narrative = open(os.path.join(ROOT, "scripts",
                                  "experiments_narrative.md")).read()
    doc = narrative
    doc = doc.replace("{{DRYRUN_SINGLE}}", dryrun_table(base, "single"))
    doc = doc.replace("{{DRYRUN_MULTI}}", dryrun_table(base, "multi"))
    doc = doc.replace("{{ROOFLINE_BASELINE}}", roofline_table(base, "single"))
    doc = doc.replace("{{ROOFLINE_OPTIMIZED}}", roofline_table(opt, "single"))
    doc = doc.replace("{{BENCH}}", bench_section())
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(doc)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
