#!/usr/bin/env python
"""Benchmark regression gate: diff a fresh benchmark run against the
committed baselines.

Compares the working-tree ``BENCH_batch.json`` / ``BENCH_join.json``
(freshly rewritten by ``benchmarks/run.py --quick``) against the versions
committed at HEAD (``git show``), and fails on a QPS regression greater
than the tolerance on the FLAT-path rows — the rows whose interpret-mode
performance is stable enough to gate on (the ``*_ivf`` rows are
straggler-dominated on CPU and tracked in the JSON, not gated).

Rows gated:
  * BENCH_batch.json: workloads.flat entries          (key: batch,  qps)
  * BENCH_join.json:  workloads.q3_flat / q4_flat     (key: left_rows,
                                                       qps_batch)
  * BENCH_sched.json: poisson sched-policy rows       (key: rate_multiplier,
                                                       qps) — the q8 arrival
    sweep runs the deadline scheduler on the flat (index-less, fused-kernel)
    plan, so its QPS is as timing-stable as the other flat rows; the
    straggler-dominated effort row stays tracked-not-gated.
  * BENCH_serve.json: q11 overload degraded-policy row (key: policy,
                                                        goodput_ratio) —
    goodput_ratio is deadline-met QPS over measured capacity, so the gate
    is machine-independent; the naive row's met count rides the exact spot
    the backlog crosses the deadline and stays tracked-not-gated.
  * BENCH_dist.json:  workloads.sharded shards=1 rows (key: batch, qps) —
    the sharded lowering at one shard IS the flat path plus a no-op merge,
    so its QPS is gate-stable; multi-shard rows measure fake-CPU-device
    collective overhead and stay tracked-not-gated.
  * BENCH_live.json:  zero_delta rows (key: batch, qps) — live-corpus
    scans with an empty delta segment are the flat path plus a shared
    validity mask and a runtime-skipped merge.  Two gates: fresh-vs-
    committed QPS like every other row, AND live-vs-frozen-twin overhead
    within one run (the q12 report carries a frozen ``frozen_qps`` twin
    measured back-to-back, so the <20% zero-delta regression bound never
    rides cross-run machine noise).  ``batch: 1`` gates too: live single
    queries reuse the batch lowering at Q=1 (``compiler._single_via_batch``)
    but the Q=1 + 1-D validity-lane fast path routes them through the
    single-query fused kernel, so b1 no longer pays the (Q, N) broadcast.
  * BENCH_adaptive.json: q14 adaptive-vs-static rows (key: workload,
    qps_adaptive) — fresh-vs-committed QPS per workload, AND the within-run
    contract that the advisor's per-left profile budgets at least match the
    static p75 pilot on the join row (ratio_adaptive_vs_static >= 1.0,
    measured back-to-back in one run); the single-table drift row's
    thinner margin is tracked, not gated.
  * BENCH_api.json:   q9 restart row — within-run contract only: the
    AOT-warm subprocess (prepare + first batch execute against a populated
    persistent plan cache, DESIGN.md §15) must be >= 10x faster than the
    cold subprocess compile, both spawned back-to-back by one q9 run.
  * BENCH_quant.json: flat quantized-scan rows (key: batch, qps) — the
    same interpret-mode fused-kernel stability argument as BENCH_batch,
    per mode (fp32 / bf16 / int8).  Two gates: fresh-vs-committed QPS per
    (mode, batch) row, AND the within-run speedup contract int8 b64 QPS
    >= 1.5x fp32 b64 QPS (both measured back-to-back in one q13 run, so
    the ratio never rides cross-run machine noise).

Exit codes: 0 pass/skip (no committed baseline, or git unavailable),
1 regression.  Tolerance: BENCH_GATE_TOL env var (default 0.20 = 20%).

Usage:  python scripts/bench_gate.py        (after benchmarks/run.py --quick)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOL = float(os.environ.get("BENCH_GATE_TOL", "0.20"))


def _committed(path: str) -> dict | None:
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{path}"], cwd=REPO, capture_output=True,
            text=True, check=True).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, FileNotFoundError,
            json.JSONDecodeError):
        return None


def _fresh(path: str) -> dict | None:
    full = os.path.join(REPO, path)
    if not os.path.exists(full):
        return None
    with open(full) as f:
        return json.load(f)


def _same_config(name: str, base: dict, fresh: dict, fields: tuple) -> bool:
    """Only compare runs with matching benchmark configuration — a smoke
    run diffed against committed full-scale numbers (or vice versa) would
    spuriously fail (or vacuously pass) the tolerance check."""
    mismatched = {f: (base.get(f), fresh.get(f)) for f in fields
                  if base.get(f) != fresh.get(f)}
    if mismatched:
        print(f"bench_gate: skip {name} — config mismatch vs committed "
              f"baseline: {mismatched}")
        return False
    return True


def _gate_rows(name: str, base_rows: list, fresh_rows: list, key: str,
               qps_field: str, failures: list) -> int:
    fresh_by_key = {e[key]: e for e in fresh_rows}
    checked = 0
    for b in base_rows:
        f = fresh_by_key.get(b[key])
        if f is None or qps_field not in b or qps_field not in f:
            continue
        checked += 1
        floor = (1.0 - TOL) * b[qps_field]
        if f[qps_field] < floor:
            failures.append(
                f"{name}[{key}={b[key]}].{qps_field}: "
                f"{f[qps_field]:.1f} < {floor:.1f} "
                f"(committed {b[qps_field]:.1f}, tol {TOL:.0%})")
    return checked


def main() -> int:
    failures: list[str] = []
    checked = 0

    base = _committed("BENCH_batch.json")
    fresh = _fresh("BENCH_batch.json")
    if base and fresh and _same_config("BENCH_batch.json", base, fresh,
                                       ("n_rows", "flat_rows", "dim", "k")):
        checked += _gate_rows(
            "batch.flat", base.get("workloads", {}).get("flat", []),
            fresh.get("workloads", {}).get("flat", []),
            "batch", "qps", failures)

    base = _committed("BENCH_join.json")
    fresh = _fresh("BENCH_join.json")
    if base and fresh and _same_config("BENCH_join.json", base, fresh,
                                       ("right_rows", "dim", "k")):
        for wl in ("q3_flat", "q4_flat"):
            checked += _gate_rows(
                f"join.{wl}", base.get("workloads", {}).get(wl, []),
                fresh.get("workloads", {}).get(wl, []),
                "left_rows", "qps_batch", failures)

    base = _committed("BENCH_sched.json")
    fresh = _fresh("BENCH_sched.json")
    if base and fresh and _same_config("BENCH_sched.json", base, fresh,
                                       ("sched_rows", "dim", "k",
                                        "max_batch", "n_requests")):
        # flatten the nested per-policy dicts onto gateable rows
        def sched_rows(report: dict) -> list:
            return [{"rate_multiplier": e["rate_multiplier"],
                     "qps": e.get("sched", {}).get("qps")}
                    for e in report.get("poisson", [])
                    if e.get("sched", {}).get("qps") is not None]

        checked += _gate_rows("sched.poisson", sched_rows(base),
                              sched_rows(fresh), "rate_multiplier", "qps",
                              failures)

    base = _committed("BENCH_serve.json")
    fresh = _fresh("BENCH_serve.json")
    if base and fresh and _same_config("BENCH_serve.json", base, fresh,
                                       ("n_rows", "dim", "k", "max_batch",
                                        "n_requests", "overload_mult",
                                        "deadline_batches")):
        # only the degraded-policy row gates: its goodput ratio is pinned
        # by the arrival trace (the resilient policy keeps up with the
        # offered load), while the naive row's met-count rides the exact
        # spot the backlog crosses the deadline — tracked, not gated.
        # goodput_ratio is qps_met / measured capacity, so the gate is
        # machine-independent.
        def serve_rows(report: dict) -> list:
            return [r for r in report.get("rows", [])
                    if r.get("policy") == "degraded"]

        checked += _gate_rows("serve.overload", serve_rows(base),
                              serve_rows(fresh), "policy", "goodput_ratio",
                              failures)

    base = _committed("BENCH_dist.json")
    fresh = _fresh("BENCH_dist.json")
    if base and fresh and _same_config("BENCH_dist.json", base, fresh,
                                       ("n_rows", "dim", "k",
                                        "device_count")):
        # only the shards=1 parity rows gate (see module docstring)
        def dist_rows(report: dict) -> list:
            return [{"batch": e["batch"], "qps": e["qps"]}
                    for e in report.get("workloads", {}).get("sharded", [])
                    if e.get("shards") == 1]

        checked += _gate_rows("dist.shards1", dist_rows(base),
                              dist_rows(fresh), "batch", "qps", failures)

    base = _committed("BENCH_live.json")
    fresh = _fresh("BENCH_live.json")
    if base and fresh and _same_config("BENCH_live.json", base, fresh,
                                       ("flat_rows", "dim", "k",
                                        "delta_cap", "cap_main")):
        # every row gates, b1 included: the Q=1 validity-lane fast path
        # put live single queries on the single-query fused kernel
        checked += _gate_rows("live.zero_delta",
                              base.get("zero_delta", []),
                              fresh.get("zero_delta", []),
                              "batch", "qps", failures)
    # live-vs-frozen twin bound, within one run (fresh if present)
    for e in ((fresh or base) or {}).get("zero_delta", []):
        if "frozen_qps" not in e:
            continue
        checked += 1
        floor = (1.0 - TOL) * e["frozen_qps"]
        if e["qps"] < floor:
            failures.append(
                f"live.zero_delta[batch={e['batch']}].qps: live "
                f"{e['qps']:.1f} < {floor:.1f} "
                f"(same-run frozen twin {e['frozen_qps']:.1f}, "
                f"tol {TOL:.0%})")

    base = _committed("BENCH_quant.json")
    fresh = _fresh("BENCH_quant.json")
    if base and fresh and _same_config("BENCH_quant.json", base, fresh,
                                       ("n_rows", "dim", "k",
                                        "rescore_factor")):
        for mode in ("fp32", "bf16", "int8"):
            checked += _gate_rows(
                f"quant.{mode}", base.get("workloads", {}).get(mode, []),
                fresh.get("workloads", {}).get(mode, []),
                "batch", "qps", failures)
    # within-run speedup contract: the quantized scan must EARN its keep —
    # int8 b64 QPS >= 1.5x fp32 b64 QPS, both timed back-to-back in one
    # q13 run so the ratio never rides cross-run machine noise
    rep = (fresh or base) or {}

    def _b64_qps(mode: str):
        for e in rep.get("workloads", {}).get(mode, []):
            if e.get("batch") == 64:
                return e.get("qps")
        return None

    i8, f32 = _b64_qps("int8"), _b64_qps("fp32")
    if i8 is not None and f32 is not None:
        checked += 1
        if i8 < 1.5 * f32:
            failures.append(
                f"quant.speedup[batch=64]: int8 {i8:.1f} < 1.5x fp32 "
                f"{f32:.1f} (same-run ratio {i8 / f32:.2f}x)")

    # within-run restart contract (BENCH_api.json): preparing a persisted
    # statement in a FRESH process must be >= 10x faster than the cold
    # subprocess compile — cold and AOT-warm children run back-to-back in
    # one q9 invocation, so the ratio never rides cross-run machine noise
    restart = ((_fresh("BENCH_api.json") or _committed("BENCH_api.json"))
               or {}).get("restart")
    if restart and restart.get("speedup") is not None:
        checked += 1
        if restart["speedup"] < 10.0:
            failures.append(
                f"api.restart: AOT-warm speedup {restart['speedup']:.1f}x "
                f"< 10x (cold {restart.get('cold_ms')}ms, warm "
                f"{restart.get('warm_ms')}ms, warm_traces="
                f"{restart.get('warm_traces')})")

    base = _committed("BENCH_adaptive.json")
    fresh = _fresh("BENCH_adaptive.json")
    if base and fresh and _same_config("BENCH_adaptive.json", base, fresh,
                                       ("single_rows", "join_rows", "dim",
                                        "n_batch", "n_left", "b_sets")):
        checked += _gate_rows("adaptive.rows", base.get("rows", []),
                              fresh.get("rows", []), "workload",
                              "qps_adaptive", failures)
    # within-run adaptive-vs-static contract: on the JOIN row the advisor's
    # per-left profile budgets must at least match the static p75 pilot
    # (both timed back-to-back in one q14 run, so the ratio never rides
    # cross-run machine noise); the single-table drift row's thinner margin
    # is tracked in the JSON, not gated
    for e in ((fresh or base) or {}).get("rows", []):
        if e.get("workload") != "join":
            continue
        ratio = e.get("ratio_adaptive_vs_static")
        if ratio is None:
            continue
        checked += 1
        if ratio < 1.0:
            failures.append(
                f"adaptive.join: ratio_adaptive_vs_static {ratio:.3f} < "
                f"1.0 — advisor per-left budgets lost to the static p75 "
                f"pilot (same-run, ms_adaptive={e.get('ms_adaptive')}, "
                f"ms_static={e.get('ms_static')})")

    if checked == 0:
        print("bench_gate: no committed baselines to compare against — skip")
        return 0
    if failures:
        print(f"bench_gate: FAIL — {len(failures)} flat-path QPS "
              f"regression(s) > {TOL:.0%}:")
        for f in failures:
            print("  " + f)
        return 1
    print(f"bench_gate: OK — {checked} flat-path rows within {TOL:.0%} "
          f"of committed QPS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
