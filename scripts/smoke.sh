#!/usr/bin/env bash
# CI smoke: the tier-1 suite (fast tests only — `slow`-marked subprocess
# integration tests are deselected by pytest.ini) plus the quick benchmark
# sweep (q1 latency/recall, q7 batched QPS, q8 scheduler smoke, q9 plan
# cache, q10 sharded scan, q11 overload goodput, q12 live-corpus
# freshness, q13 quantized-scan QPS with recall==1.0 hard-asserted, q14
# adaptive optimizer vs static pilot (bit-parity hard-asserted), q34
# batch-native joins, t5 counters) on the tiny catalog —
# q34 exercises the join families
# end-to-end on both lowerings, q8 the dynamic batch scheduler (Poisson
# policies + effort-bucketed IVF), q10 the multi-device sharded lowering
# (fake CPU devices in a child process; asserts shards=1 bit-parity), q11
# graceful degradation vs naive queueing under overload — then the seeded
# chaos smoke of the resilient serving tier, the benchmark regression gate
# (scripts/bench_gate.py: fresh flat-path QPS must stay within 20% of the
# committed BENCH_* baselines, live zero-delta QPS within 20% of its
# same-run frozen twin, and the q14 join advisor at least matching the
# static p75 pilot within one run) and the docs lint (scripts/docs_check.py:
# public-symbol docstrings in api/dist/core/serving/data/index/opt +
# launch/serve.py, DESIGN.md §-reference validity).
#
# Finishes with examples/quickstart.py --smoke so the public session API
# (connect/prepare/execute, plan cache, explain) is exercised end-to-end.
#
#   bash scripts/smoke.sh            # full smoke
#   SMOKE_SLOW=1 bash scripts/smoke.sh   # also run the slow marker set
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

JUNIT_XML="$(mktemp -t pytest-junit-XXXXXX.xml)"
python -m pytest -x -q --junit-xml="$JUNIT_XML"
# silent-skip audit: every skip must carry a registered reason
python scripts/check_skips.py "$JUNIT_XML"
rm -f "$JUNIT_XML"
if [[ "${SMOKE_SLOW:-0}" == "1" ]]; then
    python -m pytest -x -q -m slow
fi
python -m benchmarks.run --quick
# seeded chaos smoke (DESIGN.md §11–12): three seeds through every fault
# class — no hangs, no stale results, exact counters, explicit
# backpressure — plus live-corpus crash recovery at every WAL/snapshot/
# compaction kill point, recovered bit-identical to an unfailed replay
python -m benchmarks.run --chaos
python scripts/bench_gate.py
python scripts/docs_check.py
# public session API can't silently rot: run the quickstart at CI shapes
python examples/quickstart.py --smoke
