#!/usr/bin/env bash
# CI smoke: the tier-1 suite (fast tests only — `slow`-marked subprocess
# integration tests are deselected by pytest.ini) plus the quick benchmark
# sweep (q1 latency/recall, q7 batched QPS, q34 batch-native joins, t5
# counters) on the tiny catalog — q34 exercises the join families end-to-end
# on both the batch-native and the per-left-loop lowering.
#
#   bash scripts/smoke.sh            # full smoke
#   SMOKE_SLOW=1 bash scripts/smoke.sh   # also run the slow marker set
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
if [[ "${SMOKE_SLOW:-0}" == "1" ]]; then
    python -m pytest -x -q -m slow
fi
python -m benchmarks.run --quick
