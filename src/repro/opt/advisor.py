"""The feedback loop: runtime stats -> execute-time effort decisions
(DESIGN.md §14).

:class:`LoweringAdvisor` closes the loop the engine left open — it watches
every executed batch's counters (through :class:`~repro.opt.stats.StatsStore`)
and uses them to decide, at execute time, how the *next* batch of the same
plan shape should spend its effort.  Two invariants shape the design:

* **Decisions never change results.**  The advisor only chooses among
  already-compiled, bit-identical execution lanes: lock-step bucketed
  execution, or two-phase effort bucketing with a predicted pilot budget
  (whose phase-2 safety net re-runs any query that hit its budget — see
  ``serving/scheduler.run_effort_bucketed``).  Picking a *different
  lowering* (flat vs IVF vs quantized) is compile-affecting and can change
  recall under counter termination, so that surface is advisory only:
  :meth:`score_plan` ranks the lanes with the calibrated
  :class:`~repro.opt.cost.CostModel` and ``db.advise(sql)`` /
  ``explain()`` report it, but nothing switches silently.
* **Zero new retraces on the hot path.**  Predicted budgets ride the
  runtime ``probe_budget`` argument of the compiled bucket executables in
  a canonical dtype/shape per plan form (scalar int for single-table
  batches, an (Q, L) int32 array for joins), so consecutive adaptive
  executions with *different* predictions hit the same traced executable.

Decision ladder (per executed batch): a join plan with a warmed per-left
probe profile gets per-left budgets (effort inside ONE join call — left
rows live in plan arrays, so the profile carries across calls); otherwise a
warmed (plan, selectivity-bucket) aggregate predicts a scalar pilot;
otherwise the batch runs lock-step and only *observes*.  Plans with no
probe lane (flat lowerings: budgets are inert) always run lock-step.
``ExecutionHints`` always win: the Statement layer consults the advisor
only when the caller specified no execution knobs.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from ..core.compiler import _scan_of, fingerprint_digest, plan_fingerprint
from ..core.physical import probe_ceiling
from ..core.rewriter import selectivity_atoms
from .cost import CostModel
from .stats import StatsStore, bucket_of

# selectivity of an atom the sketches cannot estimate (unknown column /
# non-numeric operand): neutral, never tightens the estimate
_NEUTRAL_SEL = 1.0


@dataclasses.dataclass(frozen=True)
class OptDecision:
    """One advised execution: the chosen path and the evidence behind it.

    ``pilot`` is the runtime probe budget the decision carries — None
    (lock-step), a scalar int, or a per-left (Q, L) int32 array.  ``source``
    says what the decision was based on: ``cold`` (no stats yet),
    ``stats`` (bucket EMA), ``profile`` (per-left join profile), or
    ``flat`` (plan has no probe lane)."""
    path: str                        # "lockstep" | "effort"
    source: str                      # "cold" | "stats" | "profile" | "flat"
    digest: str
    bucket: int | None = None
    selectivity: float | None = None
    pilot: object = None             # None | int | np.ndarray
    scores: dict | None = None

    def summary(self) -> dict:
        """JSON-able form for ``explain()``'s ``-- opt:`` line."""
        out = {"path": self.path, "source": self.source,
               "plan": self.digest[:12]}
        if self.bucket is not None:
            out["bucket"] = int(self.bucket)
        if self.selectivity is not None:
            out["sel"] = round(float(self.selectivity), 4)
        if self.pilot is not None:
            if np.ndim(self.pilot) == 0:
                out["pilot"] = int(self.pilot)
            else:
                arr = np.asarray(self.pilot)
                out["pilot"] = {"min": int(arr.min()), "max": int(arr.max()),
                                "shape": list(arr.shape)}
        if self.scores:
            out["scores"] = {k: round(float(v), 1)
                             for k, v in sorted(self.scores.items())}
        return out


class LoweringAdvisor:
    """Stats-driven execute-time effort advisor over one catalog.

    Deterministic by construction: decisions are pure functions of the
    stats store contents, the catalog version clock, and the bind values —
    two advisors fed the same observation sequence advise identically
    (asserted in tests/test_opt.py)."""

    def __init__(self, catalog, stats: StatsStore | None = None,
                 cost: CostModel | None = None, *,
                 stats_path: str | None = None, sample_rows: int = 4096,
                 enabled: bool = True):
        self.catalog = catalog
        self.stats_path = stats_path
        if stats is None:
            if stats_path and os.path.exists(stats_path):
                stats = StatsStore.load(stats_path)
            else:
                stats = StatsStore()
        self.stats = stats
        self.cost = cost or CostModel.from_bench()
        self.sample_rows = int(sample_rows)
        self.enabled = enabled
        self._digests: dict[int, str] = {}       # id(compiled) -> digest
        self._sketches: dict = {}   # (table, col) -> (version, sorted sample)

    # -- plan identity -------------------------------------------------------

    def plan_key(self, compiled) -> str:
        """Stats key: normalized-plan fingerprint digest + options digest
        (one plan under two lowerings keeps two stat histories)."""
        digest = self._digests.get(id(compiled))
        if digest is None:
            fp, _ = plan_fingerprint(compiled.logical_plan)
            digest = (f"{fingerprint_digest(fp)}:"
                      f"{fingerprint_digest(compiled.options.fingerprint())}")
            self._digests[id(compiled)] = digest
        return digest

    def version_token(self, compiled) -> tuple:
        """Catalog version snapshot over the plan's dependency keys — the
        invalidation stamp every stats entry carries."""
        cat = getattr(compiled, "_catalog", None)
        keys = getattr(compiled, "_dep_keys", None)
        if cat is None or keys is None:
            return ()
        return cat.version_snapshot(keys)

    # -- selectivity estimation ----------------------------------------------

    def _sketch(self, table: str, column: str) -> np.ndarray | None:
        try:
            tab = self.catalog.table(table)
        except KeyError:
            return None
        ver = self.catalog.version(("table", table))
        cached = self._sketches.get((table, column))
        if cached is not None and cached[0] == ver:
            return cached[1]
        try:
            col = np.asarray(tab[column])
        except (KeyError, TypeError):
            return None
        if col.ndim != 1 or col.dtype.kind not in "ifub":
            return None
        if col.shape[0] > self.sample_rows:
            take = np.linspace(0, col.shape[0] - 1,
                               self.sample_rows).astype(np.int64)
            col = col[take]
        sample = np.sort(col.astype(np.float64))
        self._sketches[(table, column)] = (ver, sample)
        return sample

    def selectivity(self, compiled, binds: dict) -> float:
        """Estimated structured-filter selectivity of this batch: product of
        per-atom sketch estimates (conjunct independence), median across the
        batch when thresholds are per-query.  1.0 when there is nothing to
        estimate — the loosest bucket."""
        atoms = selectivity_atoms(compiled.analysis)
        if not atoms:
            return 1.0
        default_table = _scan_of(compiled.analysis)[0]
        sel = np.asarray(1.0)
        for atom in atoms:
            sample = self._sketch(atom["table"] or default_table,
                                  atom["column"])
            if sample is None or sample.size == 0:
                continue
            if atom["param"] is not None:
                value = binds.get(atom["param"])
                if value is None:
                    continue
            else:
                value = atom["value"]
            try:
                v = np.asarray(value, dtype=np.float64)
            except (TypeError, ValueError):
                continue
            right = np.searchsorted(sample, v, side="right") / sample.size
            op = atom["op"]
            if op in ("<", "<="):
                frac = right
            elif op in (">", ">="):
                frac = 1.0 - right
            else:
                left = np.searchsorted(sample, v, side="left") / sample.size
                frac = right - left
                if op in ("<>", "!="):
                    frac = 1.0 - frac
            sel = sel * np.clip(frac, 1e-9, 1.0)
        return float(np.median(sel))

    # -- the decision --------------------------------------------------------

    def advise_batch(self, compiled, binds: dict) -> OptDecision:
        """Decide how one stacked batch should spend its probe effort."""
        digest = self.plan_key(compiled)
        token = self.version_token(compiled)
        sel = self.selectivity(compiled, binds)
        bucket = bucket_of(sel)
        scores = self.score_plan(compiled, selectivity=sel,
                                 version=token).get("scores")
        ceiling = probe_ceiling(compiled.options)
        base = dict(digest=digest, bucket=bucket, selectivity=sel,
                    scores=scores)
        if ceiling <= 0:
            return OptDecision("lockstep", "flat", **base)
        floor = int(compiled.options.probe.min_probes) + 1
        profile = self.stats.left_profile(digest, token)
        if profile is not None and profile.max() > 0:
            qn = _stacked_qn_safe(binds)
            budgets = np.asarray(
                [self.cost.probe_budget(p, floor=floor, ceiling=ceiling)
                 for p in profile], np.int32)
            pilot = np.broadcast_to(budgets, (qn, budgets.shape[0])).copy()
            return OptDecision("effort", "profile", pilot=pilot, **base)
        entry = self.stats.lookup(digest, bucket, token)
        if entry is not None and entry["count"] > 0:
            if entry["probes_hi"] <= 0:
                # measured: this plan never probes (flat fallback) — budgets
                # would be inert, two-phase would be pure overhead
                return OptDecision("lockstep", "stats", **base)
            pilot = self.cost.probe_budget(entry["probes_hi"], floor=floor,
                                           ceiling=ceiling)
            if pilot >= ceiling:
                return OptDecision("lockstep", "stats", **base)
            return OptDecision("effort", "stats", pilot=int(pilot), **base)
        return OptDecision("lockstep", "cold", **base)

    def observe(self, compiled, decision: OptDecision, out: dict,
                latency_ms: float = 0.0) -> None:
        """Fold one executed batch's counters back into the stats store
        (the merged, phase-complete counters: they equal lock-step's)."""
        stats_tree = out.get("stats") if isinstance(out, dict) else None
        if stats_tree is None or "probes" not in stats_tree:
            return
        token = self.version_token(compiled)
        probes = np.asarray(stats_tree["probes"])
        if probes.ndim == 2:
            self.stats.observe_left(decision.digest, token, probes)
        per_query = probes
        if per_query.ndim > 1:
            per_query = per_query.max(
                axis=tuple(range(1, per_query.ndim)))
        rows = np.asarray(stats_tree.get("distance_evals", 0.0))
        self.stats.observe(
            decision.digest, decision.bucket or 0, token,
            selectivity=decision.selectivity or 1.0, probes=per_query,
            rows=float(np.mean(rows)), latency_ms=float(latency_ms))

    # -- prepare-time lowering scores ----------------------------------------

    def score_plan(self, compiled, selectivity: float = 1.0,
                   version: tuple | None = None) -> dict:
        """Cost-model lane scores for a compiled plan (advisory: feeds
        ``db.advise`` and the ``-- opt:`` explain line; execute-time picks
        stay within bit-identical lanes)."""
        a = compiled.analysis
        table, column = _scan_of(a)
        tab = self.catalog.table(table)
        n_rows = int(tab.num_rows)
        idx = self.catalog.index_for(table, column)
        cluster_rows = None
        if idx is not None:
            nlist = int(np.asarray(idx.centroids).shape[0])
            cluster_rows = n_rows / max(nlist, 1)
        quant_modes = tuple(
            mode for mode in ("int8", "bf16")
            if self.catalog.quantized_for(table, column, mode) is not None)
        k = a.k if isinstance(a.k, int) else 10
        probe = compiled.options.probe
        token = (version if version is not None
                 else self.version_token(compiled))
        entry = self.stats.lookup(self.plan_key(compiled),
                                  bucket_of(selectivity), token)
        expected = (entry["probes_mean"]
                    if entry and entry["probes_mean"] > 0 else None)
        scores = self.cost.score(
            n_rows=n_rows, k=k, selectivity=selectivity,
            cluster_rows=cluster_rows, expected_probes=expected,
            quant_modes=quant_modes, min_probes=int(probe.min_probes),
            max_probes=int(probe.max_probes))
        return {"scores": scores, "recommended": self.cost.choose(scores),
                "n_rows": n_rows, "selectivity": round(selectivity, 4),
                "cost_model": self.cost.describe()}

    def save(self, path: str | None = None) -> None:
        """Persist the stats store (to ``stats_path`` unless overridden)."""
        target = path or self.stats_path
        if target:
            self.stats.save(target)


def _stacked_qn_safe(binds: dict) -> int:
    """Leading Q of a stacked bind dict; 1 when every bind is scalar (a
    single bind set executed through the batch path)."""
    for v in binds.values():
        if hasattr(v, "ndim") and v.ndim >= 1:
            return int(v.shape[0])
    return 1
