"""Runtime execution statistics for the adaptive optimizer (DESIGN.md §14).

Every executed batch leaves behind counters the engine already computes
(per-query probes-to-termination, distance evaluations, selectivity of the
structured filter) — this module is the place they accumulate so the next
execution of the *same plan shape* can spend effort where the last one
needed it.  Two aggregate families:

* **Bucket aggregates** — keyed ``(plan digest, selectivity bucket)``:
  EMA + count of observed selectivity, mean / high-quantile probes, rows
  scanned, and wall latency.  Buckets are log2-spaced in selectivity
  (bucket 0 covers (0.5, 1], each next bucket halves the range) so a plan
  executed with a tight filter and with a loose filter keeps *separate*
  probe profiles — the whole point on skewed workloads.
* **Left profiles** — keyed plan digest: a per-left-row EMA probe vector
  for join plans, whose left rows live in the plan arrays and are therefore
  the SAME rows on every call.  The profile is what turns bind-set-granular
  effort bucketing into per-left budgets inside a single join call.

Entries are stamped with the catalog version token
(``Catalog.version_snapshot`` over the plan's dependency keys) at first
observation; a lookup or observe under a different token drops the entry —
stats never outlive the data/index generation they were measured on.
Everything is plain floats + dicts: deterministic, JSON-round-trippable
(``to_json``/``from_json``), and persistable (``save``/``load``) so stats
survive restarts keyed by the *normalized* plan fingerprint digest.
"""
from __future__ import annotations

import json
import math

import numpy as np

N_BUCKETS = 8          # log2 selectivity buckets: 0 = loose, 7 = needle
EMA_ALPHA = 0.25       # weight of the newest observation
PROBE_QUANTILE = 75.0  # the "high" probe statistic tracked per bucket


def bucket_of(selectivity: float) -> int:
    """Log2 selectivity bucket: ``floor(-log2(sel))`` clipped to
    ``[0, N_BUCKETS)`` — bucket 0 covers (0.5, 1], bucket 1 (0.25, 0.5], …
    Deterministic and monotone: tighter filters land in higher buckets."""
    s = min(max(float(selectivity), 1e-9), 1.0)
    return int(min(N_BUCKETS - 1, math.floor(-math.log2(s) + 1e-12)))


def _blank_entry() -> dict:
    return {"count": 0, "sel": 0.0, "probes_mean": 0.0, "probes_hi": 0.0,
            "rows": 0.0, "latency_ms": 0.0}


class StatsStore:
    """Online per-(plan, selectivity-bucket) execution aggregates.

    All updates are exponential moving averages (``alpha`` = weight of the
    newest observation; the first observation seeds the EMA exactly), so
    the store is O(plans × buckets) regardless of traffic, and two stores
    fed the same observation sequence are bit-identical — the determinism
    the advisor tests assert."""

    def __init__(self, alpha: float = EMA_ALPHA):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        # (digest, bucket) -> {"version": tuple, **_blank_entry()}
        self._entries: dict = {}
        # digest -> {"version": tuple, "count": int, "profile": [float, ...]}
        self._left: dict = {}

    def __len__(self) -> int:
        return len(self._entries)

    def _ema(self, old: float, new: float, count: int) -> float:
        if count == 0:
            return float(new)
        return float(self.alpha * new + (1.0 - self.alpha) * old)

    # -- bucket aggregates --------------------------------------------------

    def observe(self, digest: str, bucket: int, version: tuple, *,
                selectivity: float, probes: np.ndarray, rows: float = 0.0,
                latency_ms: float = 0.0) -> dict:
        """Fold one executed batch into the (digest, bucket) aggregate.

        ``probes`` is the per-query probes-to-termination vector (joins
        reduced to per-bind-set max by the caller); ``rows`` the mean
        distance evaluations per query.  A version-token mismatch resets
        the entry first (catalog-clock invalidation)."""
        key = (digest, int(bucket))
        entry = self._entries.get(key)
        if entry is None or tuple(entry["version"]) != tuple(version):
            entry = dict(_blank_entry(), version=tuple(version))
            self._entries[key] = entry
        p = np.asarray(probes, dtype=np.float64).reshape(-1)
        p_mean = float(p.mean()) if p.size else 0.0
        p_hi = float(np.percentile(p, PROBE_QUANTILE)) if p.size else 0.0
        n = entry["count"]
        entry["sel"] = self._ema(entry["sel"], float(selectivity), n)
        entry["probes_mean"] = self._ema(entry["probes_mean"], p_mean, n)
        entry["probes_hi"] = self._ema(entry["probes_hi"], p_hi, n)
        entry["rows"] = self._ema(entry["rows"], float(rows), n)
        entry["latency_ms"] = self._ema(entry["latency_ms"],
                                        float(latency_ms), n)
        entry["count"] = n + 1
        return entry

    def lookup(self, digest: str, bucket: int, version: tuple) -> dict | None:
        """The (digest, bucket) aggregate, or None if absent or measured
        under a different catalog version (the stale entry is dropped)."""
        key = (digest, int(bucket))
        entry = self._entries.get(key)
        if entry is None:
            return None
        if tuple(entry["version"]) != tuple(version):
            del self._entries[key]
            return None
        return entry

    # -- per-left join profiles ---------------------------------------------

    def observe_left(self, digest: str, version: tuple,
                     probes_ql: np.ndarray) -> None:
        """Fold a join execution's (Q, L) probe counters into the per-left
        EMA profile (reduced over the bind-set axis by max — a left row's
        cost is its worst bind set).  Shape or version drift resets."""
        per_left = np.asarray(probes_ql, dtype=np.float64)
        if per_left.ndim != 2:
            raise ValueError(
                f"per-left profiles need (Q, L) probe counters, got shape "
                f"{per_left.shape}")
        per_left = per_left.max(axis=0)
        rec = self._left.get(digest)
        if (rec is None or tuple(rec["version"]) != tuple(version)
                or len(rec["profile"]) != per_left.shape[0]):
            rec = {"version": tuple(version), "count": 0,
                   "profile": [0.0] * per_left.shape[0]}
            self._left[digest] = rec
        old = np.asarray(rec["profile"])
        if rec["count"] == 0:
            new = per_left
        else:
            new = self.alpha * per_left + (1.0 - self.alpha) * old
        rec["profile"] = [float(x) for x in new]
        rec["count"] += 1

    def left_profile(self, digest: str, version: tuple) -> np.ndarray | None:
        """The (L,) per-left EMA probe profile, or None if absent/stale."""
        rec = self._left.get(digest)
        if rec is None:
            return None
        if tuple(rec["version"]) != tuple(version):
            del self._left[digest]
            return None
        if rec["count"] == 0:
            return None
        return np.asarray(rec["profile"], dtype=np.float64)

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> str:
        """Serialize (sorted keys — byte-stable for identical stores)."""
        entries = [{"digest": d, "bucket": b, "version": list(e["version"]),
                    **{k: e[k] for k in _blank_entry()}}
                   for (d, b), e in sorted(self._entries.items())]
        left = [{"digest": d, "version": list(r["version"]),
                 "count": r["count"], "profile": r["profile"]}
                for d, r in sorted(self._left.items())]
        return json.dumps({"alpha": self.alpha, "entries": entries,
                           "left": left}, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "StatsStore":
        """Rebuild a store serialized by :meth:`to_json`; versions round-trip
        as tuples so invalidation keeps working across restarts."""
        blob = json.loads(text)
        store = cls(alpha=blob.get("alpha", EMA_ALPHA))
        for e in blob.get("entries", ()):
            entry = {k: e[k] for k in _blank_entry()}
            entry["version"] = _version_from_json(e["version"])
            store._entries[(e["digest"], int(e["bucket"]))] = entry
        for r in blob.get("left", ()):
            store._left[r["digest"]] = {
                "version": _version_from_json(r["version"]),
                "count": int(r["count"]),
                "profile": [float(x) for x in r["profile"]]}
        return store

    def save(self, path: str) -> None:
        """Write the JSON form to ``path``."""
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "StatsStore":
        """Read a store written by :meth:`save`."""
        with open(path) as f:
            return cls.from_json(f.read())


def _version_from_json(version) -> tuple:
    # version tokens are tuples of (key-tuple, int) pairs; JSON turns the
    # tuples into lists — restore hashable/comparable tuple form recursively
    def back(v):
        return tuple(back(x) for x in v) if isinstance(v, list) else v
    return back(version)
