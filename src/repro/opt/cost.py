"""Roofline-calibrated lane cost model for the adaptive optimizer
(DESIGN.md §14).

Costs are expressed in **flat-scan row units**: scanning one fp32 corpus
row through the fused flat kernel costs 1.0, and every other lane is
scored relative to that.  The constants come from the committed
``BENCH_*.json`` rooflines (the benchmarks this repo gates on), read once
at construction:

* ``BENCH_batch.json``  — flat ms/row and the IVF gather penalty (an IVF
  probe's rows cost more than streamed flat rows: gather + per-round
  top-k merge overhead, measured as the ratio of per-row ms).
* ``BENCH_quant.json``  — int8 / bf16 batch-64 speedups over the fp32
  flat scan (``speedup_b64``) and the rescore candidate multiple.
* ``BENCH_sched.json``  — the measured effort-bucketing speedup (sanity
  reference recorded in ``sources``; the advisor re-derives effort wins
  from live stats, not from this constant).

Missing or unreadable files degrade to the ``DEFAULTS`` below (the model
must work in a fresh checkout with no committed baselines), and the chosen
constants are reported in :meth:`CostModel.describe` so ``explain()`` and
``db.advise`` can show where a recommendation came from.  Everything here
is pure float arithmetic — deterministic by construction.
"""
from __future__ import annotations

import json
import math
import os

DEFAULTS = {
    "int8_speedup": 1.67,     # quantized b64 QPS / fp32 b64 QPS
    "bf16_speedup": 1.41,
    "rescore_factor": 3,      # candidate multiple c of the fused rescore
    "ivf_gather_penalty": 2.0,  # per-row cost of probed rows vs flat rows
    "headroom": 1.25,         # predicted budget = EMA high quantile x this
}

_REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))


def _read_json(root: str, name: str) -> dict | None:
    try:
        with open(os.path.join(root, name)) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


class CostModel:
    """Score the compiled lanes of a plan and predict probe budgets.

    The model answers two questions the advisor asks:

    * :meth:`score` — relative cost of the flat / quantized / IVF lowerings
      for a given corpus size and selectivity estimate (a prepare-time
      advisory surface: execute-time lane picks are restricted to
      bit-identical effort variants, see ``opt/advisor.py``).
    * :meth:`probe_budget` — the per-query pilot budget to run phase 1 of
      effort-bucketed execution with, given an observed probe statistic:
      high-quantile EMA × ``headroom``, +1 so queries that historically
      terminate AT the quantile still prove natural termination, clipped
      to the plan's probe ceiling.
    """

    def __init__(self, *, int8_speedup: float | None = None,
                 bf16_speedup: float | None = None,
                 rescore_factor: int | None = None,
                 ivf_gather_penalty: float | None = None,
                 headroom: float | None = None,
                 sources: tuple = ()):
        d = DEFAULTS
        self.int8_speedup = float(int8_speedup or d["int8_speedup"])
        self.bf16_speedup = float(bf16_speedup or d["bf16_speedup"])
        self.rescore_factor = int(rescore_factor or d["rescore_factor"])
        self.ivf_gather_penalty = float(
            ivf_gather_penalty or d["ivf_gather_penalty"])
        self.headroom = float(headroom or d["headroom"])
        self.sources = tuple(sources)

    @classmethod
    def from_bench(cls, root: str | None = None) -> "CostModel":
        """Calibrate from the committed BENCH_*.json files under ``root``
        (default: the repo root); absent files fall back to DEFAULTS."""
        root = root or _REPO
        sources = []
        kw: dict = {}
        quant = _read_json(root, "BENCH_quant.json")
        if quant:
            sp = quant.get("speedup_b64") or {}
            if sp.get("int8"):
                kw["int8_speedup"] = sp["int8"]
            if sp.get("bf16"):
                kw["bf16_speedup"] = sp["bf16"]
            if quant.get("rescore_factor"):
                kw["rescore_factor"] = quant["rescore_factor"]
            sources.append("BENCH_quant.json")
        batch = _read_json(root, "BENCH_batch.json")
        if batch:
            pen = _gather_penalty(batch)
            if pen is not None:
                kw["ivf_gather_penalty"] = pen
            sources.append("BENCH_batch.json")
        sched = _read_json(root, "BENCH_sched.json")
        if sched and (sched.get("effort") or {}).get("speedup"):
            sources.append("BENCH_sched.json")
        return cls(sources=tuple(sources), **kw)

    def describe(self) -> dict:
        """The calibrated constants + where they came from (JSON-able)."""
        return {"int8_speedup": self.int8_speedup,
                "bf16_speedup": self.bf16_speedup,
                "rescore_factor": self.rescore_factor,
                "ivf_gather_penalty": round(self.ivf_gather_penalty, 3),
                "headroom": self.headroom,
                "sources": list(self.sources)}

    # -- lane scoring --------------------------------------------------------

    def expected_probes(self, selectivity: float, *, min_probes: int,
                        max_probes: int) -> int:
        """Cold-start probe estimate from a selectivity estimate alone:
        every halving of selectivity costs ~2 extra probe rounds (matching
        the log2 bucket policy of the stats store).  Replaced by the EMA
        as soon as one execution has been observed."""
        s = min(max(float(selectivity), 1e-9), 1.0)
        est = min_probes + 2.0 * (-math.log2(s))
        return int(min(max(est, min_probes), max_probes))

    def score(self, *, n_rows: int, k: int = 10, selectivity: float = 1.0,
              cluster_rows: float | None = None,
              expected_probes: float | None = None,
              quant_modes: tuple = (), min_probes: int = 4,
              max_probes: int = 64) -> dict:
        """Relative lane costs (flat-scan row units) for one plan shape.

        ``cluster_rows`` is the mean IVF cluster size (n_rows / nlist);
        None means no index is registered and the IVF lane is not scored.
        ``expected_probes`` comes from the stats EMA when available."""
        scores = {"flat": float(n_rows)}
        for mode in quant_modes:
            speed = (self.int8_speedup if mode == "int8"
                     else self.bf16_speedup)
            rescore = float(self.rescore_factor * k)
            scores[f"quant:{mode}"] = n_rows / speed + rescore
        if cluster_rows is not None and cluster_rows > 0:
            probes = expected_probes
            if probes is None:
                probes = self.expected_probes(
                    selectivity, min_probes=min_probes,
                    max_probes=max_probes)
            scores["ivf"] = (float(probes) * float(cluster_rows)
                             * self.ivf_gather_penalty)
        return scores

    def choose(self, scores: dict) -> str:
        """The cheapest scored lane (ties break lexicographically —
        deterministic)."""
        return min(sorted(scores), key=lambda lane: scores[lane])

    # -- probe-budget prediction ---------------------------------------------

    def probe_budget(self, probes_hi: float, *, floor: int,
                     ceiling: int) -> int:
        """Pilot budget from an observed high-quantile probe EMA."""
        want = int(math.ceil(float(probes_hi) * self.headroom)) + 1
        return int(min(max(want, floor), ceiling))


def _gather_penalty(batch: dict) -> float | None:
    """Per-row ms of probed IVF rows over per-row ms of flat rows, from the
    largest-batch rows of BENCH_batch.json (None if counters are absent)."""
    def per_row_ms(rows):
        best = None
        for r in rows or ():
            evals = r.get("distance_evals_per_query") or 0
            if evals and r.get("ms") and r.get("batch"):
                best = (r["ms"] / r["batch"]) / evals
        return best

    w = batch.get("workloads") or {}
    flat, ivf = per_row_ms(w.get("flat")), per_row_ms(w.get("ivf"))
    if not flat or not ivf:
        return None
    return max(1.0, ivf / flat)
