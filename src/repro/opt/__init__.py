"""Adaptive query optimizer: runtime-statistics feedback + cost-based
lowering advice (DESIGN.md §14).

The engine's execute surfaces already emit everything an optimizer needs —
per-query probe counters, distance-eval counts, trace counts, a runtime
probe-budget lane — this package closes the loop:

* :class:`~repro.opt.stats.StatsStore` — deterministic, JSON-persistable
  per-(plan-fingerprint, selectivity-bucket) EMA aggregates + per-left join
  probe profiles, invalidated by the catalog version clock.
* :class:`~repro.opt.cost.CostModel` — lane costs calibrated from the
  committed BENCH_*.json rooflines; predicts pilot probe budgets.
* :class:`~repro.opt.advisor.LoweringAdvisor` — the execute-time decision
  maker, wired into ``Statement.execute`` (``connect(cat, adaptive=True)``)
  and ``serving.scheduler.run_effort_bucketed``; chooses only among
  bit-identical compiled lanes, is always overridden by ``ExecutionHints``,
  and reports itself on the ``-- opt:`` explain line.
"""
from .advisor import LoweringAdvisor, OptDecision
from .cost import CostModel
from .stats import StatsStore, bucket_of

__all__ = ["LoweringAdvisor", "OptDecision", "CostModel", "StatsStore",
           "bucket_of"]
