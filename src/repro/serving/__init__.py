from .decode import build_serve_step, generate, prefill
from .rag import HybridRetriever
from .scheduler import (BatchScheduler, SchedulerConfig, latency_stats,
                        run_effort_bucketed)

__all__ = ["build_serve_step", "generate", "prefill", "HybridRetriever",
           "BatchScheduler", "SchedulerConfig", "latency_stats",
           "run_effort_bucketed"]
