from .decode import build_serve_step, generate, prefill
from .rag import HybridRetriever

__all__ = ["build_serve_step", "generate", "prefill", "HybridRetriever"]
