"""The serving tier: LM decode, hybrid RAG retrieval, the dynamic batch
scheduler, and the resilience layer (admission control, deadlines, graceful
degradation, seeded fault injection — DESIGN.md §11)."""
from .decode import build_serve_step, generate, prefill
from .faults import FaultInjector, FaultSpec, InjectedKernelError
from .rag import HybridRetriever
from .resilience import (AdmissionConfig, AdmissionController,
                         BackpressureError, DeadlineExceededError,
                         DegradePolicy, LoadController, PoisonedBindError,
                         ServingError, validate_binds)
from .scheduler import (BatchScheduler, ResilientScheduler, SchedulerConfig,
                        latency_stats, run_effort_bucketed)

__all__ = ["build_serve_step", "generate", "prefill", "HybridRetriever",
           "BatchScheduler", "ResilientScheduler", "SchedulerConfig",
           "latency_stats", "run_effort_bucketed",
           "FaultInjector", "FaultSpec", "InjectedKernelError",
           "AdmissionConfig", "AdmissionController", "BackpressureError",
           "DeadlineExceededError", "DegradePolicy", "LoadController",
           "PoisonedBindError", "ServingError", "validate_binds"]
