"""Serving steps: prefill, single-token decode (the dry-run's ``serve_step``),
and a batched greedy generation loop."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models import decode_step, forward, init_cache
from ..models.config import ModelConfig


def build_serve_step(cfg: ModelConfig):
    """serve_step(params, cache, tokens/embeds) -> (next_token_logits, cache).

    This is the function the decode_* dry-run cells lower: one new token
    against a seq_len-deep KV cache."""

    def serve_step(params, cache, tokens=None, embeds=None):
        logits, cache = decode_step(params, cfg, cache, tokens=tokens,
                                    embeds=embeds)
        return logits[:, -1, :], cache

    return serve_step


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None,
            max_seq: int | None = None):
    """Run the full-sequence forward and build a cache by replaying tokens
    through decode steps.  For production prefill the forward pass itself
    computes K/V; here we reuse the decode path for cache fidelity (tested
    against the forward pass in tests/test_decode.py)."""
    if tokens is not None:
        b, s = tokens.shape
    else:
        b, s, _ = embeds.shape
    cache = init_cache(cfg, b, max_seq or s)

    def body(cache, t):
        if tokens is not None:
            lg, cache = decode_step(params, cfg, cache, tokens=t[:, None])
        else:
            lg, cache = decode_step(params, cfg, cache, embeds=t[:, None])
        return cache, lg[:, 0]

    xs = tokens.T if tokens is not None else jnp.moveaxis(embeds, 1, 0)
    cache, logits = jax.lax.scan(body, cache, xs)
    return cache, jnp.moveaxis(logits, 0, 1)      # (B, S, V)


def generate(params, cfg: ModelConfig, prompt_tokens: jnp.ndarray,
             num_steps: int, max_seq: int | None = None,
             temperature: float = 0.0, rng: jax.Array | None = None):
    """Greedy/temperature generation loop (tokens mode)."""
    b, s = prompt_tokens.shape
    cap = max_seq or (s + num_steps)
    cache, logits = prefill(params, cfg, tokens=prompt_tokens, max_seq=cap)
    last = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

    def body(carry, _):
        cache, tok, rng = carry
        lg, cache = decode_step(params, cfg, cache, tokens=tok[:, None])
        lg = lg[:, -1, :]
        if temperature > 0:
            rng, sub = jax.random.split(rng)
            nxt = jax.random.categorical(sub, lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        nxt = nxt.astype(jnp.int32)
        return (cache, nxt, rng), nxt

    rng = rng if rng is not None else jax.random.key(0)
    (_, _, _), toks = jax.lax.scan(body, (cache, last, rng), None,
                                   length=num_steps)
    return jnp.moveaxis(toks, 0, 1)               # (B, num_steps)
