"""Deterministic fault injection for the serving tier (DESIGN.md §11).

Chaos testing is only useful if a failing run can be replayed: every
injection decision here is drawn from a seeded, *per-fault-type* RNG
stream, so

* the same ``FaultSpec(seed=s)`` driven through the same request sequence
  injects the same faults at the same decision sites, and
* enabling one fault type does not shift the draw sequence of another
  (independent streams keyed by ``(seed, fault-name)``).

Four injectable fault classes, mirroring what production serving actually
sees:

* **latency spikes** — an execute suddenly takes ``latency_spike_ms``
  longer (a slow kernel, a noisy neighbor).  The deadline machinery must
  shed what the spike expired, not hang behind it.
* **kernel exceptions** — the execute raises
  :class:`InjectedKernelError`.  The scheduler must fail that batch's
  requests with the error and keep serving (fault containment).
* **poisoned binds** — a request payload is corrupted to NaN on submit.
  Admission validation must reject it before it reaches a kernel.
* **mid-flight catalog bumps** — ``register_index`` fires between batches
  (a background re-build landing).  The catalog-version invalidation rule
  must re-bind the plan before the next execute (no stale results, no
  crash).

The injector wraps an execute callable (:meth:`FaultInjector.wrap`);
``counters`` record exactly what was injected so chaos tests can assert
counter-exact outcomes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np


class InjectedKernelError(RuntimeError):
    """The fault harness's stand-in for a kernel/runtime failure during a
    batch execution (the scheduler must contain it per batch)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """What to inject, with what probability — all draws seeded.

    Probabilities are per decision site: ``poison_bind_p`` per submitted
    request; the others per batch execution."""
    seed: int = 0
    latency_spike_p: float = 0.0
    latency_spike_ms: float = 20.0
    kernel_error_p: float = 0.0
    poison_bind_p: float = 0.0
    catalog_bump_p: float = 0.0

    def __post_init__(self):
        for f in ("latency_spike_p", "kernel_error_p", "poison_bind_p",
                  "catalog_bump_p"):
            p = getattr(self, f)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{f} must be a probability, got {p}")


class FaultInjector:
    """Seeded chaos: wraps the serving execute path and corrupts submits.

    ``bump_fn`` is the mid-flight catalog mutation to fire (typically a
    ``register_index`` re-registering a rebuilt index); ``sleep_fn`` lets
    virtual-clock harnesses account spike time without wall-clock sleeping.
    """

    _STREAMS = ("latency", "kernel", "poison", "bump")

    def __init__(self, spec: FaultSpec,
                 bump_fn: Callable[[], None] | None = None,
                 sleep_fn: Callable[[float], None] | None = None):
        self.spec = spec
        self.bump_fn = bump_fn
        self.sleep_fn = sleep_fn if sleep_fn is not None else time.sleep
        # independent streams: enabling/IGNORING one fault type never
        # shifts another type's draw sequence
        self._rng = {name: np.random.default_rng([spec.seed, i])
                     for i, name in enumerate(self._STREAMS)}
        self.counters = {"latency_spikes": 0, "kernel_errors": 0,
                         "poisoned_binds": 0, "catalog_bumps": 0}

    # -- submit-side --------------------------------------------------------

    def maybe_poison(self, binds: dict) -> tuple[dict, bool]:
        """With ``poison_bind_p``, corrupt the request's first float-array
        bind to NaN (returns (binds, poisoned)); draws exactly once per
        call, so the decision sequence is submit-order deterministic."""
        if self._rng["poison"].random() >= self.spec.poison_bind_p:
            return binds, False
        out = dict(binds)
        for name in sorted(out):
            arr = np.asarray(out[name])
            if np.issubdtype(arr.dtype, np.floating) and arr.ndim >= 1:
                bad = np.array(arr, dtype=arr.dtype)
                bad[...] = np.nan
                out[name] = bad
                self.counters["poisoned_binds"] += 1
                return out, True
        return binds, False

    # -- execute-side -------------------------------------------------------

    def before_execute(self) -> None:
        """Pre-batch decision site: maybe fire the mid-flight catalog bump
        (draws once per batch whether or not a ``bump_fn`` is wired)."""
        fire = self._rng["bump"].random() < self.spec.catalog_bump_p
        if fire and self.bump_fn is not None:
            self.counters["catalog_bumps"] += 1
            self.bump_fn()

    def around_execute(self, fn: Callable[[], Any]) -> Any:
        """Run one batch execution under the latency/kernel fault draws."""
        if self._rng["latency"].random() < self.spec.latency_spike_p:
            self.counters["latency_spikes"] += 1
            self.sleep_fn(self.spec.latency_spike_ms * 1e-3)
        if self._rng["kernel"].random() < self.spec.kernel_error_p:
            self.counters["kernel_errors"] += 1
            raise InjectedKernelError(
                f"injected kernel fault (seed={self.spec.seed}, "
                f"fault #{self.counters['kernel_errors']})")
        return fn()

    def wrap(self, execute: Callable) -> Callable:
        """Wrap a ``execute(binds_list) -> out`` callable with the full
        per-batch fault sequence (catalog bump, spike, kernel error)."""

        def wrapped(binds_list):
            self.before_execute()
            return self.around_execute(lambda: execute(binds_list))

        return wrapped

    def snapshot(self) -> dict:
        """Injection counters (copies — safe to diff across phases)."""
        return dict(self.counters)
