"""Deterministic fault injection for the serving tier (DESIGN.md §11).

Chaos testing is only useful if a failing run can be replayed: every
injection decision here is drawn from a seeded, *per-fault-type* RNG
stream, so

* the same ``FaultSpec(seed=s)`` driven through the same request sequence
  injects the same faults at the same decision sites, and
* enabling one fault type does not shift the draw sequence of another
  (independent streams keyed by ``(seed, fault-name)``).

Four injectable fault classes, mirroring what production serving actually
sees:

* **latency spikes** — an execute suddenly takes ``latency_spike_ms``
  longer (a slow kernel, a noisy neighbor).  The deadline machinery must
  shed what the spike expired, not hang behind it.
* **kernel exceptions** — the execute raises
  :class:`InjectedKernelError`.  The scheduler must fail that batch's
  requests with the error and keep serving (fault containment).
* **poisoned binds** — a request payload is corrupted to NaN on submit.
  Admission validation must reject it before it reaches a kernel.
* **mid-flight catalog bumps** — ``register_index`` fires between batches
  (a background re-build landing).  The catalog-version invalidation rule
  must re-bind the plan before the next execute (no stale results, no
  crash).

A fifth class — **process crashes** at :data:`CRASH_SITES` durability
boundaries in the live-corpus mutation path (DESIGN.md §12) — is injected
deterministically by (site, Nth-hit) rather than probability: crash tests
need the failure at one exact WAL/snapshot/compaction boundary, and
keeping crashes out of the RNG streams preserves the per-type stream
independence above.

The injector wraps an execute callable (:meth:`FaultInjector.wrap`);
``counters`` record exactly what was injected so chaos tests can assert
counter-exact outcomes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np


class InjectedKernelError(RuntimeError):
    """The fault harness's stand-in for a kernel/runtime failure during a
    batch execution (the scheduler must contain it per batch)."""


class InjectedCrashError(RuntimeError):
    """A process "crash" fired at a :data:`CRASH_SITES` point in the
    mutation path (DESIGN.md §12).  The chaos harness catches it, discards
    all in-memory state, and must recover from disk alone."""


#: Deterministic crash points in the live-corpus mutation path, in
#: durability order.  Each site marks the instant *before* or *after* a
#: durability step, so a crash there is the worst torn state that step can
#: leave on disk: a WAL record lost entirely, a half-written tail line,
#: a snapshot requested but never written, a compaction logged but never
#: swapped (see data/mutations.py for which site guards which step).
CRASH_SITES = (
    "wal.pre_append",        # mutation validated, nothing durable yet
    "wal.torn_append",       # partial WAL line flushed, then crash
    "wal.group_commit",      # group commit torn: full prefix + half tail
    "wal.post_append",       # record durable, in-memory apply lost
    "snapshot.pre_commit",   # snapshot requested, nothing written yet
    "snapshot.post_commit",  # snapshot committed (rename landed), caller died
    "compact.pre_log",       # compaction computed, nothing durable
    "compact.post_log",      # compact WAL record durable, swap lost
    "compact.pre_swap",      # post-compaction snapshot durable, swap lost
)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """What to inject, with what probability — all draws seeded.

    Probabilities are per decision site: ``poison_bind_p`` per submitted
    request; the others per batch execution."""
    seed: int = 0
    latency_spike_p: float = 0.0
    latency_spike_ms: float = 20.0
    kernel_error_p: float = 0.0
    poison_bind_p: float = 0.0
    catalog_bump_p: float = 0.0
    # crash injection is deterministic (site + Nth hit), NOT probabilistic:
    # a crash must land at one exact durability boundary to test it, and
    # keeping it out of the RNG streams preserves stream independence
    crash_site: str | None = None
    crash_at: int = 1

    def __post_init__(self):
        for f in ("latency_spike_p", "kernel_error_p", "poison_bind_p",
                  "catalog_bump_p"):
            p = getattr(self, f)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{f} must be a probability, got {p}")
        if self.crash_site is not None and self.crash_site not in CRASH_SITES:
            raise ValueError(f"unknown crash_site {self.crash_site!r}; "
                             f"expected one of {CRASH_SITES}")
        if self.crash_at < 1:
            raise ValueError(f"crash_at must be >= 1 (1 = first hit), "
                             f"got {self.crash_at}")


class FaultInjector:
    """Seeded chaos: wraps the serving execute path and corrupts submits.

    ``bump_fn`` is the mid-flight catalog mutation to fire (typically a
    ``register_index`` re-registering a rebuilt index); ``sleep_fn`` lets
    virtual-clock harnesses account spike time without wall-clock sleeping.
    """

    _STREAMS = ("latency", "kernel", "poison", "bump")

    def __init__(self, spec: FaultSpec,
                 bump_fn: Callable[[], None] | None = None,
                 sleep_fn: Callable[[float], None] | None = None):
        self.spec = spec
        self.bump_fn = bump_fn
        self.sleep_fn = sleep_fn if sleep_fn is not None else time.sleep
        # independent streams: enabling/IGNORING one fault type never
        # shifts another type's draw sequence
        self._rng = {name: np.random.default_rng([spec.seed, i])
                     for i, name in enumerate(self._STREAMS)}
        self.counters = {"latency_spikes": 0, "kernel_errors": 0,
                         "poisoned_binds": 0, "catalog_bumps": 0,
                         "crashes": 0}
        self._site_hits = {site: 0 for site in CRASH_SITES}

    # -- submit-side --------------------------------------------------------

    def maybe_poison(self, binds: dict) -> tuple[dict, bool]:
        """With ``poison_bind_p``, corrupt the request's first float-array
        bind to NaN (returns (binds, poisoned)); draws exactly once per
        call, so the decision sequence is submit-order deterministic."""
        if self._rng["poison"].random() >= self.spec.poison_bind_p:
            return binds, False
        out = dict(binds)
        for name in sorted(out):
            arr = np.asarray(out[name])
            if np.issubdtype(arr.dtype, np.floating) and arr.ndim >= 1:
                bad = np.array(arr, dtype=arr.dtype)
                bad[...] = np.nan
                out[name] = bad
                self.counters["poisoned_binds"] += 1
                return out, True
        return binds, False

    # -- crash-side ---------------------------------------------------------

    def armed(self, site: str) -> bool:
        """Record a hit on ``site`` and report whether the configured crash
        fires here (site matches and this is the ``crash_at``-th hit).
        Hit counting is unconditional so the same mutation sequence visits
        sites identically whether or not a crash is configured."""
        if site not in self._site_hits:
            raise ValueError(f"unknown crash site {site!r}")
        self._site_hits[site] += 1
        return (self.spec.crash_site == site
                and self._site_hits[site] == self.spec.crash_at)

    def crash_point(self, site: str) -> None:
        """Raise :class:`InjectedCrashError` if the configured crash is
        armed at ``site``; otherwise a no-op (plus hit accounting)."""
        if self.armed(site):
            self.counters["crashes"] += 1
            raise InjectedCrashError(
                f"injected crash at {site!r} "
                f"(hit #{self._site_hits[site]}, seed={self.spec.seed})")

    # -- execute-side -------------------------------------------------------

    def before_execute(self) -> None:
        """Pre-batch decision site: maybe fire the mid-flight catalog bump
        (draws once per batch whether or not a ``bump_fn`` is wired)."""
        fire = self._rng["bump"].random() < self.spec.catalog_bump_p
        if fire and self.bump_fn is not None:
            self.counters["catalog_bumps"] += 1
            self.bump_fn()

    def around_execute(self, fn: Callable[[], Any]) -> Any:
        """Run one batch execution under the latency/kernel fault draws."""
        if self._rng["latency"].random() < self.spec.latency_spike_p:
            self.counters["latency_spikes"] += 1
            self.sleep_fn(self.spec.latency_spike_ms * 1e-3)
        if self._rng["kernel"].random() < self.spec.kernel_error_p:
            self.counters["kernel_errors"] += 1
            raise InjectedKernelError(
                f"injected kernel fault (seed={self.spec.seed}, "
                f"fault #{self.counters['kernel_errors']})")
        return fn()

    def wrap(self, execute: Callable) -> Callable:
        """Wrap a ``execute(binds_list) -> out`` callable with the full
        per-batch fault sequence (catalog bump, spike, kernel error)."""

        def wrapped(binds_list):
            self.before_execute()
            return self.around_execute(lambda: execute(binds_list))

        return wrapped

    def snapshot(self) -> dict:
        """Injection counters (copies — safe to diff across phases)."""
        return dict(self.counters)
