"""CHASE-backed retrieval tier for serving — the paper's technique as a
first-class feature of the LM framework.

The paper motivates VKNN-SF with RAG (§2.2 [20]): retrieve top-k documents by
embedding similarity *subject to structured filters* (freshness, safety,
tenant).  :class:`HybridRetriever` wraps a compiled CHASE query over a
document corpus; ``retrieve_for_decode`` plugs into the serving loop —
retrieve once at prefill, prepend retrieved doc tokens to the prompt."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import Catalog, EngineOptions, Metric, compile_query
from ..core.schema import (Schema, Table, category_col, float_col, int_col,
                           vector_col)
from ..index import build_ivf
from ..index.ivf import ProbeConfig

RAG_SQL = """
SELECT doc_id FROM docs
WHERE freshness >= ${min_freshness} AND safety = ${safety_class}
ORDER BY DISTANCE(embedding, ${query_embedding})
LIMIT ${K}
"""


@dataclasses.dataclass
class HybridRetriever:
    catalog: Catalog
    compiled: Any
    k: int

    @classmethod
    def build(cls, doc_embeddings: jnp.ndarray, freshness: jnp.ndarray,
              safety: jnp.ndarray, k: int = 4, nlist: int = 64,
              metric: Metric = Metric.INNER_PRODUCT,
              probe: ProbeConfig = ProbeConfig(), seed: int = 0):
        n, dim = doc_embeddings.shape
        schema = Schema({
            "doc_id": int_col(),
            "freshness": float_col(),
            "safety": category_col(4),
            "embedding": vector_col(dim, metric),
        }, primary_key="doc_id")
        table = Table(schema, {
            "doc_id": jnp.arange(n, dtype=jnp.int32),
            "freshness": freshness,
            "safety": safety,
            "embedding": doc_embeddings,
        })
        cat = Catalog()
        cat.register("docs", table)
        idx = build_ivf(jax.random.key(seed), doc_embeddings, nlist=nlist,
                        metric=metric)
        cat.register_index("docs", "embedding", idx)
        compiled = compile_query(RAG_SQL, cat,
                                 EngineOptions(engine="chase", probe=probe),
                                 K=k)
        return cls(cat, compiled, k)

    def retrieve(self, query_embedding, min_freshness=0.0, safety_class=0):
        out = self.compiled(query_embedding=query_embedding,
                            min_freshness=min_freshness,
                            safety_class=safety_class)
        return out["ids"], out["sim"], out["valid"]

    def retrieve_batch(self, query_embeddings, min_freshness=0.0,
                       safety_class=0):
        """Native batched retrieval for a serving batch: one compiled
        pipeline runs the query-tiled scan / multi-cluster IVF probes for the
        whole batch (per-query filters supported via broadcast binds)."""
        out = self.compiled.execute_batch(
            query_embedding=jnp.asarray(query_embeddings),
            min_freshness=min_freshness, safety_class=safety_class)
        return out["ids"], out["sim"], out["valid"]
