"""CHASE-backed retrieval tier for serving — the paper's technique as a
first-class feature of the LM framework.

The paper motivates VKNN-SF with RAG (§2.2 [20]): retrieve top-k documents by
embedding similarity *subject to structured filters* (freshness, safety,
tenant).  :class:`HybridRetriever` wraps a compiled CHASE query over a
document corpus; ``retrieve_for_decode`` plugs into the serving loop —
retrieve once at prefill, prepend retrieved doc tokens to the prompt."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..api import Database, Statement, connect
from ..core import Catalog, EngineOptions, Metric
from ..core.schema import (Schema, Table, category_col, float_col, int_col,
                           vector_col)
from ..index import build_ivf
from ..index.ivf import ProbeConfig

RAG_SQL = """
SELECT doc_id FROM docs
WHERE freshness >= ${min_freshness} AND safety = ${safety_class}
ORDER BY DISTANCE(embedding, ${query_embedding})
LIMIT ${K}
"""


@dataclasses.dataclass
class HybridRetriever:
    """Rides the session API: one :class:`~repro.api.Database` session over
    the doc catalog, one prepared :class:`~repro.api.Statement` — so every
    retrieval surface (single, batched, scheduled) shares the statement's
    plan-cache entry and bucket executor cache."""
    db: Database
    statement: Statement
    k: int

    @property
    def catalog(self) -> Catalog:
        """The session's catalog (docs table + IVF index)."""
        return self.db.catalog

    @property
    def compiled(self):
        """Legacy handle (the statement's cached CompiledQuery)."""
        return self.statement.compiled

    @classmethod
    def build(cls, doc_embeddings: jnp.ndarray, freshness: jnp.ndarray,
              safety: jnp.ndarray, k: int = 4, nlist: int = 64,
              metric: Metric = Metric.INNER_PRODUCT,
              probe: ProbeConfig = ProbeConfig(), seed: int = 0):
        """Build a retriever over raw doc embeddings: catalog + IVF index +
        prepared hybrid statement, in one call."""
        n, dim = doc_embeddings.shape
        schema = Schema({
            "doc_id": int_col(),
            "freshness": float_col(),
            "safety": category_col(4),
            "embedding": vector_col(dim, metric),
        }, primary_key="doc_id")
        table = Table(schema, {
            "doc_id": jnp.arange(n, dtype=jnp.int32),
            "freshness": freshness,
            "safety": safety,
            "embedding": doc_embeddings,
        })
        cat = Catalog()
        cat.register("docs", table)
        idx = build_ivf(jax.random.key(seed), doc_embeddings, nlist=nlist,
                        metric=metric)
        cat.register_index("docs", "embedding", idx)
        db = connect(cat, EngineOptions(engine="chase", probe=probe))
        statement = db.prepare(RAG_SQL, K=k)
        return cls(db, statement, k)

    def retrieve(self, query_embedding, min_freshness=0.0, safety_class=0):
        """Single-query hybrid retrieval: (ids, sims, valid) top-k under the
        freshness / safety filters."""
        out = self.statement.execute({
            "query_embedding": query_embedding,
            "min_freshness": min_freshness,
            "safety_class": safety_class})
        return out["ids"], out["sim"], out["valid"]

    def retrieve_batch(self, query_embeddings, min_freshness=0.0,
                       safety_class=0):
        """Native batched retrieval for a serving batch: one compiled
        pipeline runs the query-tiled scan / multi-cluster IVF probes for the
        whole batch (per-query filters supported via broadcast binds).

        Rides the size-bucketed executor (DESIGN.md §8): any batch size
        reuses one compiled executable per power-of-two bucket, so serving
        traffic with varying batch sizes never recompiles per shape."""
        out = self.statement.execute({
            "query_embedding": jnp.asarray(query_embeddings),
            "min_freshness": min_freshness, "safety_class": safety_class})
        return out["ids"], out["sim"], out["valid"]

    def make_scheduler(self, max_batch: int = 32, max_wait_ms: float = 2.0,
                       pilot_budget: int = 0):
        """A :class:`~repro.serving.scheduler.BatchScheduler` over this
        retriever's prepared statement (``Database.serve``) — the serving
        front-end that coalesces arriving retrieval requests into bucketed
        batch executions (``pilot_budget`` > 0 adds effort-bucketed IVF
        probing)."""
        return self.db.serve(self.statement, max_batch=max_batch,
                             max_wait_ms=max_wait_ms,
                             pilot_budget=pilot_budget)

    def retrieve_for_decode(self, query_embeddings, doc_token_embeds,
                            min_freshness=0.0, safety_class=0,
                            scheduler=None):
        """Prefill hookup: retrieve each sequence's docs and build the
        (B, K, d_model) embedding prefix to prepend to the prompt embeds
        (``serving.decode.prefill(embeds=concat([prefix, prompt], axis=1))``).

        ``doc_token_embeds`` maps doc id -> model-space embedding
        (n_docs, d_model); invalid retrieval slots contribute zeros.  When a
        ``scheduler`` (see :meth:`make_scheduler`) is given, the requests
        join its coalescing queue — the decode batch rides the same bucketed
        executables as every other retrieval client."""
        qs = jnp.asarray(query_embeddings)
        if scheduler is not None:
            rids = [scheduler.submit(query_embedding=q,
                                     min_freshness=min_freshness,
                                     safety_class=safety_class) for q in qs]
            scheduler.flush()
            outs = [scheduler.result(rid) for rid in rids]
            ids = jnp.stack([o["ids"] for o in outs])
            valid = jnp.stack([o["valid"] for o in outs])
        else:
            ids, _sims, valid = self.retrieve_batch(
                qs, min_freshness=min_freshness, safety_class=safety_class)
        safe = jnp.maximum(ids, 0)
        prefix = jnp.asarray(doc_token_embeds)[safe]          # (B, K, d_model)
        prefix = jnp.where(valid[..., None], prefix, 0.0)
        return prefix, ids, valid
