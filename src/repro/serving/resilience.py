"""Resilience primitives for the serving tier (DESIGN.md §11).

The serving path survives production traffic by making every failure mode
an *explicit, typed stage* between "request arrives" and "kernel runs":

* **Admission control** (:class:`AdmissionController`): a bounded queue
  with a hard depth watermark.  A saturated server rejects at the door with
  :class:`BackpressureError` carrying a ``retry_after_ms`` hint — clients
  get an immediate, actionable signal instead of a timeout.
* **Bind validation** (:func:`validate_binds`): poisoned payloads
  (non-finite query vectors) are rejected with :class:`PoisonedBindError`
  *before* they reach a compiled kernel, where NaNs would silently corrupt
  a whole coalesced batch's top-k ordering.
* **Deadlines** (:class:`DeadlineExceededError`): requests carry absolute
  deadlines; the scheduler sheds expired requests before
  compilation/execution and never holds a batch past its tightest member's
  deadline (see :mod:`repro.serving.scheduler`).
* **Graceful degradation** (:class:`LoadController`): under overload the
  controller steps the per-query IVF ``probe_budget`` down through
  configured (queue-depth, budget) steps — riding the effort-bucketed
  machinery of DESIGN.md §8 — trading recall for goodput instead of letting
  the queue blow through every deadline.  Hysteresis keeps the level from
  flapping at a watermark.  Executions run at a degraded level report it in
  ``Result.explain()``.

Everything here is deterministic given the observed queue depths — chaos
tests (:mod:`repro.serving.faults`) replay exact scenarios from seeds.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class ServingError(RuntimeError):
    """Base class for explicit serving-tier failures (every subclass is a
    *terminal, typed* request outcome — never a hang, never a bare
    timeout)."""


class BackpressureError(ServingError):
    """Admission rejected: the queue is at its watermark.

    Carries ``retry_after_ms`` — the client-facing shed signal ("come back
    later"), the opposite of an opaque timeout."""

    def __init__(self, depth: int, watermark: int, retry_after_ms: float):
        super().__init__(
            f"queue depth {depth} at/over admission watermark {watermark}; "
            f"retry after {retry_after_ms:.1f}ms")
        self.depth = depth
        self.watermark = watermark
        self.retry_after_ms = retry_after_ms


class DeadlineExceededError(ServingError):
    """The request's deadline passed while it was still queued; it was shed
    *before* compilation/execution (no kernel time was wasted on it)."""

    def __init__(self, rid: int, late_ms: float):
        super().__init__(f"request {rid} shed: deadline exceeded by "
                         f"{late_ms:.2f}ms while queued")
        self.rid = rid
        self.late_ms = late_ms


class PoisonedBindError(ServingError):
    """A bind payload failed validation (non-finite values) and was rejected
    at admission, before it could reach — and corrupt — a coalesced kernel
    batch."""

    def __init__(self, name: str):
        super().__init__(f"bind parameter {name!r} carries non-finite "
                         f"values; rejected at admission")
        self.name = name


class MutationError(ServingError):
    """Base class for typed mutation rejections (DESIGN.md §12).

    Every subclass is raised *at the door* — by
    :func:`validate_insert` / :func:`validate_delete` before a mutation
    touches the WAL or any device array — so a bad write can never surface
    as a mid-kernel failure or a half-applied log record."""


class UnknownIdError(MutationError):
    """A delete named an id that is not live (never inserted, already
    deleted, or compacted away after deletion)."""

    def __init__(self, ids):
        ids = list(ids)
        super().__init__(f"delete of nonexistent id(s) {ids[:8]}"
                         f"{'...' if len(ids) > 8 else ''}; "
                         f"rejected at admission")
        self.ids = ids


class DuplicateIdError(MutationError):
    """An insert named an id that is already live (in the main segment or
    the delta segment), or repeated an id within one insert batch."""

    def __init__(self, ids):
        ids = list(ids)
        super().__init__(f"insert of duplicate id(s) {ids[:8]}"
                         f"{'...' if len(ids) > 8 else ''}; "
                         f"rejected at admission")
        self.ids = ids


class InvalidVectorError(MutationError):
    """An insert payload failed vector validation (non-finite values or a
    dimension mismatch) — the mutation twin of :class:`PoisonedBindError`:
    a NaN row admitted into the delta segment would poison every scan that
    touches its lane."""

    def __init__(self, reason: str):
        super().__init__(f"insert vector rejected at admission: {reason}")
        self.reason = reason


class DeltaFullError(MutationError):
    """The delta segment has no free slots — mutation backpressure.

    The write-side analogue of :class:`BackpressureError`: carries the
    segment ``capacity``, the remaining ``free_slots``, and a
    ``compact_hint`` telling the client the segment drains via
    ``compact()`` (a retry without compaction will fail again)."""

    def __init__(self, capacity: int, requested: int, free_slots: int):
        super().__init__(
            f"delta segment full ({free_slots} of {capacity} slots free, "
            f"{requested} more requested); run compact() to fold deltas "
            f"into the main index")
        self.capacity = capacity
        self.free_slots = free_slots
        self.requested = requested
        self.compact_hint = True


def validate_insert(ids, vectors, dim: int, live_ids, free_slots: int,
                    delta_cap: int):
    """Admission checks for an insert batch; returns (ids, vectors) as numpy.

    Raises :class:`DuplicateIdError` (id already live, or repeated within
    the batch), :class:`InvalidVectorError` (shape/dim mismatch or
    non-finite values), or :class:`DeltaFullError` (no headroom) — always
    BEFORE anything is logged or applied, so a rejected insert has no
    side effects at any layer."""
    ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
    vectors = np.atleast_2d(np.asarray(vectors, dtype=np.float32))
    if vectors.ndim != 2 or vectors.shape[1] != dim:
        raise InvalidVectorError(
            f"expected shape (n, {dim}), got {tuple(vectors.shape)}")
    if vectors.shape[0] != ids.shape[0]:
        raise InvalidVectorError(
            f"{ids.shape[0]} id(s) but {vectors.shape[0]} vector row(s)")
    if not np.all(np.isfinite(vectors)):
        raise InvalidVectorError("non-finite values")
    uniq, counts = np.unique(ids, return_counts=True)
    batch_dups = uniq[counts > 1]
    existing = [int(i) for i in ids if int(i) in live_ids]
    if len(batch_dups) or existing:
        raise DuplicateIdError(sorted(set(existing) |
                                      {int(i) for i in batch_dups}))
    if ids.shape[0] > free_slots:
        raise DeltaFullError(capacity=delta_cap,
                             requested=int(ids.shape[0]),
                             free_slots=free_slots)
    return ids, vectors


def validate_delete(ids, live_ids):
    """Admission checks for a delete batch; returns the ids as numpy int64.

    Raises :class:`UnknownIdError` for any id that is not currently live
    (and for ids repeated within the batch — the second delete would also
    target a non-live id)."""
    ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
    uniq, counts = np.unique(ids, return_counts=True)
    missing = sorted({int(i) for i in ids if int(i) not in live_ids} |
                     {int(i) for i in uniq[counts > 1]})
    if missing:
        raise UnknownIdError(missing)
    return ids


def validate_binds(binds: dict) -> None:
    """Reject non-finite float bind values (raises PoisonedBindError).

    A NaN query vector inside a coalesced batch poisons every distance the
    kernel tile computes for that lane and can destabilize the shared
    top-k extract-min; the serving tier fails the one bad request at the
    door instead."""
    for name, v in binds.items():
        arr = np.asarray(v)
        if np.issubdtype(arr.dtype, np.floating) and not np.all(
                np.isfinite(arr)):
            raise PoisonedBindError(name)


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission-control knobs.

    ``max_queue_depth`` is the hard watermark: a submit that would make the
    number of in-flight requests exceed it is rejected.  ``retry_after_ms``
    scales linearly with how far over the watermark demand is pushing."""
    max_queue_depth: int = 256
    retry_after_ms: float = 10.0

    def __post_init__(self):
        if self.max_queue_depth < 1:
            raise ValueError(f"max_queue_depth must be >= 1, "
                             f"got {self.max_queue_depth}")


class AdmissionController:
    """Bounded-queue admission: admit or reject-with-retry-after.

    Stateless beyond counters — the decision is a pure function of the
    observed depth, so replays are deterministic."""

    def __init__(self, config: AdmissionConfig | None = None):
        self.config = config if config is not None else AdmissionConfig()
        self.admitted = 0
        self.rejected = 0

    def admit(self, depth: int) -> None:
        """Admit a request arriving at queue depth ``depth`` (the in-flight
        count *before* this request), or raise :class:`BackpressureError`."""
        cfg = self.config
        if depth >= cfg.max_queue_depth:
            self.rejected += 1
            over = (depth - cfg.max_queue_depth) / cfg.max_queue_depth
            raise BackpressureError(
                depth, cfg.max_queue_depth,
                cfg.retry_after_ms * (1.0 + over))
        self.admitted += 1

    def snapshot(self) -> dict:
        """Counters: requests admitted / rejected so far."""
        return {"admitted": self.admitted, "rejected": self.rejected}


@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """Load-controller policy: queue-depth watermarks -> probe budgets.

    ``steps`` is an ascending sequence of ``(queue_depth, probe_budget)``
    pairs: when the observed depth reaches ``steps[i][0]`` the controller
    moves to level ``i + 1`` and batched IVF executions are capped at
    ``steps[i][1]`` clusters per query (the DESIGN.md §8 straggler valve,
    repurposed as the overload valve).  Level 0 = full effort.
    ``hysteresis`` is how far below a step's watermark the depth must drop
    before stepping back up a level (no flapping at the boundary)."""
    steps: tuple = ((32, 16), (64, 4))
    hysteresis: int = 4

    def __post_init__(self):
        depths = [d for d, _ in self.steps]
        budgets = [b for _, b in self.steps]
        if depths != sorted(depths) or len(set(depths)) != len(depths):
            raise ValueError(f"step depths must be strictly ascending, "
                             f"got {depths}")
        if any(b < 1 for b in budgets):
            raise ValueError(f"probe budgets must be >= 1, got {budgets}")
        if budgets != sorted(budgets, reverse=True):
            raise ValueError(f"probe budgets must be non-increasing "
                             f"(deeper queue -> less effort), got {budgets}")
        if self.hysteresis < 0:
            raise ValueError(f"hysteresis must be >= 0, "
                             f"got {self.hysteresis}")


class LoadController:
    """Graceful-degradation state machine: queue depth -> effort level.

    ``observe(depth)`` is called once per drain with the current queue
    depth; it returns the level to run the next batch at.  Level L > 0 maps
    to ``policy.steps[L-1][1]`` as the per-query probe budget.  Transitions
    are deterministic: UP to the highest level whose watermark the depth
    reaches, DOWN one level at a time once depth falls ``hysteresis`` below
    the current level's watermark."""

    def __init__(self, policy: DegradePolicy | None = None):
        self.policy = policy if policy is not None else DegradePolicy()
        self.level = 0
        self.transitions = 0
        self.degraded_batches = 0

    def observe(self, depth: int) -> int:
        """Update and return the effort level for a drain at ``depth``."""
        steps = self.policy.steps
        up = 0
        for i, (watermark, _budget) in enumerate(steps):
            if depth >= watermark:
                up = i + 1
        if up > self.level:
            self.level = up
            self.transitions += 1
        elif self.level > 0:
            watermark = steps[self.level - 1][0]
            if depth <= max(0, watermark - self.policy.hysteresis):
                self.level -= 1
                self.transitions += 1
        if self.level > 0:
            self.degraded_batches += 1
        return self.level

    def probe_budget(self) -> int | None:
        """The current level's per-query probe budget (None = full effort)."""
        if self.level == 0:
            return None
        return self.policy.steps[self.level - 1][1]

    def snapshot(self) -> dict:
        """Live controller state: level, budget, transition/batch counters."""
        return {"level": self.level, "probe_budget": self.probe_budget(),
                "transitions": self.transitions,
                "degraded_batches": self.degraded_batches}
