"""Dynamic batch scheduler — the serving front-end of the size-bucketed
execution stack (DESIGN.md §8).

Serving traffic does not arrive in fixed-size batches: requests trickle in,
and every distinct batch size Q used to cost a fresh trace while lock-step
IVF rounds made every query in a batch pay for its slowest straggler.  This
module closes both gaps on top of :class:`~repro.core.compiler.BucketedExecutor`:

* **Coalescing** (:class:`BatchScheduler`): arriving requests queue until the
  batch fills (``max_batch``) or the OLDEST queued request has waited
  ``max_wait_ms`` — the deadline rule — then the whole batch drains into the
  bucketed executor (padded to the enclosing power-of-two bucket, outputs
  sliced per request).  Any traffic pattern touches at most
  log2(max_batch)+1 executables per plan.
* **Effort bucketing** (:func:`run_effort_bucketed`): a two-phase defense
  against lock-step straggler coupling.  Phase 1 runs the whole batch with a
  small per-query ``probe_budget`` (the pilot); queries that terminate
  *naturally* under the pilot are final (a budget can only freeze a query at
  or past its budget, so ``probes < pilot`` proves natural termination, and
  per-query probe state is independent — phase-1 results for light queries
  are bit-identical to a full run).  Phase 2 re-runs only the heavy
  remainder — a smaller batch, so its extra rounds no longer drag the light
  majority through ``Q x B x cap`` gathers.  The merged result is
  bit-identical to the lock-step run.  Join plans effort-bucket at bind-set
  granularity through this API; heterogeneous join LEFT rows effort-bucket
  in their query-batch form (the PR-2 flattening: left rows ARE the query
  batch — benchmarks/q8_sched_qps.py measures exactly that shape).

A virtual-clock queueing simulation (:meth:`BatchScheduler.simulate`) backs
benchmarks/q8_sched_qps.py: arrivals advance on a virtual clock, service
times are measured wall-clock of the real batch executions.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax

import numpy as np


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Coalescing + effort-bucketing knobs.

    ``max_wait_ms`` bounds the queueing latency the scheduler may add: a
    request never waits more than ``max_wait_ms`` for co-batched company
    before execution starts (it may still wait for the server to free up).
    ``pilot_budget`` > 0 enables two-phase effort-bucketed IVF execution
    (cluster units; a sensible pilot is ``ProbeConfig.min_probes`` plus a
    few rounds' worth of clusters)."""
    max_batch: int = 64
    max_wait_ms: float = 2.0
    pilot_budget: int = 0


@dataclasses.dataclass
class SimRecord:
    """One simulated request's timeline (seconds, virtual clock)."""
    rid: int
    arrival: float
    start: float
    finish: float
    batch_size: int

    @property
    def latency(self) -> float:
        return self.finish - self.arrival


def _leading_probes(stats: dict) -> np.ndarray:
    """Per-bind-set probe counters: joins report (Q, L) — reduce to the
    per-bind-set maximum (a bind set is heavy if ANY of its left rows is)."""
    probes = np.asarray(stats["probes"])
    if probes.ndim > 1:
        probes = probes.max(axis=tuple(range(1, probes.ndim)))
    return probes


def run_effort_bucketed(compiled, binds: dict, pilot_budget: int):
    """Two-phase effort-bucketed execution of a stacked bind batch.

    Returns ``(out, info)`` where ``out`` is bit-identical to
    ``compiled.execute_bucketed`` on the same binds (lock-step) and ``info``
    reports the phase split: ``n_light`` queries finished in the pilot,
    ``n_heavy`` re-ran in the (smaller) phase-2 batch."""
    if pilot_budget <= 0:
        raise ValueError("pilot_budget must be positive")
    executor = compiled.executor
    if not compiled.batch_native:
        # the vmap-of-scalar fallback has no probe_budget lane: a pilot run
        # would execute the FULL unbudgeted batch and classify every query
        # heavy — strictly more work than lock-step.  Run single-phase.
        out = executor(binds)
        qn = _leading_probes(out["stats"]).shape[0]
        return out, {"n_light": qn, "n_heavy": 0,
                     "pilot_budget": pilot_budget,
                     "skipped": "plan has no native batched lowering"}
    out1 = executor(binds, probe_budget=pilot_budget)
    probes = _leading_probes(out1["stats"])
    heavy = np.nonzero(probes >= pilot_budget)[0]
    qn = probes.shape[0]
    info = {"n_light": int(qn - heavy.size), "n_heavy": int(heavy.size),
            "pilot_budget": pilot_budget}
    if heavy.size == 0:
        return out1, info
    # host-side gather: a jnp fancy-index would compile per heavy-set shape
    sub = {k: np.asarray(v)[heavy] for k, v in binds.items()}
    out2 = executor(sub)
    out1 = jax.tree.map(np.asarray, out1)
    out2 = jax.tree.map(np.asarray, out2)

    def scatter(a, b):
        merged = np.array(a)
        merged[heavy] = b
        return merged

    return jax.tree.map(scatter, out1, out2), info


class BatchScheduler:
    """Coalesce arriving requests into size-bucketed batch executions.

    Online surface: ``submit(**binds)`` enqueues and returns a request id;
    ``poll()`` drains a batch when due (full, or the oldest request's
    ``max_wait_ms`` deadline expired); ``flush()`` drains everything;
    ``result(rid)`` returns that request's sliced outputs.  One scheduler
    serves one compiled plan (the serving deployment unit).

    ``compiled`` is anything exposing the execution contract —
    ``_stack_binds`` / ``executor`` / ``batch_native`` — i.e. a legacy
    :class:`~repro.core.compiler.CompiledQuery` or a session-API
    :class:`~repro.api.Statement` (``Database.serve`` constructs the latter;
    a Statement additionally translates renamed bind parameters onto the
    cached plan before stacking)."""

    def __init__(self, compiled, config: SchedulerConfig | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.compiled = compiled
        # None-sentinel, NOT a `config=SchedulerConfig()` default: a
        # class-level default dataclass would be one shared instance across
        # every scheduler ever constructed.
        self.config = config if config is not None else SchedulerConfig()
        self.clock = clock
        self._queue: collections.deque = collections.deque()
        self._results: dict[int, Any] = {}
        self._next_rid = 0

    # -- online API ---------------------------------------------------------

    def submit(self, **binds) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, binds, self.clock()))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    def due(self, now: float | None = None) -> bool:
        """Deadline rule: drain when full OR the oldest request has waited
        out its ``max_wait_ms`` coalescing window."""
        if not self._queue:
            return False
        if len(self._queue) >= self.config.max_batch:
            return True
        now = self.clock() if now is None else now
        oldest = self._queue[0][2]
        return (now - oldest) * 1e3 >= self.config.max_wait_ms

    def poll(self, now: float | None = None) -> list[int]:
        """Drain ONE batch if due; returns the completed request ids."""
        if not self.due(now):
            return []
        return self._drain()

    def flush(self) -> list[int]:
        """Drain everything queued, one max_batch execution at a time."""
        done: list[int] = []
        while self._queue:
            done.extend(self._drain())
        return done

    def result(self, rid: int):
        return self._results.pop(rid)

    # -- execution ----------------------------------------------------------

    def _drain(self) -> list[int]:
        take = min(len(self._queue), self.config.max_batch)
        entries = [self._queue.popleft() for _ in range(take)]
        rids = [rid for rid, _, _ in entries]
        out = self.execute([binds for _, binds, _ in entries])
        for i, rid in enumerate(rids):
            self._results[rid] = jax.tree.map(lambda v: v[i], out)
        return rids

    def execute(self, binds_list: list[dict]):
        """Execute one coalesced batch through the bucketed executor
        (effort-bucketed when ``pilot_budget`` > 0)."""
        binds = self.compiled._stack_binds(binds_list, {})
        if self.config.pilot_budget > 0:
            out, _info = run_effort_bucketed(self.compiled, binds,
                                             self.config.pilot_budget)
            return out
        return self.compiled.executor(binds)

    def warm(self, sample_binds: dict, batch_sizes: list[int]) -> None:
        """Pre-trace the bucket executables a traffic mix will touch (keeps
        compile time out of latency measurements and first requests).

        With ``pilot_budget`` > 0 both per-bucket variants are traced — the
        budgeted phase-1 executable AND the unbudgeted phase-2 one — since
        whether a drain reaches phase 2 depends on the data (all-identical
        warm batches may never produce a heavy remainder)."""
        for b in sorted({self.compiled.executor.bucket_for(s)
                         for s in batch_sizes}):
            stacked = self.compiled._stack_binds([sample_binds] * b, {})
            self.compiled.executor(stacked)
            if self.config.pilot_budget > 0 and self.compiled.batch_native:
                self.compiled.executor(stacked,
                                       probe_budget=self.config.pilot_budget)

    # -- virtual-clock simulation -------------------------------------------

    def simulate(self, arrivals: np.ndarray,
                 binds_list: list[dict]) -> list[SimRecord]:
        """Single-server queueing simulation of the coalescing policy.

        ``arrivals`` are request arrival times in seconds (sorted ascending,
        virtual clock); ``binds_list`` the matching per-request binds.  Batch
        formation follows the deadline rule; service time is the measured
        wall-clock of the REAL batch execution (warm the buckets first).
        Returns per-request :class:`SimRecord` timelines."""
        n = len(arrivals)
        assert len(binds_list) == n
        wait_s = self.config.max_wait_ms * 1e-3
        server_free = 0.0
        records: list[SimRecord] = []
        i = 0
        while i < n:
            deadline = arrivals[i] + wait_s
            close = max(deadline, server_free)
            j = i
            while (j < n and arrivals[j] <= close
                   and (j - i) < self.config.max_batch):
                j += 1
            if j - i >= self.config.max_batch:
                # the batch filled before the window closed
                start = max(server_free, float(arrivals[j - 1]))
            else:
                start = close
            t0 = time.perf_counter()
            out = self.execute(binds_list[i:j])
            jax.block_until_ready(jax.tree.leaves(out)[0])
            exec_s = time.perf_counter() - t0
            finish = start + exec_s
            for r in range(i, j):
                records.append(SimRecord(r, float(arrivals[r]), start,
                                         finish, j - i))
            server_free = finish
            i = j
        return records


def latency_stats(records: list[SimRecord]) -> dict:
    """p50/p95/mean latency (ms) + throughput (QPS) of a simulation run."""
    lats = np.asarray([r.latency for r in records]) * 1e3
    span = max(r.finish for r in records) - min(r.arrival for r in records)
    return {"p50_ms": round(float(np.percentile(lats, 50)), 3),
            "p95_ms": round(float(np.percentile(lats, 95)), 3),
            "mean_ms": round(float(lats.mean()), 3),
            "qps": round(len(records) / span, 1) if span > 0 else float("inf")}
