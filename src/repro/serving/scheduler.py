"""Dynamic batch scheduler — the serving front-end of the size-bucketed
execution stack (DESIGN.md §8).

Serving traffic does not arrive in fixed-size batches: requests trickle in,
and every distinct batch size Q used to cost a fresh trace while lock-step
IVF rounds made every query in a batch pay for its slowest straggler.  This
module closes both gaps on top of :class:`~repro.core.compiler.BucketedExecutor`:

* **Coalescing** (:class:`BatchScheduler`): arriving requests queue until the
  batch fills (``max_batch``) or the OLDEST queued request has waited
  ``max_wait_ms`` — the deadline rule — then the whole batch drains into the
  bucketed executor (padded to the enclosing power-of-two bucket, outputs
  sliced per request).  Any traffic pattern touches at most
  log2(max_batch)+1 executables per plan.
* **Effort bucketing** (:func:`run_effort_bucketed`): a two-phase defense
  against lock-step straggler coupling.  Phase 1 runs the whole batch with a
  small per-query ``probe_budget`` (the pilot); queries that terminate
  *naturally* under the pilot are final (a budget can only freeze a query at
  or past its budget, so ``probes < pilot`` proves natural termination, and
  per-query probe state is independent — phase-1 results for light queries
  are bit-identical to a full run).  Phase 2 re-runs only the heavy
  remainder — a smaller batch, so its extra rounds no longer drag the light
  majority through ``Q x B x cap`` gathers.  The merged result is
  bit-identical to the lock-step run.  Join plans effort-bucket at bind-set
  granularity through this API; heterogeneous join LEFT rows effort-bucket
  in their query-batch form (the PR-2 flattening: left rows ARE the query
  batch — benchmarks/q8_sched_qps.py measures exactly that shape).

Resilience (DESIGN.md §11): requests may carry **deadlines** and
**priorities**.  Expired requests are shed *before* compilation/execution
(:class:`~repro.serving.resilience.DeadlineExceededError` — no kernel time
is spent on a result nobody can use), a forming batch never waits past its
tightest member's deadline, and an execution that raises is contained to
its own batch — every member fails with the error, the queue keeps
draining.  :class:`ResilientScheduler` adds graceful degradation (a
:class:`~repro.serving.resilience.LoadController` stepping probe budgets
down under queue pressure) and fault-injection hooks on top.

A virtual-clock queueing simulation (:meth:`BatchScheduler.simulate`) backs
benchmarks/q8_sched_qps.py: arrivals advance on a virtual clock, service
times are measured wall-clock of the real batch executions.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax

import numpy as np

from .resilience import DeadlineExceededError, LoadController


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Coalescing + effort-bucketing + deadline knobs.

    ``max_wait_ms`` bounds the queueing latency the scheduler may add: a
    request never waits more than ``max_wait_ms`` for co-batched company
    before execution starts (it may still wait for the server to free up).
    ``pilot_budget`` > 0 enables two-phase effort-bucketed IVF execution
    (cluster units; a sensible pilot is ``ProbeConfig.min_probes`` plus a
    few rounds' worth of clusters).  ``default_deadline_ms`` stamps every
    request submitted without an explicit deadline (None = no deadline);
    ``deadline_margin_ms`` drains a forming batch that much *before* its
    tightest member deadline (headroom for service time)."""
    max_batch: int = 64
    max_wait_ms: float = 2.0
    pilot_budget: int = 0
    default_deadline_ms: float | None = None
    deadline_margin_ms: float = 0.0


@dataclasses.dataclass
class _Request:
    """One queued request: binds + arrival/deadline/priority metadata."""
    rid: int
    binds: dict
    arrival: float
    deadline: float | None = None     # absolute, clock units (seconds)
    priority: int = 0                 # higher drains first


@dataclasses.dataclass
class SimRecord:
    """One simulated request's timeline (seconds, virtual clock)."""
    rid: int
    arrival: float
    start: float
    finish: float
    batch_size: int

    @property
    def latency(self) -> float:
        """Request latency (finish - arrival) in virtual-clock seconds."""
        return self.finish - self.arrival


def _leading_probes(stats: dict) -> np.ndarray:
    """Per-bind-set probe counters: joins report (Q, L) — reduce to the
    per-bind-set maximum (a bind set is heavy if ANY of its left rows is)."""
    probes = np.asarray(stats["probes"])
    if probes.ndim > 1:
        probes = probes.max(axis=tuple(range(1, probes.ndim)))
    return probes


def _pilot_info(pilot) -> "int | dict":
    """JSON-able form of a pilot budget (scalar int or array summary)."""
    if np.ndim(pilot) == 0:
        return int(pilot)
    arr = np.asarray(pilot)
    return {"min": int(arr.min()), "max": int(arr.max()),
            "shape": list(arr.shape)}


def run_effort_bucketed(compiled, binds: dict, pilot_budget=0, *,
                        advisor=None):
    """Two-phase effort-bucketed execution of a stacked bind batch.

    Returns ``(out, info)`` where ``out`` is bit-identical to
    ``compiled.execute_bucketed`` on the same binds (lock-step) and ``info``
    reports the phase split: ``n_light`` queries finished in the pilot,
    ``n_heavy`` re-ran in the (smaller) phase-2 batch.

    ``pilot_budget`` may be a scalar (the classic static pilot), a (Q,)
    per-bind-set array, or — for join plans — a (Q, L) per-left array (the
    runtime ``probe_budget`` lane of the compiled bucket executables, so no
    shape retraces beyond the first).  A bind set is heavy if ANY of its
    queries/left rows hit its own budget; phase 2 re-runs those sets
    unbudgeted, preserving bit-exactness unconditionally.

    With ``advisor`` (a :class:`~repro.opt.advisor.LoweringAdvisor`), the
    pilot comes from the stats-driven predictor instead (DESIGN.md §14): a
    cold or probe-less plan runs single-phase lock-step, a warmed plan gets
    a predicted scalar pilot or per-left budgets, and the merged counters
    are folded back into the advisor's stats store either way.  ``compiled``
    may be a core ``CompiledQuery`` or a session-API ``Statement``."""
    inner = getattr(compiled, "compiled", compiled)
    executor = compiled.executor
    decision = None
    if advisor is not None and getattr(advisor, "enabled", True):
        decision = advisor.advise_batch(inner, binds)
        pilot_budget = (decision.pilot if decision.pilot is not None else 0)
    scalar_pilot = np.ndim(pilot_budget) == 0
    if scalar_pilot and pilot_budget <= 0 and advisor is None:
        raise ValueError("pilot_budget must be positive")
    t0 = time.perf_counter()
    if not compiled.batch_native:
        # the vmap-of-scalar fallback has no probe_budget lane: a pilot run
        # would execute the FULL unbudgeted batch and classify every query
        # heavy — strictly more work than lock-step.  Run single-phase.
        out = executor(binds)
        qn = _leading_probes(out["stats"]).shape[0]
        info = {"n_light": qn, "n_heavy": 0,
                "pilot_budget": _pilot_info(pilot_budget),
                "skipped": "plan has no native batched lowering"}
        return _observed(advisor, inner, decision, out, t0, info)
    if scalar_pilot and pilot_budget <= 0:
        # advisor-driven lock-step (cold plan, or no probe lane): one
        # phase, but the counters still feed the stats store
        out = executor(binds)
        qn = _leading_probes(out["stats"]).shape[0]
        info = {"n_light": qn, "n_heavy": 0, "pilot_budget": 0}
        return _observed(advisor, inner, decision, out, t0, info)
    if scalar_pilot:
        budget = int(pilot_budget)
    else:
        budget = np.asarray(pilot_budget, np.int32)
    out1 = executor(binds, probe_budget=budget)
    probes = np.asarray(out1["stats"]["probes"])
    limit = budget
    if not scalar_pilot and probes.ndim == 2 and np.ndim(budget) == 1:
        limit = np.asarray(budget)[:, None]   # per-bind-set vs (Q, L) stats
    hit = probes >= limit
    if hit.ndim > 1:
        hit = hit.any(axis=tuple(range(1, hit.ndim)))
    heavy = np.nonzero(hit)[0]
    qn = probes.shape[0]
    info = {"n_light": int(qn - heavy.size), "n_heavy": int(heavy.size),
            "pilot_budget": _pilot_info(budget)}
    if heavy.size == 0:
        return _observed(advisor, inner, decision, out1, t0, info)
    # host-side gather: a jnp fancy-index would compile per heavy-set shape
    sub = {k: np.asarray(v)[heavy] for k, v in binds.items()}
    out2 = executor(sub)
    out1 = jax.tree.map(np.asarray, out1)
    out2 = jax.tree.map(np.asarray, out2)

    def scatter(a, b):
        merged = np.array(a)
        merged[heavy] = b
        return merged

    merged = jax.tree.map(scatter, out1, out2)
    return _observed(advisor, inner, decision, merged, t0, info)


def _observed(advisor, inner, decision, out, t0: float, info: dict):
    """Fold the finished execution into the advisor (if any) and attach the
    decision summary to ``info`` under ``"opt"``."""
    if advisor is not None and decision is not None:
        latency_ms = (time.perf_counter() - t0) * 1e3
        advisor.observe(inner, decision, out, latency_ms)
        info["opt"] = decision.summary()
    return out, info


class BatchScheduler:
    """Coalesce arriving requests into size-bucketed batch executions.

    Online surface: ``submit(**binds)`` enqueues and returns a request id;
    ``poll()`` drains a batch when due (full, or the oldest request's
    ``max_wait_ms`` deadline expired); ``flush()`` drains everything;
    ``result(rid)`` returns that request's sliced outputs.  One scheduler
    serves one compiled plan (the serving deployment unit).

    ``compiled`` is anything exposing the execution contract —
    ``_stack_binds`` / ``executor`` / ``batch_native`` — i.e. a legacy
    :class:`~repro.core.compiler.CompiledQuery` or a session-API
    :class:`~repro.api.Statement` (``Database.serve`` constructs the latter;
    a Statement additionally translates renamed bind parameters onto the
    cached plan before stacking)."""

    def __init__(self, compiled, config: SchedulerConfig | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 advisor=None):
        self.compiled = compiled
        # None-sentinel, NOT a `config=SchedulerConfig()` default: a
        # class-level default dataclass would be one shared instance across
        # every scheduler ever constructed.
        self.config = config if config is not None else SchedulerConfig()
        self.clock = clock
        # optional repro.opt.LoweringAdvisor: replaces the static
        # pilot_budget with the stats-driven predictor (DESIGN.md §14)
        self.advisor = advisor
        self._queue: collections.deque[_Request] = collections.deque()
        self._results: dict[int, Any] = {}
        self._next_rid = 0
        self.counters = {"submitted": 0, "executed": 0, "batches": 0,
                         "shed_deadline": 0, "failed": 0}

    # -- online API ---------------------------------------------------------

    def submit(self, **binds) -> int:
        """Enqueue a request with default deadline/priority (back-compat
        surface; see :meth:`submit_request` for the full contract)."""
        return self.submit_request(binds)

    def submit_request(self, binds: dict, *, deadline_ms: float | None = None,
                       deadline: float | None = None,
                       priority: int = 0) -> int:
        """Enqueue a request and return its id.

        ``deadline_ms`` is relative to now; ``deadline`` is absolute in
        clock units (seconds) and wins when both are given.  Without either,
        ``config.default_deadline_ms`` applies (None = never expires).
        Higher ``priority`` drains first; ties drain in arrival order."""
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        if deadline is None:
            if deadline_ms is None:
                deadline_ms = self.config.default_deadline_ms
            if deadline_ms is not None:
                deadline = now + deadline_ms * 1e-3
        self._queue.append(_Request(rid, binds, now, deadline, priority))
        self.counters["submitted"] += 1
        return rid

    def pending(self) -> int:
        """Number of requests queued (submitted, not yet drained/shed)."""
        return len(self._queue)

    def due(self, now: float | None = None) -> bool:
        """Drain rule: full batch, OR the oldest request waited out its
        ``max_wait_ms`` coalescing window, OR the tightest queued deadline
        is within ``deadline_margin_ms`` — a batch never idles past the
        point where one of its members would expire."""
        if not self._queue:
            return False
        if len(self._queue) >= self.config.max_batch:
            return True
        now = self.clock() if now is None else now
        oldest = self._queue[0].arrival
        if (now - oldest) * 1e3 >= self.config.max_wait_ms:
            return True
        deadlines = [r.deadline for r in self._queue if r.deadline is not None]
        if deadlines:
            margin = self.config.deadline_margin_ms * 1e-3
            return now >= min(deadlines) - margin
        return False

    def shed_expired(self, now: float | None = None) -> list[int]:
        """Drop every queued request whose deadline has passed (strict
        ``now > deadline`` — a drain at exactly the deadline still serves).
        Each shed rid completes with a stored
        :class:`~repro.serving.resilience.DeadlineExceededError` that
        :meth:`result` re-raises; no kernel time is spent on them."""
        if not self._queue:
            return []
        now = self.clock() if now is None else now
        shed: list[int] = []
        keep: collections.deque[_Request] = collections.deque()
        for r in self._queue:
            if r.deadline is not None and now > r.deadline:
                self._results[r.rid] = DeadlineExceededError(
                    r.rid, (now - r.deadline) * 1e3)
                shed.append(r.rid)
            else:
                keep.append(r)
        if shed:
            self._queue = keep
            self.counters["shed_deadline"] += len(shed)
        return shed

    def poll(self, now: float | None = None) -> list[int]:
        """Shed expired requests, then drain ONE batch if due; returns the
        completed request ids (shed rids included — their results raise)."""
        now = self.clock() if now is None else now
        done = self.shed_expired(now)
        if self.due(now):
            done.extend(self._drain(now))
        return done

    def flush(self, now: float | None = None) -> list[int]:
        """Drain everything queued, one max_batch execution at a time."""
        now = self.clock() if now is None else now
        done = self.shed_expired(now)
        while self._queue:
            done.extend(self._drain(now))
        return done

    def result(self, rid: int):
        """Pop the request's outcome: sliced outputs, or — for a shed or
        failed request — re-raise its stored exception."""
        out = self._results.pop(rid)
        if isinstance(out, BaseException):
            raise out
        return out

    # -- execution ----------------------------------------------------------

    def _take(self) -> list[_Request]:
        """Pop up to max_batch requests, highest priority first (arrival
        order within a priority level, and the all-default-priority path
        stays pure FIFO)."""
        take = min(len(self._queue), self.config.max_batch)
        if any(r.priority for r in self._queue):
            ordered = sorted(self._queue,
                             key=lambda r: (-r.priority, r.arrival, r.rid))
            chosen = {r.rid for r in ordered[:take]}
            entries = [r for r in self._queue if r.rid in chosen]
            self._queue = collections.deque(
                r for r in self._queue if r.rid not in chosen)
            return entries
        return [self._queue.popleft() for _ in range(take)]

    def _drain(self, now: float | None = None) -> list[int]:
        now = self.clock() if now is None else now
        done = self.shed_expired(now)
        if not self._queue:
            return done
        entries = self._take()
        try:
            out = self.execute([r.binds for r in entries])
        except Exception as e:
            # fault containment: the failure is scoped to this batch —
            # every member completes with the error, the queue keeps
            # draining, and nothing is left dangling (no hangs).
            for r in entries:
                self._results[r.rid] = e
            self.counters["failed"] += len(entries)
        else:
            for i, r in enumerate(entries):
                self._results[r.rid] = self._slice(out, i)
            self.counters["executed"] += len(entries)
            self.counters["batches"] += 1
        return done + [r.rid for r in entries]

    def _slice(self, out, i: int):
        """Extract request ``i``'s view of a batch output (overridable —
        :class:`ResilientScheduler` slices structured ResultBatch)."""
        return jax.tree.map(lambda v: v[i], out)

    def execute(self, binds_list: list[dict]):
        """Execute one coalesced batch through the bucketed executor
        (effort-bucketed when ``pilot_budget`` > 0; advisor-predicted
        budgets replace the static pilot when an ``advisor`` is attached)."""
        binds = self.compiled._stack_binds(binds_list, {})
        if self.advisor is not None:
            out, _info = run_effort_bucketed(self.compiled, binds,
                                             self.config.pilot_budget,
                                             advisor=self.advisor)
            return out
        if self.config.pilot_budget > 0:
            out, _info = run_effort_bucketed(self.compiled, binds,
                                             self.config.pilot_budget)
            return out
        return self.compiled.executor(binds)

    def warm(self, sample_binds: dict, batch_sizes: list[int]) -> None:
        """Pre-trace the bucket executables a traffic mix will touch (keeps
        compile time out of latency measurements and first requests).

        With ``pilot_budget`` > 0 both per-bucket variants are traced — the
        budgeted phase-1 executable AND the unbudgeted phase-2 one — since
        whether a drain reaches phase 2 depends on the data (all-identical
        warm batches may never produce a heavy remainder)."""
        for b in sorted({self.compiled.executor.bucket_for(s)
                         for s in batch_sizes}):
            stacked = self.compiled._stack_binds([sample_binds] * b, {})
            self.compiled.executor(stacked)
            if self.config.pilot_budget > 0 and self.compiled.batch_native:
                self.compiled.executor(stacked,
                                       probe_budget=self.config.pilot_budget)

    # -- virtual-clock simulation -------------------------------------------

    def simulate(self, arrivals: np.ndarray,
                 binds_list: list[dict]) -> list[SimRecord]:
        """Single-server queueing simulation of the coalescing policy.

        ``arrivals`` are request arrival times in seconds (sorted ascending,
        virtual clock); ``binds_list`` the matching per-request binds.  Batch
        formation follows the deadline rule; service time is the measured
        wall-clock of the REAL batch execution (warm the buckets first).
        Returns per-request :class:`SimRecord` timelines."""
        n = len(arrivals)
        assert len(binds_list) == n
        wait_s = self.config.max_wait_ms * 1e-3
        server_free = 0.0
        records: list[SimRecord] = []
        i = 0
        while i < n:
            deadline = arrivals[i] + wait_s
            close = max(deadline, server_free)
            j = i
            while (j < n and arrivals[j] <= close
                   and (j - i) < self.config.max_batch):
                j += 1
            if j - i >= self.config.max_batch:
                # the batch filled before the window closed
                start = max(server_free, float(arrivals[j - 1]))
            else:
                start = close
            t0 = time.perf_counter()
            out = self.execute(binds_list[i:j])
            jax.block_until_ready(jax.tree.leaves(getattr(out, "data", out))[0])
            exec_s = time.perf_counter() - t0
            finish = start + exec_s
            for r in range(i, j):
                records.append(SimRecord(r, float(arrivals[r]), start,
                                         finish, j - i))
            server_free = finish
            i = j
        return records


class ResilientScheduler(BatchScheduler):
    """Deadline scheduler + graceful degradation + fault injection.

    Serves a session-API :class:`~repro.api.Statement` (required — the
    structured-result surface is what carries degraded-mode reporting).
    On every drain the :class:`~repro.serving.resilience.LoadController`
    observes the pre-drain queue depth and picks an effort level; level
    L > 0 caps batched IVF executions at the policy's per-query
    ``probe_budget`` (trading recall for goodput) and the served results'
    ``explain()`` reports ``degraded``.  A
    :class:`~repro.serving.faults.FaultInjector`, when wired, wraps each
    batch execution (latency spikes, kernel errors, catalog bumps) —
    injected kernel errors are contained per batch like any real failure.
    """

    def __init__(self, statement, config: SchedulerConfig | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 policy=None, faults=None):
        super().__init__(statement, config, clock)
        self.load = LoadController(policy)
        self.faults = faults

    @property
    def statement(self):
        """The served Statement (alias of the scheduler's compiled slot)."""
        return self.compiled

    def execute(self, binds_list: list[dict]):
        # local import: repro.api imports this module at package init
        from ..api.hints import ExecutionHints
        from ..api.result import ResultBatch

        depth = self.pending() + len(binds_list)  # pre-drain queue depth
        level = self.load.observe(depth)
        budget = self.load.probe_budget()
        if budget is not None and self.compiled.batch_native:
            hints = ExecutionHints(probe_budget=budget)
        elif self.config.pilot_budget > 0:
            hints = ExecutionHints(pilot_budget=self.config.pilot_budget)
        else:
            hints = None
        run = lambda bl: self.compiled.execute(bl, hints=hints)
        if self.faults is not None:
            run = self.faults.wrap(run)
        out = run(binds_list)
        if level > 0 and isinstance(out, ResultBatch):
            info = {"level": level, "probe_budget": budget}
            base_fn = out._explain_fn
            out = ResultBatch(out.data,
                              lambda: dataclasses.replace(base_fn(),
                                                          degraded=info),
                              len(out))
        return out

    def _slice(self, out, i: int):
        if hasattr(out, "query"):
            return out.query(i)
        return super()._slice(out, i)

    def warm(self, sample_binds: dict, batch_sizes: list[int]) -> None:
        """Also pre-trace the probe-budgeted executables degraded drains
        run (a load transition must not pay a compile on the hot path —
        that latency spike is exactly what degradation is fighting)."""
        super().warm(sample_binds, batch_sizes)
        if self.load.policy.steps and self.compiled.batch_native:
            budget = self.load.policy.steps[-1][1]
            ex = self.compiled.executor
            for b in sorted({ex.bucket_for(s) for s in batch_sizes}):
                stacked = self.compiled._stack_binds([sample_binds] * b, {})
                ex(stacked, probe_budget=budget)

    def snapshot(self) -> dict:
        """Scheduler counters + load-controller state (+ fault counters)."""
        snap = {**self.counters, "load": self.load.snapshot()}
        if self.faults is not None:
            snap["faults"] = self.faults.snapshot()
        return snap


def latency_stats(records: list[SimRecord]) -> dict:
    """p50/p95/mean latency (ms) + throughput (QPS) of a simulation run."""
    lats = np.asarray([r.latency for r in records]) * 1e3
    span = max(r.finish for r in records) - min(r.arrival for r in records)
    return {"p50_ms": round(float(np.percentile(lats, 50)), 3),
            "p95_ms": round(float(np.percentile(lats, 95)), 3),
            "mean_ms": round(float(lats.mean()), 3),
            "qps": round(len(records) / span, 1) if span > 0 else float("inf")}
