"""Quantized corpus scan kernels with fused fp32 rescore (DESIGN.md §13).

The flat batched scan is memory-bandwidth-bound: QPS is set by corpus bytes
streamed through the (BLOCK_N, D)·(D, BLOCK_Q) tiles, not by FLOPs.  These
kernels stream an int8 (per-row symmetric scale) or bf16 twin of the corpus
— 4×/2× fewer bytes — on the same MXU layout the fp32 query-tiled kernels
use (int8 widens + rescales in-register; bf16 feeds the contraction
MXU-NATIVE with fp32 accumulation, see :func:`_dequant_block`), and keep
results EXACT by re-ranking a small candidate set against the fp32
originals.

Two ideas make the quantized path both fast and bit-identical:

* **Segmented candidate extraction.**  The per-cell extract-min loop, not
  the matmul, dominates the fp32 kernel at moderate k.  The quantized
  kernel reduces its (B, BQ) key tile to per-``SEG``-row segment minima
  (an 8× smaller array) and extracts the top-(c·k) *segments* per query.
  A row with quantized rank ≤ c·k has at most c·k − 1 rows ahead of it, so
  at most c·k − 1 segments have a smaller minimum — its segment is always
  within the top-(c·k) segments, and expanding each selected segment back
  to its ``SEG`` rows yields a candidate superset of the quantized
  top-(c·k).  The extract loop runs c·k/(k·8) ≈ c/8 of the fp32 work.

* **Same-shape fp32 replay rescore.**  XLA's reduction order for a dot
  depends on the operand shapes, so per-query gathered matvecs do NOT
  reproduce the kernel's keys bitwise.  Instead the candidate rows are
  packed into synthetic (BLOCK_N, D) blocks and pushed through the very
  same (BLOCK_N, D)·(D, BLOCK_Q) ``_keys_from_block_batch`` contraction —
  per query block, against that block's own query tile — which reproduces
  the fp32 kernel's keys bit-for-bit for every (row, query) pair.
  Candidate ids are sorted ascending before the final stable ``top_k``,
  matching the fp32 path's lowest-id tie-break.

Range queries rescore boundary candidates inside a scale-derived slack
band: per-row dequantization error bounds (``QuantizedCorpus.half_step``)
give |k̂ − k| ≤ slack, so rows with k̂ ≤ radius − slack are certain hits,
rows with k̂ > radius + slack are certain misses, and only the band in
between is replayed in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.schema import Metric
from .ops import (LANE, _block_sizes, _mask_nq_i8, _pad_dim, _qvalid_row_i8,
                  _resolve_interpret)
from .scan_topk import _extract_topk_cols, _keys_from_block_batch

INF = float("inf")
_I32_MAX = 2 ** 31 - 1

# Segment width of the segmented candidate extraction.  8 divides every
# block size the wrappers emit (block_n >= LANE = 128) and measured best
# on the q13 sweep (16 halves the extract work again but doubles the
# expansion width; the rescore gather then dominates).
SEG = 8


# ---------------------------------------------------------------------------
# Stage 1 kernels: dequantize in-register, quantized keys on the MXU
# ---------------------------------------------------------------------------

def _dequant_block(c_ref, s_ref) -> jnp.ndarray:
    """The corpus tile in the dtype the MXU contraction consumes.

    int8 widens to fp32 and applies the per-row scales in-register (the
    MXU has no int8 × fp32 contraction with per-row rescale).  bf16
    streams MXU-NATIVE: its scales are ones by construction (DESIGN.md
    §13), and :func:`_keys_from_block_batch` contracts bf16 × fp32 with
    fp32 accumulation — bitwise identical to widening first (bf16 -> fp32
    conversion is exact), while the tile stays half-width all the way into
    the matmul."""
    if c_ref.dtype == jnp.bfloat16:
        return c_ref[...]
    return c_ref[...].astype(jnp.float32) * s_ref[...]


def _quant_topk_batch_kernel(q_ref, qv_ref, c_ref, s_ref, m_ref, keys_out,
                             ids_out, *, s_count: int, metric: Metric):
    """Grid (num_q_blocks, num_n_blocks): quantized keys + segment minima +
    top-``s_count`` SEGMENT extraction per query column.

    ``c_ref`` is the (BLOCK_N, D) int8/bf16 tile; ``s_ref`` the matching
    (BLOCK_N, 1) fp32 per-row scales (unused in bf16 mode, where the tile
    streams MXU-native through :func:`_dequant_block`).  Emits
    (s_count, BLOCK_Q) blocks of LOCAL segment indices; the wrapper rebases
    by n-block, merges globally, and expands segments back to rows for the
    fp32 replay rescore."""
    block = _dequant_block(c_ref, s_ref)                 # (B, D)
    qs = q_ref[...].astype(jnp.float32)                  # (BQ, D)
    keys = _keys_from_block_batch(block, qs, metric)     # (B, BQ)
    live = (m_ref[...] != 0) & (qv_ref[...] != 0)        # broadcasts (1, BQ)
    keys = jnp.where(live, keys, INF)
    b, bq = keys.shape
    segk = keys.reshape(b // SEG, SEG, bq).min(axis=1)   # (B/SEG, BQ)
    out_keys, out_ids = _extract_topk_cols(segk, s_count)
    keys_out[...] = out_keys
    ids_out[...] = out_ids


@functools.partial(jax.jit,
                   static_argnames=("s_count", "metric", "block_q", "block_n",
                                    "interpret"))
def quant_scan_topk_batch_pallas(qcorpus: jnp.ndarray, scales: jnp.ndarray,
                                 queries: jnp.ndarray, mask_i8: jnp.ndarray,
                                 qvalid_i8: jnp.ndarray, s_count: int,
                                 metric: Metric, block_q: int = 128,
                                 block_n: int = 1024, interpret: bool = True):
    """Stage 1 (Pallas), quantized + segmented: per (q-block, n-block) cell
    the top-``s_count`` segment minima per query.

    Inputs pre-padded by :func:`fused_scan_topk_batch_q`: qcorpus
    (Npad, Dpad) int8/bf16, scales (Npad, 1) fp32, queries (Qpad, Dpad),
    mask (Npad, Qm) int8 with Qm ∈ {1, Qpad}, qvalid (1, Qpad) int8.
    Returns (num_n_blocks*s_count, Qpad) keys and LOCAL segment ids."""
    n, d = qcorpus.shape
    qn = queries.shape[0]
    assert n % block_n == 0 and qn % block_q == 0, (n, block_n, qn, block_q)
    assert block_n % SEG == 0, (block_n, SEG)
    num_n = n // block_n
    num_q = qn // block_q
    per_query_mask = mask_i8.shape[1] != 1
    mspec = (pl.BlockSpec((block_n, block_q), lambda i, j: (j, i))
             if per_query_mask
             else pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)))
    kernel = functools.partial(_quant_topk_batch_kernel, s_count=s_count,
                               metric=metric)
    keys, ids = pl.pallas_call(
        kernel,
        grid=(num_q, num_n),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),   # query tile
            pl.BlockSpec((1, block_q), lambda i, j: (0, i)),   # q-valid row
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),   # quant tile
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),   # row scales
            mspec,                                             # mask tile
        ],
        out_specs=[
            pl.BlockSpec((s_count, block_q), lambda i, j: (j, i)),
            pl.BlockSpec((s_count, block_q), lambda i, j: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_n * s_count, qn), jnp.float32),
            jax.ShapeDtypeStruct((num_n * s_count, qn), jnp.int32),
        ],
        interpret=interpret,
    )(queries, qvalid_i8, qcorpus, scales, mask_i8)
    return keys, ids


def _quant_keys_batch_kernel(q_ref, qv_ref, c_ref, s_ref, m_ref, keys_out, *,
                             metric: Metric):
    """Grid (num_q_blocks, num_n_blocks): the quantized twin of the fp32
    range kernel's key materialization — masked quantized order keys, no
    radius test (the slack-band classification happens outside)."""
    block = _dequant_block(c_ref, s_ref)
    keys = _keys_from_block_batch(block, q_ref[...].astype(jnp.float32),
                                  metric)
    live = (m_ref[...] != 0) & (qv_ref[...] != 0)
    keys_out[...] = jnp.where(live, keys, INF)


@functools.partial(jax.jit, static_argnames=("metric", "block_q", "block_n",
                                             "interpret"))
def quant_keys_batch_pallas(qcorpus: jnp.ndarray, scales: jnp.ndarray,
                            queries: jnp.ndarray, mask_i8: jnp.ndarray,
                            qvalid_i8: jnp.ndarray, metric: Metric,
                            block_q: int = 128, block_n: int = 1024,
                            interpret: bool = True):
    """Masked (Npad, Qpad) quantized order keys (INF on dead lanes) — the
    range path's stage 1 (the fp32 range kernel materializes the same
    matrix; the quantized one just streams 4×/2× fewer corpus bytes)."""
    n, d = qcorpus.shape
    qn = queries.shape[0]
    assert n % block_n == 0 and qn % block_q == 0
    num_n = n // block_n
    num_q = qn // block_q
    per_query_mask = mask_i8.shape[1] != 1
    mspec = (pl.BlockSpec((block_n, block_q), lambda i, j: (j, i))
             if per_query_mask
             else pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)))
    kernel = functools.partial(_quant_keys_batch_kernel, metric=metric)
    keys = pl.pallas_call(
        kernel,
        grid=(num_q, num_n),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (0, i)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
            mspec,
        ],
        out_specs=pl.BlockSpec((block_n, block_q), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((n, qn), jnp.float32),
        interpret=interpret,
    )(queries, qvalid_i8, qcorpus, scales, mask_i8)
    return keys


# ---------------------------------------------------------------------------
# Fused fp32 rescore (same-shape replay — bitwise-exact keys)
# ---------------------------------------------------------------------------

def _replay_keys(corpus_pad: jnp.ndarray, queries_pad: jnp.ndarray,
                 rows: jnp.ndarray, metric: Metric, block_n: int,
                 block_q: int) -> jnp.ndarray:
    """Exact fp32 order keys for per-query candidate rows, bitwise equal to
    the fp32 batched kernels' keys for the same (row, query) pairs.

    ``rows`` is (Qpad, C) int32 row ids into ``corpus_pad`` (callers clamp
    out-of-range ids to 0 and mask afterwards).  Candidates are packed into
    synthetic (block_n, Dpad) blocks and pushed through the SAME
    (block_n, D)·(D, block_q) contraction the kernels run — per query
    block, against that block's own (block_q, Dpad) query tile — so XLA's
    shape-dependent accumulation order matches the kernel's exactly.  The
    metric epilogues (row norms on the (block_n, Dpad) block, query norms
    on the (block_q, Dpad) tile) replay on the same shapes too."""
    qn_pad, c = rows.shape
    d = corpus_pad.shape[1]
    assert qn_pad % block_q == 0, (qn_pad, block_q)
    out = []
    for qb in range(qn_pad // block_q):
        q_tile = queries_pad[qb * block_q:(qb + 1) * block_q]   # (BQ, D)
        r = rows[qb * block_q:(qb + 1) * block_q].reshape(-1)   # (BQ*C,)
        gathered = corpus_pad[r]                                # (BQ*C, D)
        total = block_q * c
        nb = -(-total // block_n)
        pad = nb * block_n - total
        if pad:
            gathered = jnp.concatenate(
                [gathered, jnp.zeros((pad, d), jnp.float32)])
        rep = jnp.concatenate(
            [_keys_from_block_batch(
                gathered[i * block_n:(i + 1) * block_n], q_tile, metric)
             for i in range(nb)], axis=0)[:total]               # (BQ*C, BQ)
        # candidate slot (q-local row i, position j) reads ITS query column
        qcol = jnp.repeat(jnp.arange(block_q, dtype=jnp.int32), c)
        out.append(rep[jnp.arange(total), qcol].reshape(block_q, c))
    return jnp.concatenate(out, axis=0)                         # (Qpad, C)


def _replay_keys_all(corpus_pad: jnp.ndarray, queries_pad: jnp.ndarray,
                     metric: Metric, block_n: int,
                     block_q: int) -> jnp.ndarray:
    """Exact fp32 order keys for EVERY (query, row) pair — (Qpad, Npad).

    Runs the kernels' own (block_n, D)·(D, block_q) contraction per
    (q-block, n-block) cell in plain XLA, so the result is bitwise the
    fp32 range kernel's key matrix.  The range path's slow-path fallback
    when a slack band overflows its rescore budget."""
    out = []
    for qb in range(queries_pad.shape[0] // block_q):
        q_tile = queries_pad[qb * block_q:(qb + 1) * block_q]
        cols = jnp.concatenate(
            [_keys_from_block_batch(
                corpus_pad[i * block_n:(i + 1) * block_n], q_tile, metric)
             for i in range(corpus_pad.shape[0] // block_n)], axis=0)
        out.append(cols.T)                              # (BQ, Npad)
    return jnp.concatenate(out, axis=0)


def _mask_at_rows(row_mask, rows_safe: jnp.ndarray, qn: int,
                  n: int) -> jnp.ndarray:
    """Row-mask values at gathered candidate positions ((Qpad, C) bool).

    Segment expansion can resurrect predicate-masked rows (a masked row
    shares a segment with a surviving one), so the rescore re-applies the
    mask before the final top-k."""
    if row_mask is None:
        return jnp.ones(rows_safe.shape, jnp.bool_)
    if row_mask.ndim == 1:
        return row_mask.astype(jnp.bool_)[rows_safe]
    qn_pad = rows_safe.shape[0]
    m = row_mask.astype(jnp.bool_)
    assert m.shape == (qn, n), (m.shape, qn, n)
    if qn_pad != qn:
        m = jnp.pad(m, ((0, qn_pad - qn), (0, 0)), constant_values=False)
    return jnp.take_along_axis(m, rows_safe, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("k", "metric", "rescore_factor",
                                    "block_q", "block_n", "interpret"))
def fused_scan_topk_batch_q(corpus: jnp.ndarray, qvecs: jnp.ndarray,
                            scales: jnp.ndarray, queries: jnp.ndarray,
                            k: int, row_mask: jnp.ndarray | None,
                            metric: Metric, rescore_factor: int = 2,
                            block_q: int = 128, block_n: int = 1024,
                            interpret: bool | None = None,
                            qvalid: jnp.ndarray | None = None):
    """Quantized twin of :func:`~repro.kernels.ops.fused_scan_topk_batch`.

    Streams the int8/bf16 ``qvecs`` (with fp32 per-row ``scales``; ones in
    bf16 mode) through the segmented quantized kernel, merges the per-cell
    segment winners to the global top-(rescore_factor·k) segments per
    query, expands them to rows, and re-ranks those candidates against the
    fp32 ``corpus`` with the same-shape replay — results are bit-identical
    to the fp32 batched path whenever the quantized top-(c·k) covers the
    fp32 top-k (module docstring; c = ``rescore_factor``).  Contract
    (masks, q-valid lane, outputs) identical to the fp32 wrapper.
    Returns (ids (Q, k), sims raw-metric (Q, k), valid (Q, k))."""
    interpret = _resolve_interpret(interpret)
    n, d = corpus.shape
    qn = queries.shape[0]
    bq, bn = _block_sizes(n, qn, block_q, block_n)
    cp = _pad_dim(_pad_dim(corpus.astype(jnp.float32), LANE, 1), bn, 0)
    zp = _pad_dim(_pad_dim(qvecs, LANE, 1), bn, 0)        # quant dtype kept
    sp = _pad_dim(scales.astype(jnp.float32).reshape(-1, 1), bn, 0)
    qp = _pad_dim(_pad_dim(queries.astype(jnp.float32), LANE, 1), bq, 0)
    mp = _mask_nq_i8(row_mask, n, qn, bn, bq)
    qv = _qvalid_row_i8(qvalid, qn, bq)
    c = max(1, int(rescore_factor))
    s_count = max(1, min(c * k, bn // SEG))
    keys, ids = quant_scan_topk_batch_pallas(
        zp, sp, qp, mp, qv, s_count, metric, block_q=bq, block_n=bn,
        interpret=interpret)
    # stage 2: query-major, rebase local segment ids, merge the global
    # top-(c·k) segments per query
    num_n = cp.shape[0] // bn
    keys = keys.T                                   # (Qpad, num_n*s_count)
    ids = ids.T
    base = (jnp.arange(num_n * s_count, dtype=jnp.int32) // s_count) \
        * (bn // SEG)
    gseg = jnp.where(ids >= 0, ids + base[None, :], -1)
    s_total = min(c * k, num_n * s_count)
    neg, idx = jax.lax.top_k(-keys, s_total)                    # row-wise
    segsel = jnp.where(jnp.isfinite(-neg),
                       jnp.take_along_axis(gseg, idx, axis=1), -1)
    # expand segments -> rows; ids sorted ascending so the stable top_k
    # below resolves exact-key ties to the lowest id (the fp32 tie-break)
    rows = (segsel[:, :, None] * SEG
            + jnp.arange(SEG, dtype=jnp.int32)[None, None, :])
    rows = jnp.where(segsel[:, :, None] >= 0, rows, _I32_MAX)
    rows = jnp.sort(rows.reshape(rows.shape[0], -1), axis=1)    # (Qpad, C)
    okrow = rows < n
    safe = jnp.where(okrow, rows, 0)
    exact = _replay_keys(cp, qp, safe, metric, bn, bq)
    exact = jnp.where(okrow & _mask_at_rows(row_mask, safe, qn, n),
                      exact, INF)
    neg2, idx2 = jax.lax.top_k(-exact, k)                       # row-wise
    out_keys = -neg2
    valid = jnp.isfinite(out_keys)
    out_ids = jnp.where(valid, jnp.take_along_axis(rows, idx2, axis=1), -1)
    sims = jnp.where(valid,
                     -out_keys if metric.is_similarity() else out_keys, 0.0)
    return out_ids[:qn], sims[:qn], valid[:qn]


# ---------------------------------------------------------------------------
# Range: slack-band classification + boundary rescore
# ---------------------------------------------------------------------------

def _range_slack(metric: Metric, half: jnp.ndarray, l1: jnp.ndarray,
                 l2: jnp.ndarray, queries: jnp.ndarray,
                 d_true: int) -> jnp.ndarray:
    """Per-(query, row) upper bound on |quantized key − exact key|.

    With h the per-row componentwise dequantization error bound
    (``QuantizedCorpus.half_step``), x̂ the dequantized row, and q the
    query (DESIGN.md §13 derives these):

    * IP:  |Δ(−q·x)| ≤ h·‖q‖₁
    * L2:  |Δ‖x−q‖²| ≤ 2h(‖x̂‖₁ + ‖q‖₁) + D·h²
    * cos: |Δ| ≤ h·(‖q‖₁/‖q‖₂ + √D) / ‖x̂‖₂

    Returns (Q, N) fp32, widened by a small relative+absolute epsilon for
    fp32 evaluation noise of the bound itself."""
    h = half.reshape(1, -1)                                 # (1, N)
    q_l1 = jnp.sum(jnp.abs(queries), axis=1, keepdims=True)  # (Q, 1)
    if metric == Metric.INNER_PRODUCT:
        slack = h * q_l1
    elif metric == Metric.L2:
        slack = 2.0 * h * (l1.reshape(1, -1) + q_l1) + d_true * h * h
    elif metric == Metric.COSINE:
        q_l2 = jnp.sqrt(jnp.sum(queries * queries, axis=1, keepdims=True))
        num = q_l1 / jnp.maximum(q_l2, 1e-12) + jnp.sqrt(float(d_true))
        slack = h * num / jnp.maximum(l2.reshape(1, -1), 1e-12)
    else:
        raise ValueError(metric)
    return slack * 1.001 + 1e-6


@functools.partial(jax.jit,
                   static_argnames=("metric", "capacity", "rescore_factor",
                                    "block_q", "block_n", "interpret"))
def fused_range_topk_batch_q(corpus: jnp.ndarray, qvecs: jnp.ndarray,
                             scales: jnp.ndarray, half: jnp.ndarray,
                             l1: jnp.ndarray, l2: jnp.ndarray,
                             queries: jnp.ndarray, radius,
                             row_mask: jnp.ndarray | None, metric: Metric,
                             capacity: int, rescore_factor: int = 2,
                             block_q: int = 128, block_n: int = 1024,
                             interpret: bool | None = None,
                             qvalid: jnp.ndarray | None = None):
    """Quantized twin of :func:`~repro.kernels.ops.fused_range_topk_batch`.

    Quantized keys classify every row into certain-hit (k̂ ≤ r − slack),
    certain-miss (k̂ > r + slack), or boundary; only boundary rows and the
    emitted best-``capacity`` candidates are replayed in fp32 (same-shape
    replay — emitted sims are bitwise the fp32 kernel's).  ``count`` is
    #certain-hits + #(replayed boundary rows that hit exactly).  The
    replay budget is ``rescore_factor·capacity`` rows per query; when a
    slack band overflows it (detected at runtime) the whole corpus is
    replayed instead, so results stay exact unconditionally — only the
    bandwidth saving degrades.  Returns (ids (Q, P), sims, valid,
    count (Q,)) with P = min(capacity, N), contract identical to the fp32
    wrapper (best-first, lowest-id ties)."""
    from ..core.expr import order_key
    interpret = _resolve_interpret(interpret)
    n, d = corpus.shape
    qn = queries.shape[0]
    bq, bn = _block_sizes(n, qn, block_q, block_n)
    cp = _pad_dim(_pad_dim(corpus.astype(jnp.float32), LANE, 1), bn, 0)
    zp = _pad_dim(_pad_dim(qvecs, LANE, 1), bn, 0)
    sp = _pad_dim(scales.astype(jnp.float32).reshape(-1, 1), bn, 0)
    qp = _pad_dim(_pad_dim(queries.astype(jnp.float32), LANE, 1), bq, 0)
    mp = _mask_nq_i8(row_mask, n, qn, bn, bq)
    qv = _qvalid_row_i8(qvalid, qn, bq)
    qkeys = quant_keys_batch_pallas(zp, sp, qp, mp, qv, metric, block_q=bq,
                                    block_n=bn, interpret=interpret)
    qkeys = qkeys[:n, :].T                                   # (Qpad, N)
    qn_pad = qkeys.shape[0]
    rk = order_key(metric, jnp.broadcast_to(
        jnp.asarray(radius, jnp.float32), (qn,)))
    rk = _pad_dim(rk.reshape(qn, 1), bq, 0, value=-jnp.inf)  # (Qpad, 1)
    slack = _range_slack(metric, half[:n], l1[:n], l2[:n],
                         _pad_dim(queries.astype(jnp.float32), bq, 0), d)
    certain = qkeys <= rk - slack
    maybe = qkeys <= rk + slack                    # INF lanes: never maybe
    boundary = maybe & ~certain
    live = jnp.isfinite(qkeys)
    cap = min(int(capacity), n)
    w = min(max(1, int(rescore_factor)) * cap, n)

    def rescore(sel_keys):
        """Top-``w`` rows per query by ``sel_keys`` (INF = excluded),
        replayed in fp32.  Returns (rows asc-sorted, in-bounds+selected
        mask, exact keys)."""
        negk, sel = jax.lax.top_k(-sel_keys, w)
        rows = jnp.where(jnp.isfinite(-negk), sel.astype(jnp.int32),
                         _I32_MAX)
        rows = jnp.sort(rows, axis=1)              # fp32 lowest-id ties
        ok = rows < n
        safe = jnp.where(ok, rows, 0)
        return rows, ok, _replay_keys(cp, qp, safe, metric, bn, bq)

    def budgeted(_):
        # emission: best-cap exact hits from the top-w maybe rows by k̂
        rows_e, ok_e, exact_e = rescore(jnp.where(maybe, qkeys, INF))
        ekeys = jnp.where(ok_e & (exact_e <= rk), exact_e, INF)
        neg, idx = jax.lax.top_k(-ekeys, cap)                   # row-wise
        out_keys = -neg
        valid = jnp.isfinite(out_keys)
        out_ids = jnp.where(valid,
                            jnp.take_along_axis(rows_e, idx, axis=1), -1)
        # count: certain hits + exact hits among replayed boundary rows
        rows_b, ok_b, exact_b = rescore(
            jnp.where(boundary, jnp.abs(qkeys - rk), INF))
        count = jnp.sum(certain, axis=1) + jnp.sum(ok_b & (exact_b <= rk),
                                                   axis=1)
        return out_ids, out_keys, valid, count

    def full(_):
        # slack band wider than the rescore budget (huge radius, coarse
        # scales): replay every row — still bitwise the fp32 kernel keys
        exact_all = _replay_keys_all(cp, qp, metric, bn, bq)[:, :n]
        ekeys = jnp.where(live & (exact_all <= rk), exact_all, INF)
        neg, idx = jax.lax.top_k(-ekeys, cap)                   # row-wise
        out_keys = -neg
        valid = jnp.isfinite(out_keys)
        out_ids = jnp.where(valid, idx.astype(jnp.int32), -1)
        return out_ids, out_keys, valid, jnp.sum(jnp.isfinite(ekeys),
                                                 axis=1)

    # boundary ⊆ maybe, so one check covers both rescore budgets; when it
    # does NOT trip, every budgeted replay set was complete — so emission
    # AND count are exact unconditionally, not just empirically
    overflow = jnp.max(jnp.sum(maybe, axis=1)) > w
    out_ids, out_keys, valid, count = jax.lax.cond(overflow, full, budgeted,
                                                   None)
    sims = jnp.where(valid,
                     -out_keys if metric.is_similarity() else out_keys, 0.0)
    return (out_ids[:qn], sims[:qn], valid[:qn],
            count[:qn].astype(jnp.int32))
