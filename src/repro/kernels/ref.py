"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantic ground truth: tests sweep shapes/dtypes and assert
``assert_allclose(kernel(interpret=True), ref)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.schema import Metric
from ..core.expr import distance_values, order_key


def keys_ref(corpus: jnp.ndarray, query: jnp.ndarray,
             metric: Metric) -> jnp.ndarray:
    """(N,) order keys (ascending-better) of corpus rows vs a single query."""
    raw = distance_values(metric, corpus.astype(jnp.float32),
                          query.astype(jnp.float32))
    return order_key(metric, raw)


def scan_topk_ref(corpus: jnp.ndarray, query: jnp.ndarray, k: int,
                  row_mask: jnp.ndarray | None, metric: Metric):
    """Fused scan+filter+topk oracle. Returns (ids, keys, valid)."""
    keys = keys_ref(corpus, query, metric)
    if row_mask is not None:
        keys = jnp.where(row_mask, keys, jnp.inf)
    neg, idx = jax.lax.top_k(-keys, k)
    out_keys = -neg
    valid = jnp.isfinite(out_keys)
    ids = jnp.where(valid, idx.astype(jnp.int32), -1)
    return ids, out_keys, valid


def range_scan_ref(corpus: jnp.ndarray, query: jnp.ndarray, radius_key,
                   row_mask: jnp.ndarray | None, metric: Metric):
    """Fused range scan oracle. Returns (hit mask (N,), keys (N,))."""
    keys = keys_ref(corpus, query, metric)
    hit = keys <= radius_key
    if row_mask is not None:
        hit = hit & row_mask
    return hit, keys


def pairwise_keys_ref(queries: jnp.ndarray, corpus: jnp.ndarray,
                      metric: Metric) -> jnp.ndarray:
    """(Q, N) order-key matrix oracle."""
    q = queries.astype(jnp.float32)
    c = corpus.astype(jnp.float32)
    ip = q @ c.T
    if metric == Metric.INNER_PRODUCT:
        return -ip
    if metric == Metric.L2:
        q2 = jnp.sum(q * q, axis=1, keepdims=True)
        c2 = jnp.sum(c * c, axis=1)
        return q2 - 2.0 * ip + c2[None, :]
    if metric == Metric.COSINE:
        qn = jnp.linalg.norm(q, axis=1, keepdims=True)
        cn = jnp.linalg.norm(c, axis=1)
        return -(ip / (qn * cn[None, :] + 1e-12))
    raise ValueError(metric)
