"""Pallas TPU kernel: blocked pairwise order-key matrix (distance join GEMM).

Q3/Q4 brute paths and the distributed join reduce to a (Q, N) distance matrix.
This is a classic tiled GEMM with a metric epilogue: (BQ, D) × (D, BC) on the
MXU, fp32 accumulation, L2/cosine epilogue in-register — the whole D dimension
is resident in VMEM per tile (D ≤ 1024 after padding ⇒ ≤ 0.5 MB per operand
tile at BQ=BC=128, comfortably inside the ~16 MB v5e VMEM budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.schema import Metric


def _pairwise_kernel(q_ref, c_ref, out_ref, *, metric: Metric):
    qb = q_ref[...].astype(jnp.float32)         # (BQ, D)
    cb = c_ref[...].astype(jnp.float32)         # (BC, D)
    ip = jnp.dot(qb, cb.T, preferred_element_type=jnp.float32)  # (BQ, BC)
    if metric == Metric.INNER_PRODUCT:
        out_ref[...] = -ip
    elif metric == Metric.L2:
        q2 = jnp.sum(qb * qb, axis=1, keepdims=True)
        c2 = jnp.sum(cb * cb, axis=1, keepdims=True)
        out_ref[...] = q2 - 2.0 * ip + c2.T
    elif metric == Metric.COSINE:
        qn = jnp.sqrt(jnp.sum(qb * qb, axis=1, keepdims=True))
        cn = jnp.sqrt(jnp.sum(cb * cb, axis=1, keepdims=True))
        out_ref[...] = -(ip / (qn * cn.T + 1e-12))
    else:
        raise ValueError(metric)


@functools.partial(jax.jit, static_argnames=("metric", "block_q", "block_c",
                                             "interpret"))
def pairwise_keys_pallas(queries: jnp.ndarray, corpus: jnp.ndarray,
                         metric: Metric, block_q: int = 128,
                         block_c: int = 512, interpret: bool = True):
    """(Qpad, Dpad), (Npad, Dpad) -> (Qpad, Npad) order-key matrix."""
    qn, d = queries.shape
    cn, d2 = corpus.shape
    assert d == d2 and qn % block_q == 0 and cn % block_c == 0
    kernel = functools.partial(_pairwise_kernel, metric=metric)
    return pl.pallas_call(
        kernel,
        grid=(qn // block_q, cn // block_c),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, cn), jnp.float32),
        interpret=interpret,
    )(queries, corpus)
