"""jit'd wrappers around the Pallas kernels (padding, two-stage merges,
and the public contracts the physical operators consume)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.schema import Metric
from .distance import pairwise_keys_pallas
from .range_scan import range_scan_pallas
from .scan_topk import scan_topk_pallas

LANE = 128


def _pad_dim(x: jnp.ndarray, mult: int, axis: int, value=0.0) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("k", "metric", "block_n",
                                             "interpret"))
def fused_scan_topk(corpus: jnp.ndarray, query: jnp.ndarray, k: int,
                    row_mask: jnp.ndarray | None, metric: Metric,
                    block_n: int = 1024, interpret: bool = True):
    """Drop-in fused replacement for FlatIndex.topk.

    Returns (ids (k,), sims raw-metric (k,), valid (k,)).  Zero-padding on D
    is metric-safe (contributes 0 to IP, 0 to L2 on both operands); padding on
    N is masked out."""
    n, d = corpus.shape
    block_n = min(block_n, max(LANE, 1 << (n - 1).bit_length()))
    mask = jnp.ones((n,), jnp.bool_) if row_mask is None else row_mask
    cp = _pad_dim(_pad_dim(corpus.astype(jnp.float32), LANE, 1), block_n, 0)
    qp = _pad_dim(query.astype(jnp.float32).reshape(-1), LANE, 0)
    mp = _pad_dim(mask.astype(jnp.int8).reshape(-1, 1), block_n, 0, value=0)
    keys, ids = scan_topk_pallas(cp, qp, mp, k, metric, block_n=block_n,
                                 interpret=interpret)
    # stage 2: merge the (num_blocks, k) candidates
    flat_keys = keys.reshape(-1)
    flat_ids = ids.reshape(-1)
    neg, idx = jax.lax.top_k(-flat_keys, k)
    out_keys = -neg
    valid = jnp.isfinite(out_keys)
    out_ids = jnp.where(valid, flat_ids[idx], -1)
    sims = jnp.where(valid,
                     -out_keys if metric.is_similarity() else out_keys, 0.0)
    return out_ids, sims, valid


@functools.partial(jax.jit, static_argnames=("metric", "block_n", "interpret"))
def fused_range_scan(corpus: jnp.ndarray, query: jnp.ndarray, radius,
                     row_mask: jnp.ndarray | None, metric: Metric,
                     block_n: int = 1024, interpret: bool = True):
    """Drop-in fused replacement for FlatIndex.range_mask.

    Returns (hit (N,), raw sims (N,), count)."""
    from ..core.expr import order_key
    n, d = corpus.shape
    block_n = min(block_n, max(LANE, 1 << (n - 1).bit_length()))
    mask = jnp.ones((n,), jnp.bool_) if row_mask is None else row_mask
    cp = _pad_dim(_pad_dim(corpus.astype(jnp.float32), LANE, 1), block_n, 0)
    qp = _pad_dim(query.astype(jnp.float32).reshape(-1), LANE, 0)
    mp = _pad_dim(mask.astype(jnp.int8).reshape(-1, 1), block_n, 0, value=0)
    radius_key = order_key(metric, jnp.asarray(radius, jnp.float32))
    keys, hits, counts = range_scan_pallas(cp, qp, radius_key, mp, metric,
                                           block_n=block_n,
                                           interpret=interpret)
    keys = keys[:n, 0]
    hit = hits[:n, 0] != 0
    raw = jnp.where(hit, -keys if metric.is_similarity() else keys, 0.0)
    return hit, raw, jnp.sum(counts)


@functools.partial(jax.jit, static_argnames=("metric", "block_q", "block_c",
                                             "interpret"))
def pairwise_keys(queries: jnp.ndarray, corpus: jnp.ndarray, metric: Metric,
                  block_q: int = 128, block_c: int = 512,
                  interpret: bool = True):
    """(Q, N) order-key matrix (padded internally, cropped on return)."""
    qn, d = queries.shape
    cn = corpus.shape[0]
    bq = min(block_q, max(8, 1 << (qn - 1).bit_length()))
    bc = min(block_c, max(LANE, 1 << (cn - 1).bit_length()))
    qp = _pad_dim(_pad_dim(queries.astype(jnp.float32), LANE, 1), bq, 0)
    cp = _pad_dim(_pad_dim(corpus.astype(jnp.float32), LANE, 1), bc, 0)
    out = pairwise_keys_pallas(qp, cp, metric, block_q=bq, block_c=bc,
                               interpret=interpret)
    return out[:qn, :cn]
