"""jit'd wrappers around the Pallas kernels (padding, two-stage merges,
and the public contracts the physical operators consume)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.schema import Metric
from .distance import pairwise_keys_pallas
from .range_scan import range_scan_batch_pallas, range_scan_pallas
from .scan_topk import scan_topk_batch_pallas, scan_topk_pallas

LANE = 128


def default_interpret() -> bool:
    """Pallas interpret mode iff no accelerator backend is attached.

    TPU/GPU runs compile real Mosaic/Triton kernels; the CPU container (CI,
    laptops) transparently falls back to the interpreter — callers pass
    ``interpret=None`` and never thread the flag."""
    return jax.default_backend() == "cpu"


def _resolve_interpret(interpret) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def _pad_dim(x: jnp.ndarray, mult: int, axis: int, value=0.0) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _mask_nq_i8(row_mask: jnp.ndarray | None, n: int, qn: int,
                block_n: int, block_q: int) -> jnp.ndarray:
    """Normalize a mask (None | (N,) shared | (Q, N) per-query) to the padded
    (Npad, Qm) int8 layout the batched kernels consume (Qm ∈ {1, Qpad})."""
    if row_mask is None:
        m = jnp.ones((n, 1), jnp.int8)
    elif row_mask.ndim == 1:
        m = row_mask.astype(jnp.int8).reshape(n, 1)
    else:
        assert row_mask.shape == (qn, n), (row_mask.shape, qn, n)
        m = _pad_dim(row_mask.astype(jnp.int8).T, block_q, 1, value=0)
    return _pad_dim(m, block_n, 0, value=0)


def _block_sizes(n: int, qn: int, block_q: int, block_n: int):
    bn = min(block_n, max(LANE, 1 << (n - 1).bit_length()))
    bq = min(block_q, max(8, 1 << (qn - 1).bit_length()))
    return bq, bn


def _qvalid_row_i8(qvalid: jnp.ndarray | None, qn: int,
                   block_q: int) -> jnp.ndarray:
    """Normalize a per-query valid vector (None | (Q,) bool) to the padded
    (1, Qpad) int8 row the batched kernels AND into their mask layout.
    Query columns beyond Q (tile padding) are invalid either way."""
    if qvalid is None:
        row = jnp.ones((1, qn), jnp.int8)
    else:
        assert qvalid.shape == (qn,), (qvalid.shape, qn)
        row = qvalid.astype(jnp.int8).reshape(1, qn)
    return _pad_dim(row, block_q, 1, value=0)


@functools.partial(jax.jit, static_argnames=("k", "metric", "block_n",
                                             "interpret"))
def fused_scan_topk(corpus: jnp.ndarray, query: jnp.ndarray, k: int,
                    row_mask: jnp.ndarray | None, metric: Metric,
                    block_n: int = 1024, interpret: bool | None = None):
    """Drop-in fused replacement for FlatIndex.topk.

    Returns (ids (k,), sims raw-metric (k,), valid (k,)).  Zero-padding on D
    is metric-safe (contributes 0 to IP, 0 to L2 on both operands); padding on
    N is masked out."""
    interpret = _resolve_interpret(interpret)
    n, d = corpus.shape
    _, block_n = _block_sizes(n, 1, 1, block_n)
    mask = jnp.ones((n,), jnp.bool_) if row_mask is None else row_mask
    cp = _pad_dim(_pad_dim(corpus.astype(jnp.float32), LANE, 1), block_n, 0)
    qp = _pad_dim(query.astype(jnp.float32).reshape(-1), LANE, 0)
    mp = _pad_dim(mask.astype(jnp.int8).reshape(-1, 1), block_n, 0, value=0)
    keys, ids = scan_topk_pallas(cp, qp, mp, k, metric, block_n=block_n,
                                 interpret=interpret)
    # stage 2: merge the (num_blocks, k) candidates
    flat_keys = keys.reshape(-1)
    flat_ids = ids.reshape(-1)
    neg, idx = jax.lax.top_k(-flat_keys, k)
    out_keys = -neg
    valid = jnp.isfinite(out_keys)
    out_ids = jnp.where(valid, flat_ids[idx], -1)
    sims = jnp.where(valid,
                     -out_keys if metric.is_similarity() else out_keys, 0.0)
    return out_ids, sims, valid


@functools.partial(jax.jit, static_argnames=("metric", "block_n", "interpret"))
def fused_range_scan(corpus: jnp.ndarray, query: jnp.ndarray, radius,
                     row_mask: jnp.ndarray | None, metric: Metric,
                     block_n: int = 1024, interpret: bool | None = None):
    """Drop-in fused replacement for FlatIndex.range_mask.

    Returns (hit (N,), raw sims (N,), count)."""
    from ..core.expr import order_key
    interpret = _resolve_interpret(interpret)
    n, d = corpus.shape
    _, block_n = _block_sizes(n, 1, 1, block_n)
    mask = jnp.ones((n,), jnp.bool_) if row_mask is None else row_mask
    cp = _pad_dim(_pad_dim(corpus.astype(jnp.float32), LANE, 1), block_n, 0)
    qp = _pad_dim(query.astype(jnp.float32).reshape(-1), LANE, 0)
    mp = _pad_dim(mask.astype(jnp.int8).reshape(-1, 1), block_n, 0, value=0)
    radius_key = order_key(metric, jnp.asarray(radius, jnp.float32))
    keys, hits, counts = range_scan_pallas(cp, qp, radius_key, mp, metric,
                                           block_n=block_n,
                                           interpret=interpret)
    keys = keys[:n, 0]
    hit = hits[:n, 0] != 0
    raw = jnp.where(hit, -keys if metric.is_similarity() else keys, 0.0)
    return hit, raw, jnp.sum(counts)


@functools.partial(jax.jit, static_argnames=("metric", "block_q", "block_c",
                                             "interpret"))
def pairwise_keys(queries: jnp.ndarray, corpus: jnp.ndarray, metric: Metric,
                  block_q: int = 128, block_c: int = 512,
                  interpret: bool | None = None):
    """(Q, N) order-key matrix (padded internally, cropped on return)."""
    interpret = _resolve_interpret(interpret)
    qn, d = queries.shape
    cn = corpus.shape[0]
    bq, bc = _block_sizes(cn, qn, block_q, block_c)
    qp = _pad_dim(_pad_dim(queries.astype(jnp.float32), LANE, 1), bq, 0)
    cp = _pad_dim(_pad_dim(corpus.astype(jnp.float32), LANE, 1), bc, 0)
    out = pairwise_keys_pallas(qp, cp, metric, block_q=bq, block_c=bc,
                               interpret=interpret)
    return out[:qn, :cn]


@functools.partial(jax.jit, static_argnames=("k", "metric", "block_q",
                                             "block_n", "interpret"))
def fused_scan_topk_batch(corpus: jnp.ndarray, queries: jnp.ndarray, k: int,
                          row_mask: jnp.ndarray | None, metric: Metric,
                          block_q: int = 128, block_n: int = 1024,
                          interpret: bool | None = None,
                          qvalid: jnp.ndarray | None = None):
    """Batched fused scan+filter+top-k: Q queries in one kernel launch.

    ``queries`` is (Q, D); ``row_mask`` is None, a shared (N,) mask, or a
    per-query (Q, N) mask.  Each (q-block, n-block) grid cell runs ONE
    (BLOCK_N, D)·(D, BLOCK_Q) MXU matmul — the per-tile corpus read is
    amortized over BLOCK_Q queries instead of re-streamed per query.
    ``qvalid`` (None | (Q,) bool) marks size-bucket pad queries: an invalid
    query's column folds into the mask layout as a (1, Qpad) lane, so it
    emits no candidates (all ids -1).
    Returns (ids (Q, k), sims raw-metric (Q, k), valid (Q, k))."""
    interpret = _resolve_interpret(interpret)
    n, d = corpus.shape
    qn = queries.shape[0]
    bq, bn = _block_sizes(n, qn, block_q, block_n)
    cp = _pad_dim(_pad_dim(corpus.astype(jnp.float32), LANE, 1), bn, 0)
    qp = _pad_dim(_pad_dim(queries.astype(jnp.float32), LANE, 1), bq, 0)
    mp = _mask_nq_i8(row_mask, n, qn, bn, bq)
    qv = _qvalid_row_i8(qvalid, qn, bq)
    keys, ids = scan_topk_batch_pallas(cp, qp, mp, qv, k, metric, block_q=bq,
                                       block_n=bn, interpret=interpret)
    # stage 2: query-major layout, rebase local ids by n-block, merge per row
    num_n = cp.shape[0] // bn
    keys = keys.T                                               # (Qpad, nb*k)
    ids = ids.T
    base = (jnp.arange(num_n * k, dtype=jnp.int32) // k) * bn   # (num_n*k,)
    gids = jnp.where(ids >= 0, ids + base[None, :], -1)
    neg, idx = jax.lax.top_k(-keys, k)                          # row-wise
    out_keys = -neg
    valid = jnp.isfinite(out_keys)
    out_ids = jnp.where(valid, jnp.take_along_axis(gids, idx, axis=1), -1)
    sims = jnp.where(valid,
                     -out_keys if metric.is_similarity() else out_keys, 0.0)
    return out_ids[:qn], sims[:qn], valid[:qn]


@functools.partial(jax.jit, static_argnames=("metric", "block_q", "block_n",
                                             "interpret"))
def fused_range_scan_batch(corpus: jnp.ndarray, queries: jnp.ndarray, radius,
                           row_mask: jnp.ndarray | None, metric: Metric,
                           block_q: int = 128, block_n: int = 1024,
                           interpret: bool | None = None,
                           qvalid: jnp.ndarray | None = None):
    """Batched fused range scan. ``radius`` is a scalar or (Q,) raw values.

    ``qvalid`` (None | (Q,) bool) marks size-bucket pad queries: an invalid
    query registers no hits and a zero count.
    Returns (hit (Q, N), raw sims (Q, N), counts (Q,))."""
    from ..core.expr import order_key
    interpret = _resolve_interpret(interpret)
    n, d = corpus.shape
    qn = queries.shape[0]
    bq, bn = _block_sizes(n, qn, block_q, block_n)
    cp = _pad_dim(_pad_dim(corpus.astype(jnp.float32), LANE, 1), bn, 0)
    qp = _pad_dim(_pad_dim(queries.astype(jnp.float32), LANE, 1), bq, 0)
    mp = _mask_nq_i8(row_mask, n, qn, bn, bq)
    qv = _qvalid_row_i8(qvalid, qn, bq)
    rk = order_key(metric, jnp.broadcast_to(
        jnp.asarray(radius, jnp.float32), (qn,)))
    rk = _pad_dim(rk.reshape(1, qn), bq, 1, value=-jnp.inf)  # padded q: no hit
    keys, hits, counts = range_scan_batch_pallas(
        cp, qp, rk, mp, qv, metric, block_q=bq, block_n=bn,
        interpret=interpret)
    keys = keys[:n, :qn].T                                  # (Q, N)
    hit = hits[:n, :qn].T != 0
    raw = jnp.where(hit, -keys if metric.is_similarity() else keys, 0.0)
    return hit, raw, jnp.sum(counts, axis=0)[:qn]


@functools.partial(jax.jit, static_argnames=("metric", "capacity", "block_q",
                                             "block_n", "interpret"))
def fused_range_topk_batch(corpus: jnp.ndarray, queries: jnp.ndarray, radius,
                           row_mask: jnp.ndarray | None, metric: Metric,
                           capacity: int, block_q: int = 128,
                           block_n: int = 1024,
                           interpret: bool | None = None,
                           qvalid: jnp.ndarray | None = None):
    """Fused range scan + per-query compaction to a fixed result buffer.

    The join families' flat lowering: every (masked) left row is one lane of
    the query-tiled range kernel, and each lane's (N,) hit vector compacts to
    its best-``capacity`` results.  ``radius`` is a scalar or (Q,) raw metric
    values; ``row_mask`` follows the (Npad, Qm) normalization of
    :func:`fused_range_scan_batch` (None | shared (N,) | per-query (Q, N));
    ``qvalid`` (None | (Q,) bool) marks size-bucket pad queries (no hits).
    Ordering policy: ascending order key (best first; the IVF range probes
    instead emit probe-discovery order).  Returns (ids (Q, capacity), sims
    raw-metric, valid (Q, capacity), count (Q,) total hits before
    truncation)."""
    from ..core.expr import order_key
    hit, raw, counts = fused_range_scan_batch(
        corpus, queries, radius, row_mask, metric, block_q=block_q,
        block_n=block_n, interpret=interpret, qvalid=qvalid)
    keys = jnp.where(hit, order_key(metric, raw), jnp.inf)
    neg, sel = jax.lax.top_k(-keys, capacity)                # row-wise
    valid = jnp.isfinite(-neg)
    ids = jnp.where(valid, sel.astype(jnp.int32), -1)
    sims = jnp.where(valid, jnp.take_along_axis(raw, sel, axis=1), 0.0)
    return ids, sims, valid, counts
