"""Pallas TPU kernel: fused distance + threshold + predicate range scan.

DR-SF hot path (§5.2): one pass computes order keys on the MXU, applies the
radius test and the structured-filter mask in-register, and emits a compact
per-block hit count plus masked keys.  The (data-dependent) compaction happens
outside the kernel; what the kernel saves is the materialization of raw
scores + a second filtering pass — the paper's fusion argument applied to
Algorithm 1's inner loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.schema import Metric
from .scan_topk import _keys_from_block, _keys_from_block_batch

INF = float("inf")


def _range_kernel(q_ref, r_ref, c_ref, m_ref, keys_out, hits_out, cnt_out, *,
                  metric: Metric):
    block = c_ref[...].astype(jnp.float32)          # (B, D)
    q = q_ref[...].astype(jnp.float32)              # (1, D)
    radius_key = r_ref[0, 0]
    keys = _keys_from_block(block, q, metric)       # (B, 1)
    mask = m_ref[...] != 0                          # (B, 1)
    hit = mask & (keys <= radius_key)
    keys_out[...] = jnp.where(hit, keys, INF)
    hits_out[...] = hit.astype(jnp.int8)
    cnt_out[...] = jnp.sum(hit.astype(jnp.int32), axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("metric", "block_n", "interpret"))
def range_scan_pallas(corpus: jnp.ndarray, query: jnp.ndarray,
                      radius_key: jnp.ndarray, mask_i8: jnp.ndarray,
                      metric: Metric, block_n: int = 1024,
                      interpret: bool = True):
    """Fused range scan. Returns ((Npad,1) masked keys, (Npad,1) int8 hits,
    (num_blocks,1) per-block hit counts)."""
    n, d = corpus.shape
    assert n % block_n == 0
    num_blocks = n // block_n
    q2 = query.reshape(1, d)
    r2 = jnp.asarray(radius_key, jnp.float32).reshape(1, 1)
    kernel = functools.partial(_range_kernel, metric=metric)
    keys, hits, counts = pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int8),
            jax.ShapeDtypeStruct((num_blocks, 1), jnp.int32),
        ],
        interpret=interpret,
    )(q2, r2, corpus, mask_i8)
    return keys, hits, counts


def _range_batch_kernel(q_ref, r_ref, qv_ref, c_ref, m_ref, keys_out,
                        hits_out, cnt_out, *, metric: Metric):
    """Grid (num_q_blocks, num_n_blocks): one corpus-tile matmul amortized
    over the query tile; per-query radius row; per-(tile, query) hit counts.

    ``qv_ref`` is the (1, BLOCK_Q) per-query valid row (size-bucket padding):
    a pad query's column registers no hits and a zero count, without
    materializing a (N, Q) mask when the row mask is shared."""
    block = c_ref[...].astype(jnp.float32)               # (B, D)
    qs = q_ref[...].astype(jnp.float32)                  # (BQ, D)
    radius_row = r_ref[...]                              # (1, BQ)
    keys = _keys_from_block_batch(block, qs, metric)     # (B, BQ)
    mask = (m_ref[...] != 0) & (qv_ref[...] != 0)        # (B, BQ) or (B, 1)
    hit = mask & (keys <= radius_row)
    keys_out[...] = jnp.where(hit, keys, INF)
    hits_out[...] = hit.astype(jnp.int8)
    cnt_out[...] = jnp.sum(hit.astype(jnp.int32), axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("metric", "block_q", "block_n",
                                             "interpret"))
def range_scan_batch_pallas(corpus: jnp.ndarray, queries: jnp.ndarray,
                            radius_keys: jnp.ndarray, mask_i8: jnp.ndarray,
                            qvalid_i8: jnp.ndarray,
                            metric: Metric, block_q: int = 128,
                            block_n: int = 1024, interpret: bool = True):
    """Query-tiled fused range scan.

    Inputs pre-padded: corpus (Npad, Dpad), queries (Qpad, Dpad),
    radius_keys (1, Qpad) order keys, mask (Npad, Qm) int8, Qm ∈ {1, Qpad},
    qvalid (1, Qpad) int8 — the per-query valid lane for size-bucket padding.
    Returns ((Npad, Qpad) masked keys, (Npad, Qpad) int8 hits,
    (num_n_blocks, Qpad) per-block per-query hit counts)."""
    n, d = corpus.shape
    qn = queries.shape[0]
    assert n % block_n == 0 and qn % block_q == 0
    assert qvalid_i8.shape == (1, qn), (qvalid_i8.shape, qn)
    num_n = n // block_n
    num_q = qn // block_q
    per_query_mask = mask_i8.shape[1] != 1
    mspec = (pl.BlockSpec((block_n, block_q), lambda i, j: (j, i))
             if per_query_mask
             else pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)))
    kernel = functools.partial(_range_batch_kernel, metric=metric)
    keys, hits, counts = pl.pallas_call(
        kernel,
        grid=(num_q, num_n),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (0, i)),
            pl.BlockSpec((1, block_q), lambda i, j: (0, i)),  # q-valid row
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            mspec,
        ],
        out_specs=[
            pl.BlockSpec((block_n, block_q), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, block_q), lambda i, j: (j, i)),
            pl.BlockSpec((1, block_q), lambda i, j: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, qn), jnp.float32),
            jax.ShapeDtypeStruct((n, qn), jnp.int8),
            jax.ShapeDtypeStruct((num_n, qn), jnp.int32),
        ],
        interpret=interpret,
    )(queries, radius_keys, qvalid_i8, corpus, mask_i8)
    return keys, hits, counts
