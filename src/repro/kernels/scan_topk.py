"""Pallas TPU kernel: fused distance + predicate filter + blockwise top-k.

This is the compute hot-spot CHASE optimizes (the map-operator fusion, §5.1):
one pass over the corpus computes similarities on the MXU, applies the
structured-filter mask in-register, and maintains top-k candidates — the full
(N,) score vector is never materialized to HBM, and nothing downstream ever
recomputes a distance.

TPU shape discipline:
* corpus tiles (BLOCK_N, D) stream HBM→VMEM via BlockSpec; D padded to a
  lane multiple (128) by the wrapper.
* the query lives in VMEM as (1, D); scores come from a (BLOCK_N, D)·(D, 1)
  MXU matmul with fp32 accumulation (preferred_element_type).
* per-block top-k runs as a k-step extract-min loop on (BLOCK_N, 1) column
  vectors — small-k selection is VPU-friendly; no unsupported `top_k` inside
  Mosaic.  A second-stage `lax.top_k` over (num_blocks × k) candidates runs
  outside the kernel (standard two-stage TPU top-k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.schema import Metric

INF = float("inf")  # python literal: safe inside kernel bodies (no captured consts)


def _extract_topk(keys_col: jnp.ndarray, ids_col: jnp.ndarray, k: int):
    """(B,1) masked keys + ids -> (1,k) smallest keys and their ids.

    k-step extract-min with where-based dynamic updates (Mosaic-safe: no
    gathers, no dynamic-slice on vectors)."""
    b = keys_col.shape[0]
    iota_col = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)
    iota_row = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

    def body(j, carry):
        vals, out_keys, out_ids = carry
        m = jnp.min(vals)
        # first index attaining the min (ties broken low)
        idxv = jnp.min(jnp.where(vals == m, iota_col, b))
        sel = iota_col == idxv
        picked_id = jnp.max(jnp.where(sel, ids_col, -2147483648))
        keep = jnp.isfinite(m)
        out_keys = jnp.where(iota_row == j, jnp.where(keep, m, INF), out_keys)
        out_ids = jnp.where(iota_row == j,
                            jnp.where(keep, picked_id, -1), out_ids)
        vals = jnp.where(sel, INF, vals)
        return vals, out_keys, out_ids

    init = (keys_col, jnp.full((1, k), INF), jnp.full((1, k), -1, jnp.int32))
    _, out_keys, out_ids = jax.lax.fori_loop(0, k, body, init)
    return out_keys, out_ids


def _keys_from_block(block: jnp.ndarray, q: jnp.ndarray,
                     metric: Metric) -> jnp.ndarray:
    """(B,D),(1,D) -> (B,1) order keys. MXU matmul + metric epilogue."""
    ip = jnp.dot(block, q.T, preferred_element_type=jnp.float32)  # (B,1)
    if metric == Metric.INNER_PRODUCT:
        return -ip
    if metric == Metric.L2:
        b2 = jnp.sum(block * block, axis=1, keepdims=True)
        q2 = jnp.sum(q * q, axis=1, keepdims=True)  # (1,1)
        return b2 - 2.0 * ip + q2
    if metric == Metric.COSINE:
        bn = jnp.sqrt(jnp.sum(block * block, axis=1, keepdims=True))
        qn = jnp.sqrt(jnp.sum(q * q, axis=1, keepdims=True))
        return -(ip / (bn * qn + 1e-12))
    raise ValueError(metric)


def _scan_topk_kernel(q_ref, c_ref, m_ref, keys_out, ids_out, *,
                      k: int, block_n: int, metric: Metric):
    i = pl.program_id(0)
    block = c_ref[...].astype(jnp.float32)          # (B, D)
    q = q_ref[...].astype(jnp.float32)              # (1, D)
    keys = _keys_from_block(block, q, metric)       # (B, 1)
    mask = m_ref[...]                               # (B, 1) int8 validity
    keys = jnp.where(mask != 0, keys, INF)
    base = (i * block_n).astype(jnp.int32)
    ids_col = base + jax.lax.broadcasted_iota(jnp.int32, (block_n, 1), 0)
    out_keys, out_ids = _extract_topk(keys, ids_col, k)
    keys_out[...] = out_keys
    ids_out[...] = out_ids


@functools.partial(jax.jit,
                   static_argnames=("k", "metric", "block_n", "interpret"))
def scan_topk_pallas(corpus: jnp.ndarray, query: jnp.ndarray,
                     mask_i8: jnp.ndarray, k: int, metric: Metric,
                     block_n: int = 1024, interpret: bool = True):
    """Stage 1 (Pallas): per-block fused top-k candidates.

    Inputs are pre-padded by ops.py: corpus (Npad, Dpad), mask (Npad, 1) int8.
    Returns (num_blocks, k) keys and ids."""
    n, d = corpus.shape
    assert n % block_n == 0, (n, block_n)
    num_blocks = n // block_n
    q2 = query.reshape(1, d)
    kernel = functools.partial(_scan_topk_kernel, k=k, block_n=block_n,
                               metric=metric)
    keys, ids = pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),          # query
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),    # corpus tile
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),    # mask tile
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_blocks, k), jnp.float32),
            jax.ShapeDtypeStruct((num_blocks, k), jnp.int32),
        ],
        interpret=interpret,
    )(q2, corpus, mask_i8)
    return keys, ids
