"""Pallas TPU kernel: fused distance + predicate filter + blockwise top-k.

This is the compute hot-spot CHASE optimizes (the map-operator fusion, §5.1):
one pass over the corpus computes similarities on the MXU, applies the
structured-filter mask in-register, and maintains top-k candidates — the full
(N,) score vector is never materialized to HBM, and nothing downstream ever
recomputes a distance.

TPU shape discipline:
* corpus tiles (BLOCK_N, D) stream HBM→VMEM via BlockSpec; D padded to a
  lane multiple (128) by the wrapper.
* the query lives in VMEM as (1, D); scores come from a (BLOCK_N, D)·(D, 1)
  MXU matmul with fp32 accumulation (preferred_element_type).
* per-block top-k runs as a k-step extract-min loop on (BLOCK_N, 1) column
  vectors — small-k selection is VPU-friendly; no unsupported `top_k` inside
  Mosaic.  A second-stage `lax.top_k` over (num_blocks × k) candidates runs
  outside the kernel (standard two-stage TPU top-k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.schema import Metric

INF = float("inf")  # python literal: safe inside kernel bodies (no captured consts)


def _extract_topk(keys_col: jnp.ndarray, ids_col: jnp.ndarray, k: int):
    """(B,1) masked keys + ids -> (1,k) smallest keys and their ids.

    k-step extract-min with where-based dynamic updates (Mosaic-safe: no
    gathers, no dynamic-slice on vectors)."""
    b = keys_col.shape[0]
    iota_col = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)
    iota_row = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

    def body(j, carry):
        vals, out_keys, out_ids = carry
        m = jnp.min(vals)
        # first index attaining the min (ties broken low)
        idxv = jnp.min(jnp.where(vals == m, iota_col, b))
        sel = iota_col == idxv
        picked_id = jnp.max(jnp.where(sel, ids_col, -2147483648))
        keep = jnp.isfinite(m)
        out_keys = jnp.where(iota_row == j, jnp.where(keep, m, INF), out_keys)
        out_ids = jnp.where(iota_row == j,
                            jnp.where(keep, picked_id, -1), out_ids)
        vals = jnp.where(sel, INF, vals)
        return vals, out_keys, out_ids

    init = (keys_col, jnp.full((1, k), INF), jnp.full((1, k), -1, jnp.int32))
    _, out_keys, out_ids = jax.lax.fori_loop(0, k, body, init)
    return out_keys, out_ids


def _keys_from_block(block: jnp.ndarray, q: jnp.ndarray,
                     metric: Metric) -> jnp.ndarray:
    """(B,D),(1,D) -> (B,1) order keys. MXU matmul + metric epilogue."""
    ip = jnp.dot(block, q.T, preferred_element_type=jnp.float32)  # (B,1)
    if metric == Metric.INNER_PRODUCT:
        return -ip
    if metric == Metric.L2:
        b2 = jnp.sum(block * block, axis=1, keepdims=True)
        q2 = jnp.sum(q * q, axis=1, keepdims=True)  # (1,1)
        return b2 - 2.0 * ip + q2
    if metric == Metric.COSINE:
        bn = jnp.sqrt(jnp.sum(block * block, axis=1, keepdims=True))
        qn = jnp.sqrt(jnp.sum(q * q, axis=1, keepdims=True))
        return -(ip / (bn * qn + 1e-12))
    raise ValueError(metric)


def _sq_rowvec(x: jnp.ndarray) -> jnp.ndarray:
    """(BQ, D) -> (1, BQ) per-row squared norms, via a dot-general contraction
    (no vector transpose/relayout inside Mosaic)."""
    ones = jnp.ones((1, x.shape[1]), jnp.float32)
    return jax.lax.dot_general(ones, x * x, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _keys_from_block_batch(block: jnp.ndarray, qs: jnp.ndarray,
                           metric: Metric) -> jnp.ndarray:
    """(B,D),(BQ,D) -> (B,BQ) order keys. One MXU matmul per corpus tile
    amortized over the whole query tile — the batched-execution hot loop.

    ``block`` may arrive in bf16 (the quantized kernels stream the bf16
    twin MXU-native — DESIGN.md §13): the contraction accumulates in fp32
    via ``preferred_element_type``, and the norm epilogues widen first.
    bf16 -> fp32 conversion is exact, so both are bitwise identical to a
    pre-widened block (and a no-op for fp32 callers)."""
    ip = jax.lax.dot_general(block, qs, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (B, BQ)
    if metric == Metric.INNER_PRODUCT:
        return -ip
    blk = block.astype(jnp.float32)
    if metric == Metric.L2:
        b2 = jnp.sum(blk * blk, axis=1, keepdims=True)       # (B, 1)
        q2 = _sq_rowvec(qs)                                  # (1, BQ)
        return b2 - 2.0 * ip + q2
    if metric == Metric.COSINE:
        bn = jnp.sqrt(jnp.sum(blk * blk, axis=1, keepdims=True))
        qn = jnp.sqrt(_sq_rowvec(qs))
        return -(ip / (bn * qn + 1e-12))
    raise ValueError(metric)


def _extract_topk_cols(keys_bq: jnp.ndarray, k: int):
    """(B, BQ) masked keys -> ((k, BQ) smallest keys, (k, BQ) row indices).

    Column-parallel k-step extract-min: every iteration selects one row per
    query column with 6 full-size array passes (min, eq, tie-break where/min,
    select, invalidate) and updates the small (k, BQ) outputs in place — no
    vector transposes, no gathers, per-column state stays in the (1, BQ)
    lane layout throughout (Mosaic-safe).  Invalid (all-INF) columns emit
    INF keys and -1 ids."""
    b, bq = keys_bq.shape
    iota_col = jax.lax.broadcasted_iota(jnp.int32, (b, bq), 0)
    iota_kq = jax.lax.broadcasted_iota(jnp.int32, (k, bq), 0)

    def body(j, carry):
        vals, out_keys, out_ids = carry
        m = jnp.min(vals, axis=0, keepdims=True)                    # (1, BQ)
        idxv = jnp.min(jnp.where(vals == m, iota_col, b), axis=0,
                       keepdims=True)                               # (1, BQ)
        sel = iota_col == idxv
        keep = jnp.isfinite(m)                                      # (1, BQ)
        out_keys = jnp.where(iota_kq == j, jnp.where(keep, m, INF), out_keys)
        out_ids = jnp.where(iota_kq == j, jnp.where(keep, idxv, -1), out_ids)
        vals = jnp.where(sel, INF, vals)
        return vals, out_keys, out_ids

    init = (keys_bq, jnp.full((k, bq), INF),
            jnp.full((k, bq), -1, jnp.int32))
    _, out_keys, out_ids = jax.lax.fori_loop(0, k, body, init)
    return out_keys, out_ids


def _scan_topk_batch_kernel(q_ref, qv_ref, c_ref, m_ref, keys_out, ids_out, *,
                            k: int, metric: Metric):
    """Grid (num_q_blocks, num_n_blocks): one (BLOCK_N, D)·(D, BLOCK_Q) MXU
    matmul per tile, per-query in-register top-k.  Emits (k, BLOCK_Q) blocks
    of LOCAL row indices; the wrapper rebases by n-block and transposes.

    ``qv_ref`` is the (1, BLOCK_Q) per-query valid row (size-bucket padding):
    it folds into the mask layout, so a pad query's column is all-INF and
    emits no candidates — without materializing a (N, Q) mask when the row
    mask is shared."""
    block = c_ref[...].astype(jnp.float32)               # (B, D)
    qs = q_ref[...].astype(jnp.float32)                  # (BQ, D)
    keys = _keys_from_block_batch(block, qs, metric)     # (B, BQ)
    mask = m_ref[...]                                    # (B, BQ) or (B, 1)
    live = (mask != 0) & (qv_ref[...] != 0)              # broadcasts (1, BQ)
    keys = jnp.where(live, keys, INF)
    out_keys, out_ids = _extract_topk_cols(keys, k)      # (k, BQ) each
    keys_out[...] = out_keys
    ids_out[...] = out_ids


@functools.partial(jax.jit,
                   static_argnames=("k", "metric", "block_q", "block_n",
                                    "interpret"))
def scan_topk_batch_pallas(corpus: jnp.ndarray, queries: jnp.ndarray,
                           mask_i8: jnp.ndarray, qvalid_i8: jnp.ndarray,
                           k: int, metric: Metric,
                           block_q: int = 128, block_n: int = 1024,
                           interpret: bool = True):
    """Stage 1 (Pallas), query-tiled: per (q-block, n-block) top-k candidates.

    Inputs are pre-padded by ops.py: corpus (Npad, Dpad), queries (Qpad, Dpad),
    mask (Npad, Qm) int8 with Qm ∈ {1, Qpad} (shared vs per-query masks), and
    qvalid (1, Qpad) int8 — the per-query valid lane for size-bucket padding.
    Returns (num_n_blocks*k, Qpad) keys and LOCAL ids (kernel-native layout;
    ops.py rebases ids by n-block and transposes to query-major)."""
    n, d = corpus.shape
    qn = queries.shape[0]
    assert n % block_n == 0 and qn % block_q == 0, (n, block_n, qn, block_q)
    assert qvalid_i8.shape == (1, qn), (qvalid_i8.shape, qn)
    num_n = n // block_n
    num_q = qn // block_q
    per_query_mask = mask_i8.shape[1] != 1
    mspec = (pl.BlockSpec((block_n, block_q), lambda i, j: (j, i))
             if per_query_mask
             else pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)))
    kernel = functools.partial(_scan_topk_batch_kernel, k=k, metric=metric)
    keys, ids = pl.pallas_call(
        kernel,
        grid=(num_q, num_n),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),   # query tile
            pl.BlockSpec((1, block_q), lambda i, j: (0, i)),   # q-valid row
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),   # corpus tile
            mspec,                                             # mask tile
        ],
        out_specs=[
            pl.BlockSpec((k, block_q), lambda i, j: (j, i)),
            pl.BlockSpec((k, block_q), lambda i, j: (j, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_n * k, qn), jnp.float32),
            jax.ShapeDtypeStruct((num_n * k, qn), jnp.int32),
        ],
        interpret=interpret,
    )(queries, qvalid_i8, corpus, mask_i8)
    return keys, ids


def _scan_topk_kernel(q_ref, c_ref, m_ref, keys_out, ids_out, *,
                      k: int, block_n: int, metric: Metric):
    i = pl.program_id(0)
    block = c_ref[...].astype(jnp.float32)          # (B, D)
    q = q_ref[...].astype(jnp.float32)              # (1, D)
    keys = _keys_from_block(block, q, metric)       # (B, 1)
    mask = m_ref[...]                               # (B, 1) int8 validity
    keys = jnp.where(mask != 0, keys, INF)
    base = (i * block_n).astype(jnp.int32)
    ids_col = base + jax.lax.broadcasted_iota(jnp.int32, (block_n, 1), 0)
    out_keys, out_ids = _extract_topk(keys, ids_col, k)
    keys_out[...] = out_keys
    ids_out[...] = out_ids


@functools.partial(jax.jit,
                   static_argnames=("k", "metric", "block_n", "interpret"))
def scan_topk_pallas(corpus: jnp.ndarray, query: jnp.ndarray,
                     mask_i8: jnp.ndarray, k: int, metric: Metric,
                     block_n: int = 1024, interpret: bool = True):
    """Stage 1 (Pallas): per-block fused top-k candidates.

    Inputs are pre-padded by ops.py: corpus (Npad, Dpad), mask (Npad, 1) int8.
    Returns (num_blocks, k) keys and ids."""
    n, d = corpus.shape
    assert n % block_n == 0, (n, block_n)
    num_blocks = n // block_n
    q2 = query.reshape(1, d)
    kernel = functools.partial(_scan_topk_kernel, k=k, block_n=block_n,
                               metric=metric)
    keys, ids = pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),          # query
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),    # corpus tile
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),    # mask tile
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_blocks, k), jnp.float32),
            jax.ShapeDtypeStruct((num_blocks, k), jnp.int32),
        ],
        interpret=interpret,
    )(q2, corpus, mask_i8)
    return keys, ids
