"""Pallas TPU kernels for CHASE's compute hot-spots.

Layout per the kernels contract:
* ``scan_topk.py`` / ``range_scan.py`` / ``distance.py`` — pl.pallas_call
  bodies with explicit BlockSpec VMEM tiling,
* ``quant.py`` — int8/bf16 quantized scan kernels + fused fp32 rescore,
* ``ops.py``  — jit'd public wrappers (padding, two-stage merges),
* ``ref.py``  — pure-jnp oracles used by the allclose test sweeps.
"""
from .ops import (default_interpret, fused_range_scan, fused_range_scan_batch,
                  fused_scan_topk, fused_scan_topk_batch, pairwise_keys)
from .quant import fused_range_topk_batch_q, fused_scan_topk_batch_q

__all__ = ["default_interpret", "fused_range_scan", "fused_range_scan_batch",
           "fused_range_topk_batch_q", "fused_scan_topk", "fused_scan_topk_batch",
           "fused_scan_topk_batch_q", "pairwise_keys"]
