"""Physical operators + engine modes (CHASE §5) and their lowering to JAX.

Each builder returns a pure function ``fn(arrays, binds) -> outputs`` that the
compiler jits — the data-centric codegen step (§6): one XLA computation per
pipeline, no operator boundaries at runtime.

Engine modes reproduce the paper's comparison systems *as query plans* (the
inefficiencies are plan-structural, so they are faithfully reproducible):

* ``chase``  — rewritten plan: fused predicate probes, similarity from the
               scan reused by sort/rank (map operator), updateState early stop.
* ``vbase``  — incremental ANN probes (relaxed monotonicity) but similarity is
               RECOMPUTED by the sort operator above the scan (Fig. 1c), and
               structured filtering happens between scan and sort.
* ``pase``   — K' = oversample·K unfiltered ANN fetch, post-filter, no
               re-sort needed (index order) but heavy redundant compute and
               recall loss under selective filters (Fig. 1b).
* ``brute``  — compiled, fused, index-less full scan (the LingoDB-V analogue).

For window families (Q4-Q6) the paper's baselines cannot use the ANN index at
all (§2.4); their mode falls back to the brute plan of Fig. 5a (per-partition
full sort), which we also lower faithfully (``brute_sort``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..index.flat import FlatIndex, masked_topk
from ..index.ivf import (IVFIndex, ProbeConfig, ivf_range, ivf_range_batch,
                         ivf_range_category, ivf_range_category_batch,
                         ivf_topk, ivf_topk_batch)
from .expr import (Bindings, Column, Const, Cmp, BoolOp, Arith, Distance,
                   Expr, Param, distance_values, evaluate, in_range, order_key)
from .schema import Catalog, ColumnKind, Metric, Table
from .semantics import Analysis, QueryClass


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """Compile-time engine configuration (every field shapes compilation;
    see :meth:`fingerprint`).  ``engine`` selects the plan shape of one of
    the paper's comparison systems (module docstring)."""
    engine: str = "chase"          # chase | vbase | pase | brute | brute_sort
    # default_factory, NOT a shared ProbeConfig() instance: a class-level
    # default dataclass would be one object aliased across every
    # EngineOptions ever constructed (both frozen, so mutation can't bite
    # today — but identity-based caches and dataclasses.replace patterns
    # must never observe cross-caller sharing).
    probe: ProbeConfig = dataclasses.field(default_factory=ProbeConfig)
    pase_oversample: int = 10      # K' = oversample * K
    use_pallas: bool = False       # fused Pallas kernel for flat scans
    max_pairs: int = 512           # per-left-row buffer for join families
    # None -> kernels.default_interpret(): interpret on CPU, compiled Mosaic
    # kernels on TPU/GPU, without callers threading the flag.
    interpret_pallas: bool | None = None
    # Q3-Q6 physical lowering: 'batch' treats the left rows as ONE query
    # batch on the batched kernels/probes (DESIGN.md §7); 'perleft' keeps the
    # legacy per-left-row scan loop (and forces the vmap-of-scalar
    # execute_batch fallback) — the measured baseline in benchmarks/q34.
    join_lowering: str = "batch"   # batch | perleft
    # Multi-device sharded scan (DESIGN.md §10): a
    # repro.dist.sharding.DistSpec row-shards the scanned corpus over its
    # mesh and lowers EVERY query class onto the distributed fused flat
    # scan (shard rows x tile queries + hierarchical per-query merge).
    # Fingerprint-affecting: a mesh change misses the plan cache.  Exact —
    # index probes are bypassed (a row-sharded corpus has no co-sharded IVF
    # gather yet), so only engines 'chase' and 'brute' compose with it.
    dist: "DistSpec | None" = None
    # Quantized corpus scan (DESIGN.md §13): stream the int8 (per-row
    # symmetric scale) or bf16 twin of the scanned column through the
    # quantized Pallas kernels and re-rank the top-(rescore_factor·K)
    # candidates against the fp32 originals — 4×/2× fewer corpus bytes,
    # results bit-identical to the fp32 path.  Requires use_pallas; only
    # engines 'chase' and 'brute' compose (IVF probes stay fp32-exact —
    # their key-dependent early-stop would be perturbed by quantized
    # keys).  Fingerprint-affecting, like every field here.
    quant: str | None = None       # None | 'int8' | 'bf16'
    # Candidate multiple c for the fused fp32 rescore: the quantized scan
    # keeps c·K candidates per query (c·capacity boundary rows for range).
    # 2 is bit-exact on every parity suite; raise for adversarial
    # near-tie corpora (ExecutionHints.rescore_factor folds in here).
    rescore_factor: int = 2

    def fingerprint(self) -> str:
        """Stable serialization for the plan-cache key: every field shapes
        compilation, so any change must miss the cache.  Frozen dataclass
        repr covers all fields (including the nested ProbeConfig and the
        DistSpec mesh description)."""
        return repr(self)


def probe_ceiling(options: "EngineOptions") -> int:
    """Effective probe-budget ceiling of plans compiled under ``options`` —
    what the adaptive optimizer clamps predicted budgets to (DESIGN.md
    §14).  0 means the lowering has no probe lane: flat/brute scans and the
    sharded distributed scan execute in one pass, so a runtime
    ``probe_budget`` is inert and effort bucketing is pure overhead."""
    if options.engine not in ("chase", "vbase", "pase"):
        return 0
    if options.dist is not None:
        return 0
    return int(options.probe.max_probes)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _metric_of(catalog: Catalog, table: str, column: str) -> Metric:
    return catalog.table(table).schema[column].metric


def _static_int(v, binds: Bindings, what: str) -> int:
    if isinstance(v, int):
        return v
    if isinstance(v, str) and v in binds:
        return int(binds[v])
    raise ValueError(f"{what} must be statically resolvable, got {v!r}")


def _row_mask_fn(pred: Expr | None, table: Table):
    """Predicate -> (binds -> (N,) bool) or None."""
    if pred is None:
        return None

    def fn(binds: Bindings) -> jnp.ndarray:
        return evaluate(pred, table, binds)

    return fn


def _owner_fn(ltab: Table, rtab: Table, lalias: str | None,
              ralias: str | None):
    def owner(col: Column) -> str:
        if col.table in (lalias, ltab.name):
            return "l"
        if col.table in (ralias, rtab.name):
            return "r"
        inl = col.name in ltab.schema
        inr = col.name in rtab.schema
        if inl and inr:
            raise ValueError(f"ambiguous column {col.name}")
        return "l" if inl else "r"

    return owner


def _eval_join_pred(pred: Expr, owner, ev_left, ev_right,
                    binds: Bindings) -> jnp.ndarray:
    """One interpreter for both join-mask lowerings; ``ev_left``/``ev_right``
    decide the column shape (scalar-at-lidx vs (L, 1) / (N,) vs (1, N))."""
    def ev(e: Expr):
        if isinstance(e, Column):
            return ev_left(e.name) if owner(e) == "l" else ev_right(e.name)
        if isinstance(e, Const):
            return jnp.asarray(e.value)
        if isinstance(e, Param):
            return jnp.asarray(binds[e.name])
        if isinstance(e, Cmp):
            lo, hi = ev(e.lhs), ev(e.rhs)
            return {"<": lambda: lo < hi, "<=": lambda: lo <= hi,
                    ">": lambda: lo > hi, ">=": lambda: lo >= hi,
                    "=": lambda: lo == hi, "<>": lambda: lo != hi}[e.op]()
        if isinstance(e, BoolOp):
            if e.op == "not":
                return ~ev(e.operands[0])
            vals = [ev(o) for o in e.operands]
            out = vals[0]
            for v in vals[1:]:
                out = (out & v) if e.op == "and" else (out | v)
            return out
        if isinstance(e, Arith):
            lo, hi = ev(e.lhs), ev(e.rhs)
            return {"+": lambda: lo + hi, "-": lambda: lo - hi,
                    "*": lambda: lo * hi, "/": lambda: lo / hi}[e.op]()
        raise TypeError(f"unsupported join-predicate node {type(e)}")

    return ev(pred)


def _join_mask_fn(pred: Expr | None, ltab: Table, rtab: Table,
                  lalias: str | None, ralias: str | None):
    """Residual join predicate -> (left_row_idx, binds) -> (Nright,) bool.

    Left columns resolve to scalars at ``left_row_idx`` (vmap lane), right
    columns to full arrays — the per-left-row filter of the KnnSubquery."""
    if pred is None:
        return None
    owner = _owner_fn(ltab, rtab, lalias, ralias)

    def fn(lidx, binds: Bindings) -> jnp.ndarray:
        m = _eval_join_pred(pred, owner,
                            lambda name: ltab[name][lidx],
                            lambda name: rtab[name], binds)
        return jnp.broadcast_to(m, (rtab.num_rows,))

    return fn


def _join_mask_batch_fn(pred: Expr | None, ltab: Table, rtab: Table,
                        lalias: str | None, ralias: str | None):
    """Residual join predicate -> (binds) -> (L, Nright) bool, ALL left rows.

    The batch-native twin of :func:`_join_mask_fn`: left columns evaluate as
    (L, 1), right columns as (1, N), and broadcasting produces every
    (left row, right row) pair's mask in one columnar pass — the (Q, N) mask
    layout the batched kernels/probes consume, with the left rows playing Q."""
    if pred is None:
        return None
    owner = _owner_fn(ltab, rtab, lalias, ralias)

    def fn(binds: Bindings) -> jnp.ndarray:
        m = _eval_join_pred(pred, owner,
                            lambda name: ltab[name][:, None],
                            lambda name: rtab[name][None, :], binds)
        return jnp.broadcast_to(m, (ltab.num_rows, rtab.num_rows))

    return fn


def _resort_redundant(metric: Metric, corpus, q, ids, valid, k):
    """VBASE's Fig.1c inefficiency: the sort operator recomputes
    vec <*> query for tuples the scan already scored."""
    safe = jnp.maximum(ids, 0)
    vecs = corpus[safe]
    raw = distance_values(metric, vecs, q)          # REDUNDANT distance evals
    keys = jnp.where(valid, order_key(metric, raw), jnp.inf)
    neg, idx = jax.lax.top_k(-keys, k)
    keys2 = -neg
    ids2 = ids[idx]
    valid2 = jnp.isfinite(keys2)
    sims = jnp.where(valid2, -keys2 if metric.is_similarity() else keys2, 0.0)
    return jnp.where(valid2, ids2, -1), sims, valid2


def _flat_topk(opts: EngineOptions, flat: FlatIndex, q, k, row_mask):
    if opts.use_pallas:
        from ..kernels.ops import fused_scan_topk
        return fused_scan_topk(flat.vectors, q, k, row_mask, flat.metric,
                               interpret=opts.interpret_pallas)
    return flat.topk(q, k, row_mask)


def _flat_topk_batch(opts: EngineOptions, arrays, metric: Metric, corpus,
                     qs, k: int, row_mask, qvalid=None):
    """Fused flat batched top-k; routes through the quantized lowering
    (DESIGN.md §13) when ``EngineOptions.quant`` is set — the quantized
    twin's arrays ride the plan's ``arrays`` dict (``qvecs``/``qscales``),
    so Catalog re-registrations re-bind with zero retraces."""
    if opts.quant is not None:
        from ..kernels.quant import fused_scan_topk_batch_q
        return fused_scan_topk_batch_q(
            corpus, arrays["qvecs"], arrays["qscales"], qs, k, row_mask,
            metric, rescore_factor=opts.rescore_factor,
            interpret=opts.interpret_pallas, qvalid=qvalid)
    from ..kernels.ops import fused_scan_topk_batch
    return fused_scan_topk_batch(corpus, qs, k, row_mask, metric,
                                 interpret=opts.interpret_pallas,
                                 qvalid=qvalid)


def _flat_evals(qvalid, m: int, n: int) -> jnp.ndarray:
    """Per-query flat-scan distance-eval counters; size-bucket pad queries
    (qvalid False) contribute zero."""
    evals = jnp.full((m,), n, jnp.int32)
    return evals if qvalid is None else jnp.where(qvalid, evals, 0)


def _flat_range_topk_batch(opts: EngineOptions, metric: Metric, corpus,
                           qs, radius, row_mask, capacity: int,
                           qvalid=None, arrays=None):
    """Flat range scan over a (M, d) query batch, compacted to ``capacity``.

    Dispatch: the quantized Pallas kernel (``opts.quant``, slack-band
    boundary rescore — needs the plan ``arrays`` for the quantized twin),
    the query-tiled fp32 Pallas kernel (``use_pallas``), or a vmapped
    exact scan.  ``radius`` is a scalar or (M,); ``row_mask`` None, shared
    (N,) (a live validity lane), or per-query (M, N);
    ``qvalid`` None or (M,) bool (size-bucket pad queries register no hits
    and zero counters).  Results are ordered best-first (ascending order
    key).  Returns (ids (M, P), sims, valid, count (M,), per-row stats) with
    P = min(capacity, N)."""
    m, n = qs.shape[0], corpus.shape[0]
    cap = min(int(capacity), n)
    radius = jnp.broadcast_to(jnp.asarray(radius, jnp.float32), (m,))
    if opts.use_pallas and opts.quant is not None:
        from ..kernels.quant import fused_range_topk_batch_q
        ids, sims, valid, count = fused_range_topk_batch_q(
            corpus, arrays["qvecs"], arrays["qscales"], arrays["qhalf"],
            arrays["ql1"], arrays["ql2"], qs, radius, row_mask, metric,
            cap, rescore_factor=opts.rescore_factor,
            interpret=opts.interpret_pallas, qvalid=qvalid)
    elif opts.use_pallas:
        from ..kernels.ops import fused_range_topk_batch
        ids, sims, valid, count = fused_range_topk_batch(
            corpus, qs, radius, row_mask, metric, cap,
            interpret=opts.interpret_pallas, qvalid=qvalid)
    else:
        flat = FlatIndex(metric, corpus)
        if row_mask is None:
            hit, raw = jax.vmap(lambda q, r: flat.range_mask(q, r, None))(
                qs, radius)
        elif row_mask.ndim == 1:
            hit, raw = jax.vmap(
                lambda q, r: flat.range_mask(q, r, row_mask))(qs, radius)
        else:
            hit, raw = jax.vmap(flat.range_mask)(qs, radius, row_mask)
        if qvalid is not None:
            hit = hit & qvalid[:, None]
        keys = jnp.where(hit, order_key(metric, raw), jnp.inf)
        neg, sel = jax.lax.top_k(-keys, cap)                   # row-wise
        valid = jnp.isfinite(-neg)
        ids = jnp.where(valid, sel.astype(jnp.int32), -1)
        sims = jnp.where(valid, jnp.take_along_axis(raw, sel, axis=1), 0.0)
        count = jnp.sum(hit, axis=1)
    stats = {"probes": jnp.zeros((m,), jnp.int32),
             "distance_evals": _flat_evals(qvalid, m, n)}
    return ids, sims, valid, count, stats


def _stacked_batch_size(binds: dict) -> int:
    """Leading Q axis of stacked binds (static at trace time)."""
    dims = [v.shape[0] for v in binds.values()
            if hasattr(v, "ndim") and v.ndim >= 1]
    if not dims:
        raise ValueError("batched join execution needs at least one stacked "
                         "bind to carry the batch size; use binds_list")
    return dims[0]


def _flatten_left_batch(lvec, binds: dict, mask_b):
    """(Q bind sets x L left rows) -> ONE kernel query batch.

    Replicates the (L, d) left block per bind set and evaluates the per-bind
    join masks into the flattened (Q·L, N) layout (q-major, matching
    ``reshape`` on the outputs).  On the flat path the replication recomputes
    (L, N) distances Q-fold — bind sets only vary radius/masks, applied
    post-matmul — acceptable for parameter batches (Q small); a
    share-the-matmul flat fast path is future work."""
    nleft, d = lvec.shape
    qn = _stacked_batch_size(binds)
    qs = jnp.broadcast_to(lvec[None], (qn, nleft, d)).reshape(-1, d)
    rm = (jax.vmap(mask_b)(binds).reshape(qn * nleft, -1)
          if mask_b else None)
    return qn, nleft, qs, rm


def _flatten_valid_budget(qvalid, probe_budget, qn: int, nleft: int):
    """Expand per-bind-set ``qvalid`` (Q,) and ``probe_budget`` (scalar |
    (Q,) | (Q, L)) to the flattened (Q·L,) query-batch layout."""
    fq = (None if qvalid is None
          else jnp.repeat(jnp.asarray(qvalid, jnp.bool_), nleft))
    if probe_budget is None:
        fb = None
    else:
        b = jnp.asarray(probe_budget, jnp.int32)
        if b.ndim == 1:
            b = b[:, None]
        fb = jnp.broadcast_to(b, (qn, nleft)).reshape(-1)
    return fq, fb


# ---------------------------------------------------------------------------
# Sharded lowering (DESIGN.md §10) — selected by EngineOptions.dist
# ---------------------------------------------------------------------------
#
# A DistSpec row-shards the scanned corpus over a device mesh; each device
# runs the query-tiled fused scan for ALL Q queries, then a hierarchical
# per-query merge (dist/collectives.py).  The lowering is EXACT and
# engine-independent: index probes are bypassed (a row-sharded corpus has no
# co-sharded IVF gather yet — ROADMAP item), so at shards=1 results are
# bit-identical to the single-device fused flat path (engine='brute',
# use_pallas=True) for every query class.  The q-valid lane threads through
# to every shard: a size-bucket pad query emits no candidates and zero
# counters on any device.


def _dist_mask(arrays, rm, per_query_mask: bool) -> jnp.ndarray:
    """Normalize the row mask to what the distributed collectives consume.

    With a per-query mask (``rm`` (Q, N), a plan with a row predicate) the
    divisibility-pad columns (beyond the real N — see
    ``ShardedCorpus.build``) pad False to (Q, Npad).  Without one, the
    shared (Npad,) ``row_ids >= 0`` mask excludes exactly the pad rows and
    no (Q, N) array is ever materialized — predicate-free scans at
    production N would otherwise ship Q·Npad mask bytes per batch."""
    if not per_query_mask:
        assert rm is None
        return arrays["drow_ids"] >= 0
    n = arrays["corpus"].shape[0]
    npad = arrays["dcorpus"].shape[0]
    m = rm.astype(jnp.bool_)
    if npad != n:
        m = jnp.pad(m, ((0, 0), (0, npad - n)), constant_values=False)
    return m


def _dist_qvalid(qvalid, qn: int) -> jnp.ndarray:
    """Materialize the per-query valid lane ((Q,) bool; None -> all valid)."""
    return (jnp.ones((qn,), jnp.bool_) if qvalid is None
            else jnp.asarray(qvalid, jnp.bool_))


def _dist_topk_core(opts: EngineOptions, metric: Metric, k: int,
                    per_query_mask: bool):
    """Build ``(arrays, qs, rm, qvalid) -> (ids, sims, valid, stats)``: the
    sharded twin of the fused flat top-k batch (exact; counters match the
    single-device flat path — N distance evals per valid query, 0 probes).
    ``per_query_mask`` is static per plan: whether this plan evaluates a
    row predicate into a (Q, N) mask (see :func:`_dist_mask`)."""
    from ..dist.collectives import (distributed_topk_batch,
                                    distributed_topk_batch_q)
    from ..dist.sharding import resolve_mesh
    spec = opts.dist
    if opts.quant is not None:
        dfn = distributed_topk_batch_q(resolve_mesh(spec), metric, k,
                                       spec.axes,
                                       interpret=opts.interpret_pallas,
                                       per_query_mask=per_query_mask,
                                       rescore_factor=opts.rescore_factor)
    else:
        dfn = distributed_topk_batch(resolve_mesh(spec), metric, k, spec.axes,
                                     interpret=opts.interpret_pallas,
                                     per_query_mask=per_query_mask)

    def run(arrays, qs, rm, qvalid=None):
        qn, n = qs.shape[0], arrays["corpus"].shape[0]
        mask = _dist_mask(arrays, rm, per_query_mask)
        qv = _dist_qvalid(qvalid, qn)
        if opts.quant is not None:
            ids, sims, valid = dfn(arrays["dcorpus"], arrays["dqvecs"],
                                   arrays["dqscales"], arrays["drow_ids"],
                                   qs, mask, qv)
        else:
            ids, sims, valid = dfn(arrays["dcorpus"], arrays["drow_ids"], qs,
                                   mask, qv)
        stats = {"probes": jnp.zeros((qn,), jnp.int32),
                 "distance_evals": _flat_evals(qvalid, qn, n)}
        return ids, sims, valid, stats

    return run


def _dist_range_core(opts: EngineOptions, metric: Metric, capacity: int,
                     n_rows: int, per_query_mask: bool):
    """Build ``(arrays, qs, radius, rm, qvalid) -> (ids, sims, valid, count,
    stats)``: the sharded twin of :func:`_flat_range_topk_batch`.  The
    result buffer is ``min(capacity, n_rows)`` wide regardless of shard
    count (per-shard buffers concatenate and re-truncate best-first at each
    merge level); ``count`` stays exact past truncation (psum of per-shard
    hit counts).  ``per_query_mask`` as in :func:`_dist_topk_core`."""
    from ..dist.collectives import (distributed_range_batch,
                                    distributed_range_batch_q)
    from ..dist.sharding import resolve_mesh
    spec = opts.dist
    cap = min(int(capacity), int(n_rows))
    if opts.quant is not None:
        dfn = distributed_range_batch_q(resolve_mesh(spec), metric, cap,
                                        spec.axes,
                                        interpret=opts.interpret_pallas,
                                        per_query_mask=per_query_mask,
                                        rescore_factor=opts.rescore_factor)
    else:
        dfn = distributed_range_batch(resolve_mesh(spec), metric, cap,
                                      spec.axes,
                                      interpret=opts.interpret_pallas,
                                      per_query_mask=per_query_mask)

    def run(arrays, qs, radius, rm, qvalid=None):
        qn, n = qs.shape[0], arrays["corpus"].shape[0]
        radius = jnp.broadcast_to(jnp.asarray(radius, jnp.float32), (qn,))
        mask = _dist_mask(arrays, rm, per_query_mask)
        qv = _dist_qvalid(qvalid, qn)
        if opts.quant is not None:
            ids, sims, valid, count = dfn(
                arrays["dcorpus"], arrays["dqvecs"], arrays["dqscales"],
                arrays["dqhalf"], arrays["dql1"], arrays["dql2"],
                arrays["drow_ids"], qs, radius, mask, qv)
        else:
            ids, sims, valid, count = dfn(arrays["dcorpus"],
                                          arrays["drow_ids"], qs, radius,
                                          mask, qv)
        stats = {"probes": jnp.zeros((qn,), jnp.int32),
                 "distance_evals": _flat_evals(qvalid, qn, n)}
        return ids, sims, valid, count, stats

    return run


# ---------------------------------------------------------------------------
# Live-corpus lowering (DESIGN.md §12) — selected by an attached LiveCorpus
# ---------------------------------------------------------------------------
#
# When catalog.live_for(scan table, scan column) is attached, the batched
# builders swap two things into the standard pipeline and leave everything
# else untouched:
#
# 1. Masks come from the LIVE arrays: the main-segment validity lane (the
#    tombstone bitmap) ANDed with the predicate evaluated over the live
#    scalar columns — the same (Q, N) row-mask layout every kernel and IVF
#    probe path already threads, so a tombstoned row is inert exactly the
#    way a pad row is.  The delta segment gets the same treatment at its
#    own width ((Q, delta_cap)).
# 2. After the main-segment result (IVF / flat / sharded — unchanged code),
#    the delta segment is scanned by the flat batched machinery and merged
#    in as one extra, device-local level of the hierarchical per-query
#    merge (index/delta.py + dist.collectives.merge_topk_level).  Merged
#    ids >= cap_main name delta slots (LiveCorpus.user_ids maps back).
#
# Live mode composes with the exact engines only (chase / brute — see
# compiler._validate_live); the single-query path reuses the batched
# lowering at Q=1 (compiler._single_via_batch), so no single builder needs
# a live branch.  NOTE on ordering: the delta merge re-sorts each query's
# buffer best-first, so live IVF range results are best-first even at zero
# deltas (fresh-attach live plans — the parity reference — share this code
# and therefore this order; frozen IVF plans keep probe-discovery order).


class _ColsTable:
    """Dict-of-arrays stand-in for :class:`Table` inside ``evaluate()``
    (expression evaluation only reads ``table[name]``), letting predicates
    run against the live segment columns without a frozen Table."""

    def __init__(self, cols: dict):
        self._cols = cols

    def __getitem__(self, name: str):
        return self._cols[name]


def _as_per_query(m, qn: int):
    """Broadcast a shared 1-D live mask to the (Q, N) layout for consumers
    without a shared-mask fast path (IVF probes, the sharded core)."""
    if m is None or m.ndim == 2:
        return m
    return jnp.broadcast_to(m[None], (qn,) + m.shape)


def _live_scan_masks(pred: Expr | None, arrays, binds, qn: int):
    """Live (main, delta) row masks for the scan classes (Q1/Q2/Q5).

    With a structured predicate each is per-query 2-D — (Q, cap_main) /
    (Q, delta_cap) — combining the segment validity lane (tombstones +
    unoccupied slots) with the predicate evaluated over the live scalar
    columns.  Without one the validity lanes are returned UNBROADCAST
    (1-D): the fused kernels take the shared-mask fast path, which keeps
    the zero-delta live scan at frozen-scan cost (the (Q, N) mask alone
    costs ~25% on the b64 flat workload)."""
    mv, dv = arrays["live_main_valid"], arrays["live_delta_valid"]
    n, dn = mv.shape[0], dv.shape[0]
    if pred is None:
        return mv, dv

    def seg(cols, seg_valid, seg_n):
        m = jax.vmap(lambda b: jnp.broadcast_to(
            evaluate(pred, _ColsTable(cols), b), (seg_n,)))(binds)
        return m & seg_valid[None, :]

    return (seg(arrays["live_cols"], mv, n),
            seg(arrays["live_dcols"], dv, dn))


def _live_join_masks(pred: Expr | None, ltab: Table, rtab: Table,
                     lalias: str | None, ralias: str | None,
                     arrays, binds, qn: int, nleft: int):
    """Live (main, delta) masks for the join classes, in the flattened
    (Q·L, seg) layout of :func:`_flatten_left_batch`.

    The twin of :func:`_join_mask_batch_fn` with right columns read from
    the live segment arrays instead of the frozen right table (the left
    side stays frozen — only the scanned column is live)."""
    mv, dv = arrays["live_main_valid"], arrays["live_delta_valid"]
    n, dn = mv.shape[0], dv.shape[0]
    if pred is None:
        return (jnp.broadcast_to(mv[None], (qn * nleft, n)),
                jnp.broadcast_to(dv[None], (qn * nleft, dn)))
    owner = _owner_fn(ltab, rtab, lalias, ralias)

    def seg(cols, seg_valid, seg_n):
        def per_bind(b):
            m = _eval_join_pred(pred, owner,
                                lambda name: ltab[name][:, None],
                                lambda name: cols[name][None, :], b)
            return jnp.broadcast_to(m, (nleft, seg_n))

        m = jax.vmap(per_bind)(binds).reshape(qn * nleft, seg_n)
        return m & seg_valid[None, :]

    return (seg(arrays["live_cols"], mv, n),
            seg(arrays["live_dcols"], dv, dn))


def _merge_delta_topk(opts: EngineOptions, metric: Metric, arrays, qs,
                      k: int, dmask, qvalid, ids, sims, valid, stats):
    """Merge the delta-segment top-k into a main-segment (Q, k) result.

    Main candidates go in as merge side A (ties resolve main-first —
    ``jax.lax.top_k`` stability), so an empty delta leaves the main result
    bit-identical — which licenses the runtime ``lax.cond`` below: with no
    live delta row the whole scan+merge is skipped (the merge alone costs
    ~20% of the b64 flat workload, and zero-delta is the steady state
    between compactions).  Top-k main results are already best-first, so
    the skip branch is the identity.  The delta scan adds delta_cap
    distance evals per valid query to the counters only when it runs (it
    IS a flat scan of the segment)."""
    from ..index.delta import delta_topk_batch
    from ..dist.collectives import merge_topk_level
    offset = arrays["corpus"].shape[0]
    has_delta = jnp.any(arrays["live_delta_valid"])

    def merged(main):
        ids, sims, valid = main
        # the delta segment is delta_cap rows by construction: the jnp scan
        # is a trivial (Q, delta_cap) matmul, while a second Pallas launch
        # per execute costs more than the whole segment (worst in interpret
        # mode)
        dkeys, dgids = delta_topk_batch(
            metric, arrays["live_delta_vec"], qs, k, dmask, qvalid, offset,
            use_pallas=False)
        mkeys = jnp.where(valid, order_key(metric, sims), jnp.inf)
        mgids = jnp.where(valid, ids, -1)
        return merge_topk_level(metric, mkeys, mgids, dkeys, dgids, k)

    ids, sims, valid = jax.lax.cond(has_delta, merged, lambda main: main,
                                    (ids, sims, valid))
    stats = dict(stats)
    stats["distance_evals"] = stats["distance_evals"] + jnp.where(
        has_delta,
        _flat_evals(qvalid, qs.shape[0], arrays["live_delta_vec"].shape[0]),
        0)
    return ids, sims, valid, stats


def _merge_delta_range(opts: EngineOptions, metric: Metric, arrays, qs,
                       radius, capacity: int, dmask, qvalid,
                       ids, sims, valid, count, stats):
    """Merge the delta-segment range hits into a main-segment result batch.

    The merged buffer is ``min(capacity, main width + delta width)`` wide
    best-first; ``count`` stays exact past truncation (main count + exact
    delta hit count).  Counter accounting as in :func:`_merge_delta_topk`,
    but NO empty-delta runtime skip: the merge is what re-sorts IVF range
    hits (probe-discovery order) best-first, an ordering the live range
    classes promise at any delta fill — and none of them is on the gated
    zero-delta flat workload."""
    from ..index.delta import delta_range_batch
    from ..dist.collectives import merge_topk_level
    offset = arrays["corpus"].shape[0]
    dkeys, dgids, dcount = delta_range_batch(
        metric, arrays["live_delta_vec"], qs, radius, dmask, qvalid, offset,
        int(capacity), use_pallas=False)  # tiny segment: see delta_topk note
    mkeys = jnp.where(valid, order_key(metric, sims), jnp.inf)
    mgids = jnp.where(valid, ids, -1)
    w = min(int(capacity), ids.shape[1] + dkeys.shape[1])
    ids, sims, valid = merge_topk_level(metric, mkeys, mgids, dkeys, dgids,
                                        w)
    stats = dict(stats)
    stats["distance_evals"] = stats["distance_evals"] + _flat_evals(
        qvalid, qs.shape[0], arrays["live_delta_vec"].shape[0])
    return ids, sims, valid, count + dcount.astype(count.dtype), stats


# ---------------------------------------------------------------------------
# Q1 — VKNN-SF
# ---------------------------------------------------------------------------

def build_vknn_sf(a: Analysis, catalog: Catalog, opts: EngineOptions,
                  binds_static: Bindings) -> Callable:
    """Q1 (VKNN-SF) single-query pipeline: filtered top-k by engine mode."""
    table = catalog.table(a.table)
    metric = _metric_of(catalog, a.table, a.vector_column)
    k = _static_int(a.k, binds_static, "K")
    mask_fn = _row_mask_fn(a.structured_predicate, table)
    qparam = a.query_expr
    assert isinstance(qparam, Param), "VKNN-SF query must be a parameter"
    index = catalog.index_for(a.table, a.vector_column)
    cfg = opts.probe

    def fn(arrays, binds):
        corpus = arrays["corpus"]
        q = jnp.asarray(binds[qparam.name])
        row_mask = mask_fn(binds) if mask_fn else None
        stats = {}
        if opts.engine == "chase" and index is not None:
            idx: IVFIndex = arrays["index"]
            ids, sims, valid, stats = ivf_topk(idx, corpus, q, k, row_mask, cfg)
        elif opts.engine == "vbase" and index is not None:
            idx = arrays["index"]
            ids, _sims, valid, stats = ivf_topk(idx, corpus, q, k, row_mask, cfg)
            ids, sims, valid = _resort_redundant(metric, corpus, q, ids,
                                                 valid, k)
            stats = dict(stats)
            stats["distance_evals"] = stats["distance_evals"] + k
        elif opts.engine == "pase" and index is not None:
            idx = arrays["index"]
            kk = min(opts.pase_oversample * k, corpus.shape[0])
            ids_o, sims_o, valid_o, stats = ivf_topk(idx, corpus, q, kk, None,
                                                     cfg)
            if row_mask is not None:
                valid_o = valid_o & jnp.where(
                    ids_o >= 0, row_mask[jnp.maximum(ids_o, 0)], False)
            # keep first k surviving (index order is already ascending key)
            keep = jnp.cumsum(valid_o) <= k
            valid_o = valid_o & keep
            keys = jnp.where(valid_o, order_key(metric, sims_o), jnp.inf)
            neg, sel = jax.lax.top_k(-keys, k)
            valid = jnp.isfinite(-neg)
            ids = jnp.where(valid, ids_o[sel], -1)
            sims = jnp.where(valid, sims_o[sel], 0.0)
        else:  # brute (LingoDB-V analogue) or missing index
            flat = FlatIndex(metric, corpus)
            ids, sims, valid = _flat_topk(opts, flat, q, k, row_mask)
            stats = {"probes": jnp.int32(0),
                     "distance_evals": jnp.int32(corpus.shape[0])}
        return {"ids": ids, "sim": sims, "valid": valid, "stats": stats}

    return fn


# ---------------------------------------------------------------------------
# Q2 — DR-SF
# ---------------------------------------------------------------------------

def build_dr_sf(a: Analysis, catalog: Catalog, opts: EngineOptions,
                binds_static: Bindings) -> Callable:
    """Q2 (DR-SF) single-query pipeline: filtered range scan by engine."""
    table = catalog.table(a.table)
    metric = _metric_of(catalog, a.table, a.vector_column)
    mask_fn = _row_mask_fn(a.structured_predicate, table)
    qparam = a.query_expr
    index = catalog.index_for(a.table, a.vector_column)
    cfg = opts.probe
    radius_expr = a.radius

    def radius_of(binds):
        return evaluate(radius_expr, table, binds)

    def fn(arrays, binds):
        corpus = arrays["corpus"]
        q = jnp.asarray(binds[qparam.name])
        radius = radius_of(binds)
        row_mask = mask_fn(binds) if mask_fn else None
        if opts.engine == "chase" and index is not None:
            idx = arrays["index"]
            ids, sims, valid, count, stats = ivf_range(idx, corpus, q, radius,
                                                       row_mask, cfg)
        elif opts.engine == "vbase" and index is not None:
            idx = arrays["index"]
            # scan without fused predicate; filter as a separate operator,
            # whose predicate re-evaluates similarity for the range check
            ids, _sims, valid, count, stats = ivf_range(idx, corpus, q, radius,
                                                        None, cfg)
            safe = jnp.maximum(ids, 0)
            raw = distance_values(metric, corpus[safe], q)    # REDUNDANT
            valid = valid & in_range(metric, raw, radius)
            if row_mask is not None:
                valid = valid & row_mask[safe]
            sims = jnp.where(valid, raw, 0.0)
            count = jnp.sum(valid)
            stats = dict(stats)
            stats["distance_evals"] = stats["distance_evals"] + cfg.capacity
        else:
            # PASE/pgvector cannot route range queries to the ANN index (§2.3)
            flat = FlatIndex(metric, corpus)
            hit, raw = flat.range_mask(q, radius, row_mask)
            capacity = cfg.capacity
            keys = jnp.where(hit, order_key(metric, raw), jnp.inf)
            neg, sel = jax.lax.top_k(-keys, min(capacity, corpus.shape[0]))
            valid = jnp.isfinite(-neg)
            ids = jnp.where(valid, sel.astype(jnp.int32), -1)
            sims = jnp.where(valid, raw[sel], 0.0)
            count = jnp.sum(hit)
            stats = {"probes": jnp.int32(0),
                     "distance_evals": jnp.int32(corpus.shape[0])}
        return {"ids": ids, "sim": sims, "valid": valid, "count": count,
                "stats": stats}

    return fn


# ---------------------------------------------------------------------------
# Q3 — distance join
# ---------------------------------------------------------------------------
#
# Batch-native lowering (the default): the left side of a vector join IS a
# query batch, so the (masked) left embeddings are gathered into one (L, d)
# batch and pushed through ivf_range_batch / the query-tiled range kernel in
# a single shot — per-left-row join predicates become the (L, N) mask the
# batched operators already consume, and stats come back as per-left (L,)
# arrays (``benchmarks.counters.per_left_amortized`` reports them).  The
# legacy per-left-row loop survives behind join_lowering='perleft' as the
# measured baseline.  Ordering policy: flat plans emit best-first per left
# row; IVF plans emit probe-discovery order (identical to the per-left loop
# with probe_batch=1).


def _dist_join_core(a: Analysis, catalog: Catalog, opts: EngineOptions):
    """(arrays, qs (M,d), radius, rm (M,N)|None) -> Q3 result batch."""
    metric = _metric_of(catalog, a.right_table, a.right_vector)
    index = catalog.index_for(a.right_table, a.right_vector)
    cfg = dataclasses.replace(opts.probe, capacity=opts.max_pairs)
    live = catalog.live_for(a.right_table, a.right_vector) is not None
    sharded = (_dist_range_core(opts, metric, opts.max_pairs,
                                catalog.table(a.right_table).num_rows,
                                per_query_mask=(a.join_predicate is not None
                                                or live))
               if opts.dist is not None else None)

    def core(arrays, qs, radius, rm, qvalid=None, probe_budget=None,
             dmask=None):
        corpus = arrays["corpus"]
        m = qs.shape[0]
        radius = jnp.broadcast_to(jnp.asarray(radius, jnp.float32), (m,))

        def out(ids, sims, valid, count, stats):
            if not live:
                return ids, sims, valid, count, stats
            return _merge_delta_range(opts, metric, arrays, qs, radius,
                                      opts.max_pairs, dmask, qvalid,
                                      ids, sims, valid, count, stats)

        if sharded is not None:
            return out(*sharded(arrays, qs, radius, rm, qvalid))
        if opts.engine in ("chase", "vbase") and index is not None:
            idx = arrays["index"]
            if opts.engine == "chase":
                ids, sims, valid, count, stats = ivf_range_batch(
                    idx, corpus, qs, radius, rm, cfg,
                    probe_budget=probe_budget, qvalid=qvalid)
            else:
                ids, _s, valid, count, stats = ivf_range_batch(
                    idx, corpus, qs, radius, None, cfg,
                    probe_budget=probe_budget, qvalid=qvalid)
                safe = jnp.maximum(ids, 0)
                raw = distance_values(metric, corpus[safe],
                                      qs[:, None, :])          # REDUNDANT
                valid = valid & in_range(metric, raw, radius[:, None])
                if rm is not None:
                    valid = valid & jnp.take_along_axis(rm, safe, axis=1)
                sims = jnp.where(valid, raw, 0.0)
                count = jnp.sum(valid, axis=1)
                # legacy-parity quirk: the per-left Q3 vbase plan never
                # counted its redundant re-check evals; keep counters
                # identical across lowerings
            return out(ids, sims, valid, count, stats)
        return out(*_flat_range_topk_batch(opts, metric, corpus, qs, radius,
                                           rm, opts.max_pairs,
                                           qvalid=qvalid, arrays=arrays))

    return core


def build_dist_join(a: Analysis, catalog: Catalog, opts: EngineOptions,
                    binds_static: Bindings) -> Callable:
    """Q3 (distance join): left rows ride ONE query batch (see section
    comment above; ``join_lowering='perleft'`` keeps the legacy loop)."""
    if opts.join_lowering == "perleft":
        return _build_dist_join_perleft(a, catalog, opts, binds_static)
    ltab, rtab = catalog.table(a.left_table), catalog.table(a.right_table)
    mask_b = _join_mask_batch_fn(a.join_predicate, ltab, rtab, a.left_alias,
                                 a.right_alias)
    core = _dist_join_core(a, catalog, opts)
    radius_expr = a.radius

    def fn(arrays, binds):
        lvec = arrays["left"]                                  # (L, d)
        nleft = lvec.shape[0]
        radius = evaluate(radius_expr, rtab, binds)
        rm = mask_b(binds) if mask_b else None                 # (L, N)
        ids, sims, valid, counts, stats = core(arrays, lvec, radius, rm)
        return {"qid": jnp.broadcast_to(
                    jnp.arange(nleft, dtype=jnp.int32)[:, None], ids.shape),
                "tid": ids, "sim": sims, "valid": valid, "count": counts,
                "stats": stats}

    return fn


def build_dist_join_batch(a: Analysis, catalog: Catalog, opts: EngineOptions,
                          binds_static: Bindings) -> Callable:
    """Q bind sets x L left rows, flattened into ONE kernel query batch."""
    ltab, rtab = catalog.table(a.left_table), catalog.table(a.right_table)
    mask_b = _join_mask_batch_fn(a.join_predicate, ltab, rtab, a.left_alias,
                                 a.right_alias)
    live = catalog.live_for(a.right_table, a.right_vector) is not None
    core = _dist_join_core(a, catalog, opts)
    radius_expr = a.radius

    def fn(arrays, binds, qvalid=None, probe_budget=None):
        if live:
            qn, nleft, qs, _ = _flatten_left_batch(arrays["left"], binds,
                                                   None)
            rm, dmask = _live_join_masks(a.join_predicate, ltab, rtab,
                                         a.left_alias, a.right_alias,
                                         arrays, binds, qn, nleft)
        else:
            qn, nleft, qs, rm = _flatten_left_batch(arrays["left"], binds,
                                                    mask_b)
            dmask = None
        fq, fb = _flatten_valid_budget(qvalid, probe_budget, qn, nleft)
        radius = jnp.broadcast_to(
            jax.vmap(lambda b: evaluate(radius_expr, rtab, b))(binds), (qn,))
        ids, sims, valid, counts, stats = core(
            arrays, qs, jnp.repeat(radius, nleft), rm, qvalid=fq,
            probe_budget=fb, dmask=dmask)
        pairs = ids.shape[1]
        shape = (qn, nleft, pairs)
        return {"qid": jnp.broadcast_to(
                    jnp.arange(nleft, dtype=jnp.int32)[None, :, None], shape),
                "tid": ids.reshape(shape), "sim": sims.reshape(shape),
                "valid": valid.reshape(shape),
                "count": counts.reshape(qn, nleft),
                "stats": jax.tree.map(lambda v: v.reshape(qn, nleft), stats)}

    return fn


def _build_dist_join_perleft(a: Analysis, catalog: Catalog,
                             opts: EngineOptions,
                             binds_static: Bindings) -> Callable:
    """Legacy lowering: one scan/probe per left row (vmapped matvecs)."""
    ltab, rtab = catalog.table(a.left_table), catalog.table(a.right_table)
    metric = _metric_of(catalog, a.right_table, a.right_vector)
    pair_mask = _join_mask_fn(a.join_predicate, ltab, rtab, a.left_alias,
                              a.right_alias)
    index = catalog.index_for(a.right_table, a.right_vector)
    cfg = dataclasses.replace(opts.probe, capacity=opts.max_pairs)
    radius_expr = a.radius

    def fn(arrays, binds):
        lvec = arrays["left"]
        corpus = arrays["corpus"]
        radius = evaluate(radius_expr, rtab, binds)
        nleft = lvec.shape[0]

        def per_left(i):
            q = lvec[i]
            rm = pair_mask(i, binds) if pair_mask else None
            if opts.engine in ("chase", "vbase") and index is not None:
                idx = arrays["index"]
                if opts.engine == "chase":
                    ids, sims, valid, count, stats = ivf_range(
                        idx, corpus, q, radius, rm, cfg)
                else:
                    ids, _s, valid, count, stats = ivf_range(
                        idx, corpus, q, radius, None, cfg)
                    safe = jnp.maximum(ids, 0)
                    raw = distance_values(metric, corpus[safe], q)  # REDUNDANT
                    valid = valid & in_range(metric, raw, radius)
                    if rm is not None:
                        valid = valid & rm[safe]
                    sims = jnp.where(valid, raw, 0.0)
                    count = jnp.sum(valid)
            else:
                if opts.use_pallas:
                    # single-query kernel per left row: the matvec-shaped
                    # baseline the query-tiled lowering replaces
                    from ..kernels.ops import fused_range_scan
                    hit, raw, _cnt = fused_range_scan(
                        corpus, q, radius, rm, metric,
                        interpret=opts.interpret_pallas)
                else:
                    flat = FlatIndex(metric, corpus)
                    hit, raw = flat.range_mask(q, radius, rm)
                keys = jnp.where(hit, order_key(metric, raw), jnp.inf)
                neg, sel = jax.lax.top_k(-keys, opts.max_pairs)
                valid = jnp.isfinite(-neg)
                ids = jnp.where(valid, sel.astype(jnp.int32), -1)
                sims = jnp.where(valid, raw[sel], 0.0)
                count = jnp.sum(hit)
                stats = {"probes": jnp.int32(0),
                         "distance_evals": jnp.int32(corpus.shape[0])}
            return ids, sims, valid, count, stats

        ids, sims, valid, counts, stats = jax.vmap(per_left)(
            jnp.arange(nleft, dtype=jnp.int32))
        return {"qid": jnp.broadcast_to(
                    jnp.arange(nleft, dtype=jnp.int32)[:, None], ids.shape),
                "tid": ids, "sim": sims, "valid": valid, "count": counts,
                "stats": stats}

    return fn


# ---------------------------------------------------------------------------
# Q4 — entity-centric KNN join
# ---------------------------------------------------------------------------

def _knn_join_core(a: Analysis, catalog: Catalog, opts: EngineOptions,
                   k: int):
    """(arrays, qs (M,d), rm (M,N)|None) -> (ids, sims, valid, stats)."""
    metric = _metric_of(catalog, a.right_table, a.right_vector)
    index = catalog.index_for(a.right_table, a.right_vector)
    cfg = opts.probe
    live = catalog.live_for(a.right_table, a.right_vector) is not None
    sharded = (_dist_topk_core(opts, metric, k,
                               per_query_mask=(a.join_predicate is not None
                                               or live))
               if opts.dist is not None else None)

    def core(arrays, qs, rm, qvalid=None, probe_budget=None, dmask=None):
        corpus = arrays["corpus"]
        m, n = qs.shape[0], corpus.shape[0]
        if sharded is not None:
            ids, sims, valid, stats = sharded(arrays, qs, rm, qvalid)
        elif opts.engine == "chase" and index is not None:
            # R2: ANN top-k, all left rows in one probe batch — the 7500x
            # path with the matvec loop batched away
            ids, sims, valid, stats = ivf_topk_batch(
                arrays["index"], corpus, qs, k, rm, cfg,
                probe_budget=probe_budget, qvalid=qvalid)
        elif opts.engine == "brute_sort":
            # Fig. 5a plan: window sorts the WHOLE partition (|B| log |B|)
            # per left row — the full sort is the measured inefficiency
            raw = distance_values(metric, corpus[None], qs[:, None, :])
            keys = order_key(metric, raw)                     # (M, N)
            if rm is not None:
                keys = jnp.where(rm, keys, jnp.inf)
            if qvalid is not None:
                keys = jnp.where(qvalid[:, None], keys, jnp.inf)
            perm = jnp.argsort(keys, axis=1)       # full sort, on purpose
            sel = perm[:, :k]
            skeys = jnp.take_along_axis(keys, sel, axis=1)
            valid = jnp.isfinite(skeys)
            ids = jnp.where(valid, sel.astype(jnp.int32), -1)
            sims = jnp.where(valid,
                             -skeys if metric.is_similarity() else skeys,
                             0.0)
            stats = {"probes": jnp.zeros((m,), jnp.int32),
                     "distance_evals": _flat_evals(qvalid, m, n)}
        else:  # brute (compiled top-k; LingoDB-V-like)
            if opts.use_pallas:
                ids, sims, valid = _flat_topk_batch(
                    opts, arrays, metric, corpus, qs, k, rm, qvalid=qvalid)
            else:
                flat = FlatIndex(metric, corpus)
                if rm is None:
                    ids, sims, valid = jax.vmap(
                        lambda q: flat.topk(q, k, None))(qs)
                else:
                    ids, sims, valid = jax.vmap(
                        lambda q, r: flat.topk(q, k, r))(qs, rm)
                if qvalid is not None:
                    valid = valid & qvalid[:, None]
                    ids = jnp.where(valid, ids, -1)
                    sims = jnp.where(valid, sims, 0.0)
            stats = {"probes": jnp.zeros((m,), jnp.int32),
                     "distance_evals": _flat_evals(qvalid, m, n)}
        if live:
            ids, sims, valid, stats = _merge_delta_topk(
                opts, metric, arrays, qs, k, dmask, qvalid,
                ids, sims, valid, stats)
        return ids, sims, valid, stats

    return core


def build_knn_join(a: Analysis, catalog: Catalog, opts: EngineOptions,
                   binds_static: Bindings) -> Callable:
    """Q4 (entity-centric KNN join): per-left top-k as one query batch."""
    if opts.join_lowering == "perleft":
        return _build_knn_join_perleft(a, catalog, opts, binds_static)
    ltab, rtab = catalog.table(a.left_table), catalog.table(a.right_table)
    k = _static_int(a.k, binds_static, "K")
    mask_b = _join_mask_batch_fn(a.join_predicate, ltab, rtab, a.left_alias,
                                 a.right_alias)
    core = _knn_join_core(a, catalog, opts, k)

    def fn(arrays, binds):
        lvec = arrays["left"]                                  # (L, d)
        nleft = lvec.shape[0]
        rm = mask_b(binds) if mask_b else None                 # (L, N)
        ids, sims, valid, stats = core(arrays, lvec, rm)
        ranks = jnp.broadcast_to(jnp.arange(1, k + 1, dtype=jnp.int32)[None],
                                 ids.shape)
        return {"qid": jnp.broadcast_to(
                    jnp.arange(nleft, dtype=jnp.int32)[:, None], ids.shape),
                "tid": ids, "sim": sims, "valid": valid, "rank": ranks,
                "stats": stats}

    return fn


def build_knn_join_batch(a: Analysis, catalog: Catalog, opts: EngineOptions,
                         binds_static: Bindings) -> Callable:
    """Q bind sets x L left rows, flattened into ONE kernel query batch."""
    ltab, rtab = catalog.table(a.left_table), catalog.table(a.right_table)
    k = _static_int(a.k, binds_static, "K")
    mask_b = _join_mask_batch_fn(a.join_predicate, ltab, rtab, a.left_alias,
                                 a.right_alias)
    live = catalog.live_for(a.right_table, a.right_vector) is not None
    core = _knn_join_core(a, catalog, opts, k)

    def fn(arrays, binds, qvalid=None, probe_budget=None):
        if live:
            qn, nleft, qs, _ = _flatten_left_batch(arrays["left"], binds,
                                                   None)
            rm, dmask = _live_join_masks(a.join_predicate, ltab, rtab,
                                         a.left_alias, a.right_alias,
                                         arrays, binds, qn, nleft)
        else:
            qn, nleft, qs, rm = _flatten_left_batch(arrays["left"], binds,
                                                    mask_b)
            dmask = None
        fq, fb = _flatten_valid_budget(qvalid, probe_budget, qn, nleft)
        ids, sims, valid, stats = core(arrays, qs, rm, qvalid=fq,
                                       probe_budget=fb, dmask=dmask)
        shape = (qn, nleft, k)
        return {"qid": jnp.broadcast_to(
                    jnp.arange(nleft, dtype=jnp.int32)[None, :, None], shape),
                "tid": ids.reshape(shape), "sim": sims.reshape(shape),
                "valid": valid.reshape(shape),
                "rank": jnp.broadcast_to(
                    jnp.arange(1, k + 1, dtype=jnp.int32)[None, None], shape),
                "stats": jax.tree.map(lambda v: v.reshape(qn, nleft), stats)}

    return fn


def _build_knn_join_perleft(a: Analysis, catalog: Catalog,
                            opts: EngineOptions,
                            binds_static: Bindings) -> Callable:
    """Legacy lowering: one scan/probe per left row (vmapped matvecs)."""
    ltab, rtab = catalog.table(a.left_table), catalog.table(a.right_table)
    metric = _metric_of(catalog, a.right_table, a.right_vector)
    k = _static_int(a.k, binds_static, "K")
    pair_mask = _join_mask_fn(a.join_predicate, ltab, rtab, a.left_alias,
                              a.right_alias)
    index = catalog.index_for(a.right_table, a.right_vector)
    cfg = opts.probe

    def fn(arrays, binds):
        lvec = arrays["left"]
        corpus = arrays["corpus"]
        nleft = lvec.shape[0]

        def per_left(i):
            q = lvec[i]
            rm = pair_mask(i, binds) if pair_mask else None
            if opts.engine == "chase" and index is not None:
                # R2: ANN top-k per left row — the 7500x path
                idx = arrays["index"]
                ids, sims, valid, stats = ivf_topk(idx, corpus, q, k, rm, cfg)
            elif opts.engine == "brute_sort":
                # Fig. 5a plan: window sorts the WHOLE partition (|B| log |B|)
                raw = distance_values(metric, corpus, q)
                keys = order_key(metric, raw)
                if rm is not None:
                    keys = jnp.where(rm, keys, jnp.inf)
                perm = jnp.argsort(keys)               # full sort, on purpose
                sel = perm[:k]
                skeys = keys[perm[:k]]
                valid = jnp.isfinite(skeys)
                ids = jnp.where(valid, sel.astype(jnp.int32), -1)
                sims = jnp.where(valid,
                                 -skeys if metric.is_similarity() else skeys,
                                 0.0)
                stats = {"probes": jnp.int32(0),
                         "distance_evals": jnp.int32(corpus.shape[0])}
            else:  # brute (compiled top-k; LingoDB-V-like)
                flat = FlatIndex(metric, corpus)
                ids, sims, valid = _flat_topk(opts, flat, q, k, rm)
                stats = {"probes": jnp.int32(0),
                         "distance_evals": jnp.int32(corpus.shape[0])}
            return ids, sims, valid, stats

        ids, sims, valid, stats = jax.vmap(per_left)(
            jnp.arange(nleft, dtype=jnp.int32))
        ranks = jnp.broadcast_to(jnp.arange(1, k + 1, dtype=jnp.int32)[None],
                                 ids.shape)
        return {"qid": jnp.broadcast_to(
                    jnp.arange(nleft, dtype=jnp.int32)[:, None], ids.shape),
                "tid": ids, "sim": sims, "valid": valid, "rank": ranks,
                "stats": stats}

    return fn


# ---------------------------------------------------------------------------
# Q5 / Q6 — category-driven
# ---------------------------------------------------------------------------

def _rank_per_category(metric: Metric, ids, keys, valid, cats, C: int, K: int):
    """Buffer -> per-category top-K (the window operator over probe output).
    Consumes the scan's similarity via `keys` — map-operator contract."""
    def per_cat(c):
        m = valid & (cats == c)
        return masked_topk(keys, ids, m, K)

    ck, cids, cvalid = jax.vmap(per_cat)(jnp.arange(C, dtype=jnp.int32))
    sims = jnp.where(cvalid, -ck if metric.is_similarity() else ck, 0.0)
    return cids, sims, cvalid


def _rank_per_category_batch(metric: Metric, ids, keys, valid, cats,
                             C: int, K: int):
    """Vectorized window rank: (M, P) probe buffers -> (M, C, K) results.

    One (M, C, P) masked top-k over the whole batch — the category ranking
    runs for every left row / bind set at once instead of per query."""
    return jax.vmap(lambda i, k2, v, c: _rank_per_category(
        metric, i, k2, v, c, C, K))(ids, keys, valid, cats)


def _category_core(opts: EngineOptions, metric: Metric, index,
                   C: int, k: int, vbase_extra_evals: bool,
                   n_rows: int = 0, per_query_mask: bool = True,
                   live: bool = False, cat_col: str | None = None):
    """(arrays, qs (M,d), radius, rm (M,N)|None) -> (M, C, K) ranked batch.

    Shared by the Q5 bind-batch lowering and the Q6 left-row batch: probe a
    (M, d) query batch (Algorithm 2's record table batched when updateState
    applies), then run the window rank for all M queries at once.
    ``n_rows`` (the scanned table's row count) sizes the sharded range
    buffer when ``opts.dist`` selects the distributed lowering.  Under
    ``live``, the delta segment is merged in LOSSLESSLY (main + delta
    buffer widths) before the window rank, and merged ids >= cap_main read
    their category from the live delta columns (``cat_col``)."""
    cfg = dataclasses.replace(opts.probe, num_categories=C, k_per_category=k)
    use_update_state = opts.engine == "chase"
    sharded = (_dist_range_core(opts, metric, cfg.capacity, n_rows,
                                per_query_mask=per_query_mask)
               if opts.dist is not None else None)

    def core(arrays, qs, radius, rm, qvalid=None, probe_budget=None,
             dmask=None):
        corpus = arrays["corpus"]
        cats = arrays["categories"]
        m = qs.shape[0]
        radius = jnp.broadcast_to(jnp.asarray(radius, jnp.float32), (m,))
        if sharded is not None:
            ids, sims, valid, count, stats = sharded(arrays, qs, radius,
                                                     _as_per_query(rm, m),
                                                     qvalid)
        elif index is not None and opts.engine in ("chase", "vbase",
                                                   "chase_no_updatestate"):
            idx = arrays["index"]
            rm = _as_per_query(rm, m)
            if use_update_state:
                ids, sims, valid, count, stats = ivf_range_category_batch(
                    idx, corpus, cats, qs, radius, rm, cfg,
                    probe_budget=probe_budget, qvalid=qvalid)
            else:
                ids, sims, valid, count, stats = ivf_range_batch(
                    idx, corpus, qs, radius, rm, cfg,
                    probe_budget=probe_budget, qvalid=qvalid)
            if opts.engine == "vbase":
                safe = jnp.maximum(ids, 0)
                raw = distance_values(metric, corpus[safe],
                                      qs[:, None, :])          # REDUNDANT
                sims = jnp.where(valid, raw, 0.0)
                if vbase_extra_evals:
                    extra = (cfg.capacity if qvalid is None
                             else jnp.where(qvalid, cfg.capacity, 0))
                    stats = dict(stats)
                    stats["distance_evals"] = stats["distance_evals"] + extra
        else:
            ids, sims, valid, count, stats = _flat_range_topk_batch(
                opts, metric, corpus, qs, radius, rm, cfg.capacity,
                qvalid=qvalid, arrays=arrays)
        if live:
            # lossless merge width (main + delta buffers): the window rank
            # below consumes the WHOLE buffer, so truncating here would
            # drop per-category candidates the frozen plan would keep
            dcap = arrays["live_delta_vec"].shape[0]
            ids, sims, valid, count, stats = _merge_delta_range(
                opts, metric, arrays, qs, radius, ids.shape[1] + dcap,
                dmask, qvalid, ids, sims, valid, count, stats)
            n = corpus.shape[0]
            dcats = arrays["live_dcols"][cat_col]
            bcats = jnp.where(
                valid,
                jnp.where(ids < n, cats[jnp.clip(ids, 0, n - 1)],
                          dcats[jnp.clip(ids - n, 0, dcap - 1)]),
                -1)
        else:
            bcats = jnp.where(valid, cats[jnp.maximum(ids, 0)], -1)
        keys = jnp.where(valid, order_key(metric, sims), jnp.inf)
        cids, csims, cvalid = _rank_per_category_batch(
            metric, ids, keys, valid, bcats, C, k)
        return cids, csims, cvalid, stats

    return core


def build_category_partition(a: Analysis, catalog: Catalog,
                             opts: EngineOptions,
                             binds_static: Bindings) -> Callable:
    """Q5 (category-driven, single table): range probe + per-category rank
    (updateState early stop under the chase engine)."""
    table = catalog.table(a.table)
    metric = _metric_of(catalog, a.table, a.vector_column)
    k = _static_int(a.k, binds_static, "K")
    cat_col = a.category_column.name
    C = table.schema[cat_col].num_categories
    assert C, f"category column {cat_col} needs num_categories"
    mask_fn = _row_mask_fn(a.structured_predicate, table)
    qparam = a.query_expr
    index = catalog.index_for(a.table, a.vector_column)
    cfg = dataclasses.replace(opts.probe, num_categories=C, k_per_category=k)
    radius_expr = a.radius
    use_update_state = opts.engine == "chase"

    def fn(arrays, binds):
        corpus = arrays["corpus"]
        cats = arrays["categories"]
        q = jnp.asarray(binds[qparam.name])
        radius = evaluate(radius_expr, table, binds)
        row_mask = mask_fn(binds) if mask_fn else None
        if index is not None and opts.engine in ("chase", "vbase",
                                                 "chase_no_updatestate"):
            idx = arrays["index"]
            if use_update_state:
                ids, sims, valid, count, stats = ivf_range_category(
                    idx, corpus, cats, q, radius, row_mask, cfg)
            else:
                ids, sims, valid, count, stats = ivf_range(
                    idx, corpus, q, radius, row_mask, cfg)
            if opts.engine == "vbase":
                safe = jnp.maximum(ids, 0)
                raw = distance_values(metric, corpus[safe], q)  # REDUNDANT
                sims = jnp.where(valid, raw, 0.0)
                stats = dict(stats)
                stats["distance_evals"] = stats["distance_evals"] + cfg.capacity
        else:
            flat = FlatIndex(metric, corpus)
            hit, raw = flat.range_mask(q, radius, row_mask)
            keys = jnp.where(hit, order_key(metric, raw), jnp.inf)
            neg, sel = jax.lax.top_k(-keys, cfg.capacity)
            valid = jnp.isfinite(-neg)
            ids = jnp.where(valid, sel.astype(jnp.int32), -1)
            sims = jnp.where(valid, raw[sel], 0.0)
            stats = {"probes": jnp.int32(0),
                     "distance_evals": jnp.int32(corpus.shape[0])}
        keys = jnp.where(valid, order_key(metric, sims), jnp.inf)
        bcats = jnp.where(valid, cats[jnp.maximum(ids, 0)], -1)
        cids, csims, cvalid = _rank_per_category(metric, ids, keys, valid,
                                                 bcats, C, k)
        return {"ids": cids, "sim": csims, "valid": cvalid,
                "category": jnp.broadcast_to(
                    jnp.arange(C, dtype=jnp.int32)[:, None], cids.shape),
                "stats": stats}

    return fn


def build_category_partition_batch(a: Analysis, catalog: Catalog,
                                   opts: EngineOptions,
                                   binds_static: Bindings) -> Callable:
    """Q5 over Q bind sets: one batched category probe + one window rank."""
    table = catalog.table(a.table)
    metric = _metric_of(catalog, a.table, a.vector_column)
    k = _static_int(a.k, binds_static, "K")
    cat_col = a.category_column.name
    C = table.schema[cat_col].num_categories
    assert C, f"category column {cat_col} needs num_categories"
    mask_fn = _row_mask_fn(a.structured_predicate, table)
    qparam = a.query_expr
    index = catalog.index_for(a.table, a.vector_column)
    live = catalog.live_for(a.table, a.vector_column) is not None
    core = _category_core(opts, metric, index, C, k, vbase_extra_evals=True,
                          n_rows=table.num_rows,
                          per_query_mask=mask_fn is not None or live,
                          live=live, cat_col=cat_col)
    radius_expr = a.radius

    def fn(arrays, binds, qvalid=None, probe_budget=None):
        qs = jnp.asarray(binds[qparam.name])                      # (Q, D)
        qn = qs.shape[0]
        radius = jnp.broadcast_to(
            jax.vmap(lambda b: evaluate(radius_expr, table, b))(binds), (qn,))
        dmask = None
        if live:
            row_mask, dmask = _live_scan_masks(a.structured_predicate,
                                               arrays, binds, qn)
        else:
            row_mask = jax.vmap(mask_fn)(binds) if mask_fn else None  # (Q, N)
        cids, csims, cvalid, stats = core(arrays, qs, radius, row_mask,
                                          qvalid=qvalid,
                                          probe_budget=probe_budget,
                                          dmask=dmask)
        return {"ids": cids, "sim": csims, "valid": cvalid,
                "category": jnp.broadcast_to(
                    jnp.arange(C, dtype=jnp.int32)[None, :, None],
                    cids.shape),
                "stats": stats}

    return fn


def build_category_join(a: Analysis, catalog: Catalog, opts: EngineOptions,
                        binds_static: Bindings) -> Callable:
    """Q6 (category-driven join): Q5's probe+rank per left row, batched."""
    if opts.join_lowering == "perleft":
        return _build_category_join_perleft(a, catalog, opts, binds_static)
    ltab, rtab = catalog.table(a.left_table), catalog.table(a.right_table)
    metric = _metric_of(catalog, a.right_table, a.right_vector)
    k = _static_int(a.k, binds_static, "K")
    cat_col = a.category_column.name
    C = rtab.schema[cat_col].num_categories
    assert C, f"category column {cat_col} needs num_categories"
    mask_b = _join_mask_batch_fn(a.join_predicate, ltab, rtab, a.left_alias,
                                 a.right_alias)
    index = catalog.index_for(a.right_table, a.right_vector)
    # legacy-parity quirk: the per-left Q6 vbase plan never counted its
    # redundant re-sort evals — keep counters identical across lowerings
    core = _category_core(opts, metric, index, C, k, vbase_extra_evals=False,
                          n_rows=rtab.num_rows,
                          per_query_mask=a.join_predicate is not None)
    radius_expr = a.radius

    def fn(arrays, binds):
        lvec = arrays["left"]                                  # (L, d)
        nleft = lvec.shape[0]
        radius = evaluate(radius_expr, rtab, binds)
        rm = mask_b(binds) if mask_b else None                 # (L, N)
        cids, csims, cvalid, stats = core(arrays, lvec, radius, rm)
        return {"qid": jnp.broadcast_to(
                    jnp.arange(nleft, dtype=jnp.int32)[:, None, None],
                    cids.shape),
                "tid": cids, "sim": csims, "valid": cvalid,
                "category": jnp.broadcast_to(
                    jnp.arange(C, dtype=jnp.int32)[None, :, None],
                    cids.shape),
                "stats": stats}

    return fn


def build_category_join_batch(a: Analysis, catalog: Catalog,
                              opts: EngineOptions,
                              binds_static: Bindings) -> Callable:
    """Q bind sets x L left rows, flattened into ONE kernel query batch."""
    ltab, rtab = catalog.table(a.left_table), catalog.table(a.right_table)
    metric = _metric_of(catalog, a.right_table, a.right_vector)
    k = _static_int(a.k, binds_static, "K")
    cat_col = a.category_column.name
    C = rtab.schema[cat_col].num_categories
    assert C, f"category column {cat_col} needs num_categories"
    mask_b = _join_mask_batch_fn(a.join_predicate, ltab, rtab, a.left_alias,
                                 a.right_alias)
    index = catalog.index_for(a.right_table, a.right_vector)
    live = catalog.live_for(a.right_table, a.right_vector) is not None
    core = _category_core(opts, metric, index, C, k, vbase_extra_evals=False,
                          n_rows=rtab.num_rows,
                          per_query_mask=(a.join_predicate is not None
                                          or live),
                          live=live, cat_col=cat_col)
    radius_expr = a.radius

    def fn(arrays, binds, qvalid=None, probe_budget=None):
        if live:
            qn, nleft, qs, _ = _flatten_left_batch(arrays["left"], binds,
                                                   None)
            rm, dmask = _live_join_masks(a.join_predicate, ltab, rtab,
                                         a.left_alias, a.right_alias,
                                         arrays, binds, qn, nleft)
        else:
            qn, nleft, qs, rm = _flatten_left_batch(arrays["left"], binds,
                                                    mask_b)
            dmask = None
        fq, fb = _flatten_valid_budget(qvalid, probe_budget, qn, nleft)
        radius = jnp.broadcast_to(
            jax.vmap(lambda b: evaluate(radius_expr, rtab, b))(binds), (qn,))
        cids, csims, cvalid, stats = core(
            arrays, qs, jnp.repeat(radius, nleft), rm, qvalid=fq,
            probe_budget=fb, dmask=dmask)
        shape = (qn, nleft, C, k)
        return {"qid": jnp.broadcast_to(
                    jnp.arange(nleft, dtype=jnp.int32)[None, :, None, None],
                    shape),
                "tid": cids.reshape(shape), "sim": csims.reshape(shape),
                "valid": cvalid.reshape(shape),
                "category": jnp.broadcast_to(
                    jnp.arange(C, dtype=jnp.int32)[None, None, :, None],
                    shape),
                "stats": jax.tree.map(lambda v: v.reshape(qn, nleft), stats)}

    return fn


def _build_category_join_perleft(a: Analysis, catalog: Catalog,
                                 opts: EngineOptions,
                                 binds_static: Bindings) -> Callable:
    """Legacy lowering: one category probe per left row (vmapped matvecs)."""
    ltab, rtab = catalog.table(a.left_table), catalog.table(a.right_table)
    metric = _metric_of(catalog, a.right_table, a.right_vector)
    k = _static_int(a.k, binds_static, "K")
    cat_col = a.category_column.name
    C = rtab.schema[cat_col].num_categories
    assert C, f"category column {cat_col} needs num_categories"
    pair_mask = _join_mask_fn(a.join_predicate, ltab, rtab, a.left_alias,
                              a.right_alias)
    index = catalog.index_for(a.right_table, a.right_vector)
    cfg = dataclasses.replace(opts.probe, num_categories=C, k_per_category=k)
    radius_expr = a.radius
    use_update_state = opts.engine == "chase"

    def fn(arrays, binds):
        lvec = arrays["left"]
        corpus = arrays["corpus"]
        cats = arrays["categories"]
        radius = evaluate(radius_expr, rtab, binds)
        nleft = lvec.shape[0]

        def per_left(i):
            q = lvec[i]
            rm = pair_mask(i, binds) if pair_mask else None
            if index is not None and opts.engine in ("chase", "vbase",
                                                     "chase_no_updatestate"):
                idx = arrays["index"]
                if use_update_state:
                    ids, sims, valid, count, stats = ivf_range_category(
                        idx, corpus, cats, q, radius, rm, cfg)
                else:
                    ids, sims, valid, count, stats = ivf_range(
                        idx, corpus, q, radius, rm, cfg)
                if opts.engine == "vbase":
                    safe = jnp.maximum(ids, 0)
                    raw = distance_values(metric, corpus[safe], q)  # REDUNDANT
                    sims = jnp.where(valid, raw, 0.0)
            else:
                flat = FlatIndex(metric, corpus)
                hit, raw = flat.range_mask(q, radius, rm)
                keys = jnp.where(hit, order_key(metric, raw), jnp.inf)
                neg, sel = jax.lax.top_k(-keys, cfg.capacity)
                valid = jnp.isfinite(-neg)
                ids = jnp.where(valid, sel.astype(jnp.int32), -1)
                sims = jnp.where(valid, raw[sel], 0.0)
                stats = {"probes": jnp.int32(0),
                         "distance_evals": jnp.int32(corpus.shape[0])}
            keys = jnp.where(valid, order_key(metric, sims), jnp.inf)
            bcats = jnp.where(valid, cats[jnp.maximum(ids, 0)], -1)
            cids, csims, cvalid = _rank_per_category(metric, ids, keys, valid,
                                                     bcats, C, k)
            return cids, csims, cvalid, stats

        cids, csims, cvalid, stats = jax.vmap(per_left)(
            jnp.arange(nleft, dtype=jnp.int32))
        return {"qid": jnp.broadcast_to(
                    jnp.arange(nleft, dtype=jnp.int32)[:, None, None],
                    cids.shape),
                "tid": cids, "sim": csims, "valid": cvalid,
                "category": jnp.broadcast_to(
                    jnp.arange(C, dtype=jnp.int32)[None, :, None], cids.shape),
                "stats": stats}

    return fn


# ---------------------------------------------------------------------------
# Batched execution path — parameter-only batches (same plan, Q bind vectors)
# ---------------------------------------------------------------------------
#
# Batch builders receive ``binds`` whose every value carries a leading Q axis
# (the compiler stacks/broadcasts them) and lower onto the NATIVE batched
# operators: the query-tiled Pallas scans and the multi-cluster IVF probes.
# Structured predicates evaluate per query via vmap, producing a (Q, N) mask
# the fused kernels consume directly.  Query classes without a native batched
# builder fall back to a vmap of their single-query pipeline in the compiler.

def build_vknn_sf_batch(a: Analysis, catalog: Catalog, opts: EngineOptions,
                        binds_static: Bindings) -> Callable:
    """Q1 batched: Q bind sets on the query-tiled kernels / batched probes
    (uniform batch_fn signature — see :class:`CompiledPlan`)."""
    table = catalog.table(a.table)
    metric = _metric_of(catalog, a.table, a.vector_column)
    k = _static_int(a.k, binds_static, "K")
    mask_fn = _row_mask_fn(a.structured_predicate, table)
    qparam = a.query_expr
    assert isinstance(qparam, Param), "VKNN-SF query must be a parameter"
    index = catalog.index_for(a.table, a.vector_column)
    cfg = opts.probe
    live = catalog.live_for(a.table, a.vector_column) is not None
    dist = (_dist_topk_core(opts, metric, k,
                            per_query_mask=mask_fn is not None or live)
            if opts.dist is not None else None)

    def fn(arrays, binds, qvalid=None, probe_budget=None):
        corpus = arrays["corpus"]
        n = corpus.shape[0]
        qs = jnp.asarray(binds[qparam.name])                     # (Q, D)
        qn = qs.shape[0]
        dmask = None
        if live:
            row_mask, dmask = _live_scan_masks(a.structured_predicate,
                                               arrays, binds, qn)
        else:
            row_mask = jax.vmap(mask_fn)(binds) if mask_fn else None  # (Q, N)
        if dist is not None:
            ids, sims, valid, stats = dist(arrays, qs,
                                           _as_per_query(row_mask, qn),
                                           qvalid)
        elif opts.engine == "chase" and index is not None:
            idx: IVFIndex = arrays["index"]
            ids, sims, valid, stats = ivf_topk_batch(
                idx, corpus, qs, k, _as_per_query(row_mask, qn), cfg,
                probe_budget=probe_budget, qvalid=qvalid)
        elif opts.engine == "vbase" and index is not None:
            idx = arrays["index"]
            ids, _sims, valid, stats = ivf_topk_batch(
                idx, corpus, qs, k, _as_per_query(row_mask, qn), cfg,
                probe_budget=probe_budget, qvalid=qvalid)
            ids, sims, valid = jax.vmap(
                lambda q, i, v: _resort_redundant(metric, corpus, q, i, v, k)
            )(qs, ids, valid)
            extra = k if qvalid is None else jnp.where(qvalid, k, 0)
            stats = dict(stats)
            stats["distance_evals"] = stats["distance_evals"] + extra
        elif opts.engine == "pase" and index is not None:
            idx = arrays["index"]
            kk = min(opts.pase_oversample * k, n)
            ids_o, sims_o, valid_o, stats = ivf_topk_batch(
                idx, corpus, qs, kk, None, cfg,
                probe_budget=probe_budget, qvalid=qvalid)

            def post(ids_q, sims_q, valid_q, rm_q):
                if rm_q is not None:
                    valid_q = valid_q & jnp.where(
                        ids_q >= 0, rm_q[jnp.maximum(ids_q, 0)], False)
                keep = jnp.cumsum(valid_q) <= k
                valid_q = valid_q & keep
                keys = jnp.where(valid_q, order_key(metric, sims_q), jnp.inf)
                neg, sel = jax.lax.top_k(-keys, k)
                v = jnp.isfinite(-neg)
                return (jnp.where(v, ids_q[sel], -1),
                        jnp.where(v, sims_q[sel], 0.0), v)

            if row_mask is None:
                ids, sims, valid = jax.vmap(
                    lambda i, s, v: post(i, s, v, None))(ids_o, sims_o,
                                                         valid_o)
            else:
                ids, sims, valid = jax.vmap(post)(
                    ids_o, sims_o, valid_o, _as_per_query(row_mask, qn))
        else:  # brute (LingoDB-V analogue) or missing index
            if (opts.use_pallas and opts.quant is None and qn == 1
                    and qvalid is None
                    and (row_mask is None or row_mask.ndim == 1)):
                # single-query fast path: plans routed through
                # _single_via_batch (live/dist/quant singles) share the
                # 1-D validity-lane single kernel instead of paying the
                # batched kernel's BLOCK_Q=8 pad + (Q, N) mask broadcast
                # — the q12 b1 live-scan overhead (bench_gate gates it)
                from ..kernels.ops import fused_scan_topk
                i1, s1, v1 = fused_scan_topk(
                    corpus, qs[0], k, row_mask, metric,
                    interpret=opts.interpret_pallas)
                ids, sims, valid = i1[None], s1[None], v1[None]
            elif opts.use_pallas:
                ids, sims, valid = _flat_topk_batch(
                    opts, arrays, metric, corpus, qs, k, row_mask,
                    qvalid=qvalid)
            else:
                flat = FlatIndex(metric, corpus)
                if row_mask is None:
                    ids, sims, valid = jax.vmap(
                        lambda q: flat.topk(q, k, None))(qs)
                elif row_mask.ndim == 1:            # shared live validity lane
                    ids, sims, valid = jax.vmap(
                        lambda q: flat.topk(q, k, row_mask))(qs)
                else:
                    ids, sims, valid = jax.vmap(
                        lambda q, rm: flat.topk(q, k, rm))(qs, row_mask)
                if qvalid is not None:
                    valid = valid & qvalid[:, None]
                    ids = jnp.where(valid, ids, -1)
                    sims = jnp.where(valid, sims, 0.0)
            stats = {"probes": jnp.zeros((qn,), jnp.int32),
                     "distance_evals": _flat_evals(qvalid, qn, n)}
        if live:
            ids, sims, valid, stats = _merge_delta_topk(
                opts, metric, arrays, qs, k, dmask, qvalid,
                ids, sims, valid, stats)
        return {"ids": ids, "sim": sims, "valid": valid, "stats": stats}

    return fn


def build_dr_sf_batch(a: Analysis, catalog: Catalog, opts: EngineOptions,
                      binds_static: Bindings) -> Callable:
    """Q2 batched: Q bind sets on the batched range kernels / probes."""
    table = catalog.table(a.table)
    metric = _metric_of(catalog, a.table, a.vector_column)
    mask_fn = _row_mask_fn(a.structured_predicate, table)
    qparam = a.query_expr
    index = catalog.index_for(a.table, a.vector_column)
    cfg = opts.probe
    radius_expr = a.radius
    live = catalog.live_for(a.table, a.vector_column) is not None
    dist = (_dist_range_core(opts, metric, cfg.capacity, table.num_rows,
                             per_query_mask=mask_fn is not None or live)
            if opts.dist is not None else None)

    def radius_of(binds):
        return evaluate(radius_expr, table, binds)

    def fn(arrays, binds, qvalid=None, probe_budget=None):
        corpus = arrays["corpus"]
        n = corpus.shape[0]
        qs = jnp.asarray(binds[qparam.name])                      # (Q, D)
        qn = qs.shape[0]
        radius = jnp.broadcast_to(jax.vmap(radius_of)(binds), (qn,))
        dmask = None
        if live:
            row_mask, dmask = _live_scan_masks(a.structured_predicate,
                                               arrays, binds, qn)
        else:
            row_mask = jax.vmap(mask_fn)(binds) if mask_fn else None  # (Q, N)
        if dist is not None:
            ids, sims, valid, count, stats = dist(arrays, qs, radius,
                                                  _as_per_query(row_mask, qn),
                                                  qvalid)
        elif opts.engine == "chase" and index is not None:
            idx = arrays["index"]
            ids, sims, valid, count, stats = ivf_range_batch(
                idx, corpus, qs, radius, _as_per_query(row_mask, qn), cfg,
                probe_budget=probe_budget, qvalid=qvalid)
        elif opts.engine == "vbase" and index is not None:
            idx = arrays["index"]
            ids, _sims, valid, count, stats = ivf_range_batch(
                idx, corpus, qs, radius, None, cfg,
                probe_budget=probe_budget, qvalid=qvalid)

            def post(q, ids_q, valid_q, r_q, rm_q):
                safe = jnp.maximum(ids_q, 0)
                raw = distance_values(metric, corpus[safe], q)    # REDUNDANT
                v = valid_q & in_range(metric, raw, r_q)
                if rm_q is not None:
                    v = v & rm_q[safe]
                return jnp.where(v, raw, 0.0), v

            if row_mask is None:
                sims, valid = jax.vmap(
                    lambda q, i, v, r: post(q, i, v, r, None))(
                        qs, ids, valid, radius)
            else:
                sims, valid = jax.vmap(post)(qs, ids, valid, radius,
                                             _as_per_query(row_mask, qn))
            count = jnp.sum(valid, axis=1)
            extra = (cfg.capacity if qvalid is None
                     else jnp.where(qvalid, cfg.capacity, 0))
            stats = dict(stats)
            stats["distance_evals"] = stats["distance_evals"] + extra
        else:
            # PASE/pgvector cannot route range queries to the ANN index (§2.3)
            ids, sims, valid, count, stats = _flat_range_topk_batch(
                opts, metric, corpus, qs, radius, row_mask, cfg.capacity,
                qvalid=qvalid, arrays=arrays)
        if live:
            ids, sims, valid, count, stats = _merge_delta_range(
                opts, metric, arrays, qs, radius, cfg.capacity, dmask,
                qvalid, ids, sims, valid, count, stats)
        return {"ids": ids, "sim": sims, "valid": valid, "count": count,
                "stats": stats}

    return fn


BUILDERS = {
    QueryClass.VKNN_SF: build_vknn_sf,
    QueryClass.DR_SF: build_dr_sf,
    QueryClass.DIST_JOIN: build_dist_join,
    QueryClass.KNN_JOIN: build_knn_join,
    QueryClass.CATEGORY_PARTITION: build_category_partition,
    QueryClass.CATEGORY_JOIN: build_category_join,
}

# Every hybrid class now has a NATIVE batched lowering.  Join families
# flatten (bind sets x left rows) into one kernel-level query batch; the
# vmap-of-scalar fallback remains only for join_lowering='perleft'
# (core/compiler.py gates it — the measured baseline).
BATCH_BUILDERS = {
    QueryClass.VKNN_SF: build_vknn_sf_batch,
    QueryClass.DR_SF: build_dr_sf_batch,
    QueryClass.DIST_JOIN: build_dist_join_batch,
    QueryClass.KNN_JOIN: build_knn_join_batch,
    QueryClass.CATEGORY_PARTITION: build_category_partition_batch,
    QueryClass.CATEGORY_JOIN: build_category_join_batch,
}

# the join classes whose lowering obeys opts.join_lowering: 'perleft' swaps
# their single-call builder for the legacy loop AND forces the vmap
# execute_batch fallback.  Q5 (CATEGORY_PARTITION) has no per-left loop, so
# the flag never touches it — its bind-batch builder is always native.
JOIN_LOWERING_FAMILIES = frozenset({
    QueryClass.DIST_JOIN, QueryClass.KNN_JOIN, QueryClass.CATEGORY_JOIN,
})
