"""CHASE core: native hybrid-query engine (the paper's contribution).

``compile_query``/``CompiledQuery`` are the legacy one-shot surface; new
code should go through the session API (:mod:`repro.api`), which adds a
normalized plan cache, unified execution hints, and structured results on
top of the same compilation stack."""
from .compiler import (BucketedExecutor, CompiledPlan, CompiledQuery,
                       StalePlanError, compile_plan, compile_query,
                       plan_fingerprint)
from .expr import Bindings, Column, Const, Distance, Param
from .physical import EngineOptions
from .schema import (Catalog, ColumnKind, ColumnType, Metric, Schema, Table,
                     bool_col, category_col, float_col, int_col, vector_col)
from .semantics import Analysis, QueryClass, analyze
from .sql import parse_sql
from .rewriter import rewrite

__all__ = [
    "BucketedExecutor", "CompiledPlan", "CompiledQuery", "StalePlanError",
    "compile_plan", "compile_query", "plan_fingerprint", "Bindings", "Column", "Const",
    "Distance", "Param", "EngineOptions", "Catalog", "ColumnKind",
    "ColumnType", "Metric", "Schema", "Table", "bool_col", "category_col",
    "float_col", "int_col", "vector_col", "Analysis", "QueryClass", "analyze",
    "parse_sql", "rewrite",
]
