"""CHASE core: native hybrid-query engine (the paper's contribution)."""
from .compiler import CompiledQuery, compile_query
from .expr import Bindings, Column, Const, Distance, Param
from .physical import EngineOptions
from .schema import (Catalog, ColumnKind, ColumnType, Metric, Schema, Table,
                     bool_col, category_col, float_col, int_col, vector_col)
from .semantics import Analysis, QueryClass, analyze
from .sql import parse_sql
from .rewriter import rewrite

__all__ = [
    "CompiledQuery", "compile_query", "Bindings", "Column", "Const",
    "Distance", "Param", "EngineOptions", "Catalog", "ColumnKind",
    "ColumnType", "Metric", "Schema", "Table", "bool_col", "category_col",
    "float_col", "int_col", "vector_col", "Analysis", "QueryClass", "analyze",
    "parse_sql", "rewrite",
]
