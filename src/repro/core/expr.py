"""Scalar / vector expression IR for predicates and projections.

Mirrors CHASE §6's db-dialect extensions: distance functions
(``L2Distance`` / ``InnerProduct``) are expression nodes over a first-class
vector column, so the optimizer can *see* them — the prerequisite for the map
operator rewrite (R1) and for routing a predicate ``DISTANCE(...) <= r`` to the
ANN range-scan physical operator instead of a brute-force filter.

Expressions evaluate columnar over a Table (every node returns an (N,) array,
or (N, dim) for vector-valued nodes), so the compiled plan is pure vectorized
JAX — this *is* the data-centric codegen analogue.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax.numpy as jnp

from .schema import Metric, Table


class Expr:
    """Base expression node; operator overloads build trees Python-side."""

    def children(self) -> Sequence["Expr"]:
        """Direct child expressions (empty for leaves)."""
        return ()

    # -- convenience builders -------------------------------------------------
    def __lt__(self, o): return Cmp("<", self, wrap(o))
    def __le__(self, o): return Cmp("<=", self, wrap(o))
    def __gt__(self, o): return Cmp(">", self, wrap(o))
    def __ge__(self, o): return Cmp(">=", self, wrap(o))

    def eq(self, o):
        """Build an equality comparison (``=``; ``==`` is identity here)."""
        return Cmp("=", self, wrap(o))

    def ne(self, o):
        """Build an inequality comparison (``<>``)."""
        return Cmp("<>", self, wrap(o))

    def __and__(self, o): return BoolOp("and", (self, wrap(o)))
    def __or__(self, o): return BoolOp("or", (self, wrap(o)))
    def __invert__(self): return BoolOp("not", (self,))
    def __add__(self, o): return Arith("+", self, wrap(o))
    def __sub__(self, o): return Arith("-", self, wrap(o))
    def __mul__(self, o): return Arith("*", self, wrap(o))


def wrap(v) -> Expr:
    """Lift a Python value into the IR (passthrough for Expr nodes)."""
    return v if isinstance(v, Expr) else Const(v)


@dataclasses.dataclass(frozen=True, eq=False)
class Column(Expr):
    """A (possibly table-qualified) column reference."""
    name: str
    table: str | None = None   # qualifier, e.g. "users.embedding"

    def __repr__(self):
        return f"{self.table}.{self.name}" if self.table else self.name


@dataclasses.dataclass(frozen=True, eq=False)
class Const(Expr):
    """A literal constant (number, bool, or array-like)."""
    value: Any

    def __repr__(self):
        return repr(self.value)


@dataclasses.dataclass(frozen=True, eq=False)
class Param(Expr):
    """A `${name}` placeholder bound at execution time (query vector, radius...)."""
    name: str

    def __repr__(self):
        return f"${{{self.name}}}"


@dataclasses.dataclass(frozen=True, eq=False)
class Cmp(Expr):
    """A binary comparison (``< <= > >= = <>``)."""
    op: str  # < <= > >= = <>
    lhs: Expr
    rhs: Expr

    def children(self):
        """Direct child expressions: (lhs, rhs)."""
        return (self.lhs, self.rhs)

    def __repr__(self):
        return f"({self.lhs!r} {self.op} {self.rhs!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class BoolOp(Expr):
    """A boolean connective over operand expressions (``and/or/not``)."""
    op: str  # and / or / not
    operands: tuple[Expr, ...]

    def children(self):
        """Direct child expressions: the operands."""
        return self.operands

    def __repr__(self):
        if self.op == "not":
            return f"(not {self.operands[0]!r})"
        return "(" + f" {self.op} ".join(map(repr, self.operands)) + ")"


@dataclasses.dataclass(frozen=True, eq=False)
class Arith(Expr):
    """A binary arithmetic expression (``+ - * /``)."""
    op: str  # + - * /
    lhs: Expr
    rhs: Expr

    def children(self):
        """Direct child expressions: (lhs, rhs)."""
        return (self.lhs, self.rhs)

    def __repr__(self):
        return f"({self.lhs!r} {self.op} {self.rhs!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class Distance(Expr):
    """DISTANCE(vector_expr, vector_expr) — the hybrid-query pivot node.

    ``metric`` resolves from the column's declared metric at bind time.
    Under similarity metrics (IP/cosine) the paper's convention is that
    ``ORDER BY DISTANCE(...)`` ranks most-similar first and
    ``DISTANCE(...) <= r`` means similarity >= r (LAION uses inner product with
    threshold 0.8); the engine normalizes both through :meth:`score`.
    """
    lhs: Expr
    rhs: Expr
    metric: Metric | None = None

    def children(self):
        """Direct child expressions: (lhs, rhs)."""
        return (self.lhs, self.rhs)

    def __repr__(self):
        return f"DISTANCE({self.lhs!r}, {self.rhs!r})"


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------

def distance_values(metric: Metric, x: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Rowwise distance/similarity between (N,d) x and (d,) or (N,d) q."""
    if q.ndim == 1:
        q = jnp.broadcast_to(q, x.shape)
    x = x.astype(jnp.float32)
    q = q.astype(jnp.float32)
    if metric == Metric.L2:
        d = x - q
        return jnp.sum(d * d, axis=-1)
    if metric == Metric.INNER_PRODUCT:
        return jnp.sum(x * q, axis=-1)
    if metric == Metric.COSINE:
        num = jnp.sum(x * q, axis=-1)
        den = jnp.linalg.norm(x, axis=-1) * jnp.linalg.norm(q, axis=-1) + 1e-12
        return num / den
    raise ValueError(metric)


def order_key(metric: Metric, values: jnp.ndarray) -> jnp.ndarray:
    """Map raw distance/similarity to an ascending sort key (smaller = better)."""
    return -values if metric.is_similarity() else values


def in_range(metric: Metric, values: jnp.ndarray, radius) -> jnp.ndarray:
    """``DISTANCE(x,q) <= radius`` under the paper's convention."""
    return values >= radius if metric.is_similarity() else values <= radius


class Bindings(dict):
    """Parameter name → value (query vectors, thresholds, K...)."""


def evaluate(expr: Expr, table: Table, binds: Bindings,
             prefix_cols: dict[str, jnp.ndarray] | None = None) -> jnp.ndarray:
    """Columnar evaluation of ``expr`` over ``table``.

    ``prefix_cols`` supplies extra computed columns (e.g. the map operator's
    ``__sim``) that shadow schema columns.
    """
    pc = prefix_cols or {}

    def ev(e: Expr) -> jnp.ndarray:
        if isinstance(e, Column):
            if e.name in pc:
                return pc[e.name]
            return table[e.name]
        if isinstance(e, Const):
            return jnp.asarray(e.value)
        if isinstance(e, Param):
            return jnp.asarray(binds[e.name])
        if isinstance(e, Cmp):
            lo, hi = ev(e.lhs), ev(e.rhs)
            return {
                "<": lambda: lo < hi, "<=": lambda: lo <= hi,
                ">": lambda: lo > hi, ">=": lambda: lo >= hi,
                "=": lambda: lo == hi, "<>": lambda: lo != hi,
            }[e.op]()
        if isinstance(e, BoolOp):
            if e.op == "not":
                return ~ev(e.operands[0])
            vals = [ev(o) for o in e.operands]
            out = vals[0]
            for v in vals[1:]:
                out = (out & v) if e.op == "and" else (out | v)
            return out
        if isinstance(e, Arith):
            lo, hi = ev(e.lhs), ev(e.rhs)
            return {"+": lambda: lo + hi, "-": lambda: lo - hi,
                    "*": lambda: lo * hi, "/": lambda: lo / hi}[e.op]()
        if isinstance(e, Distance):
            x = ev(e.lhs)
            q = ev(e.rhs)
            metric = e.metric or Metric.INNER_PRODUCT
            return distance_values(metric, x, q)
        raise TypeError(f"cannot evaluate {type(e)}")

    return ev(expr)


# -- structural helpers used by the semantic analyzer -----------------------

def walk(expr: Expr):
    """Yield ``expr`` and every descendant, pre-order."""
    yield expr
    for c in expr.children():
        yield from walk(c)


def find_distance(expr: Expr) -> Distance | None:
    """First :class:`Distance` node in the tree, or None."""
    for node in walk(expr):
        if isinstance(node, Distance):
            return node
    return None


def contains_distance(expr: Expr) -> bool:
    """True iff the tree contains a :class:`Distance` node."""
    return find_distance(expr) is not None


def split_conjuncts(expr: Expr | None) -> list[Expr]:
    """Flatten nested ANDs into a conjunct list."""
    if expr is None:
        return []
    if isinstance(expr, BoolOp) and expr.op == "and":
        out: list[Expr] = []
        for o in expr.operands:
            out.extend(split_conjuncts(o))
        return out
    return [expr]


def conjoin(exprs: Sequence[Expr]) -> Expr | None:
    """AND a conjunct list back together (None/identity for 0/1 items)."""
    exprs = list(exprs)
    if not exprs:
        return None
    if len(exprs) == 1:
        return exprs[0]
    return BoolOp("and", tuple(exprs))
