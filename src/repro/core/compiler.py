"""Query compilation — CHASE §6, XLA edition.

LingoDB lowers relalg -> subop -> LLVM IR -> machine code.  Here the analogue
chain is: logical plan -> (semantic analysis + rewrite) -> physical builder ->
traced JAX function -> jaxpr -> XLA HLO -> machine code.  CSE / DCE / constant
folding (§6's "general passes") happen inside XLA.  One pipeline = one fused
XLA computation; there is no operator interpretation at runtime.

The compilation product is split in two (the size-bucketed execution stack,
DESIGN.md §8):

* :class:`CompiledPlan` — the shape-independent plan artifact: analysis,
  plans, options, and the traced-but-unjitted single/batch pipeline
  functions.  §6's "one plan, one executable" claim generalizes to "one
  plan, one executable *per batch shape*" under serving traffic — which is
  exactly the problem, because every distinct request-batch size Q retraces.
* :class:`BucketedExecutor` — the runtime half: a lazy per-power-of-two
  bucket executor cache.  A batch of Q queries pads up to the enclosing
  bucket, runs the bucket's (single, reused) executable with a per-query
  ``valid`` mask that makes pad queries inert at every layer (kernel mask
  lanes, IVF ``active`` state), and slices outputs back to Q.

:class:`CompiledQuery` remains the user-facing handle tying the two
together (plus the exact-shape ``execute_batch`` used as the bit-parity
reference and by callers with a fixed batch size).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .expr import BoolOp, Bindings, Expr, Param
from .physical import (BATCH_BUILDERS, BUILDERS, JOIN_LOWERING_FAMILIES,
                       EngineOptions)
from .plan import PlanNode
from .rewriter import rewrite
from .schema import Catalog
from .semantics import Analysis, QueryClass, analyze
from .sql import parse_sql


class StalePlanError(RuntimeError):
    """A compiled plan's catalog registrations changed in a way that cannot
    be re-bound in place (DESIGN.md §11).

    Raised when a table was re-registered (the builders close over its
    predicate columns) or an index appeared/disappeared after compilation
    (index *presence* selects the lowering at build time).  Recovery is a
    re-prepare: the session API does it transparently
    (:meth:`repro.api.Statement` re-prepares through the plan cache); legacy
    ``compile_query`` callers must compile fresh."""


def _scan_of(a: Analysis) -> tuple[str, str]:
    """The (table, vector column) pair a plan's corpus scan reads — the
    pair live-corpus / index / sharded registrations key on."""
    if a.query_class in (QueryClass.VKNN_SF, QueryClass.DR_SF,
                         QueryClass.CATEGORY_PARTITION):
        return a.table, a.vector_column
    return a.right_table, a.right_vector


def _catalog_dep_keys(a: Analysis, catalog: Catalog,
                      options: EngineOptions) -> tuple:
    """The catalog registration keys a compiled plan captures — what
    :meth:`CompiledQuery.ensure_fresh` watches for version bumps."""
    qc = a.query_class
    scan = _scan_of(a)
    if qc in (QueryClass.VKNN_SF, QueryClass.DR_SF,
              QueryClass.CATEGORY_PARTITION):
        keys = [("table", a.table), ("index",) + scan]
    else:
        keys = [("table", a.left_table), ("table", a.right_table),
                ("index",) + scan]
    if options.dist is not None:
        keys.append(("sharded",) + scan)
    if options.quant is not None and catalog.live_for(*scan) is None:
        # frozen quantized twin: a re-registered same-shape twin re-binds
        # in place (live twins instead ride the live key — mutations bump
        # it, and the twin caches on the LiveCorpus device dict)
        keys.append(("quantized",) + scan)
    if catalog.live_for(*scan) is not None:
        # every insert/delete/compact bumps this key: mutations become
        # visible through the in-place array re-bind, zero retraces
        keys.append(("live",) + scan)
    return tuple(keys)


# ---------------------------------------------------------------------------
# plan fingerprinting (the normalized plan-cache key, DESIGN.md §9)
# ---------------------------------------------------------------------------
#
# Two SQL texts that parse to the same logical plan modulo (a) whitespace,
# (b) parameter names, and (c) the order of commutative AND/OR conjuncts
# must share one CompiledPlan — plan reuse across requests is the dominant
# serving cost, and prepared statements arrive in every textual variant.
#
# Canonicalization: parameters are renamed positionally (?0, ?1, ... in
# canonical traversal order) and commutative BoolOp operands are sorted by
# their *name-erased* serialization (params rendered as a bare "?"), so the
# operand order and the positional assignment are both stable across
# variants.  The fingerprint is the canonical serialization; the canonical
# parameter order is returned alongside so a cache hit can translate the
# statement's own bind names onto the cached plan's names.

def _param_slot(params: list, name: str) -> int:
    if name not in params:
        params.append(name)
    return params.index(name)


def _fp_value(v: Any, params: list | None) -> str:
    if isinstance(v, (Expr, PlanNode)):
        return _fp_node(v, params)
    if isinstance(v, tuple):
        return "(" + ",".join(_fp_value(x, params) for x in v) + ")"
    return repr(v)


def _fp_node(n: Any, params: list | None) -> str:
    """Serialize one plan/expr node; ``params is None`` => name-erased mode
    (every parameter renders as "?" — the commutative-sort key)."""
    if isinstance(n, Param):
        return "?" if params is None else f"?{_param_slot(params, n.name)}"
    parts = []
    for f in dataclasses.fields(n):
        v = getattr(n, f.name)
        # Limit.k (and the rewritten nodes' k) may hold a *param name* string
        if f.name == "k" and isinstance(v, str):
            parts.append("?" if params is None
                         else f"?{_param_slot(params, v)}")
            continue
        if (isinstance(n, BoolOp) and f.name == "operands"
                and n.op in ("and", "or")):
            erased = [_fp_node(o, None) for o in n.operands]
            order = sorted(range(len(erased)), key=erased.__getitem__)
            parts.append("(" + ",".join(
                _fp_node(n.operands[i], params) for i in order) + ")")
            continue
        parts.append(_fp_value(v, params))
    return type(n).__name__ + "[" + ";".join(parts) + "]"


def plan_fingerprint(plan: PlanNode) -> tuple[str, tuple[str, ...]]:
    """Canonical fingerprint of a logical plan.

    Returns ``(fingerprint, param_order)``: the fingerprint is identical for
    whitespace / parameter-rename / AND-OR-operand-order variants of the same
    SQL, and ``param_order[i]`` is THIS plan's original name for canonical
    parameter slot ``i`` (two variant plans align slot-by-slot)."""
    params: list[str] = []
    fp = _fp_node(plan, params)
    return fp, tuple(params)


def fingerprint_digest(fp: str) -> str:
    """Short stable digest of a plan fingerprint (for explain/report keys)."""
    return hashlib.sha256(fp.encode()).hexdigest()[:12]


@dataclasses.dataclass
class CompiledPlan:
    """Shape-independent compilation artifact (one per SQL + options).

    ``batch_fn`` has the uniform signature
    ``(arrays, binds, qvalid=None, probe_budget=None)``: every value in
    ``binds`` carries a leading Q axis, ``qvalid`` is an optional (Q,) bool
    marking size-bucket pad queries (inert: no results, zero counters), and
    ``probe_budget`` is an optional per-query IVF cluster budget (the
    straggler valve; ignored by index-less plans)."""
    sql: str
    analysis: Analysis
    logical_plan: PlanNode
    rewritten_plan: PlanNode
    options: EngineOptions
    fn: Callable
    batch_fn: Callable
    batch_native: bool
    batch_reason: str


def _bucket_for(qn: int) -> int:
    """Enclosing power-of-two size bucket (1, 2, 4, 8, ...)."""
    if qn < 1:
        raise ValueError(f"batch size must be >= 1, got {qn}")
    return 1 << (qn - 1).bit_length()


def _pad_leading(v, bucket: int) -> np.ndarray:
    """Edge-pad the leading Q axis up to ``bucket`` (pad rows repeat the last
    real row, so they are well-formed binds — correctness never depends on
    their values; the ``valid`` mask makes them inert).

    Host-side numpy on purpose: op-by-op jnp padding would compile a tiny
    XLA program per DISTINCT Q, re-introducing exactly the per-batch-size
    compile latency the bucket cache exists to kill."""
    v = np.asarray(v)
    pad = bucket - v.shape[0]
    if pad == 0:
        return v
    return np.concatenate(
        [v, np.broadcast_to(v[-1:], (pad,) + v.shape[1:])])


class BucketedExecutor:
    """Lazy per-(plan, bucket) executor cache — the serving execution tier.

    One jitted executable exists per power-of-two bucket actually seen;
    ``trace_counts[bucket]`` counts how many times that bucket's function was
    (re)traced, so tests can assert the compile-once contract.  A batch of Q
    requests pads to ``_bucket_for(Q)``, executes with ``valid[q] = q < Q``,
    and slices every output leaf back to Q.  Pad queries are inert by
    construction (kernel mask lanes / IVF ``active`` freeze), so bucketed
    results are bit-identical to an exact-shape ``execute_batch``.
    """

    def __init__(self, plan: CompiledPlan, arrays: Any):
        self.plan = plan
        self.arrays = arrays
        self._cache: dict[int, Any] = {}
        self.trace_counts: dict[int, int] = {}
        # persistent AOT plan cache (DESIGN.md §15): binding + loaded/
        # exported executables keyed (bucket, argument signature)
        self._aot = None
        self._aot_exec: dict[tuple[int, str], Any] = {}
        self.aot_loaded: dict[int, int] = {}

    def attach_aot(self, binding) -> None:
        """Route this executor through a persistent AOT plan cache
        (:class:`repro.core.aot.AOTPlanCache`, DESIGN.md §15).

        Once attached, every bucket executable is loaded from disk when a
        valid entry exists (zero traces — ``trace_counts`` stays honest)
        and exported + persisted write-through when it does not.  Failure
        anywhere in the persistence path degrades to the plain in-memory
        jit path with a typed :class:`~repro.core.aot.AOTCacheWarning`."""
        self._aot = binding

    def bucket_for(self, qn: int) -> int:
        """Enclosing power-of-two bucket a batch of ``qn`` queries runs in."""
        return _bucket_for(qn)

    @property
    def buckets(self) -> list[int]:
        """Buckets with a compiled executable (sorted)."""
        return sorted(self._cache)

    def executable(self, bucket: int):
        """The (lazily jitted) executable for one bucket."""
        if bucket not in self._cache:
            self.trace_counts.setdefault(bucket, 0)

            def run(arrays, binds, qvalid, probe_budget, _b=bucket):
                self.trace_counts[_b] += 1      # advances only on (re)trace
                return self.plan.batch_fn(arrays, binds, qvalid=qvalid,
                                          probe_budget=probe_budget)

            self._cache[bucket] = jax.jit(run)
        return self._cache[bucket]

    def run_padded(self, binds: dict, qn: int, probe_budget=None):
        """Execute at bucket granularity WITHOUT slicing outputs back.

        Returns (padded outputs, bucket, valid): every output leaf keeps its
        leading bucket axis, so tests (and debuggers) can observe that pad
        rows are inert — empty results, zero probe/distance counters."""
        bucket = _bucket_for(qn)
        padded = {k: _pad_leading(v, bucket) for k, v in binds.items()}
        valid = np.arange(bucket) < qn
        if probe_budget is not None:
            budget = np.asarray(probe_budget, np.int32)
            if budget.ndim >= 1 and budget.shape[0] == qn:
                budget = _pad_leading(budget, bucket)
            probe_budget = budget
        args = (self.arrays, padded, valid, probe_budget)
        if self._aot is not None:
            out = self._aot_call(bucket, args)
        else:
            out = self.executable(bucket)(*args)
        return out, bucket, valid

    # -- persistent AOT plan cache (DESIGN.md §15) --------------------------

    def _aot_call(self, bucket: int, args: tuple):
        """Dispatch one bucket execution through the persistent cache.

        Keyed by (bucket, argument signature): a live-corpus delta growth
        or index replacement that changes leaf shapes gets a new entry,
        exactly as the plain jit path would retrace."""
        from . import aot as _aot
        sig = _aot.args_signature(args)
        key = (bucket, sig)
        fn = self._aot_exec.get(key)
        if fn is None:
            fn = self._aot.cache.load(self._aot, bucket, sig)
            if fn is not None:
                # disk hit: executable restored without tracing anything
                self.trace_counts.setdefault(bucket, 0)
                self.aot_loaded[bucket] = self.aot_loaded.get(bucket, 0) + 1
            else:
                fn = self._aot_compile(bucket, sig, args)
            self._aot_exec[key] = fn
        return fn(args)

    def _aot_compile(self, bucket: int, sig: str, args: tuple):
        """Cold path under an attached cache: trace once via ``jax.export``,
        persist (portable StableHLO + native annex), return the compiled
        callable.  An unserializable plan restores the trace-count snapshot
        and falls back to the plain in-memory jit executable."""
        from . import aot as _aot
        binding = self._aot
        self.trace_counts.setdefault(bucket, 0)
        snapshot = self.trace_counts[bucket]
        leaves, treedef = jax.tree.flatten(args)

        def flat_run(lvs, _b=bucket, _td=treedef):
            self.trace_counts[_b] += 1      # advances only on (re)trace
            arrays, binds, qvalid, probe_budget = jax.tree.unflatten(_td, lvs)
            return self.plan.batch_fn(arrays, binds, qvalid=qvalid,
                                      probe_budget=probe_budget)

        try:
            exported = _aot.export_flat(flat_run, leaves)
            portable = exported.serialize()
        except Exception as exc:                       # noqa: BLE001
            # the failed export may have traced already: keep the count
            # honest before the plain path's own first-call trace
            self.trace_counts[bucket] = snapshot
            binding.cache.note_unserializable(binding.plan_key, exc)
            return lambda a, _b=bucket: self.executable(_b)(*a)
        compiled, annex = _aot.native_annex(exported, leaves)
        binding.cache.save(binding, bucket, sig, portable, annex)
        if compiled is not None:
            return lambda a: compiled(jax.tree.leaves(a))
        jitted = jax.jit(exported.call)
        return lambda a: jitted(jax.tree.leaves(a))

    def __call__(self, binds: dict, probe_budget=None):
        """Bucketed execution: pad -> run bucket executable -> slice to Q.

        Output slicing happens on host (numpy): a jnp slice would compile
        one tiny executable per distinct Q — see :func:`_pad_leading`."""
        qn = _stacked_qn(binds)
        out, _bucket, _valid = self.run_padded(binds, qn, probe_budget)
        return jax.tree.map(lambda v: np.asarray(v)[:qn], out)


def _stacked_qn(binds: dict) -> int:
    dims = [v.shape[0] for v in binds.values()
            if hasattr(v, "ndim") and v.ndim >= 1]
    if not dims:
        raise ValueError("stacked binds carry no leading batch axis")
    return dims[0]


@dataclasses.dataclass
class CompiledQuery:
    """User-facing handle: plan artifact + per-bucket executor cache.

    ``__call__`` runs the single-query executable; ``execute_batch`` runs the
    exact-shape batch executable (one trace per distinct Q — the bit-parity
    reference); ``execute_bucketed`` runs the size-bucketed serving path
    (one executable per power-of-two bucket, any Q)."""
    plan: CompiledPlan
    _jitted: Any
    _arrays: Any
    _batch_jitted: Any
    executor: BucketedExecutor
    # catalog-version invalidation (DESIGN.md §11): the catalog, the
    # registration keys this plan captured, and their versions at bind time
    _catalog: Any = None
    _dep_keys: tuple = ()
    _bound_versions: tuple = ()
    rebinds: int = 0

    # -- plan delegation (back-compat surface) ------------------------------
    @property
    def sql(self) -> str:
        """The statement's original SQL text."""
        return self.plan.sql

    @property
    def analysis(self) -> Analysis:
        """Semantic analysis (query class + extracted slots)."""
        return self.plan.analysis

    @property
    def logical_plan(self) -> PlanNode:
        """The parsed (pre-rewrite) logical plan."""
        return self.plan.logical_plan

    @property
    def rewritten_plan(self) -> PlanNode:
        """The CHASE-rewritten logical plan (R1-R3 applied)."""
        return self.plan.rewritten_plan

    @property
    def options(self) -> EngineOptions:
        """The EngineOptions this plan compiled under."""
        return self.plan.options

    @property
    def batch_native(self) -> bool:
        """True when execute_batch lowers natively (no vmap fallback)."""
        return self.plan.batch_native

    def ensure_fresh(self) -> bool:
        """Re-bind this plan to the catalog's current registrations.

        Called at execute time by every surface (single / exact-shape /
        bucketed, and by the session API / scheduler).  Compares the
        captured registration versions against the catalog clock:

        * unchanged — no-op (a few dict lookups);
        * an index / sharded-handle replacement — re-gathers the plan's
          device ``arrays`` in place (the jitted pipelines take arrays as an
          *argument*, so a same-shape replacement costs zero retraces) and
          returns True;
        * a table re-registration, or index presence flipping — raises
          :class:`StalePlanError` (the builders' closures hold stale state;
          only a re-prepare can fix it).
        """
        if self._catalog is None:
            return False
        current = self._catalog.version_snapshot(self._dep_keys)
        if current == self._bound_versions:
            return False
        stale_tables = [
            k[1] for k, old, new in zip(self._dep_keys, self._bound_versions,
                                        current)
            if old != new and k[0] == "table"]
        if stale_tables:
            raise StalePlanError(
                f"table(s) {stale_tables} were re-registered after this plan "
                f"compiled; the plan's predicate columns are frozen at the "
                f"old table — re-prepare the statement")
        new_arrays = _gather_arrays(self.analysis, self._catalog,
                                    self.options)
        if set(new_arrays) != set(self._arrays):
            raise StalePlanError(
                f"catalog registration change altered the plan's array set "
                f"({sorted(self._arrays)} -> {sorted(new_arrays)}); index "
                f"presence selects the lowering at compile time — "
                f"re-prepare the statement")
        # in place: the BucketedExecutor holds THE SAME dict object
        self._arrays.clear()
        self._arrays.update(new_arrays)
        self._bound_versions = self._catalog.version_snapshot(self._dep_keys)
        self.rebinds += 1
        return True

    def __call__(self, **binds):
        self.ensure_fresh()
        return self._jitted(self._arrays, dict(binds))

    def execute_batch(self, binds_list: list[dict] | None = None, **stacked):
        """Execute a parameter-only batch: ONE compiled pipeline, Q bind sets.

        Accepts either ``binds_list`` (a list of per-query bind dicts, which
        get stacked) or keyword binds already stacked with a leading Q axis
        (scalars broadcast).  Every hybrid class has a native batched
        lowering: VKNN-SF / DR-SF run the query-tiled kernels and
        multi-cluster IVF probes directly, and the join families (Q3-Q6)
        flatten (bind sets x left rows) into ONE kernel-level query batch.
        The vmap-of-scalar fallback survives only under
        ``join_lowering='perleft'`` (the benchmark baseline).  Every output
        gains a leading Q axis; stats report per-query counters (per
        (bind set, left row) for joins).

        NOTE: each distinct Q traces a fresh executable.  Serving traffic
        with varying batch sizes should use :meth:`execute_bucketed`."""
        self.ensure_fresh()
        binds = self._stack_binds(binds_list, stacked)
        return self._batch_jitted(self._arrays, binds)

    def execute_bucketed(self, binds_list: list[dict] | None = None,
                         probe_budget=None, **stacked):
        """Size-bucketed batch execution (the serving path).

        Semantically identical to :meth:`execute_batch` (bit-identical
        outputs) but pads Q up to the enclosing power-of-two bucket and
        reuses ONE compiled executable per bucket, so arbitrary request-batch
        sizes cost at most log2(max_batch) compilations.  ``probe_budget``
        (scalar or (Q,) int, cluster units) optionally caps each query's IVF
        probes — the effort-bucket valve used by serving/scheduler.py."""
        self.ensure_fresh()
        binds = self._stack_binds(binds_list, stacked)
        return self.executor(binds, probe_budget=probe_budget)

    def _stack_binds(self, binds_list, stacked) -> dict:
        if binds_list is not None:
            if stacked:
                raise TypeError("pass binds_list OR keyword binds, not both")
            if not binds_list:
                raise ValueError("binds_list is empty")
            keys = binds_list[0].keys()
            for i, b in enumerate(binds_list):
                missing = keys - b.keys()
                extra = b.keys() - keys
                if missing or extra:
                    offending = sorted(missing | extra)[0]
                    kind = "missing" if offending in missing else "unexpected"
                    raise ValueError(
                        f"ragged binds_list: binds_list[{i}] has {kind} key "
                        f"{offending!r} (binds_list[0] keys: "
                        f"{sorted(keys)})")
            # host-side stack: a jnp.stack over N request arrays compiles a
            # fresh concatenate per DISTINCT N — per-batch-size compile
            # latency the bucketed serving path exists to kill
            return {k: np.stack([np.asarray(b[k]) for b in binds_list])
                    for k in keys}
        binds = {k: jnp.asarray(v) for k, v in stacked.items()}
        qe = self.analysis.query_expr
        if isinstance(qe, Param) and qe.name in binds:
            qv = binds[qe.name]
            if qv.ndim != 2:
                raise ValueError(
                    f"execute_batch needs a stacked (Q, D) query vector for "
                    f"${{{qe.name}}}, got shape {qv.shape}; pass a single "
                    f"query through __call__ instead")
            qn = qv.shape[0]
        else:
            dims = [v.shape[0] for v in binds.values() if v.ndim >= 1]
            if not dims:
                raise ValueError("cannot infer batch size from scalar binds; "
                                 "use binds_list")
            qn = dims[0]
        bad = {k: v.shape for k, v in binds.items()
               if v.ndim >= 1 and v.shape[0] != qn}
        if bad:
            raise ValueError(f"stacked binds disagree on batch size {qn}: "
                             f"{bad}")
        # scalar broadcast on host (numpy): jnp.broadcast_to would compile
        # one tiny executable per distinct Q
        return {k: (np.broadcast_to(np.asarray(v), (qn,)) if v.ndim == 0
                    else v)
                for k, v in binds.items()}

    def lower(self, **binds):
        """AOT lowering for inspection (HLO text, cost analysis)."""
        return self._jitted.lower(self._arrays, dict(binds))

    def lower_batch(self, binds_list: list[dict] | None = None, **stacked):
        """AOT lowering of the BATCHED executable (HLO text, cost
        analysis) — what ``execute_batch`` would run at this Q."""
        self.ensure_fresh()
        binds = self._stack_binds(binds_list, stacked)
        return self._batch_jitted.lower(self._arrays, binds)

    def export_batch(self, binds_list: list[dict] | None = None,
                     **stacked) -> bytes:
        """Serialize the batched executable at this Q to portable
        ``jax.export`` StableHLO bytes (DESIGN.md §15).

        The round-trip partner is :meth:`deserialize_batch`: the returned
        bytes restore — in this or any later process on the same backend —
        a callable taking the same ``(arrays, binds)`` the batched
        executable takes, bit-identical to :meth:`execute_batch`.  The
        full persistent cache (:mod:`repro.core.aot`) layers keying,
        validation, and the native annex on top of this primitive."""
        from . import aot as _aot
        self.ensure_fresh()
        binds = self._stack_binds(binds_list, stacked)
        args = (self._arrays, binds)
        leaves, treedef = jax.tree.flatten(args)

        def flat(lvs, _td=treedef):
            arrays, b = jax.tree.unflatten(_td, lvs)
            return self.plan.batch_fn(arrays, b)

        return _aot.export_flat(flat, leaves).serialize()

    @staticmethod
    def deserialize_batch(data: bytes):
        """Restore an :meth:`export_batch` payload to a callable taking
        ``(arrays, binds)`` (re-pays the XLA compile, not the trace)."""
        from . import aot as _aot
        fn = _aot.load_portable(data)
        return lambda arrays, binds: fn((arrays, binds))

    def explain(self) -> str:
        """Engine/class/lowering summary plus both plan trees, as text."""
        out = [f"-- engine: {self.options.engine}",
               f"-- class:  {self.analysis.query_class.value}",
               f"-- batch:  {self.plan.batch_reason}",
               "-- logical plan:", self.logical_plan.pretty(),
               "-- rewritten plan:", self.rewritten_plan.pretty()]
        return "\n".join(out)


def _gather_arrays(a: Analysis, catalog: Catalog,
                   options: EngineOptions | None = None) -> dict:
    """Collect the device arrays a compiled pipeline closes over.

    For distributed plans (``options.dist``) the scanned corpus is
    additionally row-sharded over the spec's mesh: a matching
    :class:`~repro.dist.sharding.ShardedCorpus` registered on the catalog
    is reused (the registry is keyed per (table, column, mesh spec), so
    handles for different meshes coexist); otherwise one is built and
    registered."""
    arrays: dict[str, Any] = {}
    qc = a.query_class
    scan_table, scan_column = _scan_of(a)
    live = catalog.live_for(scan_table, scan_column)
    if qc in (QueryClass.VKNN_SF, QueryClass.DR_SF,
              QueryClass.CATEGORY_PARTITION):
        tab = catalog.table(a.table)
        arrays["corpus"] = tab[a.vector_column]
        idx = catalog.index_for(a.table, a.vector_column)
        if idx is not None:
            arrays["index"] = idx
        if qc == QueryClass.CATEGORY_PARTITION:
            arrays["categories"] = tab[a.category_column.name]
    else:
        ltab = catalog.table(a.left_table)
        rtab = catalog.table(a.right_table)
        arrays["left"] = ltab[a.left_vector]
        arrays["corpus"] = rtab[a.right_vector]
        idx = catalog.index_for(a.right_table, a.right_vector)
        if idx is not None:
            arrays["index"] = idx
        if qc == QueryClass.CATEGORY_JOIN:
            arrays["categories"] = rtab[a.category_column.name]
    if live is not None:
        # the live segment arrays REPLACE the frozen corpus: padded main
        # segment + validity (tombstone bitmap), delta segment, and the
        # live scalar columns predicates evaluate against (DESIGN.md §12)
        arrays.update(live.plan_arrays())
        if "categories" in arrays:
            arrays["categories"] = arrays["live_cols"][a.category_column.name]
    if options is not None and options.dist is not None:
        from ..dist.sharding import ShardedCorpus, resolve_mesh
        if live is not None:
            # keyed off the live device cache, which compaction clears (the
            # only mutation that moves main-segment vectors) — catalog
            # sharded registration would go stale silently
            key = f"sharded:{options.dist!r}"
            sharded = live._dev.get(key)
            if sharded is None:
                sharded = ShardedCorpus.build(resolve_mesh(options.dist),
                                              arrays["corpus"],
                                              options.dist.axes)
                live._dev[key] = sharded
        else:
            sharded = catalog.sharded_for(scan_table, scan_column,
                                          options.dist)
            if sharded is None:
                sharded = ShardedCorpus.build(resolve_mesh(options.dist),
                                              arrays["corpus"],
                                              options.dist.axes)
                catalog.register_sharded(scan_table, scan_column, sharded)
        arrays["dcorpus"] = sharded.corpus
        arrays["drow_ids"] = sharded.row_ids
    if options is not None and options.quant is not None:
        from ..data.quantized import quantize_corpus
        if live is not None:
            # keyed off the live device cache: compaction (the only
            # mutation that moves main-segment vectors) clears it, so the
            # twin re-quantizes exactly when the fp32 source moved; the
            # delta segment stays fp32 (it is small and mutation-hot)
            key = f"quant:{options.quant}"
            quant = live._dev.get(key)
            if quant is None:
                quant = quantize_corpus(arrays["corpus"], options.quant)
                live._dev[key] = quant
        else:
            quant = catalog.quantized_for(scan_table, scan_column,
                                          options.quant)
            if quant is None:
                quant = quantize_corpus(arrays["corpus"], options.quant)
                catalog.register_quantized(scan_table, scan_column, quant)
        arrays.update(quant.plan_arrays())
        if options.dist is not None:
            arrays.update(_sharded_quant(catalog, live, options, arrays,
                                         scan_table, scan_column)
                          .plan_arrays(prefix="d"))
    return arrays


def _sharded_quant(catalog, live, options, arrays, scan_table: str,
                   scan_column: str):
    """The quantized twin of the SHARDED corpus (divisibility-padded rows
    included — all-zero pads quantize to zero and are masked by row_id=-1),
    each per-row array device_put onto the dist mesh with the same row
    sharding as ``dcorpus``.  Cached like the sharded handle itself:
    per-(mode, spec) on the catalog, or on the live device cache."""
    from jax.sharding import NamedSharding, PartitionSpec
    from ..data.quantized import QuantizedCorpus, quantize_corpus
    from ..dist.sharding import resolve_mesh
    if live is not None:
        key = f"quant:{options.quant}:dist:{options.dist!r}"
        dq = live._dev.get(key)
    else:
        dq = catalog.quantized_for(scan_table, scan_column,
                                   (options.quant, options.dist))
    if dq is None:
        raw = quantize_corpus(arrays["dcorpus"], options.quant)
        mesh = resolve_mesh(options.dist)
        rows = NamedSharding(mesh, PartitionSpec(options.dist.axes, None))
        lane = NamedSharding(mesh, PartitionSpec(options.dist.axes))
        dq = QuantizedCorpus(
            mode=raw.mode,
            qvecs=jax.device_put(raw.qvecs, rows),
            scales=jax.device_put(raw.scales, rows),
            half_step=jax.device_put(raw.half_step, lane),
            row_l1=jax.device_put(raw.row_l1, lane),
            row_l2=jax.device_put(raw.row_l2, lane))
        if live is not None:
            live._dev[f"quant:{options.quant}:dist:{options.dist!r}"] = dq
        else:
            catalog.register_quantized(scan_table, scan_column, dq,
                                       key=(options.quant, options.dist))
    return dq


def _vmap_fallback(fn: Callable) -> Callable:
    """vmap-of-scalar batch fallback with the uniform batch_fn signature.

    Pad queries cannot be skipped here (the scalar pipeline has no valid
    lane), so inertness is enforced on the way out: invalid queries report
    zero counters and all-False validity.  ``probe_budget`` has no lane
    either and is ignored — callers that depend on it (effort bucketing)
    must check ``batch_native`` first (serving/scheduler.py does)."""

    def bfn(arrs, binds, qvalid=None, probe_budget=None):
        out = jax.vmap(lambda b: fn(arrs, b))(binds)
        if qvalid is None:
            return out
        masked = {}
        for key, v in out.items():
            if key in ("stats", "count"):
                masked[key] = jax.tree.map(
                    lambda s: jnp.where(
                        qvalid.reshape((-1,) + (1,) * (s.ndim - 1)), s, 0),
                    v)
            elif hasattr(v, "dtype") and v.dtype == jnp.bool_:
                masked[key] = v & qvalid.reshape(
                    (-1,) + (1,) * (v.ndim - 1))
            else:
                masked[key] = v
        return masked

    return bfn


def _batch_lowering(a: Analysis, options: EngineOptions):
    """(batch_builder | None, batch_native, human-readable reason)."""
    qc = a.query_class
    batch_builder = BATCH_BUILDERS.get(qc)
    if batch_builder is None:
        return None, False, (f"vmap-of-scalar fallback (no native batch "
                             f"builder registered for class {qc.value})")
    if options.dist is not None:
        spec = options.dist
        mesh = dict(zip(spec.axes, spec.mesh_shape))
        return batch_builder, True, (
            f"native sharded (distributed fused flat scan: "
            f"{spec.num_shards} shard(s) over mesh {mesh}, "
            f"merge depth {spec.merge_depth})")
    if options.join_lowering == "perleft" and qc in JOIN_LOWERING_FAMILIES:
        return None, False, "vmap-of-scalar fallback (perleft join lowering)"
    if qc in JOIN_LOWERING_FAMILIES:
        return batch_builder, True, ("native (bind sets x left rows "
                                     "flattened into one kernel-level "
                                     "query batch)")
    return batch_builder, True, ("native (query-tiled kernels / "
                                 "multi-cluster probes)")


def _validate_dist(options: EngineOptions) -> None:
    """Reject option combinations the sharded lowering cannot honor.

    The distributed lowering is the exact fused flat scan (index probes are
    bypassed — DESIGN.md §10), so the approximate comparison engines
    (pase / vbase / brute_sort), whose measured inefficiency lives in the
    bypassed plan structure, and the perleft join baseline cannot compose
    with it."""
    if options.dist is None:
        return
    if options.engine not in ("chase", "brute"):
        raise ValueError(
            f"EngineOptions.dist runs the exact distributed flat scan and "
            f"only composes with engine 'chase' or 'brute', not "
            f"{options.engine!r} (the comparison engines' plan-structural "
            f"inefficiencies would be silently bypassed)")
    if options.join_lowering != "batch":
        raise ValueError(
            "EngineOptions.dist requires join_lowering='batch': the sharded "
            "lowering IS a query-batched scan (left rows ride the shard x "
            "tile composition); the perleft loop has no sharded twin")


def _validate_live(a: Analysis, catalog: Catalog,
                   options: EngineOptions) -> None:
    """Reject option combinations the live-corpus lowering cannot honor.

    The delta merge composes with the exact paths only: the comparison
    engines (pase / vbase / brute_sort) model *plan-structural*
    inefficiencies of the frozen lowering, and the perleft join baseline
    has no delta twin — same restriction (and same reasoning) as the
    distributed lowering (:func:`_validate_dist`)."""
    if catalog.live_for(*_scan_of(a)) is None:
        return
    if options.engine not in ("chase", "brute"):
        raise ValueError(
            f"a live corpus is attached to {'.'.join(_scan_of(a))} and only "
            f"composes with engine 'chase' or 'brute', not "
            f"{options.engine!r}")
    if options.join_lowering != "batch":
        raise ValueError(
            "a live corpus requires join_lowering='batch': the delta merge "
            "rides the query-batched lowering; the perleft loop has no "
            "live twin")


def _validate_quant(options: EngineOptions) -> None:
    """Reject option combinations the quantized lowering cannot honor.

    The quantized scan IS the fused batched kernel path (DESIGN.md §13):
    no jnp twin exists, and the comparison engines' plan-structural
    inefficiencies would be silently bypassed — same restriction (and
    same reasoning) as the distributed lowering (:func:`_validate_dist`).
    IVF probes stay fp32-exact under quant (their key-dependent
    early-stop would be perturbed), so engine 'chase' composes: flat
    scans quantize, probes do not."""
    if options.quant is None:
        if options.rescore_factor < 1:
            raise ValueError(
                f"EngineOptions.rescore_factor must be >= 1, got "
                f"{options.rescore_factor}")
        return
    from ..data.quantized import MODES
    if options.quant not in MODES:
        raise ValueError(
            f"EngineOptions.quant must be one of {MODES} (or None), got "
            f"{options.quant!r}")
    if not options.use_pallas:
        raise ValueError(
            "EngineOptions.quant requires use_pallas=True: the quantized "
            "lowering IS the fused kernel path (no jnp twin)")
    if options.engine not in ("chase", "brute"):
        raise ValueError(
            f"EngineOptions.quant is exact (fused fp32 rescore) and only "
            f"composes with engine 'chase' or 'brute', not "
            f"{options.engine!r}")
    if options.join_lowering != "batch":
        raise ValueError(
            "EngineOptions.quant requires join_lowering='batch': the "
            "quantized kernels are query-batched; the perleft loop has no "
            "quantized twin")
    if options.rescore_factor < 1:
        raise ValueError(
            f"EngineOptions.rescore_factor must be >= 1, got "
            f"{options.rescore_factor}")


def _single_via_batch(bfn: Callable) -> Callable:
    """Single-query front for distributed / live / quantized plans.

    These plans have ONE lowering — the query-batched scan — so the
    single-query pipeline runs it at Q=1 and slices the leading axis off
    every output leaf (bit-identical to a one-element batch; no separate
    single-query shard_map to compile or maintain)."""

    def fn(arrays, binds):
        stacked = {k: jnp.asarray(v)[None] for k, v in binds.items()}
        out = bfn(arrays, stacked)
        return jax.tree.map(lambda v: v[0], out)

    return fn


def compile_query(sql: str, catalog: Catalog,
                  options: EngineOptions | None = None,
                  **static_binds) -> CompiledQuery:
    """Parse, analyze, rewrite, select physical operators, and jit.

    ``static_binds`` resolve parameters that shape the computation (K values).
    Runtime parameters (query vectors, radii, filter constants) are passed at
    call time and are traced, so re-running with a new query vector reuses the
    compiled executable — the production serving pattern.

    This is the legacy one-shot front door; the session API
    (:func:`repro.api.connect`) routes through :func:`compile_plan` with a
    normalized plan cache in front, so textual variants of one query share
    one compilation.  Each ``compile_query`` call compiles fresh."""
    options = options or EngineOptions()
    plan = parse_sql(sql)
    return compile_plan(sql, plan, catalog, options, static_binds)


def compile_plan(sql: str, plan: PlanNode, catalog: Catalog,
                 options: EngineOptions, static_binds: dict) -> CompiledQuery:
    """Compile an already-parsed logical plan (the plan-cache entry point —
    ``Database.prepare`` parses once for fingerprinting, then compiles the
    same tree only on a cache miss)."""
    a = analyze(plan, catalog)
    if a.query_class == QueryClass.NON_HYBRID:
        raise NotImplementedError(
            "plan did not match a hybrid pattern; use the interpreter engine")
    _validate_dist(options)
    _validate_live(a, catalog, options)
    _validate_quant(options)
    rewritten = rewrite(a)
    arrays = _gather_arrays(a, catalog, options)
    batch_builder, batch_native, batch_reason = _batch_lowering(a, options)
    if (options.dist is not None or options.quant is not None
            or catalog.live_for(*_scan_of(a)) is not None):
        # one lowering per dist, live, OR quant plan: the batched pipeline
        # (which carries the delta merge / shard composition / quantized
        # rescore) serves the single-query path at Q=1 (_single_via_batch)
        bfn = batch_builder(a, catalog, options, Bindings(static_binds))
        fn = _single_via_batch(bfn)
    else:
        builder = BUILDERS[a.query_class]
        fn = builder(a, catalog, options, Bindings(static_binds))
        if batch_native:
            bfn = batch_builder(a, catalog, options, Bindings(static_binds))
        else:
            bfn = _vmap_fallback(fn)
    compiled_plan = CompiledPlan(sql, a, plan, rewritten, options, fn, bfn,
                                 batch_native, batch_reason)
    executor = BucketedExecutor(compiled_plan, arrays)
    # snapshot AFTER _gather_arrays: gathering a dist plan may itself
    # register a sharded handle (a version bump this plan must not see as
    # staleness on its first execute)
    dep_keys = _catalog_dep_keys(a, catalog, options)
    return CompiledQuery(compiled_plan, jax.jit(fn), arrays, jax.jit(bfn),
                         executor, _catalog=catalog, _dep_keys=dep_keys,
                         _bound_versions=catalog.version_snapshot(dep_keys))
