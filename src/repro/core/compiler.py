"""Query compilation — CHASE §6, XLA edition.

LingoDB lowers relalg -> subop -> LLVM IR -> machine code.  Here the analogue
chain is: logical plan -> (semantic analysis + rewrite) -> physical builder ->
traced JAX function -> jaxpr -> XLA HLO -> machine code.  CSE / DCE / constant
folding (§6's "general passes") happen inside XLA.  One pipeline = one fused
XLA computation; there is no operator interpretation at runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .expr import Bindings, Param
from .physical import (BATCH_BUILDERS, BUILDERS, JOIN_LOWERING_FAMILIES,
                       EngineOptions)
from .plan import PlanNode
from .rewriter import rewrite
from .schema import Catalog
from .semantics import Analysis, QueryClass, analyze
from .sql import parse_sql


@dataclasses.dataclass
class CompiledQuery:
    sql: str
    analysis: Analysis
    logical_plan: PlanNode
    rewritten_plan: PlanNode
    options: EngineOptions
    _jitted: Any
    _arrays: Any
    _batch_jitted: Any = None
    batch_native: bool = False

    def __call__(self, **binds):
        return self._jitted(self._arrays, dict(binds))

    def execute_batch(self, binds_list: list[dict] | None = None, **stacked):
        """Execute a parameter-only batch: ONE compiled pipeline, Q bind sets.

        Accepts either ``binds_list`` (a list of per-query bind dicts, which
        get stacked) or keyword binds already stacked with a leading Q axis
        (scalars broadcast).  Every hybrid class has a native batched
        lowering: VKNN-SF / DR-SF run the query-tiled kernels and
        multi-cluster IVF probes directly, and the join families (Q3-Q6)
        flatten (bind sets x left rows) into ONE kernel-level query batch.
        The vmap-of-scalar fallback survives only under
        ``join_lowering='perleft'`` (the benchmark baseline).  Every output
        gains a leading Q axis; stats report per-query counters (per
        (bind set, left row) for joins)."""
        binds = self._stack_binds(binds_list, stacked)
        return self._batch_jitted(self._arrays, binds)

    def _stack_binds(self, binds_list, stacked) -> dict:
        if binds_list is not None:
            if stacked:
                raise TypeError("pass binds_list OR keyword binds, not both")
            keys = binds_list[0].keys()
            return {k: jnp.stack([jnp.asarray(b[k]) for b in binds_list])
                    for k in keys}
        binds = {k: jnp.asarray(v) for k, v in stacked.items()}
        qe = self.analysis.query_expr
        if isinstance(qe, Param) and qe.name in binds:
            qv = binds[qe.name]
            if qv.ndim != 2:
                raise ValueError(
                    f"execute_batch needs a stacked (Q, D) query vector for "
                    f"${{{qe.name}}}, got shape {qv.shape}; pass a single "
                    f"query through __call__ instead")
            qn = qv.shape[0]
        else:
            dims = [v.shape[0] for v in binds.values() if v.ndim >= 1]
            if not dims:
                raise ValueError("cannot infer batch size from scalar binds; "
                                 "use binds_list")
            qn = dims[0]
        bad = {k: v.shape for k, v in binds.items()
               if v.ndim >= 1 and v.shape[0] != qn}
        if bad:
            raise ValueError(f"stacked binds disagree on batch size {qn}: "
                             f"{bad}")
        return {k: jnp.broadcast_to(v, (qn,)) if v.ndim == 0 else v
                for k, v in binds.items()}

    def lower(self, **binds):
        """AOT lowering for inspection (HLO text, cost analysis)."""
        return self._jitted.lower(self._arrays, dict(binds))

    def explain(self) -> str:
        qc = self.analysis.query_class
        if not self.batch_native:
            batch = "vmap-of-scalar fallback (perleft join lowering)"
        elif qc in (QueryClass.DIST_JOIN, QueryClass.KNN_JOIN,
                    QueryClass.CATEGORY_JOIN):
            batch = ("native (bind sets x left rows flattened into one "
                     "kernel-level query batch)")
        else:
            batch = "native (query-tiled kernels / multi-cluster probes)"
        out = [f"-- engine: {self.options.engine}",
               f"-- class:  {self.analysis.query_class.value}",
               f"-- batch:  {batch}",
               "-- logical plan:", self.logical_plan.pretty(),
               "-- rewritten plan:", self.rewritten_plan.pretty()]
        return "\n".join(out)


def _gather_arrays(a: Analysis, catalog: Catalog) -> dict:
    arrays: dict[str, Any] = {}
    qc = a.query_class
    if qc in (QueryClass.VKNN_SF, QueryClass.DR_SF,
              QueryClass.CATEGORY_PARTITION):
        tab = catalog.table(a.table)
        arrays["corpus"] = tab[a.vector_column]
        idx = catalog.index_for(a.table, a.vector_column)
        if idx is not None:
            arrays["index"] = idx
        if qc == QueryClass.CATEGORY_PARTITION:
            arrays["categories"] = tab[a.category_column.name]
    else:
        ltab = catalog.table(a.left_table)
        rtab = catalog.table(a.right_table)
        arrays["left"] = ltab[a.left_vector]
        arrays["corpus"] = rtab[a.right_vector]
        idx = catalog.index_for(a.right_table, a.right_vector)
        if idx is not None:
            arrays["index"] = idx
        if qc == QueryClass.CATEGORY_JOIN:
            arrays["categories"] = rtab[a.category_column.name]
    return arrays


def compile_query(sql: str, catalog: Catalog,
                  options: EngineOptions | None = None,
                  **static_binds) -> CompiledQuery:
    """Parse, analyze, rewrite, select physical operators, and jit.

    ``static_binds`` resolve parameters that shape the computation (K values).
    Runtime parameters (query vectors, radii, filter constants) are passed at
    call time and are traced, so re-running with a new query vector reuses the
    compiled executable — the production serving pattern."""
    options = options or EngineOptions()
    plan = parse_sql(sql)
    a = analyze(plan, catalog)
    if a.query_class == QueryClass.NON_HYBRID:
        raise NotImplementedError(
            "plan did not match a hybrid pattern; use the interpreter engine")
    rewritten = rewrite(a)
    builder = BUILDERS[a.query_class]
    fn = builder(a, catalog, options, Bindings(static_binds))
    arrays = _gather_arrays(a, catalog)
    jitted = jax.jit(fn)
    batch_builder = BATCH_BUILDERS.get(a.query_class)
    batch_native = batch_builder is not None and not (
        options.join_lowering == "perleft"
        and a.query_class in JOIN_LOWERING_FAMILIES)
    if batch_native:
        bfn = batch_builder(a, catalog, options, Bindings(static_binds))
    else:
        def bfn(arrs, binds, _fn=fn):
            return jax.vmap(lambda b: _fn(arrs, b))(binds)
    return CompiledQuery(sql, a, plan, rewritten, options, jitted, arrays,
                         jax.jit(bfn), batch_native)
