"""Query compilation — CHASE §6, XLA edition.

LingoDB lowers relalg -> subop -> LLVM IR -> machine code.  Here the analogue
chain is: logical plan -> (semantic analysis + rewrite) -> physical builder ->
traced JAX function -> jaxpr -> XLA HLO -> machine code.  CSE / DCE / constant
folding (§6's "general passes") happen inside XLA.  One pipeline = one fused
XLA computation; there is no operator interpretation at runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .expr import Bindings
from .physical import BUILDERS, EngineOptions
from .plan import PlanNode
from .rewriter import rewrite
from .schema import Catalog
from .semantics import Analysis, QueryClass, analyze
from .sql import parse_sql


@dataclasses.dataclass
class CompiledQuery:
    sql: str
    analysis: Analysis
    logical_plan: PlanNode
    rewritten_plan: PlanNode
    options: EngineOptions
    _jitted: Any
    _arrays: Any

    def __call__(self, **binds):
        return self._jitted(self._arrays, dict(binds))

    def lower(self, **binds):
        """AOT lowering for inspection (HLO text, cost analysis)."""
        return self._jitted.lower(self._arrays, dict(binds))

    def explain(self) -> str:
        out = [f"-- engine: {self.options.engine}",
               f"-- class:  {self.analysis.query_class.value}",
               "-- logical plan:", self.logical_plan.pretty(),
               "-- rewritten plan:", self.rewritten_plan.pretty()]
        return "\n".join(out)


def _gather_arrays(a: Analysis, catalog: Catalog) -> dict:
    arrays: dict[str, Any] = {}
    qc = a.query_class
    if qc in (QueryClass.VKNN_SF, QueryClass.DR_SF,
              QueryClass.CATEGORY_PARTITION):
        tab = catalog.table(a.table)
        arrays["corpus"] = tab[a.vector_column]
        idx = catalog.index_for(a.table, a.vector_column)
        if idx is not None:
            arrays["index"] = idx
        if qc == QueryClass.CATEGORY_PARTITION:
            arrays["categories"] = tab[a.category_column.name]
    else:
        ltab = catalog.table(a.left_table)
        rtab = catalog.table(a.right_table)
        arrays["left"] = ltab[a.left_vector]
        arrays["corpus"] = rtab[a.right_vector]
        idx = catalog.index_for(a.right_table, a.right_vector)
        if idx is not None:
            arrays["index"] = idx
        if qc == QueryClass.CATEGORY_JOIN:
            arrays["categories"] = rtab[a.category_column.name]
    return arrays


def compile_query(sql: str, catalog: Catalog,
                  options: EngineOptions | None = None,
                  **static_binds) -> CompiledQuery:
    """Parse, analyze, rewrite, select physical operators, and jit.

    ``static_binds`` resolve parameters that shape the computation (K values).
    Runtime parameters (query vectors, radii, filter constants) are passed at
    call time and are traced, so re-running with a new query vector reuses the
    compiled executable — the production serving pattern."""
    options = options or EngineOptions()
    plan = parse_sql(sql)
    a = analyze(plan, catalog)
    if a.query_class == QueryClass.NON_HYBRID:
        raise NotImplementedError(
            "plan did not match a hybrid pattern; use the interpreter engine")
    rewritten = rewrite(a)
    builder = BUILDERS[a.query_class]
    fn = builder(a, catalog, options, Bindings(static_binds))
    arrays = _gather_arrays(a, catalog)
    jitted = jax.jit(fn)
    return CompiledQuery(sql, a, plan, rewritten, options, jitted, arrays)
