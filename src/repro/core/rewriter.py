"""Logical plan rewriting — CHASE §4 (R1/R2/R3).

Consumes an :class:`~repro.core.semantics.Analysis` and emits the rewritten
logical plan tree.  The rewritten tree is what the physical layer lowers and
what tests assert against (plan-shape equivalence to the paper's Figures
4b/5b/6b); it is also pretty-printable for EXPLAIN-style output.
"""
from __future__ import annotations

from .expr import (Cmp, Column, Const, Distance, Param, split_conjuncts,
                   walk)
from .plan import (Filter, IndexScan, Join, KnnSubquery, Limit, Map, OrderBy,
                   PlanNode, Project, Scan, UpdateState, WindowRank)
from .semantics import Analysis, QueryClass

SIM_COL = "__sim"

# comparison direction when an atom is flipped to column-on-the-left form
_FLIP_OP = {"<": ">", "<=": ">=", ">": "<", ">=": "<=",
            "=": "=", "<>": "<>", "!=": "!="}


def selectivity_atoms(a: Analysis) -> list[dict]:
    """Threshold atoms of the structured/join predicates, in a form the
    adaptive optimizer can estimate selectivity for (DESIGN.md §14).

    Each atom is ``{"table", "column", "op", "param", "value"}`` — a
    column-vs-threshold comparison in column-on-the-left form, where the
    threshold is either a bind parameter (``param`` set) or a literal
    (``value`` set).  Conjuncts that are not simple threshold comparisons
    (distance terms, OR trees, column-vs-column join residuals, arithmetic)
    are skipped — the estimator stays conservative rather than guessing."""
    atoms: list[dict] = []
    for pred in (a.structured_predicate, a.join_predicate):
        for conj in split_conjuncts(pred):
            if not isinstance(conj, Cmp) or conj.op not in _FLIP_OP:
                continue
            if any(isinstance(node, Distance) for node in walk(conj)):
                continue
            for lhs, rhs, op in ((conj.lhs, conj.rhs, conj.op),
                                 (conj.rhs, conj.lhs, _FLIP_OP[conj.op])):
                if (isinstance(lhs, Column)
                        and isinstance(rhs, (Param, Const))):
                    atoms.append({
                        "table": lhs.table, "column": lhs.name, "op": op,
                        "param": rhs.name if isinstance(rhs, Param)
                        else None,
                        "value": rhs.value if isinstance(rhs, Const)
                        else None})
                    break
    return atoms


def rewrite(a: Analysis) -> PlanNode:
    """Apply the rewrite rule for the detected hybrid family."""
    if a.query_class == QueryClass.VKNN_SF:
        return _rewrite_vknn(a)
    if a.query_class == QueryClass.DR_SF:
        return _rewrite_drsf(a)
    if a.query_class == QueryClass.DIST_JOIN:
        return _rewrite_dist_join(a)
    if a.query_class == QueryClass.KNN_JOIN:
        return _rewrite_knn_join(a)
    if a.query_class == QueryClass.CATEGORY_PARTITION:
        return _rewrite_category_partition(a)
    if a.query_class == QueryClass.CATEGORY_JOIN:
        return _rewrite_category_join(a)
    return a.plan


def _project(a: Analysis, child: PlanNode) -> PlanNode:
    if a.outer_project:
        return Project(child, a.outer_project)
    return child


def _rewrite_vknn(a: Analysis) -> PlanNode:
    """R1 (Fig. 4b): IndexScan(topk, emits sim) -> Map(__sim) ->
    OrderBy(__sim) -> Limit.  The orderBy key is *replaced* with the
    materialized column so no distance is recomputed."""
    scan = IndexScan(a.table, a.vector_column, a.query_expr, mode="topk",
                     k=a.k, predicate=a.structured_predicate, alias=a.alias)
    mapped = Map(scan, SIM_COL, None, from_index_scan=True)
    ordered = OrderBy(mapped, Column(SIM_COL))
    limited = Limit(ordered, a.k)
    return _project(a, limited)


def _rewrite_drsf(a: Analysis) -> PlanNode:
    """Q2: route the distance predicate to the RangeSearch interface (§5.2)
    instead of a brute filter; structured residual fuses into the scan."""
    scan = IndexScan(a.table, a.vector_column, a.query_expr, mode="range",
                     radius=a.radius, predicate=a.structured_predicate,
                     alias=a.alias)
    return _project(a, Map(scan, SIM_COL, None, from_index_scan=True))


def _rewrite_dist_join(a: Analysis) -> PlanNode:
    """Q3: right side becomes a per-left-row range IndexScan; the join keeps
    only the residual structured condition."""
    left = Scan(a.left_table, a.left_alias)
    right = IndexScan(a.right_table, a.right_vector,
                      Column(a.left_vector, table=a.left_alias), mode="range",
                      radius=a.radius, predicate=None, alias=a.right_alias)
    joined = Join(left, right, a.join_predicate)
    return _project(a, Map(joined, SIM_COL, None, from_index_scan=True))


def _rewrite_knn_join(a: Analysis) -> PlanNode:
    """R2 (Fig. 5b): decouple orderBy from the window, insert an explicit
    limit; scan+orderBy+limit form one ANN-servable pipeline per left row."""
    left = Scan(a.left_table, a.left_alias)
    return _project(a, KnnSubquery(
        left, a.right_table, a.right_vector,
        Column(a.left_vector, table=a.left_alias), a.k,
        a.join_predicate, a.rank_name))


def _rewrite_category_partition(a: Analysis) -> PlanNode:
    """R3 (Fig. 6b): insert updateState between the range IndexScan and the
    window so the scan can stop at R2 <= R1."""
    scan = IndexScan(a.table, a.vector_column, a.query_expr, mode="range",
                     radius=a.radius, predicate=a.structured_predicate,
                     alias=a.alias)
    upd = UpdateState(scan, a.category_column, a.k)
    win = WindowRank(Map(upd, SIM_COL, None, from_index_scan=True),
                     a.partition_keys, Column(SIM_COL), a.rank_name)
    ranked = Filter(win, Column(a.rank_name) <= a.k)
    return _project(a, ranked)


def _rewrite_category_join(a: Analysis) -> PlanNode:
    """Q6 = Q3's join shape + R3's updateState per left row."""
    left = Scan(a.left_table, a.left_alias)
    scan = IndexScan(a.right_table, a.right_vector,
                     Column(a.left_vector, table=a.left_alias), mode="range",
                     radius=a.radius, predicate=None, alias=a.right_alias)
    upd = UpdateState(scan, a.category_column, a.k)
    joined = Join(left, upd, a.join_predicate)
    win = WindowRank(Map(joined, SIM_COL, None, from_index_scan=True),
                     a.partition_keys, Column(SIM_COL), a.rank_name)
    ranked = Filter(win, Column(a.rank_name) <= a.k)
    return _project(a, ranked)
