"""Semantic analysis: classify a logical plan into the paper's hybrid families.

CHASE §3/§4: the engine traverses the logical plan, checks it against the
hybrid-query patterns, and only then rewrites.  The classifier here is
pattern-structural *and* schema-aware (it verifies that the window partitions
by the query table's primary key for entity-centric queries, that the window
frame spans the whole partition — ours always does, there is no frame syntax —
and that DISTANCE references an indexed vector column), mirroring the paper's
"guarantees alignment with the semantics of a specific category" requirement.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from .expr import (BoolOp, Cmp, Column, Const, Distance, Expr, Param,
                   contains_distance, conjoin, split_conjuncts)
from .plan import (Filter, Join, Limit, OrderBy, PlanNode, Project, Scan,
                   WindowRank)
from .schema import Catalog, ColumnKind
from .sql import _Aliased


class QueryClass(enum.Enum):
    """The paper's hybrid query taxonomy (Q1-Q6) plus NON_HYBRID."""
    VKNN_SF = "vknn_sf"                    # Q1
    DR_SF = "dr_sf"                        # Q2
    DIST_JOIN = "dist_join"                # Q3
    KNN_JOIN = "knn_join"                  # Q4 (entity-centric W-VKNN-SF)
    CATEGORY_PARTITION = "category_part"   # Q5 (category-driven, single table)
    CATEGORY_JOIN = "category_join"        # Q6 (category-driven, join)
    NON_HYBRID = "non_hybrid"


@dataclasses.dataclass
class Analysis:
    """Everything the rewriter / physical layer needs, extracted once."""
    query_class: QueryClass
    plan: PlanNode
    # single-table slots
    table: str | None = None
    alias: str | None = None
    vector_column: str | None = None
    query_expr: Expr | None = None          # Param (or left Column for joins)
    k: "int | str | None" = None
    radius: Expr | None = None
    structured_predicate: Expr | None = None
    # join slots
    left_table: str | None = None
    left_alias: str | None = None
    right_table: str | None = None
    right_alias: str | None = None
    left_vector: str | None = None
    right_vector: str | None = None
    join_predicate: Expr | None = None      # residual (non-distance) condition
    # window slots
    partition_keys: tuple[Expr, ...] = ()
    category_column: Expr | None = None
    rank_name: str = "rank"
    # bookkeeping
    outer_project: tuple[tuple[str, Expr], ...] | None = None
    notes: list[str] = dataclasses.field(default_factory=list)


# ---------------------------------------------------------------------------


def _strip(node: PlanNode):
    """Peel Project/_Aliased wrappers, remembering the outermost projection."""
    outer_proj = None
    while True:
        if isinstance(node, Project):
            if outer_proj is None:
                outer_proj = node.outputs
            node = node.child
        elif isinstance(node, _Aliased):
            node = node.child
        else:
            return node, outer_proj


def _range_conjunct(pred: Expr | None):
    """Split conjuncts into (distance-range conjunct, structured residual).

    Recognizes ``DISTANCE(col, q) <= r`` (and >= under similarity convention —
    normalization happens downstream via the column metric)."""
    dist_c, radius, rest = None, None, []
    for c in split_conjuncts(pred):
        if (dist_c is None and isinstance(c, Cmp) and c.op in ("<=", "<", ">=", ">")
                and isinstance(c.lhs, Distance) and not contains_distance(c.rhs)):
            dist_c, radius = c.lhs, c.rhs
        else:
            rest.append(c)
    return dist_c, radius, conjoin(rest)


def _resolve_scan(node: PlanNode):
    """Return (scan, filter_predicate) for a Filter?->Scan chain, else None."""
    pred = None
    if isinstance(node, Filter):
        pred = node.predicate
        node = node.child
    if isinstance(node, Scan):
        return node, pred
    return None


def _column_of(e: Expr) -> Column | None:
    return e if isinstance(e, Column) else None


def _is_vector_col(catalog: Catalog, table: str, col: Column | None) -> bool:
    if col is None or not catalog.has_table(table):
        return False
    schema = catalog.table(table).schema
    return col.name in schema and schema[col.name].kind == ColumnKind.VECTOR


def _belongs_to(col: Column, table_name: str, alias: str | None) -> bool:
    return col.table in (None, table_name, alias)


def analyze(plan: PlanNode, catalog: Catalog) -> Analysis:
    """Classify ``plan`` and extract rewrite slots.  Never raises on unknown
    shapes — falls back to NON_HYBRID, which executes un-rewritten."""
    node, outer_proj = _strip(plan)

    # --- Peel outer rank filter (WHERE ranked.rank <= K) for window queries
    rank_k: int | str | None = None
    if isinstance(node, Filter):
        c = node.predicate
        if (isinstance(c, Cmp) and c.op in ("<=", "<")
                and isinstance(c.lhs, Column) and isinstance(c.rhs, (Const, Param))):
            inner, proj2 = _strip(node.child)
            if isinstance(inner, WindowRank) and c.lhs.name == inner.rank_name:
                rank_k = (c.rhs.value if isinstance(c.rhs, Const)
                          else c.rhs.name)
                if isinstance(rank_k, (int, float)):
                    rank_k = int(rank_k) - (1 if c.op == "<" else 0)
                if outer_proj is None:
                    outer_proj = proj2
                node = inner

    # ======================= windowed families (Q4/Q5/Q6) ==================
    if isinstance(node, WindowRank):
        return _analyze_window(node, rank_k, outer_proj, catalog, plan)

    # ======================= Limit -> OrderBy (Q1) ==========================
    if isinstance(node, Limit):
        k = node.k
        child = node.child
        if isinstance(child, OrderBy) and isinstance(child.key, Distance):
            scan_info = _resolve_scan(child.child)
            dist = child.key
            vcol = _column_of(dist.lhs) or _column_of(dist.rhs)
            qexpr = dist.rhs if _column_of(dist.lhs) is vcol else dist.lhs
            if scan_info is not None:
                scan, pred = scan_info
                if _is_vector_col(catalog, scan.table, vcol):
                    # pattern: orderBy(D, distance) -> topK  (paper §4.1)
                    return Analysis(
                        QueryClass.VKNN_SF, plan, table=scan.table,
                        alias=scan.alias, vector_column=vcol.name,
                        query_expr=qexpr, k=k, structured_predicate=pred,
                        outer_project=outer_proj)

    # ======================= DR-SF (Q2) and distance join (Q3) =============
    if isinstance(node, Filter) or isinstance(node, Join):
        if isinstance(node, Filter):
            scan_info = _resolve_scan(node)
            if scan_info is not None:
                scan, pred = scan_info
                dist, radius, rest = _range_conjunct(pred)
                if dist is not None:
                    vcol = _column_of(dist.lhs) or _column_of(dist.rhs)
                    qexpr = dist.rhs if _column_of(dist.lhs) is vcol else dist.lhs
                    if _is_vector_col(catalog, scan.table, vcol):
                        return Analysis(
                            QueryClass.DR_SF, plan, table=scan.table,
                            alias=scan.alias, vector_column=vcol.name,
                            query_expr=qexpr, radius=radius,
                            structured_predicate=rest, outer_project=outer_proj)
            # filter above a join: fold predicate into the join condition
            if isinstance(node.child, Join):
                j = node.child
                cond = conjoin(split_conjuncts(j.condition)
                               + split_conjuncts(node.predicate))
                node = Join(j.left, j.right, cond)

        if isinstance(node, Join):
            res = _analyze_dist_join(node, outer_proj, catalog, plan)
            if res is not None:
                return res

    return Analysis(QueryClass.NON_HYBRID, plan, outer_project=outer_proj)


def _analyze_dist_join(node: Join, outer_proj, catalog: Catalog,
                       plan: PlanNode) -> Analysis | None:
    li = _resolve_scan(node.left)
    ri = _resolve_scan(node.right)
    if li is None or ri is None:
        return None
    (lscan, lpred), (rscan, rpred) = li, ri
    dist, radius, rest = _range_conjunct(node.condition)
    if dist is None:
        return None
    lcol, rcol = _column_of(dist.lhs), _column_of(dist.rhs)
    if lcol is None or rcol is None:
        return None
    # orient: lcol belongs to left scan
    if not _belongs_to(lcol, lscan.table, lscan.alias):
        lcol, rcol = rcol, lcol
    if not (_is_vector_col(catalog, lscan.table, lcol)
            and _is_vector_col(catalog, rscan.table, rcol)):
        return None
    residual = conjoin(split_conjuncts(rest) + split_conjuncts(lpred)
                       + split_conjuncts(rpred))
    return Analysis(
        QueryClass.DIST_JOIN, plan,
        left_table=lscan.table, left_alias=lscan.alias,
        right_table=rscan.table, right_alias=rscan.alias,
        left_vector=lcol.name, right_vector=rcol.name,
        radius=radius, join_predicate=residual, outer_project=outer_proj)


def _analyze_window(node: WindowRank, rank_k, outer_proj, catalog: Catalog,
                    plan: PlanNode) -> Analysis:
    order = node.order_by
    if not isinstance(order, Distance) or rank_k is None:
        return Analysis(QueryClass.NON_HYBRID, plan, outer_project=outer_proj)

    child = node.child

    # ---- single-table: Q5 (category partition) -----------------------------
    scan_info = _resolve_scan(child)
    if scan_info is not None:
        scan, pred = scan_info
        dist_c, radius, rest = _range_conjunct(pred)
        vcol = _column_of(order.lhs) or _column_of(order.rhs)
        qexpr = order.rhs if _column_of(order.lhs) is vcol else order.lhs
        if (_is_vector_col(catalog, scan.table, vcol)
                and len(node.partition_by) >= 1):
            cat = node.partition_by[-1]
            # PARTITION BY category ≡ PARTITION BY 1, category (paper §2.4)
            cat_ok = isinstance(cat, Column)
            if cat_ok and dist_c is not None:
                return Analysis(
                    QueryClass.CATEGORY_PARTITION, plan, table=scan.table,
                    alias=scan.alias, vector_column=vcol.name, query_expr=qexpr,
                    k=rank_k, radius=radius, structured_predicate=rest,
                    partition_keys=tuple(node.partition_by),
                    category_column=cat, rank_name=node.rank_name,
                    outer_project=outer_proj)

    # ---- join families: Q4 (entity-centric) / Q6 (category join) ----------
    jnode = child
    extra_pred = None
    if isinstance(jnode, Filter):
        extra_pred = jnode.predicate
        jnode = jnode.child
    if isinstance(jnode, Join):
        li, ri = _resolve_scan(jnode.left), _resolve_scan(jnode.right)
        if li is not None and ri is not None:
            (lscan, lpred), (rscan, rpred) = li, ri
            cond = conjoin(split_conjuncts(jnode.condition)
                           + split_conjuncts(extra_pred))
            dist_c, radius, residual = _range_conjunct(cond)
            residual = conjoin(split_conjuncts(residual)
                               + split_conjuncts(lpred) + split_conjuncts(rpred))
            lcol = _column_of(order.lhs)
            rcol = _column_of(order.rhs)
            if lcol is not None and rcol is not None:
                if not _belongs_to(lcol, lscan.table, lscan.alias):
                    lcol, rcol = rcol, lcol
                lv = _is_vector_col(catalog, lscan.table, lcol)
                rv = _is_vector_col(catalog, rscan.table, rcol)
                if lv and rv:
                    pk = catalog.table(lscan.table).schema.primary_key
                    parts = node.partition_by
                    first = parts[0] if parts else None
                    pk_first = (isinstance(first, Column) and first.name == pk
                                and _belongs_to(first, lscan.table, lscan.alias))
                    if len(parts) == 1 and pk_first and radius is None:
                        # Q4 pattern: window(Tq ⋈ Tr, partitionBy(pk_q)) (§4.2)
                        return Analysis(
                            QueryClass.KNN_JOIN, plan,
                            left_table=lscan.table, left_alias=lscan.alias,
                            right_table=rscan.table, right_alias=rscan.alias,
                            left_vector=lcol.name, right_vector=rcol.name,
                            k=rank_k, join_predicate=residual,
                            partition_keys=tuple(parts),
                            rank_name=node.rank_name, outer_project=outer_proj)
                    if (len(parts) == 2 and pk_first and radius is not None
                            and isinstance(parts[1], Column)):
                        # Q6 pattern: partitionBy(pk_q, c_r), join ON dist<=R1 (§4.3)
                        return Analysis(
                            QueryClass.CATEGORY_JOIN, plan,
                            left_table=lscan.table, left_alias=lscan.alias,
                            right_table=rscan.table, right_alias=rscan.alias,
                            left_vector=lcol.name, right_vector=rcol.name,
                            k=rank_k, radius=radius, join_predicate=residual,
                            partition_keys=tuple(parts),
                            category_column=parts[1],
                            rank_name=node.rank_name, outer_project=outer_proj)

    return Analysis(QueryClass.NON_HYBRID, plan, outer_project=outer_proj)
