"""Volcano-style iterator engine — the *interpreted* baseline (CHASE §2.4).

The paper argues that tuple-at-a-time iterator execution (repeated ``Next``
virtual calls, unpredictable branches) is a dominant overhead that code
generation removes.  This module implements that traditional engine honestly:
every operator is a Python iterator pulling one tuple dict at a time; every
distance is a per-tuple numpy dot.  Counters (next-calls, distance evals,
predicate evals) feed the Table-5-analogue benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import numpy as np

from .expr import (Arith, BoolOp, Cmp, Column, Const, Distance, Expr, Param)
from .plan import (Filter, Join, Limit, Map, OrderBy, PlanNode, Project, Scan,
                   WindowRank)
from .schema import Catalog, Metric
from .sql import _Aliased


@dataclasses.dataclass
class Counters:
    """Interpreter overhead counters (the Table-5-analogue measurables)."""
    next_calls: int = 0
    distance_evals: int = 0
    predicate_evals: int = 0
    tuples_materialized: int = 0


class Interpreter:
    """Tuple-at-a-time Volcano evaluator over a catalog (see module doc)."""

    def __init__(self, catalog: Catalog, binds: dict[str, Any]):
        self.catalog = catalog
        self.binds = binds
        self.counters = Counters()

    # -- per-tuple expression evaluation (the slow path, on purpose) --------
    def eval_expr(self, e: Expr, t: dict) -> Any:
        """Evaluate an expression against ONE tuple dict (counted)."""
        if isinstance(e, Column):
            key = f"{e.table}.{e.name}" if e.table else e.name
            if key in t:
                return t[key]
            return t[e.name]
        if isinstance(e, Const):
            return e.value
        if isinstance(e, Param):
            return self.binds[e.name]
        if isinstance(e, Cmp):
            lo = self.eval_expr(e.lhs, t)
            hi = self.eval_expr(e.rhs, t)
            self.counters.predicate_evals += 1
            op = e.op
            # paper convention: DISTANCE(x,q) <= r means "within radius r";
            # under similarity metrics (IP/cosine) the raw value ranks
            # inversely, so the comparison flips (same rule the compiled
            # engine applies via in_range()).
            if isinstance(e.lhs, Distance):
                metric = e.lhs.metric or Metric.INNER_PRODUCT
                if metric.is_similarity():
                    op = {"<": ">", "<=": ">=", ">": "<", ">=": "<=",
                          "=": "=", "<>": "<>"}[op]
            return {"<": lo < hi, "<=": lo <= hi, ">": lo > hi,
                    ">=": lo >= hi, "=": lo == hi, "<>": lo != hi}[op]
        if isinstance(e, BoolOp):
            if e.op == "not":
                return not self.eval_expr(e.operands[0], t)
            if e.op == "and":
                return all(self.eval_expr(o, t) for o in e.operands)
            return any(self.eval_expr(o, t) for o in e.operands)
        if isinstance(e, Arith):
            lo = self.eval_expr(e.lhs, t)
            hi = self.eval_expr(e.rhs, t)
            return {"+": lo + hi, "-": lo - hi, "*": lo * hi,
                    "/": lo / hi}[e.op]
        if isinstance(e, Distance):
            x = np.asarray(self.eval_expr(e.lhs, t), dtype=np.float32)
            q = np.asarray(self.eval_expr(e.rhs, t), dtype=np.float32)
            self.counters.distance_evals += 1
            metric = e.metric or Metric.INNER_PRODUCT
            if metric == Metric.L2:
                d = x - q
                return float(np.dot(d, d))
            if metric == Metric.INNER_PRODUCT:
                return float(np.dot(x, q))
            return float(np.dot(x, q)
                         / (np.linalg.norm(x) * np.linalg.norm(q) + 1e-12))
        raise TypeError(type(e))

    def order_value(self, e: Expr, t: dict) -> float:
        """Ascending sort key; similarity metrics sort descending raw."""
        v = self.eval_expr(e, t)
        if isinstance(e, Distance):
            metric = e.metric or Metric.INNER_PRODUCT
            if metric.is_similarity():
                return -v
        return v

    # -- iterator construction ----------------------------------------------
    def run(self, plan: PlanNode) -> list[dict]:
        """Drain the plan's iterator tree into a list of tuple dicts."""
        out = []
        for t in self.iterate(plan):
            self.counters.next_calls += 1
            out.append(t)
        return out

    def iterate(self, node: PlanNode) -> Iterator[dict]:
        """Build the pull-based iterator for one plan node (recursive)."""
        if isinstance(node, Scan):
            tab = self.catalog.table(node.table)
            cols = {n: np.asarray(v) for n, v in tab.columns.items()}
            alias = node.alias or node.table
            names = list(cols)
            for i in range(tab.num_rows):
                self.counters.next_calls += 1
                t = {}
                for n in names:
                    v = cols[n][i]
                    t[n] = v
                    t[f"{alias}.{n}"] = v
                    t[f"{node.table}.{n}"] = v
                yield t
            return
        if isinstance(node, Filter):
            for t in self.iterate(node.child):
                self.counters.next_calls += 1
                if self.eval_expr(node.predicate, t):
                    yield t
            return
        if isinstance(node, Map):
            for t in self.iterate(node.child):
                self.counters.next_calls += 1
                t = dict(t)
                t[node.name] = self.eval_expr(node.expr, t)
                yield t
            return
        if isinstance(node, OrderBy):
            rows = [(self.order_value(node.key, t), i, t)
                    for i, t in enumerate(self.iterate(node.child))]
            self.counters.tuples_materialized += len(rows)
            rows.sort(key=lambda r: (r[0], r[1]))
            for _, _, t in rows:
                self.counters.next_calls += 1
                yield t
            return
        if isinstance(node, Limit):
            k = node.k if isinstance(node.k, int) else int(self.binds[node.k])
            for i, t in enumerate(self.iterate(node.child)):
                if i >= k:
                    return
                self.counters.next_calls += 1
                yield t
            return
        if isinstance(node, Join):
            right_rows = list(self.iterate(node.right))
            self.counters.tuples_materialized += len(right_rows)
            for lt in self.iterate(node.left):
                for rt in right_rows:
                    self.counters.next_calls += 1
                    merged = {**lt, **rt}
                    if node.condition is None or self.eval_expr(
                            node.condition, merged):
                        yield merged
            return
        if isinstance(node, WindowRank):
            rows = list(self.iterate(node.child))
            self.counters.tuples_materialized += len(rows)
            groups: dict[tuple, list] = {}
            for t in rows:
                key = tuple(_hashable(self.eval_expr(p, t))
                            for p in node.partition_by)
                groups.setdefault(key, []).append(t)
            for key, grp in groups.items():
                scored = [(self.order_value(node.order_by, t), i, t)
                          for i, t in enumerate(grp)]
                scored.sort(key=lambda r: (r[0], r[1]))
                for rank, (_, _, t) in enumerate(scored, start=1):
                    self.counters.next_calls += 1
                    t = dict(t)
                    t[node.rank_name] = rank
                    yield t
            return
        if isinstance(node, Project):
            for t in self.iterate(node.child):
                self.counters.next_calls += 1
                yield {name: self.eval_expr(e, t) for name, e in node.outputs}
            return
        if isinstance(node, _Aliased):
            for t in self.iterate(node.child):
                t = dict(t)
                for k in list(t.keys()):
                    if "." not in str(k):
                        t[f"{node.alias}.{k}"] = t[k]
                yield t
            return
        raise NotImplementedError(f"interpreter: {type(node).__name__}")


def _hashable(v):
    if isinstance(v, np.ndarray):
        return v.tobytes()
    if isinstance(v, (np.generic,)):
        return v.item()
    return v


def run_interpreted(sql: str, catalog: Catalog, binds: dict[str, Any]):
    """Parse + execute on the iterator engine. Returns (rows, counters)."""
    from .sql import parse_sql
    interp = Interpreter(catalog, binds)
    plan = parse_sql(sql)
    rows = interp.run(plan)
    return rows, interp.counters
