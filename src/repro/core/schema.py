"""Relational schema with first-class vector columns.

The paper's central design point is that the vector attribute must be a
first-class citizen of the engine (CHASE §1, §6: ``DenseVectorType<dim>`` in the
db dialect).  Here a :class:`Table` is a columnar batch of jnp arrays with a
typed :class:`Schema`; vector columns carry their dimensionality and metric.

TPU static-shape discipline: tables are fixed-capacity.  Row deletion /
selection is represented by a validity mask, never by physically shrinking an
array, so every operator stays shape-stable under jit.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Mapping

import jax.numpy as jnp
import numpy as np


class ColumnKind(enum.Enum):
    """Column type tags for the relational schema."""
    INT = "int"
    FLOAT = "float"
    BOOL = "bool"
    CATEGORY = "category"  # small-int category codes (dictionary-encoded)
    VECTOR = "vector"      # dense embedding


class Metric(enum.Enum):
    """Vector distance/similarity metric of a vector column."""
    L2 = "l2"
    INNER_PRODUCT = "ip"
    COSINE = "cosine"

    def is_similarity(self) -> bool:
        """True when larger values mean *more* similar (IP / cosine)."""
        return self in (Metric.INNER_PRODUCT, Metric.COSINE)


@dataclasses.dataclass(frozen=True)
class ColumnType:
    """Typed column declaration (kind, dtype, and vector/category extras)."""
    kind: ColumnKind
    dtype: Any = None          # jnp dtype; defaulted per kind
    dim: int | None = None     # vector dimensionality
    num_categories: int | None = None  # category cardinality (when known)
    metric: Metric = Metric.INNER_PRODUCT

    def __post_init__(self):
        if self.dtype is None:
            default = {
                ColumnKind.INT: jnp.int32,
                ColumnKind.FLOAT: jnp.float32,
                ColumnKind.BOOL: jnp.bool_,
                ColumnKind.CATEGORY: jnp.int32,
                ColumnKind.VECTOR: jnp.float32,
            }[self.kind]
            object.__setattr__(self, "dtype", default)
        if self.kind == ColumnKind.VECTOR and not self.dim:
            raise ValueError("vector columns require dim")


def int_col(dtype=jnp.int32) -> ColumnType:
    """Integer column declaration."""
    return ColumnType(ColumnKind.INT, dtype)


def float_col(dtype=jnp.float32) -> ColumnType:
    """Float column declaration."""
    return ColumnType(ColumnKind.FLOAT, dtype)


def bool_col() -> ColumnType:
    """Boolean column declaration."""
    return ColumnType(ColumnKind.BOOL)


def category_col(num_categories: int | None = None) -> ColumnType:
    """Dictionary-encoded category column declaration."""
    return ColumnType(ColumnKind.CATEGORY, num_categories=num_categories)


def vector_col(dim: int, metric: Metric = Metric.INNER_PRODUCT,
               dtype=jnp.float32) -> ColumnType:
    """Dense vector column declaration (first-class: carries dim + metric)."""
    return ColumnType(ColumnKind.VECTOR, dtype, dim=dim, metric=metric)


@dataclasses.dataclass(frozen=True)
class Schema:
    """Ordered column-name -> ColumnType mapping for one table."""
    columns: Mapping[str, ColumnType]
    primary_key: str | None = None

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    def __getitem__(self, name: str) -> ColumnType:
        return self.columns[name]

    def vector_columns(self) -> list[str]:
        """Names of the schema's vector columns."""
        return [n for n, t in self.columns.items() if t.kind == ColumnKind.VECTOR]

    def names(self) -> list[str]:
        """All column names, in declaration order."""
        return list(self.columns.keys())


class Table:
    """Columnar fixed-capacity table: dict of equally-sized jnp arrays.

    ``valid`` marks live rows (static-shape selection).  All engine operators
    consume and produce Tables, threading ``valid`` through.
    """

    def __init__(self, schema: Schema, columns: Mapping[str, jnp.ndarray],
                 valid: jnp.ndarray | None = None, name: str = "t"):
        self.schema = schema
        self.columns = dict(columns)
        self.name = name
        sizes = {v.shape[0] for v in self.columns.values()}
        if len(sizes) != 1:
            raise ValueError(f"ragged columns: {sizes}")
        (self.num_rows,) = sizes
        for cname, ctype in schema.columns.items():
            if cname not in self.columns:
                raise ValueError(f"missing column {cname}")
            if ctype.kind == ColumnKind.VECTOR:
                arr = self.columns[cname]
                if arr.ndim != 2 or arr.shape[1] != ctype.dim:
                    raise ValueError(
                        f"vector column {cname}: expected (N,{ctype.dim}), got {arr.shape}")
        if valid is None:
            valid = jnp.ones((self.num_rows,), dtype=jnp.bool_)
        self.valid = valid

    def __getitem__(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    def with_column(self, name: str, ctype: ColumnType, values: jnp.ndarray) -> "Table":
        """A new Table with one extra (or replaced) column."""
        cols = dict(self.columns)
        cols[name] = values
        schema = Schema({**dict(self.schema.columns), name: ctype},
                        self.schema.primary_key)
        return Table(schema, cols, self.valid, self.name)

    def with_valid(self, valid: jnp.ndarray) -> "Table":
        """A new Table sharing columns but with a replaced validity mask."""
        return Table(self.schema, self.columns, valid, self.name)

    def take(self, idx: jnp.ndarray, valid: jnp.ndarray | None = None) -> "Table":
        """Gather rows by index (fixed output size = idx size)."""
        cols = {n: v[idx] for n, v in self.columns.items()}
        base_valid = self.valid[idx]
        if valid is not None:
            base_valid = base_valid & valid
        return Table(self.schema, cols, base_valid, self.name)

    def to_numpy(self) -> dict[str, np.ndarray]:
        """Host-side copy of all columns plus the ``__valid`` mask."""
        out = {n: np.asarray(v) for n, v in self.columns.items()}
        out["__valid"] = np.asarray(self.valid)
        return out


class Catalog:
    """Name → Table registry plus per-(table, column) ANN indexes and
    row-sharded corpus handles (for distributed plans, DESIGN.md §10).

    Every registration is **versioned** (DESIGN.md §11): ``register`` /
    ``register_index`` / ``register_sharded`` bump a monotonic catalog clock
    and stamp the touched registration key with it.  Compiled plans snapshot
    the versions of the registrations they captured at prepare time and
    compare at execute time (``CompiledQuery.ensure_fresh``), so a
    post-prepare ``register_index`` re-binds the plan's arrays — or raises a
    clear ``StalePlanError`` — instead of silently serving frozen data (the
    historical stale-plan invalidation bug)."""

    def __init__(self):
        self._tables: dict[str, Table] = {}
        self._indexes: dict[tuple[str, str], Any] = {}
        self._sharded: dict[tuple[str, str], Any] = {}
        self._quantized: dict[tuple, Any] = {}
        self._live: dict[tuple[str, str], Any] = {}
        self._clock = 0
        self._versions: dict[tuple, int] = {}

    def _bump(self, key: tuple) -> None:
        self._clock += 1
        self._versions[key] = self._clock

    def version(self, key: tuple) -> int:
        """Monotonic version of one registration key.

        Keys are ``("table", name)``, ``("index", table, column)``,
        ``("sharded", table, column)``, ``("quantized", table, column)``,
        or ``("live", table, column)``; a key never registered is
        version 0.
        Versions only grow, and no two bumps share a value (one global
        catalog clock), so equality of snapshots implies nothing changed."""
        return self._versions.get(key, 0)

    def version_snapshot(self, keys: tuple) -> tuple:
        """Versions of ``keys`` as an orderless-compare-safe tuple."""
        return tuple(self.version(k) for k in keys)

    def register(self, name: str, table: Table) -> None:
        """Register (or replace) a table under ``name``.

        Replacing bumps ``("table", name)``: plans compiled against the old
        table hold its columns in their closures and cannot re-bind — they
        raise ``StalePlanError`` and must be re-prepared."""
        table.name = name
        self._tables[name] = table
        for key in [k for k in self._quantized if k[0] == name]:
            del self._quantized[key]     # twins of the old columns are stale
        self._bump(("table", name))

    def table(self, name: str) -> Table:
        """Look up a registered table (KeyError when absent)."""
        return self._tables[name]

    def has_table(self, name: str) -> bool:
        """True iff ``name`` is a registered table."""
        return name in self._tables

    def register_index(self, table: str, column: str, index: Any) -> None:
        """Attach (or replace) an ANN index on a (table, vector column) pair.

        Bumps ``("index", table, column)``: compiled plans re-bind the new
        index arrays on their next execute (``ensure_fresh``) — index data
        rides the ``arrays`` argument of the jitted pipeline, so a
        same-structure replacement costs zero retraces."""
        self._indexes[(table, column)] = index
        self._bump(("index", table, column))

    def index_for(self, table: str, column: str):
        """The ANN index registered for (table, column), or None."""
        return self._indexes.get((table, column))

    def register_sharded(self, table: str, column: str, sharded: Any) -> None:
        """Attach a :class:`~repro.dist.sharding.ShardedCorpus` handle to a
        (table, vector column) pair.

        Keyed by the handle's own mesh spec (``sharded.spec``), so handles
        for different meshes coexist: every plan compiled with a matching
        ``EngineOptions.dist`` reuses the handle's device placement instead
        of re-slicing the corpus per prepare.

        Bumps ``("sharded", table, column)`` (spec-independent on purpose:
        any handle change invalidates every dist plan on the pair; a
        spurious re-bind re-reads an unchanged handle and is cheap)."""
        self._sharded[(table, column, sharded.spec)] = sharded
        self._bump(("sharded", table, column))

    def sharded_for(self, table: str, column: str, spec: Any):
        """The ShardedCorpus registered for (table, column) on exactly the
        mesh ``spec`` (a ``DistSpec``) describes, or None."""
        return self._sharded.get((table, column, spec))

    def register_quantized(self, table: str, column: str, quant: Any,
                           key: Any = None) -> None:
        """Attach a :class:`~repro.data.quantized.QuantizedCorpus` twin to a
        (table, vector column) pair (DESIGN.md §13).

        Keyed by ``key`` (defaults to ``quant.mode``), so int8/bf16 twins —
        and per-``DistSpec`` sharded twins, keyed ``(mode, spec)`` —
        coexist.  Bumps ``("quantized", table, column)``: quant plans carry
        the twin's arrays in their bound ``arrays`` dict, so a re-registered
        same-shape twin re-binds through ``ensure_fresh`` with zero
        retraces.  Re-registering the TABLE purges its twins (the fp32
        source changed) and stales the plans via the table key."""
        self._quantized[(table, column, key or quant.mode)] = quant
        self._bump(("quantized", table, column))

    def quantized_for(self, table: str, column: str, key: Any):
        """The QuantizedCorpus registered for (table, column) under ``key``
        (a mode string, or ``(mode, spec)`` for sharded twins), or None."""
        return self._quantized.get((table, column, key))

    def register_live(self, table: str, column: str, live: Any) -> None:
        """Attach a :class:`~repro.data.mutations.LiveCorpus` to a (table,
        vector column) pair (DESIGN.md §12).

        Bumps BOTH ``("live", table, column)`` and ``("table", table)``:
        attaching changes the corpus array layout (fixed-capacity padded
        segments replace the frozen column), so plans compiled pre-attach
        must raise ``StalePlanError`` and re-prepare.  Subsequent
        insert/delete/compact mutations bump only the live key — live plans
        carry every segment array from first compile, so mutations re-bind
        in place with zero retraces."""
        self._live[(table, column)] = live
        self._bump(("live", table, column))
        self._bump(("table", table))

    def bump_live(self, table: str, column: str) -> int:
        """Advance the ``("live", table, column)`` version (one mutation or
        compaction landed) and return the new clock value — the WAL's LSN
        source, so log sequence numbers ride the same monotonic clock that
        drives plan re-binding."""
        self._bump(("live", table, column))
        return self._versions[("live", table, column)]

    def live_for(self, table: str, column: str):
        """The LiveCorpus attached to (table, column), or None."""
        return self._live.get((table, column))

    def live_columns(self, table: str) -> list[str]:
        """Vector columns of ``table`` with a live corpus attached."""
        return [c for (t, c) in self._live if t == table]

    def advance_clock(self, to: int) -> None:
        """Fast-forward the catalog clock to at least ``to``.

        Crash recovery replays WAL records whose LSNs were minted by a
        previous process's clock; bumps in the recovered process must stay
        monotonic past them (DESIGN.md §12 LSN rule)."""
        self._clock = max(self._clock, int(to))

    def tables(self) -> list[str]:
        """Names of all registered tables."""
        return list(self._tables)
