"""Relational schema with first-class vector columns.

The paper's central design point is that the vector attribute must be a
first-class citizen of the engine (CHASE §1, §6: ``DenseVectorType<dim>`` in the
db dialect).  Here a :class:`Table` is a columnar batch of jnp arrays with a
typed :class:`Schema`; vector columns carry their dimensionality and metric.

TPU static-shape discipline: tables are fixed-capacity.  Row deletion /
selection is represented by a validity mask, never by physically shrinking an
array, so every operator stays shape-stable under jit.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Mapping

import jax.numpy as jnp
import numpy as np


class ColumnKind(enum.Enum):
    INT = "int"
    FLOAT = "float"
    BOOL = "bool"
    CATEGORY = "category"  # small-int category codes (dictionary-encoded)
    VECTOR = "vector"      # dense embedding


class Metric(enum.Enum):
    L2 = "l2"
    INNER_PRODUCT = "ip"
    COSINE = "cosine"

    def is_similarity(self) -> bool:
        """True when larger values mean *more* similar (IP / cosine)."""
        return self in (Metric.INNER_PRODUCT, Metric.COSINE)


@dataclasses.dataclass(frozen=True)
class ColumnType:
    kind: ColumnKind
    dtype: Any = None          # jnp dtype; defaulted per kind
    dim: int | None = None     # vector dimensionality
    num_categories: int | None = None  # category cardinality (when known)
    metric: Metric = Metric.INNER_PRODUCT

    def __post_init__(self):
        if self.dtype is None:
            default = {
                ColumnKind.INT: jnp.int32,
                ColumnKind.FLOAT: jnp.float32,
                ColumnKind.BOOL: jnp.bool_,
                ColumnKind.CATEGORY: jnp.int32,
                ColumnKind.VECTOR: jnp.float32,
            }[self.kind]
            object.__setattr__(self, "dtype", default)
        if self.kind == ColumnKind.VECTOR and not self.dim:
            raise ValueError("vector columns require dim")


def int_col(dtype=jnp.int32) -> ColumnType:
    return ColumnType(ColumnKind.INT, dtype)


def float_col(dtype=jnp.float32) -> ColumnType:
    return ColumnType(ColumnKind.FLOAT, dtype)


def bool_col() -> ColumnType:
    return ColumnType(ColumnKind.BOOL)


def category_col(num_categories: int | None = None) -> ColumnType:
    return ColumnType(ColumnKind.CATEGORY, num_categories=num_categories)


def vector_col(dim: int, metric: Metric = Metric.INNER_PRODUCT,
               dtype=jnp.float32) -> ColumnType:
    return ColumnType(ColumnKind.VECTOR, dtype, dim=dim, metric=metric)


@dataclasses.dataclass(frozen=True)
class Schema:
    columns: Mapping[str, ColumnType]
    primary_key: str | None = None

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    def __getitem__(self, name: str) -> ColumnType:
        return self.columns[name]

    def vector_columns(self) -> list[str]:
        return [n for n, t in self.columns.items() if t.kind == ColumnKind.VECTOR]

    def names(self) -> list[str]:
        return list(self.columns.keys())


class Table:
    """Columnar fixed-capacity table: dict of equally-sized jnp arrays.

    ``valid`` marks live rows (static-shape selection).  All engine operators
    consume and produce Tables, threading ``valid`` through.
    """

    def __init__(self, schema: Schema, columns: Mapping[str, jnp.ndarray],
                 valid: jnp.ndarray | None = None, name: str = "t"):
        self.schema = schema
        self.columns = dict(columns)
        self.name = name
        sizes = {v.shape[0] for v in self.columns.values()}
        if len(sizes) != 1:
            raise ValueError(f"ragged columns: {sizes}")
        (self.num_rows,) = sizes
        for cname, ctype in schema.columns.items():
            if cname not in self.columns:
                raise ValueError(f"missing column {cname}")
            if ctype.kind == ColumnKind.VECTOR:
                arr = self.columns[cname]
                if arr.ndim != 2 or arr.shape[1] != ctype.dim:
                    raise ValueError(
                        f"vector column {cname}: expected (N,{ctype.dim}), got {arr.shape}")
        if valid is None:
            valid = jnp.ones((self.num_rows,), dtype=jnp.bool_)
        self.valid = valid

    def __getitem__(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    def with_column(self, name: str, ctype: ColumnType, values: jnp.ndarray) -> "Table":
        cols = dict(self.columns)
        cols[name] = values
        schema = Schema({**dict(self.schema.columns), name: ctype},
                        self.schema.primary_key)
        return Table(schema, cols, self.valid, self.name)

    def with_valid(self, valid: jnp.ndarray) -> "Table":
        return Table(self.schema, self.columns, valid, self.name)

    def take(self, idx: jnp.ndarray, valid: jnp.ndarray | None = None) -> "Table":
        """Gather rows by index (fixed output size = idx size)."""
        cols = {n: v[idx] for n, v in self.columns.items()}
        base_valid = self.valid[idx]
        if valid is not None:
            base_valid = base_valid & valid
        return Table(self.schema, cols, base_valid, self.name)

    def to_numpy(self) -> dict[str, np.ndarray]:
        out = {n: np.asarray(v) for n, v in self.columns.items()}
        out["__valid"] = np.asarray(self.valid)
        return out


class Catalog:
    """Name → Table registry plus per-(table, column) ANN indexes."""

    def __init__(self):
        self._tables: dict[str, Table] = {}
        self._indexes: dict[tuple[str, str], Any] = {}

    def register(self, name: str, table: Table) -> None:
        table.name = name
        self._tables[name] = table

    def table(self, name: str) -> Table:
        return self._tables[name]

    def has_table(self, name: str) -> bool:
        return name in self._tables

    def register_index(self, table: str, column: str, index: Any) -> None:
        self._indexes[(table, column)] = index

    def index_for(self, table: str, column: str):
        return self._indexes.get((table, column))

    def tables(self) -> list[str]:
        return list(self._tables)
