"""Logical plan IR — the `relalg` dialect analogue (CHASE §6).

Nodes are immutable dataclasses forming a tree.  The semantic analyzer
(:mod:`repro.core.semantics`) pattern-matches these trees against the paper's
hybrid-query patterns (§4) and the rewriter (:mod:`repro.core.rewriter`)
produces new trees containing the CHASE-specific operators:

* :class:`Map`          — R1: materialize index-scan similarity into `__sim`
* :class:`KnnSubquery`  — R2: decoupled entity-centric VKNN-SF pipeline
* :class:`UpdateState`  — R3: category-convergence tracking for early stop

Physical selection then lowers this tree to executors (the `subop` analogue).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from .expr import Expr, Distance


class PlanNode:
    """Base logical-plan node (an immutable tree; see module docstring)."""

    def children(self) -> Sequence["PlanNode"]:
        """Direct child plan nodes (empty for leaves)."""
        return ()

    def pretty(self, indent: int = 0) -> str:
        """Indented multi-line rendering of the subtree (for explain())."""
        pad = "  " * indent
        head = f"{pad}{self.label()}"
        lines = [head]
        for c in self.children():
            lines.append(c.pretty(indent + 1))
        return "\n".join(lines)

    def label(self) -> str:
        """One-line node description used by :meth:`pretty`."""
        return type(self).__name__


@dataclasses.dataclass(frozen=True, eq=False)
class Scan(PlanNode):
    """Full table scan (the relational leaf)."""
    table: str
    alias: str | None = None

    def label(self):
        a = f" AS {self.alias}" if self.alias and self.alias != self.table else ""
        return f"Scan[{self.table}{a}]"


@dataclasses.dataclass(frozen=True, eq=False)
class Filter(PlanNode):
    """Row selection by a boolean predicate expression."""
    child: PlanNode
    predicate: Expr

    def children(self):
        return (self.child,)

    def label(self):
        return f"Filter[{self.predicate!r}]"


@dataclasses.dataclass(frozen=True, eq=False)
class Map(PlanNode):
    """Compute expression -> new column.  CHASE's R1 target: when ``expr`` is
    ``FromIndexScan`` the column is *wired* from the scan's similarity output
    instead of being recomputed (relalg.map in Fig. 7b)."""
    child: PlanNode
    name: str
    expr: Expr | None            # None => wired from index scan similarity
    from_index_scan: bool = False

    def children(self):
        return (self.child,)

    def label(self):
        src = "<index-scan sim>" if self.from_index_scan else repr(self.expr)
        return f"Map[{self.name} := {src}]"


@dataclasses.dataclass(frozen=True, eq=False)
class OrderBy(PlanNode):
    """Sort by one key expression."""
    child: PlanNode
    key: Expr
    # ascending in *order-key* space; Distance keys are normalized by metric.

    def children(self):
        return (self.child,)

    def label(self):
        return f"OrderBy[{self.key!r}]"


@dataclasses.dataclass(frozen=True, eq=False)
class Limit(PlanNode):
    """Keep the first k rows (k may be a static-bind parameter name)."""
    child: PlanNode
    k: "int | str"   # int or param name

    def children(self):
        return (self.child,)

    def label(self):
        return f"Limit[{self.k}]"


@dataclasses.dataclass(frozen=True, eq=False)
class Project(PlanNode):
    """Output projection: (name, expression) pairs."""
    child: PlanNode
    outputs: tuple[tuple[str, Expr], ...]   # (output name, expr)

    def children(self):
        return (self.child,)

    def label(self):
        cols = ", ".join(n for n, _ in self.outputs)
        return f"Project[{cols}]"


@dataclasses.dataclass(frozen=True, eq=False)
class Join(PlanNode):
    """Inner join on an optional condition (vector joins carry the
    DISTANCE predicate here before rewriting)."""
    left: PlanNode
    right: PlanNode
    condition: Expr | None

    def children(self):
        return (self.left, self.right)

    def label(self):
        return f"Join[{self.condition!r}]"


@dataclasses.dataclass(frozen=True, eq=False)
class WindowRank(PlanNode):
    """RANK() OVER (PARTITION BY ... ORDER BY ...) AS name."""
    child: PlanNode
    partition_by: tuple[Expr, ...]
    order_by: Expr
    rank_name: str = "rank"

    def children(self):
        return (self.child,)

    def label(self):
        parts = ", ".join(map(repr, self.partition_by))
        return f"WindowRank[partition=({parts}) order={self.order_by!r} as {self.rank_name}]"


# ---------------------------------------------------------------------------
# CHASE-introduced logical operators (products of rewriting, §4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class IndexScan(PlanNode):
    """ANN-index-backed scan.  ``mode``:
    * 'topk'  — Topk interface (Q1/Q4): ordered traversal, emits sims
    * 'range' — RangeSearch interface (Q2/Q3/Q5/Q6): Algorithm 1
    Residual structured predicate is applied inline (fused)."""
    table: str
    vector_column: str
    query: Expr                    # Param or Column (join side)
    mode: str                      # 'topk' | 'range'
    k: "int | str | None" = None
    radius: Expr | None = None
    predicate: Expr | None = None
    alias: str | None = None
    emit_similarity: bool = True   # CHASE physical-op change (§5.1)

    def label(self):
        extra = f" k={self.k}" if self.mode == "topk" else f" radius={self.radius!r}"
        pred = f" pred={self.predicate!r}" if self.predicate is not None else ""
        return (f"IndexScan[{self.table}.{self.vector_column} <*> {self.query!r}"
                f" mode={self.mode}{extra}{pred} emit_sim={self.emit_similarity}]")


@dataclasses.dataclass(frozen=True, eq=False)
class KnnSubquery(PlanNode):
    """R2 product: per-row-of-left VKNN-SF against right's ANN index
    (scan→orderBy→limit pipeline with the join as pipeline breaker)."""
    left: PlanNode                # query table pipeline
    right_table: str
    vector_column: str
    left_vector: Expr             # column of left acting as query vector
    k: "int | str"
    join_predicate: Expr | None   # residual structured join condition
    rank_name: str = "rank"

    def children(self):
        return (self.left,)

    def label(self):
        return (f"KnnSubquery[{self.right_table}.{self.vector_column} per-left-row "
                f"k={self.k} pred={self.join_predicate!r}]")


@dataclasses.dataclass(frozen=True, eq=False)
class UpdateState(PlanNode):
    """R3 product: per-category convergence tracking (Algorithm 2) feeding
    early termination back into the range IndexScan below it."""
    child: PlanNode
    category: Expr
    k: "int | str"

    def children(self):
        return (self.child,)

    def label(self):
        return f"UpdateState[category={self.category!r} K={self.k}]"


# ---------------------------------------------------------------------------
# Tree utilities
# ---------------------------------------------------------------------------

def walk_plan(node: PlanNode):
    """Yield ``node`` and every descendant, pre-order."""
    yield node
    for c in node.children():
        yield from walk_plan(c)


def replace_child(node: PlanNode, old: PlanNode, new: PlanNode) -> PlanNode:
    """Shallow rebuild of ``node`` with ``old`` child replaced by ``new``."""
    kwargs = {}
    for f in dataclasses.fields(node):
        v = getattr(node, f.name)
        kwargs[f.name] = new if v is old else v
    return type(node)(**kwargs)


def find_first(node: PlanNode, kind) -> Optional[PlanNode]:
    """First node of type ``kind`` in pre-order, or None."""
    for n in walk_plan(node):
        if isinstance(n, kind):
            return n
    return None


def plan_distance(node: PlanNode) -> Distance | None:
    """First Distance expression anywhere in the plan (for metric resolution)."""
    from .expr import find_distance
    for n in walk_plan(node):
        for f in dataclasses.fields(n):
            v = getattr(n, f.name)
            if isinstance(v, Expr):
                d = find_distance(v)
                if d is not None:
                    return d
            if isinstance(v, tuple):
                for item in v:
                    e = item[1] if isinstance(item, tuple) else item
                    if isinstance(e, Expr):
                        d = find_distance(e)
                        if d is not None:
                            return d
    return None
