"""SQL subset parser: text -> logical plan.

Covers the full surface of the paper's six query templates (Fig. 2), parsed
verbatim: SELECT lists with aliases, FROM table/aliased-subquery, JOIN ... ON,
WHERE conjunctions/disjunctions, DISTANCE(a, b), ``${param}`` placeholders,
RANK() OVER (PARTITION BY ... ORDER BY ...), ORDER BY, LIMIT.

This is a hand-written recursive-descent parser (the production analogue of
LingoDB's SQL frontend) — deliberately small but real: the benchmark queries in
:mod:`benchmarks` are authored as SQL strings, not pre-built plans.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from .expr import (Arith, BoolOp, Cmp, Column, Const, Distance, Expr, Param)
from .plan import (Filter, Join, Limit, OrderBy, PlanNode, Project, Scan,
                   WindowRank)

_TOKEN_RE = re.compile(
    r"""
      (?P<ws>\s+)
    | (?P<param>\$\{\s*[A-Za-z_][A-Za-z0-9_]*\s*\})
    | (?P<number>\d+\.\d+|\.\d+|\d+)
    | (?P<string>'(?:[^']|'')*')
    | (?P<name>[A-Za-z_][A-Za-z0-9_]*)
    | (?P<op><>|<=|>=|!=|=|<|>)
    | (?P<punct>[(),.*])
    """,
    re.VERBOSE,
)

KEYWORDS = {
    "select", "from", "where", "and", "or", "not", "order", "by", "limit",
    "join", "on", "as", "rank", "over", "partition", "distance", "asc", "desc",
    "inner",
}


@dataclasses.dataclass
class Token:
    """One lexeme: (kind, text, source position)."""
    kind: str
    text: str
    pos: int


def tokenize(sql: str) -> list[Token]:
    """Lex ``sql`` into tokens (keywords lower-cased, whitespace dropped)."""
    out: list[Token] = []
    i = 0
    while i < len(sql):
        m = _TOKEN_RE.match(sql, i)
        if not m:
            raise SyntaxError(f"bad character at {i}: {sql[i:i+20]!r}")
        i = m.end()
        kind = m.lastgroup
        if kind == "ws":
            continue
        text = m.group()
        if kind == "name" and text.lower() in KEYWORDS:
            kind = "kw"
            text = text.lower()
        out.append(Token(kind, text, m.start()))
    out.append(Token("eof", "", len(sql)))
    return out


@dataclasses.dataclass
class SelectItem:
    """One SELECT-list entry (expression, window item, or ``*``)."""
    expr: Expr | None       # None for window items (handled specially) or '*'
    alias: str | None
    window: Optional["WindowSpec"] = None
    star: bool = False


@dataclasses.dataclass
class WindowSpec:
    """RANK() OVER (PARTITION BY ... ORDER BY ...) clause body."""
    partition_by: list[Expr]
    order_by: Expr


class Parser:
    """Recursive-descent parser for the hybrid-query SQL template surface."""

    def __init__(self, sql: str):
        self.toks = tokenize(sql)
        self.i = 0

    # -- token plumbing ------------------------------------------------------
    def peek(self, off: int = 0) -> Token:
        """Look ahead ``off`` tokens without consuming."""
        return self.toks[min(self.i + off, len(self.toks) - 1)]

    def next(self) -> Token:
        """Consume and return the current token."""
        t = self.toks[self.i]
        self.i += 1
        return t

    def accept(self, kind: str, text: str | None = None) -> Token | None:
        """Consume the current token iff it matches; None otherwise."""
        t = self.peek()
        if t.kind == kind and (text is None or t.text == text):
            return self.next()
        return None

    def expect(self, kind: str, text: str | None = None) -> Token:
        """Consume a required token or raise SyntaxError."""
        t = self.accept(kind, text)
        if t is None:
            got = self.peek()
            raise SyntaxError(f"expected {text or kind}, got {got.text!r} at {got.pos}")
        return t

    def parse_alias(self) -> str:
        """An alias name (permits the ``rank`` keyword as a name)."""
        t = self.peek()
        if t.kind == "name" or (t.kind == "kw" and t.text in ("rank",)):
            return self.next().text
        raise SyntaxError(f"expected alias, got {t.text!r} at {t.pos}")

    # -- entry ---------------------------------------------------------------
    def parse(self) -> PlanNode:
        """Parse a full statement to a logical plan (must consume all input)."""
        plan = self.parse_select()
        self.expect("eof")
        return plan

    def parse_select(self) -> PlanNode:
        """SELECT ... FROM ... [WHERE] [ORDER BY] [LIMIT] -> plan tree."""
        self.expect("kw", "select")
        items = [self.parse_select_item()]
        while self.accept("punct", ","):
            items.append(self.parse_select_item())

        self.expect("kw", "from")
        plan = self.parse_from_item()
        while self.peek().kind == "kw" and self.peek().text in ("join", "inner"):
            if self.accept("kw", "inner"):
                pass
            self.expect("kw", "join")
            right = self.parse_from_item()
            cond = None
            if self.accept("kw", "on"):
                cond = self.parse_expr()
            plan = Join(plan, right, cond)

        if self.accept("kw", "where"):
            plan = Filter(plan, self.parse_expr())

        # window items become WindowRank nodes above the filtered input
        for it in items:
            if it.window is not None:
                plan = WindowRank(plan, tuple(it.window.partition_by),
                                  it.window.order_by, it.alias or "rank")

        if self.accept("kw", "order"):
            self.expect("kw", "by")
            key = self.parse_expr()
            if self.accept("kw", "desc"):
                key = Arith("*", Const(-1), key)
            else:
                self.accept("kw", "asc")
            plan = OrderBy(plan, key)

        if self.accept("kw", "limit"):
            t = self.peek()
            if t.kind == "number":
                self.next()
                plan = Limit(plan, int(t.text))
            elif t.kind == "param":
                self.next()
                plan = Limit(plan, _param_name(t.text))
            else:
                raise SyntaxError(f"bad LIMIT at {t.pos}")

        outs = []
        star = False
        for it in items:
            if it.star:
                star = True
            elif it.window is None:
                name = it.alias or _default_name(it.expr)
                outs.append((name, it.expr))
            else:
                outs.append((it.alias or "rank", Column(it.alias or "rank")))
        if not star:
            plan = Project(plan, tuple(outs))
        return plan

    def parse_from_item(self) -> PlanNode:
        """A FROM item: table (with alias) or parenthesized subquery."""
        if self.accept("punct", "("):
            sub = self.parse_select()
            self.expect("punct", ")")
            alias = None
            if self.accept("kw", "as"):
                alias = self.expect("name").text
            elif self.peek().kind == "name":
                alias = self.next().text
            return _Aliased(sub, alias) if alias else sub
        t = self.expect("name")
        alias = None
        if self.accept("kw", "as"):
            alias = self.expect("name").text
        elif self.peek().kind == "name":
            alias = self.next().text
        return Scan(t.text, alias or t.text)

    def parse_select_item(self) -> SelectItem:
        """A SELECT-list item: ``*``, RANK() OVER (...), or expression."""
        if self.accept("punct", "*"):
            return SelectItem(None, None, star=True)
        # RANK() OVER (...)
        if self.peek().kind == "kw" and self.peek().text == "rank" \
                and self.peek(1).text == "(":
            self.next()
            self.expect("punct", "(")
            self.expect("punct", ")")
            self.expect("kw", "over")
            self.expect("punct", "(")
            parts: list[Expr] = []
            if self.accept("kw", "partition"):
                self.expect("kw", "by")
                parts.append(self.parse_expr())
                while self.accept("punct", ","):
                    parts.append(self.parse_expr())
            self.expect("kw", "order")
            self.expect("kw", "by")
            order = self.parse_expr()
            self.expect("punct", ")")
            alias = None
            if self.accept("kw", "as"):
                alias = self.parse_alias()
            return SelectItem(None, alias, window=WindowSpec(parts, order))
        e = self.parse_expr()
        alias = None
        if self.accept("kw", "as"):
            alias = self.parse_alias()
        return SelectItem(e, alias)

    # -- expressions (precedence: or < and < not < cmp < add < mul < unary) --
    def parse_expr(self) -> Expr:
        """An expression at the lowest precedence level (OR)."""
        return self.parse_or()

    def parse_or(self) -> Expr:
        """Left-associative OR chain."""
        e = self.parse_and()
        while self.accept("kw", "or"):
            e = BoolOp("or", (e, self.parse_and()))
        return e

    def parse_and(self) -> Expr:
        """Left-associative AND chain."""
        e = self.parse_not()
        while self.accept("kw", "and"):
            e = BoolOp("and", (e, self.parse_not()))
        return e

    def parse_not(self) -> Expr:
        """Prefix NOT (right-associative)."""
        if self.accept("kw", "not"):
            return BoolOp("not", (self.parse_not(),))
        return self.parse_cmp()

    def parse_cmp(self) -> Expr:
        """A comparison (non-associative) over additive operands."""
        e = self.parse_add()
        t = self.peek()
        if t.kind == "op":
            self.next()
            op = "<>" if t.text == "!=" else t.text
            return Cmp(op, e, self.parse_add())
        return e

    def parse_add(self) -> Expr:
        """Additive level (template surface: passthrough to unary)."""
        # The template surface needs no arithmetic beyond DESC negation
        # (built internally); extendable here if required.
        return self.parse_unary()

    def parse_unary(self) -> Expr:
        """Atoms: parens, literals, params, DISTANCE(...), columns."""
        t = self.peek()
        if t.kind == "punct" and t.text == "(":
            self.next()
            e = self.parse_expr()
            self.expect("punct", ")")
            return e
        if t.kind == "number":
            self.next()
            return Const(float(t.text) if "." in t.text else int(t.text))
        if t.kind == "string":
            self.next()
            return Const(t.text[1:-1].replace("''", "'"))
        if t.kind == "param":
            self.next()
            return Param(_param_name(t.text))
        if t.kind == "kw" and t.text == "distance":
            self.next()
            self.expect("punct", "(")
            a = self.parse_expr()
            self.expect("punct", ",")
            b = self.parse_expr()
            self.expect("punct", ")")
            return Distance(a, b)
        if t.kind == "name":
            self.next()
            if self.accept("punct", "."):
                f = self.peek()
                if f.kind == "name" or (f.kind == "kw" and f.text in ("rank",)):
                    self.next()
                    return Column(f.text, table=t.text)
                raise SyntaxError(f"expected field name at {f.pos}")
            return Column(t.text)
        if t.kind == "kw" and t.text == "rank":
            # bare reference to a rank alias outside window syntax
            self.next()
            return Column("rank")
        raise SyntaxError(f"unexpected token {t.text!r} at {t.pos}")


@dataclasses.dataclass(frozen=True, eq=False)
class _Aliased(PlanNode):
    """FROM (subquery) AS alias — transparent wrapper kept for qualification."""
    child: PlanNode
    alias: str

    def children(self):
        return (self.child,)

    def label(self):
        return f"Aliased[{self.alias}]"


def _param_name(text: str) -> str:
    return text.strip()[2:-1].strip()


def _default_name(e: Expr) -> str:
    if isinstance(e, Column):
        return e.name
    return "expr"


def parse_sql(sql: str) -> PlanNode:
    """Parse a SQL string into the initial (pre-rewrite) logical plan."""
    return Parser(sql).parse()
