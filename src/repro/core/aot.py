"""Persistent AOT plan cache — killing the cold start (DESIGN.md §15).

CHASE's compilation-based processing pays its cost up front: q9 measures
~400ms cold ``prepare`` + first execute vs ~0.3ms warm, and every process
restart re-pays the full trace + XLA compile for every plan.  This module
makes restarts warm by persisting compiled bucket executables to disk, in
the JaCe wrapped→lowered→compiled staging idiom: each stage is an explicit,
serializable object.

**Entry payload.**  Every entry carries two serializations of one bucket
executable:

* the **portable artifact** — :mod:`jax.export` StableHLO bytes, the
  authoritative format (versioned, backend-checked by jax itself).  Loading
  it skips the Python re-trace of the physical builders but still pays the
  XLA compile of the deserialized module;
* the **native annex** — the XLA *compiled executable* serialized via
  :mod:`jax.experimental.serialize_executable`.  Loading it skips the XLA
  compile too (true AOT: milliseconds instead of hundreds).  It is only
  valid for the exact (backend, jaxlib) pair that produced it — which the
  entry key already pins — and the loader falls back to the portable
  artifact whenever the annex fails to restore.

**Key contract.**  An entry's filename is a digest over everything that
shapes the compiled computation: the normalized plan fingerprint
(DESIGN.md §9), the ``EngineOptions`` fingerprint, the canonical static
binds, the bucket Q, the full argument signature (pytree structure +
shapes + dtypes of ``(arrays, binds, qvalid, probe_budget)``), the jax /
jaxlib versions, the backend, and the entry-format version.  The same
fields are echoed in the entry header and re-validated on load, so a
renamed or hand-edited file can never serve the wrong executable.

**Invalidation.**  Entries additionally carry a cross-process **catalog
token**: a content hash of the structural state a compiled plan bakes into
its closures (table schemas, scalar predicate columns, validity masks,
index presence — NOT the corpus/index payload arrays, which ride the
``arrays`` argument and re-bind on load exactly like in-memory cache hits
do, see ``CompiledQuery.ensure_fresh``).  A token mismatch invalidates the
disk entry itself (it is deleted and re-saved on the next cold compile),
not just the in-memory plan.

**Corruption semantics.**  Truncation, garbage bytes, header/key skew, a
stale catalog token, or an unserializable plan all degrade to a clean cold
miss: a :class:`AOTCacheWarning` is emitted, the matching
``corrupt`` / ``stale`` / ``errors`` counter bumps, the bad file is
removed, and compilation proceeds exactly as if no cache existed.  No
exception ever escapes into ``prepare`` or ``execute``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import struct
import tempfile
import threading
import time
import warnings
from typing import Any, Callable

import jax
import jaxlib
import numpy as np

from .schema import ColumnKind

MAGIC = b"CHASEAOT1\n"
FORMAT_VERSION = 1


class AOTCacheWarning(UserWarning):
    """A persistent-plan-cache entry could not be used (corrupt bytes,
    version/key skew, catalog drift, or an unserializable plan).  Always a
    degradation signal, never an error: the engine falls back to a cold
    compile and keeps serving."""


def _sha(*parts: bytes) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(p)
    return h.hexdigest()


def args_signature(args: Any) -> str:
    """Digest of an argument tuple's pytree structure + leaf avals.

    Two argument tuples share a signature iff a single exported executable
    can serve both: same tree structure (bind names, index presence,
    probe-budget lane presence) and same leaf shapes/dtypes (bucket Q,
    corpus capacity, vector dim)."""
    leaves, treedef = jax.tree.flatten(args)
    parts = [repr(treedef)]
    for leaf in leaves:
        dtype = getattr(leaf, "dtype", None)
        if dtype is None:
            dtype = np.asarray(leaf).dtype
        parts.append(f"{dtype}:{tuple(np.shape(leaf))}")
    return _sha("\x1f".join(parts).encode())[:32]


def catalog_token(catalog: Any, dep_keys: tuple) -> str:
    """Cross-process content token of the catalog state a plan bakes in.

    The in-memory version clock (DESIGN.md §11) is process-local, so
    persisted entries cannot carry it.  Instead the token hashes exactly
    the state that ends up *inside* the traced computation — what a
    re-registration would silently freeze:

    * ``("table", name)`` — schema layout, every non-vector column's raw
      bytes (predicate columns become XLA constants in the trace), the
      validity mask, and vector columns' shape/dtype (their *content*
      rides the ``arrays`` argument and re-binds in place on load);
    * ``("index", t, c)`` — presence and type only (index arrays ride
      ``arrays``; presence/shape changes already miss via the signature);
    * ``("live" | "sharded" | "quantized", t, c)`` — presence only
      (mutations and twin re-registrations re-bind through ``arrays`` with
      zero retraces, exactly as in-memory hits do).
    """
    h = hashlib.sha256()
    for key in dep_keys:
        h.update(repr(key).encode())
        kind = key[0]
        if kind == "table":
            name = key[1]
            if not catalog.has_table(name):
                h.update(b"<absent>")
                continue
            tab = catalog.table(name)
            for cname in tab.schema.names():
                ctype = tab.schema[cname]
                col = tab[cname]
                h.update(f"{cname}:{ctype.kind.value}:"
                         f"{np.asarray(col).dtype}:{np.shape(col)}".encode())
                if ctype.kind != ColumnKind.VECTOR:
                    h.update(np.ascontiguousarray(np.asarray(col)).tobytes())
            h.update(np.ascontiguousarray(np.asarray(tab.valid)).tobytes())
        elif kind == "index":
            idx = catalog.index_for(key[1], key[2])
            h.update(b"<none>" if idx is None
                     else type(idx).__name__.encode())
        elif kind == "live":
            h.update(b"live" if catalog.live_for(key[1], key[2]) is not None
                     else b"<none>")
        # "sharded" / "quantized": handle content rides `arrays`; presence
        # and layout changes already miss via the argument signature
    return h.hexdigest()


@dataclasses.dataclass
class AOTBinding:
    """One compiled plan's hook into the persistent cache: the cache, the
    plan-level key components, and the catalog it must watch for
    structural drift.  Attached to a :class:`BucketedExecutor` by
    ``Database.prepare`` when the session has ``aot_cache_path`` set."""
    cache: "AOTPlanCache"
    plan_key: tuple           # (plan fingerprint, options fp, static key)
    catalog: Any
    dep_keys: tuple
    _token: tuple | None = None

    def token(self) -> str:
        """The catalog content token, cached per version snapshot (the
        snapshot is a few dict lookups; the hash walks column bytes)."""
        snap = self.catalog.version_snapshot(self.dep_keys)
        if self._token is None or self._token[0] != snap:
            self._token = (snap, catalog_token(self.catalog, self.dep_keys))
        return self._token[1]


# ---------------------------------------------------------------------------
# export / load helpers (the wrapped -> lowered -> compiled staging chain)
# ---------------------------------------------------------------------------

def export_flat(flat_fn: Callable, leaves: list):
    """Stage 1+2: trace ``flat_fn`` (a function of the flat leaf list) and
    lower it to a serializable :class:`jax.export.Exported`.

    Flattening the arguments to leaves *before* export sidesteps
    ``jax.export``'s pytree-serialization registry: custom container types
    (``IVFIndex``, live-segment handles) stay host-side in the caller's
    treedef closure, and the exported module sees only arrays."""
    from jax import export
    return export.export(jax.jit(flat_fn))(leaves)


def native_annex(exported, leaves: list):
    """Stage 3: XLA-compile the exported module and serialize the compiled
    executable.  Returns ``(compiled, annex_bytes)`` — ``(None, b"")``
    when the backend cannot serialize executables (the portable artifact
    still persists; loads then recompile the StableHLO)."""
    try:
        from jax.experimental import serialize_executable
        compiled = jax.jit(exported.call).lower(leaves).compile()
        payload, in_tree, out_tree = serialize_executable.serialize(compiled)
        return compiled, pickle.dumps((payload, in_tree, out_tree))
    except Exception:                                  # noqa: BLE001
        return None, b""


def load_native(annex: bytes) -> Callable:
    """Restore a native-annex payload to a callable over the original
    argument tuple (flattened to leaves at call time).  Near-zero cost: the
    XLA executable deserializes directly, no trace and no compile."""
    from jax.experimental import serialize_executable
    payload, in_tree, out_tree = pickle.loads(annex)
    loaded = serialize_executable.deserialize_and_load(payload, in_tree,
                                                       out_tree)
    return lambda args: loaded(jax.tree.leaves(args))


def load_portable(portable: bytes) -> Callable:
    """Restore a portable ``jax.export`` payload to a callable over the
    original argument tuple.  Skips the Python trace but re-pays the XLA
    compile of the StableHLO module on first call."""
    from jax import export
    jitted = jax.jit(export.deserialize(portable).call)
    return lambda args: jitted(jax.tree.leaves(args))


class AOTPlanCache:
    """Disk-backed AOT plan cache: one file per (plan, bucket, signature).

    Thread-safe (one process-wide lock around counters and file moves) and
    crash-safe (entries are written to a temp file and atomically
    renamed).  Shared by every ``Database`` connected with the same
    ``aot_cache_path``; safe to share across processes — the filename
    digest pins the full key, and a half-written or hand-edited file
    degrades to a clean cold miss (corruption semantics above)."""

    def __init__(self, path: str):
        self.path = os.path.abspath(os.fspath(path))
        os.makedirs(self.path, exist_ok=True)
        self._lock = threading.Lock()
        self.counters = {"hits": 0, "misses": 0, "corrupt": 0, "stale": 0,
                         "errors": 0, "saves": 0}

    # -- key / identity -----------------------------------------------------

    def _identity(self, plan_key: tuple, bucket: int,
                  sig: str) -> tuple[str, dict]:
        """(filename stem, header echo dict) for one entry."""
        expect = {
            "format": FORMAT_VERSION,
            "plan_fp": _sha(str(plan_key[0]).encode())[:32],
            "options_fp": _sha(str(plan_key[1]).encode())[:32],
            "static_key": _sha(str(plan_key[2]).encode())[:32],
            "bucket": int(bucket),
            "sig": sig,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "jaxlib_version": jaxlib.__version__,
        }
        name = _sha(json.dumps(expect, sort_keys=True).encode())[:40]
        return name, expect

    def entry_path(self, plan_key: tuple, bucket: int, sig: str) -> str:
        """Absolute path of the entry file for one key (exists or not)."""
        name, _ = self._identity(plan_key, bucket, sig)
        return os.path.join(self.path, name + ".aot")

    # -- counters / reporting -----------------------------------------------

    def stats(self) -> dict:
        """Snapshot of the disk-cache counters (hit/miss/corrupt/stale/
        errors/saves)."""
        with self._lock:
            return dict(self.counters)

    def _bump(self, counter: str) -> None:
        with self._lock:
            self.counters[counter] += 1

    def _reject(self, path: str, counter: str, detail: str) -> None:
        """Count + warn + remove an unusable entry (clean cold miss)."""
        self._bump(counter)
        try:
            os.remove(path)
        except OSError:
            pass
        warnings.warn(AOTCacheWarning(
            f"AOT plan cache: {counter} entry {os.path.basename(path)} "
            f"({detail}); falling back to cold compile"), stacklevel=3)

    def note_unserializable(self, plan_key: tuple, exc: Exception) -> None:
        """An export attempt failed: typed warning + ``errors`` bump, then
        the caller proceeds with the plain in-memory jit path."""
        self._bump("errors")
        warnings.warn(AOTCacheWarning(
            f"AOT plan cache: plan is not serializable via jax.export "
            f"({type(exc).__name__}: {exc}); executing without "
            f"persistence"), stacklevel=3)

    # -- save ---------------------------------------------------------------

    def save(self, binding: AOTBinding, bucket: int, sig: str,
             portable: bytes, annex: bytes) -> bool:
        """Atomically persist one bucket executable (write-through: called
        right after the cold trace, so LRU eviction later drops only the
        in-memory copy — the disk entry IS the eviction target)."""
        name, expect = self._identity(binding.plan_key, bucket, sig)
        path = os.path.join(self.path, name + ".aot")
        header = dict(expect)
        header.update({
            "catalog_token": binding.token(),
            "portable_len": len(portable),
            "annex_len": len(annex),
            "portable_sha": _sha(portable),
            "annex_sha": _sha(annex),
            "created_at": time.time(),
        })
        try:
            hj = json.dumps(header, sort_keys=True).encode()
            blob = MAGIC + struct.pack(">I", len(hj)) + hj + portable + annex
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    f.write(blob)
                os.replace(tmp, path)
            finally:
                if os.path.exists(tmp):
                    os.remove(tmp)
            self._bump("saves")
            return True
        except Exception as exc:                       # noqa: BLE001
            self._bump("errors")
            warnings.warn(AOTCacheWarning(
                f"AOT plan cache: failed to persist entry {name} "
                f"({type(exc).__name__}: {exc})"), stacklevel=2)
            return False

    # -- load ---------------------------------------------------------------

    def _parse(self, blob: bytes, path: str):
        """Validate framing + checksums; None (counted corrupt) on any
        mismatch."""
        if not blob.startswith(MAGIC) or len(blob) < len(MAGIC) + 4:
            self._reject(path, "corrupt", "bad magic / truncated preamble")
            return None
        off = len(MAGIC)
        (hlen,) = struct.unpack(">I", blob[off:off + 4])
        off += 4
        try:
            header = json.loads(blob[off:off + hlen].decode())
        except (UnicodeDecodeError, json.JSONDecodeError):
            self._reject(path, "corrupt", "unparseable header")
            return None
        off += hlen
        plen = header.get("portable_len", -1)
        alen = header.get("annex_len", -1)
        if plen < 0 or alen < 0 or len(blob) != off + plen + alen:
            self._reject(path, "corrupt",
                         f"payload length mismatch ({len(blob) - off} bytes "
                         f"on disk, header claims {plen}+{alen})")
            return None
        portable = blob[off:off + plen]
        annex = blob[off + plen:]
        if (_sha(portable) != header.get("portable_sha")
                or _sha(annex) != header.get("annex_sha")):
            self._reject(path, "corrupt", "payload checksum mismatch")
            return None
        return header, portable, annex

    def load(self, binding: AOTBinding, bucket: int,
             sig: str) -> Callable | None:
        """Load one bucket executable, or None (counted) when the entry is
        absent / corrupt / stale.  The returned callable takes the same
        ``(arrays, binds, qvalid, probe_budget)`` tuple the in-memory
        executable takes, so current catalog arrays re-bind on every call
        exactly as in-memory hits do."""
        name, expect = self._identity(binding.plan_key, bucket, sig)
        path = os.path.join(self.path, name + ".aot")
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except OSError:
            self._bump("misses")
            return None
        parsed = self._parse(blob, path)
        if parsed is None:
            return None
        header, portable, annex = parsed
        for field, want in expect.items():
            if header.get(field) != want:
                self._reject(path, "stale",
                             f"key field {field!r} mismatch "
                             f"({header.get(field)!r} != {want!r})")
                return None
        if header.get("catalog_token") != binding.token():
            self._reject(path, "stale",
                         "catalog structural drift since persist")
            return None
        fn = None
        if annex:
            try:
                fn = load_native(annex)
            except Exception:                          # noqa: BLE001
                fn = None                  # portable artifact still valid
        if fn is None:
            try:
                fn = load_portable(portable)
            except Exception as exc:                   # noqa: BLE001
                self._reject(path, "corrupt",
                             f"deserialization failed "
                             f"({type(exc).__name__}: {exc})")
                return None
        self._bump("hits")
        return fn
