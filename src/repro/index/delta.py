"""Delta-segment scans for the live corpus (DESIGN.md §12).

Inserts land in a fixed-capacity append-only delta segment — a (delta_cap,
d) array whose empty slots are zero rows masked off by a validity lane, the
exact pad-row contract the fused kernels already honor for divisibility
padding.  These helpers scan that segment with the existing flat batched
machinery (``kernels.ops.fused_scan_topk_batch`` / ``FlatIndex``) and emit
candidates in the (keys, global-ids) form that
``dist.collectives.merge_topk_level`` consumes: the delta segment is merged
into the main IVF/flat result as one extra, device-local "shard level" of
the hierarchical per-query merge.

Global ids: delta slot ``s`` surfaces as ``offset + s`` where ``offset`` is
the main segment's capacity, so merged ids unambiguously name a row in
either segment.  Order keys are ascending with ``+inf`` on empty lanes —
ties against main-segment candidates resolve main-first in the merge
(``jax.lax.top_k`` stability), keeping a zero-delta merge bit-identical to
the main result alone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.expr import order_key
from ..core.schema import Metric
from .flat import FlatIndex


def _delta_scan_topk(metric: Metric, delta_vec, qs, k: int, dmask, qvalid,
                     use_pallas: bool, interpret):
    """Dispatch a top-k scan over the delta segment (fused kernel or
    FlatIndex vmap — the same dispatch rule as the main flat path)."""
    if use_pallas:
        from ..kernels.ops import fused_scan_topk_batch
        return fused_scan_topk_batch(delta_vec, qs, k, dmask, metric,
                                     interpret=interpret, qvalid=qvalid)
    flat = FlatIndex(metric, delta_vec)
    if dmask is None or dmask.ndim == 1:
        ids, sims, valid = jax.vmap(lambda q: flat.topk(q, k, dmask))(qs)
    else:
        ids, sims, valid = jax.vmap(
            lambda q, m: flat.topk(q, k, m))(qs, dmask)
    if qvalid is not None:
        valid = valid & qvalid[:, None]
        ids = jnp.where(valid, ids, -1)
        sims = jnp.where(valid, sims, 0.0)
    return ids, sims, valid


def delta_topk_batch(metric: Metric, delta_vec, qs, k: int, dmask, qvalid,
                     offset: int, use_pallas: bool = False, interpret=None):
    """Top-k over the (delta_cap, d) delta segment for a (Q, d) query batch.

    ``dmask`` is the delta-row mask (validity ANDed with any predicate):
    None, shared (delta_cap,), or per-query (Q, delta_cap) — the same
    layout contract as the main-segment row mask.  Returns merge-ready
    ``(keys, gids)``: ascending order keys with ``+inf`` empty lanes and
    global ids ``offset + slot`` (-1 on empty lanes), each (Q, min(k,
    delta_cap))."""
    kd = min(int(k), delta_vec.shape[0])
    ids, sims, valid = _delta_scan_topk(metric, delta_vec, qs, kd, dmask,
                                        qvalid, use_pallas, interpret)
    keys = jnp.where(valid, order_key(metric, sims), jnp.inf)
    gids = jnp.where(valid, ids + offset, -1)
    return keys, gids


def delta_range_batch(metric: Metric, delta_vec, qs, radius, dmask, qvalid,
                      offset: int, capacity: int, use_pallas: bool = False,
                      interpret=None):
    """Range scan over the delta segment for a (Q, d) query batch.

    Mirrors the main flat range path: up to ``min(capacity, delta_cap)``
    best-first in-range hits per query, plus an exact per-query hit count
    (0 for ``qvalid``-invalid queries).  Returns ``(keys, gids, count)``
    with keys/gids merge-ready as in :func:`delta_topk_batch`."""
    m, dn = qs.shape[0], delta_vec.shape[0]
    cap = min(int(capacity), dn)
    radius = jnp.broadcast_to(jnp.asarray(radius, jnp.float32), (m,))
    if use_pallas:
        from ..kernels.ops import fused_range_topk_batch
        ids, sims, valid, count = fused_range_topk_batch(
            delta_vec, qs, radius, dmask, metric, cap,
            interpret=interpret, qvalid=qvalid)
    else:
        flat = FlatIndex(metric, delta_vec)
        if dmask is None or dmask.ndim == 1:
            hit, raw = jax.vmap(
                lambda q, r: flat.range_mask(q, r, dmask))(qs, radius)
        else:
            hit, raw = jax.vmap(flat.range_mask)(qs, radius, dmask)
        if qvalid is not None:
            hit = hit & qvalid[:, None]
        keys = jnp.where(hit, order_key(metric, raw), jnp.inf)
        neg, sel = jax.lax.top_k(-keys, cap)                       # row-wise
        valid = jnp.isfinite(-neg)
        ids = jnp.where(valid, sel.astype(jnp.int32), -1)
        sims = jnp.where(valid, jnp.take_along_axis(raw, sel, axis=1), 0.0)
        count = jnp.sum(hit, axis=1)
    keys = jnp.where(valid, order_key(metric, sims), jnp.inf)
    gids = jnp.where(valid, ids + offset, -1)
    return keys, gids, count
