"""IVF index: the TPU-native adaptation of CHASE's ANN layer.

HNSW (the paper's index) is a pointer-chasing graph walk — hostile to the MXU.
IVF preserves the property the paper's algorithms actually rely on —
*monotone outward expansion from the query's neighborhood* — while turning
every step into dense batched compute:

* probe order   = ascending centroid order-key (a `Q·Cᵀ` matmul + argsort),
* cluster scan  = padded gather + blocked distance matmul + predicate mask,
* Algorithm 1's per-tuple ``outRangeCounter`` becomes a per-*cluster* counter
  inside a ``jax.lax.while_loop`` (§DESIGN.md 2),
* Algorithm 2's hash record-table becomes dense per-category state arrays.

Beyond-paper addition: each cluster stores its radius (max member-centroid
distance), giving a *sound lower bound* on any unprobed member's order key.
``termination='bound'`` uses it for exact early termination (the paper's R2
shrinkage made provable); ``termination='counter'`` is the faithful heuristic.

All probes return raw similarity values alongside ids — the physical layer's
contract with the **map operator** (§5.1): similarity computed during the scan
is *never* recomputed downstream.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..core.expr import distance_values, order_key
from ..core.schema import Metric
from .kmeans import assign, kmeans

INF = jnp.float32(jnp.inf)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["centroids", "lists", "list_sizes", "radii", "centroid_sq"],
    meta_fields=["metric", "nlist", "cap"],
)
@dataclasses.dataclass
class IVFIndex:
    """Inverted-file index: k-means centroids with fixed-capacity member
    lists (-1 padded) plus per-list radii for the geometric probe-pruning
    bound.  A pytree — probe kernels trace over the arrays."""
    metric: Metric
    centroids: jnp.ndarray     # (nlist, d)
    lists: jnp.ndarray         # (nlist, cap) int32 row ids, -1 padded
    list_sizes: jnp.ndarray    # (nlist,) int32
    radii: jnp.ndarray         # (nlist,) max ||member - centroid||
    centroid_sq: jnp.ndarray   # (nlist,) ||c||^2 (L2 fast path)
    nlist: int
    cap: int


@dataclasses.dataclass(frozen=True)
class ProbeConfig:
    """Static probe parameters (the engine's physical-operator knobs)."""
    max_probes: int = 64            # hard cap on clusters visited
    min_probes: int = 4             # converge-first phase (Alg.1 lines 2-3)
    stop_after_no_improve: int = 4  # top-k adaptive-queue stop (VBASE analogue)
    out_range_stop: int = 2         # Alg.1 `IsAboveN` N, cluster-granular
    capacity: int = 4096            # range-probe result buffer
    termination: str = "counter"    # 'counter' (faithful) | 'bound' (exact)
    probe_batch: int = 1            # clusters gathered per while_loop round
    no_new_category_stop: int = 2   # Alg.2: clusters w/o new category
    num_categories: int = 0         # static category cardinality (Alg.2)
    k_per_category: int = 10        # Alg.2 K
    # per-query cluster budget for the BATCHED probes (0 = unlimited): the
    # user-facing straggler valve — a query that exhausts its budget freezes
    # with its best-so-far results instead of holding the lock-step batch
    # hostage.  A runtime ``probe_budget`` argument (scalar or (Q,)) overrides
    # this static default per call.
    probe_budget: int = 0


def build_ivf(key: jax.Array, vectors: jnp.ndarray, nlist: int,
              metric: Metric = Metric.INNER_PRODUCT, iters: int = 8,
              cap_multiple: int = 4, cap: int | None = None) -> IVFIndex:
    """Train centroids, bucket rows into padded inverted lists.

    ``cap`` pins the inverted-list capacity instead of deriving it from the
    actual max cluster size.  ``cap`` (with ``nlist``/``metric``) is STATIC
    index metadata — it shapes the compiled probe loops — so live-corpus
    compaction (DESIGN.md §12) rebuilds with a fixed ``cap`` to keep
    re-bound plans at zero retraces."""
    import numpy as np
    n, d = vectors.shape
    centroids = kmeans(key, vectors, nlist, iters=iters)
    a = np.asarray(assign(vectors, centroids))
    counts = np.bincount(a, minlength=nlist)
    derived = int(counts.max())
    derived = max(8, -(-derived // 8) * 8)  # round up for lane alignment
    if cap is None:
        cap = derived
    elif cap < derived:
        raise ValueError(f"fixed cap {cap} < max cluster size "
                         f"{int(counts.max())}")
    lists = np.full((nlist, cap), -1, dtype=np.int32)
    cursor = np.zeros(nlist, dtype=np.int64)
    order = np.argsort(a, kind="stable")
    for row in order:
        c = a[row]
        lists[c, cursor[c]] = row
        cursor[c] += 1
    # cluster radii: max ||x - centroid|| per cluster
    vec_np = np.asarray(vectors, dtype=np.float32)
    cent_np = np.asarray(centroids, dtype=np.float32)
    diffs = vec_np - cent_np[a]
    norms = np.linalg.norm(diffs, axis=1)
    radii = np.zeros(nlist, dtype=np.float32)
    np.maximum.at(radii, a, norms)
    return IVFIndex(
        metric=metric,
        centroids=jnp.asarray(centroids),
        lists=jnp.asarray(lists),
        list_sizes=jnp.asarray(counts.astype(np.int32)),
        radii=jnp.asarray(radii),
        centroid_sq=jnp.sum(jnp.asarray(centroids) ** 2, axis=1),
        nlist=nlist,
        cap=cap,
    )


# ---------------------------------------------------------------------------
# shared probe plumbing
# ---------------------------------------------------------------------------

def _max_probes(index: IVFIndex, cfg: ProbeConfig) -> int:
    """Cluster cap for the sequential probes: ``max_probes`` bounded by the
    index size, tightened by the ``probe_budget`` knob when set (the same
    per-query budget semantics as the batched probes' runtime argument)."""
    cap = min(cfg.max_probes, index.nlist)
    if cfg.probe_budget > 0:
        cap = min(cap, cfg.probe_budget)
    return cap


def _cluster_order(index: IVFIndex, q: jnp.ndarray):
    """Clusters sorted by ascending centroid order-key; returns (order, keys,
    bound_keys) where bound_keys[i] lower-bounds any member of order[i]."""
    raw = distance_values(index.metric, index.centroids, q)
    keys = order_key(index.metric, raw)
    if index.metric == Metric.L2:
        # members within radius r of c: sqdist >= max(0, ||q-c|| - r)^2
        dist = jnp.sqrt(jnp.maximum(keys, 0.0))
        bound = jnp.maximum(dist - index.radii, 0.0) ** 2
    elif index.metric == Metric.INNER_PRODUCT:
        # x·q <= c·q + r*||q||  =>  key = -x·q >= -(c·q) - r||q||
        qn = jnp.linalg.norm(q)
        bound = keys - index.radii * qn
    else:  # cosine: |cos(x,q) - cos-ish bound|; use conservative -1 shift
        bound = keys - index.radii
    order = jnp.argsort(keys)
    # suffix-min of bounds: bound_sufmin[p] lower-bounds every member of every
    # cluster from probe position p onward (bounds are NOT monotone in probe
    # order, so the exact-termination test needs the suffix minimum).
    bound_sufmin = jnp.flip(jax.lax.cummin(jnp.flip(bound[order])))
    return order, keys[order], bound_sufmin


def _scan_cluster(index: IVFIndex, corpus: jnp.ndarray, q: jnp.ndarray,
                  cluster: jnp.ndarray, row_mask: jnp.ndarray | None):
    """Gather one inverted list and compute masked order-keys.

    Returns (ids (cap,), keys (cap,), valid (cap,), n_distance_evals)."""
    ids = index.lists[cluster]                       # (cap,)
    pad = ids >= 0
    safe = jnp.maximum(ids, 0)
    vecs = corpus[safe]                              # (cap, d)
    raw = distance_values(index.metric, vecs, q)
    keys = order_key(index.metric, raw)
    valid = pad
    if row_mask is not None:
        valid = valid & row_mask[safe]
    return ids, jnp.where(pad, keys, INF), valid, jnp.sum(pad)


def _merge_topk(best_keys, best_ids, cand_keys, cand_ids, cand_valid, k):
    keys = jnp.concatenate([best_keys, jnp.where(cand_valid, cand_keys, INF)])
    ids = jnp.concatenate([best_ids, cand_ids])
    neg, idx = jax.lax.top_k(-keys, k)
    return -neg, ids[idx]


# ---------------------------------------------------------------------------
# Top-k probe (VKNN-SF physical operator, §5.1)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "cfg"))
def ivf_topk(index: IVFIndex, corpus: jnp.ndarray, q: jnp.ndarray, k: int,
             row_mask: jnp.ndarray | None = None,
             cfg: ProbeConfig = ProbeConfig()):
    """Filtered top-k with the adaptive probe queue.

    VBASE's relaxed-monotonicity insight, IVF-shaped: instead of fetching a
    conservative K' ≫ K (PASE), keep extending the probe frontier until K
    *filtered* results are held AND the frontier stops improving the heap
    ('counter'), or provably cannot ('bound').  Returns
    (ids(k,), sims(k,), valid(k,), stats)."""
    order, _, bounds = _cluster_order(index, q)
    max_probes = _max_probes(index, cfg)

    def cond(state):
        p, bk, bi, no_imp, evals = state
        have_k = jnp.isfinite(bk[k - 1])
        kth = bk[k - 1]
        if cfg.termination == "bound":
            next_bound = bounds[jnp.minimum(p, index.nlist - 1)]
            done = have_k & (next_bound > kth)
        else:
            done = have_k & (no_imp >= cfg.stop_after_no_improve)
        done = done & (p >= cfg.min_probes)
        return (p < max_probes) & ~done

    def body(state):
        p, bk, bi, no_imp, evals = state
        ids, keys, valid, n = _scan_cluster(index, corpus, q, order[p], row_mask)
        old_kth = bk[k - 1]
        bk2, bi2 = _merge_topk(bk, bi, keys, ids, valid, k)
        improved = (bk2[k - 1] < old_kth) | (~jnp.isfinite(old_kth)
                                             & jnp.isfinite(bk2[k - 1]))
        no_imp2 = jnp.where(improved, 0, no_imp + 1)
        return (p + 1, bk2, bi2, no_imp2, evals + n)

    init = (jnp.int32(0), jnp.full((k,), INF), jnp.full((k,), -1, jnp.int32),
            jnp.int32(0), jnp.int32(0))
    p, bk, bi, _, evals = jax.lax.while_loop(cond, body, init)
    valid = jnp.isfinite(bk)
    sims = jnp.where(valid, -bk if index.metric.is_similarity() else bk, 0.0)
    stats = {"probes": p, "distance_evals": evals}
    return jnp.where(valid, bi, -1), sims, valid, stats


# ---------------------------------------------------------------------------
# Range probe — Algorithm 1, cluster-granular
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def ivf_range(index: IVFIndex, corpus: jnp.ndarray, q: jnp.ndarray,
              radius, row_mask: jnp.ndarray | None = None,
              cfg: ProbeConfig = ProbeConfig()):
    """DR-SF physical operator (paper Algorithm 1).

    Probes clusters by ascending centroid key; a probe round with in-range hits
    sets ``hasInRange``; after entering the range, ``out_range_stop``
    consecutive empty rounds end the scan ('counter'), or the radius-vs-bound
    test ends it exactly ('bound').  Returns (ids(capacity,), sims, valid,
    count, stats)."""
    order, _, bounds = _cluster_order(index, q)
    max_probes = _max_probes(index, cfg)
    radius_key = order_key(index.metric, jnp.asarray(radius, jnp.float32))
    capacity = cfg.capacity

    def cond(state):
        p, *_rest, has_in, out_cnt, evals = state
        if cfg.termination == "bound":
            next_bound = bounds[jnp.minimum(p, index.nlist - 1)]
            done = next_bound > radius_key
        else:
            done = has_in & (out_cnt >= cfg.out_range_stop)
        done = done & (p >= cfg.min_probes)
        return (p < max_probes) & ~done

    def body(state):
        p, out_ids, out_keys, count, has_in, out_cnt, evals = state
        ids, keys, valid, n = _scan_cluster(index, corpus, q, order[p], None)
        in_range_hit = valid & (keys <= radius_key)     # pre-filter (Alg.1's
        # hasInRange tracks the RANGE only; the structured filter must not
        # starve the termination signal at low selectivity)
        hit = in_range_hit
        if row_mask is not None:
            hit = hit & row_mask[jnp.maximum(ids, 0)]
        n_range = jnp.sum(in_range_hit)
        n_hits = jnp.sum(hit)
        # compact-append filtered hits into the fixed buffer
        pos = count + jnp.cumsum(hit) - 1
        ok = hit & (pos < capacity)
        safe_pos = jnp.where(ok, pos, capacity)        # capacity row = scratch
        out_ids = out_ids.at[safe_pos].set(jnp.where(ok, ids, -1), mode="drop")
        out_keys = out_keys.at[safe_pos].set(jnp.where(ok, keys, INF),
                                             mode="drop")
        count2 = jnp.minimum(count + n_hits, capacity)
        has_in2 = has_in | (n_range > 0)
        out_cnt2 = jnp.where(n_range > 0, 0, jnp.where(has_in, out_cnt + 1, 0))
        return (p + 1, out_ids, out_keys, count2, has_in2, out_cnt2, evals + n)

    init = (jnp.int32(0),
            jnp.full((capacity,), -1, jnp.int32),
            jnp.full((capacity,), INF),
            jnp.int32(0), jnp.bool_(False), jnp.int32(0), jnp.int32(0))
    p, out_ids, out_keys, count, _, _, evals = jax.lax.while_loop(cond, body, init)
    valid = out_ids >= 0
    sims = jnp.where(valid,
                     -out_keys if index.metric.is_similarity() else out_keys,
                     0.0)
    stats = {"probes": p, "distance_evals": evals}
    return out_ids, sims, valid, count, stats


# ---------------------------------------------------------------------------
# Category probe — Algorithm 2 (updateState) fused into the range scan
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def ivf_range_category(index: IVFIndex, corpus: jnp.ndarray,
                       categories: jnp.ndarray, q: jnp.ndarray, radius,
                       row_mask: jnp.ndarray | None = None,
                       cfg: ProbeConfig = ProbeConfig(num_categories=8)):
    """Category-driven probe: range scan + the updateState record table.

    The paper's hash table T becomes dense arrays over the static category
    universe: per-category hit counts (``filteredK_c``), a per-category best-K
    key heap (the 'search queue'), and a seen mask.  A category *converges*
    when it holds K hits whose kth key beats the probe frontier (the
    monotonicity check of Alg. 2 line 6, made sound by cluster radii under
    'bound' termination).  The scan stops early when every seen category has
    converged and ``no_new_category_stop`` rounds brought no new category —
    i.e. the dynamic R2 < R1 range shrinkage of §4.3.

    Returns (ids, sims, valid, count, stats)."""
    C = cfg.num_categories
    K = cfg.k_per_category
    assert C > 0, "category probe needs static num_categories"
    order, _, bounds = _cluster_order(index, q)
    max_probes = _max_probes(index, cfg)
    radius_key = order_key(index.metric, jnp.asarray(radius, jnp.float32))
    capacity = cfg.capacity

    def cond(state):
        (p, _oi, _ok, _cnt, has_in, out_cnt, seen, counts, kth, no_new,
         evals) = state
        frontier = bounds[jnp.minimum(p, index.nlist - 1)] \
            if cfg.termination == "bound" else radius_key
        # Alg.2: converged_c = filteredK_c >= K and queue monotonic past kth
        converged = (counts >= K) & (kth[:, K - 1] <= frontier)
        rest = jnp.sum(seen & ~converged)            # T.restElements
        cat_done = (rest == 0) & (no_new >= cfg.no_new_category_stop) \
            & jnp.any(seen)
        if cfg.termination == "bound":
            range_done = bounds[jnp.minimum(p, index.nlist - 1)] > radius_key
        else:
            range_done = has_in & (out_cnt >= cfg.out_range_stop)
        done = (cat_done | range_done) & (p >= cfg.min_probes)
        return (p < max_probes) & ~done

    def body(state):
        (p, out_ids, out_keys, count, has_in, out_cnt, seen, counts, kth,
         no_new, evals) = state
        ids, keys, valid, n = _scan_cluster(index, corpus, q, order[p], None)
        in_range_hit = valid & (keys <= radius_key)   # range only (Alg.1)
        hit = in_range_hit
        if row_mask is not None:
            hit = hit & row_mask[jnp.maximum(ids, 0)]
        n_range = jnp.sum(in_range_hit)
        n_hits = jnp.sum(hit)
        safe = jnp.maximum(ids, 0)
        cats = jnp.where(hit, categories[safe], -1)  # (cap,)

        onehot = (cats[:, None] == jnp.arange(C)[None, :])   # (cap, C)
        cat_hits = jnp.sum(onehot, axis=0)                   # (C,)
        new_seen = seen | (cat_hits > 0)
        n_new_cats = jnp.sum(new_seen) - jnp.sum(seen)
        counts2 = counts + cat_hits
        # per-category best-K merge ('search queue' update, Alg.2 line 5)
        cand = jnp.where(onehot, keys[:, None], INF)         # (cap, C)
        merged = jnp.concatenate([kth, cand.T], axis=1)      # (C, K+cap)
        kth2 = -jax.lax.top_k(-merged, K)[0]                 # smallest K keys

        pos = count + jnp.cumsum(hit) - 1
        ok = hit & (pos < capacity)
        safe_pos = jnp.where(ok, pos, capacity)
        out_ids = out_ids.at[safe_pos].set(jnp.where(ok, ids, -1), mode="drop")
        out_keys = out_keys.at[safe_pos].set(jnp.where(ok, keys, INF),
                                             mode="drop")
        count2 = jnp.minimum(count + n_hits, capacity)
        has_in2 = has_in | (n_range > 0)
        out_cnt2 = jnp.where(n_range > 0, 0,
                             jnp.where(has_in, out_cnt + 1, 0))
        no_new2 = jnp.where(n_new_cats > 0, 0, no_new + 1)
        return (p + 1, out_ids, out_keys, count2, has_in2, out_cnt2,
                new_seen, counts2, kth2, no_new2, evals + n)

    init = (jnp.int32(0),
            jnp.full((capacity,), -1, jnp.int32),
            jnp.full((capacity,), INF),
            jnp.int32(0), jnp.bool_(False), jnp.int32(0),
            jnp.zeros((C,), jnp.bool_), jnp.zeros((C,), jnp.int32),
            jnp.full((C, K), INF), jnp.int32(0), jnp.int32(0))
    (p, out_ids, out_keys, count, _hi, _oc, seen, counts, _kth, _nn,
     evals) = jax.lax.while_loop(cond, body, init)
    valid = out_ids >= 0
    sims = jnp.where(valid,
                     -out_keys if index.metric.is_similarity() else out_keys,
                     0.0)
    stats = {"probes": p, "distance_evals": evals,
             "categories_seen": jnp.sum(seen)}
    return out_ids, sims, valid, count, stats


# ---------------------------------------------------------------------------
# Batched probes — Q queries, ``probe_batch`` clusters per while_loop round
# ---------------------------------------------------------------------------
#
# The per-query loop above gathers ONE inverted list per round: a (cap, d)
# gather followed by a matvec — MXU-hostile.  The batched path amortizes both
# axes at once: Q queries advance in lock-step (merged per-query termination
# state decides who still probes) and each round gathers ``probe_batch``
# clusters into one (B·cap, d) block per query, so every round is one dense
# batched matmul.  Per-query early termination is preserved at ROUND
# granularity: a finished query's state freezes (``active`` mask) while
# stragglers keep probing — with probe_batch=1 the probe sequence, merges, and
# counters are bit-identical to the sequential functions.
#
# Lock-step straggler tradeoff (DESIGN.md §6/§7): when the batch mixes
# heterogeneous queries — e.g. join left rows whose structured masks have very
# different selectivity — the while_loop runs until the SLOWEST query
# terminates.  The guarantees that keep this sound rather than wasteful:
#   * frozen queries do no work that is observable: their buffers, counters,
#     and stats stop advancing the round they terminate, so per-query
#     ``probes`` / ``distance_evals`` counters report each query's OWN
#     termination point, not the batch's wall-clock round count;
#   * counters advance in CLUSTER units (a round adds ``n_probed``), so the
#     ``stop_after_no_improve`` / ``out_range_stop`` / ``no_new_category_stop``
#     knobs stay calibrated for any probe_batch: a query's batched probe count
#     exceeds its sequential count by at most one round's rounding,
#     ``ceil(sequential / B) * B``;
#   * an optional per-query ``probe_budget`` (cluster units) caps heavy
#     queries individually, so one adversarial left row cannot hold the whole
#     batch hostage — light rows still freeze at their own termination and a
#     budgeted row freezes at its cap (tests/test_join_batched.py).
# The wall-clock cost of stragglers is real (every round gathers B·cap rows
# for the LIVE queries); the ROADMAP's dynamic batch scheduler (size/effort
# bucketing) is the planned systemic fix.


def _apply_budget(active, probes, probe_budget, qn: int):
    """Freeze queries that exhausted their per-query cluster budget."""
    if probe_budget is None:
        return active
    budget = jnp.broadcast_to(jnp.asarray(probe_budget, jnp.int32), (qn,))
    return active & (probes < budget)


def _resolve_budget(probe_budget, cfg: ProbeConfig):
    """Runtime budget argument wins; else the cfg.probe_budget knob
    (0 = unlimited -> None)."""
    if probe_budget is not None:
        return probe_budget
    return cfg.probe_budget if cfg.probe_budget > 0 else None


def _active_init(qvalid, qn: int):
    """Initial per-query active mask: size-bucket pad queries (qvalid False)
    never probe — their buffers, counters, and stats stay at zero."""
    if qvalid is None:
        return jnp.ones((qn,), jnp.bool_)
    return jnp.asarray(qvalid, jnp.bool_).reshape(qn)

def _round_schedule(index: IVFIndex, cfg: ProbeConfig):
    """(B, n_rounds, max_probes) for the round-granular probe loop."""
    max_probes = min(cfg.max_probes, index.nlist)
    B = max(1, min(cfg.probe_batch, max_probes))
    n_rounds = -(-max_probes // B)
    return B, n_rounds, max_probes


def _order_pad_batch(index: IVFIndex, qs: jnp.ndarray, B: int, n_rounds: int,
                     max_probes: int):
    """Per-query probe order padded to n_rounds*B with -1 sentinels."""
    order, _, bounds = jax.vmap(lambda q: _cluster_order(index, q))(qs)
    order = order[:, :max_probes]
    pad = n_rounds * B - max_probes
    if pad:
        order = jnp.pad(order, ((0, 0), (0, pad)), constant_values=-1)
    return order, bounds


def _scan_clusters_batch(index: IVFIndex, corpus: jnp.ndarray,
                         qs: jnp.ndarray, clusters: jnp.ndarray,
                         row_mask: jnp.ndarray | None):
    """Gather B inverted lists per query, one batched matmul for the keys.

    clusters: (Q, B) with -1 sentinels.  Returns (ids (Q, B·cap),
    keys (Q, B·cap), valid, rm_hit (row-mask lookup), n_evals (Q,))."""
    qn, bsz = clusters.shape
    safe_cl = jnp.maximum(clusters, 0)
    ids = index.lists[safe_cl]                          # (Q, B, cap)
    ids = jnp.where(clusters[..., None] >= 0, ids, -1)
    ids = ids.reshape(qn, bsz * index.cap)
    pad = ids >= 0
    safe = jnp.maximum(ids, 0)
    vecs = corpus[safe]                                 # (Q, B·cap, d)
    raw = distance_values(index.metric, vecs, qs[:, None, :])
    keys = order_key(index.metric, raw)
    if row_mask is None:
        rm_hit = pad
    elif row_mask.ndim == 1:
        rm_hit = row_mask[safe]
    else:
        rm_hit = jnp.take_along_axis(row_mask, safe, axis=1)
    return ids, jnp.where(pad, keys, INF), pad, rm_hit, jnp.sum(pad, axis=1)


@functools.partial(jax.jit, static_argnames=("k", "cfg"))
def ivf_topk_batch(index: IVFIndex, corpus: jnp.ndarray, qs: jnp.ndarray,
                   k: int, row_mask: jnp.ndarray | None = None,
                   cfg: ProbeConfig = ProbeConfig(),
                   probe_budget: jnp.ndarray | None = None,
                   qvalid: jnp.ndarray | None = None):
    """Batched filtered top-k: (Q, d) queries, multi-cluster probe rounds.

    ``row_mask`` is None, a shared (N,) mask, or per-query (Q, N).  Returns
    (ids (Q, k), sims (Q, k), valid (Q, k), stats with per-query (Q,) arrays).
    With ``cfg.probe_batch == 1`` results match :func:`ivf_topk` exactly
    (same probe prefix, same merges); with B > 1 each query probes a prefix
    that is a superset of its sequential prefix, so its kth key can only
    improve.  ``probe_budget`` optionally caps each query's probed clusters
    individually (scalar or (Q,) int; defaults to cfg.probe_budget when > 0),
    the straggler valve for heterogeneous batches — a budgeted query freezes
    with its best-so-far results.  ``qvalid`` (None | (Q,) bool) marks
    size-bucket pad queries: they start with ``active=False``, so they never
    probe and their counters stay zero."""
    qn = qs.shape[0]
    probe_budget = _resolve_budget(probe_budget, cfg)
    B, n_rounds, max_probes = _round_schedule(index, cfg)
    order, bounds = _order_pad_batch(index, qs, B, n_rounds, max_probes)

    def cond(state):
        r, *_rest, active = state
        return (r < n_rounds) & jnp.any(active)

    def body(state):
        r, bk, bi, no_imp, probes, evals, active = state
        cl = jax.lax.dynamic_slice_in_dim(order, r * B, B, axis=1)
        ids, keys, valid, rm_hit, nev = _scan_clusters_batch(
            index, corpus, qs, cl, row_mask)
        valid = valid & rm_hit
        old_kth = bk[:, k - 1]
        merged_k, merged_i = jax.vmap(
            lambda a, b, c, d, e: _merge_topk(a, b, c, d, e, k))(
                bk, bi, keys, ids, valid)
        bk2 = jnp.where(active[:, None], merged_k, bk)
        bi2 = jnp.where(active[:, None], merged_i, bi)
        improved = (bk2[:, k - 1] < old_kth) | (~jnp.isfinite(old_kth)
                                                & jnp.isfinite(bk2[:, k - 1]))
        n_probed = jnp.minimum(B, max_probes - r * B)
        # the no-improvement counter advances per CLUSTER, not per round: a
        # non-improving round means all n_probed clusters failed to improve
        # (kth only tightens), keeping stop_after_no_improve calibrated in
        # cluster units for any probe_batch
        no_imp2 = jnp.where(active,
                            jnp.where(improved, 0, no_imp + n_probed),
                            no_imp)
        probes2 = probes + jnp.where(active, n_probed, 0)
        evals2 = evals + jnp.where(active, nev, 0)
        p_next = (r + 1) * B
        have_k = jnp.isfinite(bk2[:, k - 1])
        if cfg.termination == "bound":
            nb = bounds[:, jnp.minimum(p_next, index.nlist - 1)]
            done = have_k & (nb > bk2[:, k - 1])
        else:
            done = have_k & (no_imp2 >= cfg.stop_after_no_improve)
        done = done & (p_next >= cfg.min_probes)
        active2 = active & ~done & (p_next < max_probes)
        active2 = _apply_budget(active2, probes2, probe_budget, qn)
        return (r + 1, bk2, bi2, no_imp2, probes2, evals2, active2)

    init = (jnp.int32(0),
            jnp.full((qn, k), INF), jnp.full((qn, k), -1, jnp.int32),
            jnp.zeros((qn,), jnp.int32), jnp.zeros((qn,), jnp.int32),
            jnp.zeros((qn,), jnp.int32), _active_init(qvalid, qn))
    _, bk, bi, _, probes, evals, _ = jax.lax.while_loop(cond, body, init)
    valid = jnp.isfinite(bk)
    sims = jnp.where(valid, -bk if index.metric.is_similarity() else bk, 0.0)
    stats = {"probes": probes, "distance_evals": evals}
    return jnp.where(valid, bi, -1), sims, valid, stats


@functools.partial(jax.jit, static_argnames=("cfg",))
def ivf_range_batch(index: IVFIndex, corpus: jnp.ndarray, qs: jnp.ndarray,
                    radius, row_mask: jnp.ndarray | None = None,
                    cfg: ProbeConfig = ProbeConfig(),
                    probe_budget: jnp.ndarray | None = None,
                    qvalid: jnp.ndarray | None = None):
    """Batched DR-SF probe (Algorithm 1 over a query batch).

    ``radius`` is a scalar or per-query (Q,) raw metric values.  Returns
    (ids (Q, capacity), sims, valid, count (Q,), stats with (Q,) arrays).
    probe_batch=1 matches :func:`ivf_range` per query exactly.
    ``probe_budget`` (scalar or (Q,) clusters; defaults to cfg.probe_budget
    when > 0) individually caps stragglers; ``qvalid`` marks size-bucket pad
    queries (inert: empty buffers, zero counters); results are ordered by
    probe discovery, not by key."""
    qn = qs.shape[0]
    probe_budget = _resolve_budget(probe_budget, cfg)
    B, n_rounds, max_probes = _round_schedule(index, cfg)
    order, bounds = _order_pad_batch(index, qs, B, n_rounds, max_probes)
    radius_key = order_key(index.metric, jnp.broadcast_to(
        jnp.asarray(radius, jnp.float32), (qn,)))
    capacity = cfg.capacity

    def cond(state):
        r, *_rest, active = state
        return (r < n_rounds) & jnp.any(active)

    def body(state):
        (r, out_ids, out_keys, count, has_in, out_cnt, probes, evals,
         active) = state
        cl = jax.lax.dynamic_slice_in_dim(order, r * B, B, axis=1)
        ids, keys, valid, rm_hit, nev = _scan_clusters_batch(
            index, corpus, qs, cl, row_mask)
        in_range_hit = valid & (keys <= radius_key[:, None])
        hit = in_range_hit & rm_hit & active[:, None]
        n_range = jnp.sum(in_range_hit, axis=1)
        n_hits = jnp.sum(hit, axis=1)
        pos = count[:, None] + jnp.cumsum(hit, axis=1) - 1
        ok = hit & (pos < capacity)
        safe_pos = jnp.where(ok, pos, capacity)

        def append(oi, ok_, okr, sp, idsr, keysr):
            oi = oi.at[sp].set(jnp.where(ok_, idsr, -1), mode="drop")
            okr = okr.at[sp].set(jnp.where(ok_, keysr, INF), mode="drop")
            return oi, okr

        out_ids2, out_keys2 = jax.vmap(append)(out_ids, ok, out_keys,
                                               safe_pos, ids, keys)
        count2 = jnp.where(active, jnp.minimum(count + n_hits, capacity),
                           count)
        has_in2 = jnp.where(active, has_in | (n_range > 0), has_in)
        n_probed = jnp.minimum(B, max_probes - r * B)
        # out-of-range counter in CLUSTER units (see ivf_topk_batch): an
        # empty round is n_probed consecutive empty cluster probes
        out_cnt2 = jnp.where(
            active,
            jnp.where(n_range > 0, 0,
                      jnp.where(has_in, out_cnt + n_probed, 0)),
            out_cnt)
        probes2 = probes + jnp.where(active, n_probed, 0)
        evals2 = evals + jnp.where(active, nev, 0)
        p_next = (r + 1) * B
        if cfg.termination == "bound":
            done = bounds[:, jnp.minimum(p_next, index.nlist - 1)] > radius_key
        else:
            done = has_in2 & (out_cnt2 >= cfg.out_range_stop)
        done = done & (p_next >= cfg.min_probes)
        active2 = active & ~done & (p_next < max_probes)
        active2 = _apply_budget(active2, probes2, probe_budget, qn)
        return (r + 1, out_ids2, out_keys2, count2, has_in2, out_cnt2,
                probes2, evals2, active2)

    init = (jnp.int32(0),
            jnp.full((qn, capacity), -1, jnp.int32),
            jnp.full((qn, capacity), INF),
            jnp.zeros((qn,), jnp.int32), jnp.zeros((qn,), jnp.bool_),
            jnp.zeros((qn,), jnp.int32), jnp.zeros((qn,), jnp.int32),
            jnp.zeros((qn,), jnp.int32), _active_init(qvalid, qn))
    (_, out_ids, out_keys, count, _hi, _oc, probes, evals,
     _a) = jax.lax.while_loop(cond, body, init)
    valid = out_ids >= 0
    sims = jnp.where(valid,
                     -out_keys if index.metric.is_similarity() else out_keys,
                     0.0)
    stats = {"probes": probes, "distance_evals": evals}
    return out_ids, sims, valid, count, stats


@functools.partial(jax.jit, static_argnames=("cfg",))
def ivf_range_category_batch(index: IVFIndex, corpus: jnp.ndarray,
                             categories: jnp.ndarray, qs: jnp.ndarray,
                             radius, row_mask: jnp.ndarray | None = None,
                             cfg: ProbeConfig = ProbeConfig(num_categories=8),
                             probe_budget: jnp.ndarray | None = None,
                             qvalid: jnp.ndarray | None = None):
    """Batched category probe (Algorithm 2 over a query batch).

    The updateState record table gains a leading Q axis: per-query seen mask
    (Q, C), per-category hit counts (Q, C), and the per-category best-K key
    queues (Q, C, K).  Category convergence / dynamic range shrinkage decide
    termination per query; as everywhere on the batched path the ``active``
    mask freezes finished queries at ROUND granularity and counters advance
    in CLUSTER units.  probe_batch=1 matches :func:`ivf_range_category` per
    query exactly.  ``probe_budget`` defaults to cfg.probe_budget when > 0;
    ``qvalid`` marks size-bucket pad queries (inert).  Returns
    (ids (Q, capacity), sims, valid, count (Q,), stats with per-query (Q,)
    arrays)."""
    C = cfg.num_categories
    K = cfg.k_per_category
    assert C > 0, "category probe needs static num_categories"
    qn = qs.shape[0]
    probe_budget = _resolve_budget(probe_budget, cfg)
    B, n_rounds, max_probes = _round_schedule(index, cfg)
    order, bounds = _order_pad_batch(index, qs, B, n_rounds, max_probes)
    radius_key = order_key(index.metric, jnp.broadcast_to(
        jnp.asarray(radius, jnp.float32), (qn,)))
    capacity = cfg.capacity

    def cond(state):
        r, *_rest, active = state
        return (r < n_rounds) & jnp.any(active)

    def body(state):
        (r, out_ids, out_keys, count, has_in, out_cnt, seen, counts, kth,
         no_new, probes, evals, active) = state
        cl = jax.lax.dynamic_slice_in_dim(order, r * B, B, axis=1)
        ids, keys, valid, rm_hit, nev = _scan_clusters_batch(
            index, corpus, qs, cl, row_mask)
        in_range_hit = valid & (keys <= radius_key[:, None])  # range only
        hit = in_range_hit & rm_hit & active[:, None]
        n_range = jnp.sum(in_range_hit, axis=1)
        n_hits = jnp.sum(hit, axis=1)
        safe = jnp.maximum(ids, 0)
        cats = jnp.where(hit, categories[safe], -1)           # (Q, B·cap)

        # record-table update — hits of frozen queries are already masked out,
        # so the category state freezes automatically with ``active``
        onehot = cats[..., None] == jnp.arange(C)[None, None, :]  # (Q,Bc,C)
        cat_hits = jnp.sum(onehot, axis=1)                    # (Q, C)
        seen2 = seen | (cat_hits > 0)
        n_new = jnp.sum(seen2, axis=1) - jnp.sum(seen, axis=1)
        counts2 = counts + cat_hits
        cand = jnp.where(onehot, keys[..., None], INF)        # (Q, B·cap, C)
        merged = jnp.concatenate([kth, jnp.swapaxes(cand, 1, 2)], axis=2)
        kth2 = -jax.lax.top_k(-merged, K)[0]                  # (Q, C, K)

        pos = count[:, None] + jnp.cumsum(hit, axis=1) - 1
        ok = hit & (pos < capacity)
        safe_pos = jnp.where(ok, pos, capacity)

        def append(oi, okeys, ok_, sp, idsr, keysr):
            oi = oi.at[sp].set(jnp.where(ok_, idsr, -1), mode="drop")
            okeys = okeys.at[sp].set(jnp.where(ok_, keysr, INF), mode="drop")
            return oi, okeys

        out_ids2, out_keys2 = jax.vmap(append)(out_ids, out_keys, ok,
                                               safe_pos, ids, keys)
        count2 = jnp.where(active, jnp.minimum(count + n_hits, capacity),
                           count)
        has_in2 = jnp.where(active, has_in | (n_range > 0), has_in)
        n_probed = jnp.minimum(B, max_probes - r * B)
        out_cnt2 = jnp.where(
            active,
            jnp.where(n_range > 0, 0,
                      jnp.where(has_in, out_cnt + n_probed, 0)),
            out_cnt)
        no_new2 = jnp.where(active,
                            jnp.where(n_new > 0, 0, no_new + n_probed),
                            no_new)
        probes2 = probes + jnp.where(active, n_probed, 0)
        evals2 = evals + jnp.where(active, nev, 0)
        p_next = (r + 1) * B
        next_bound = bounds[:, jnp.minimum(p_next, index.nlist - 1)]
        frontier = next_bound if cfg.termination == "bound" else radius_key
        converged = (counts2 >= K) & (kth2[:, :, K - 1] <= frontier[:, None])
        rest = jnp.sum(seen2 & ~converged, axis=1)            # T.restElements
        cat_done = ((rest == 0) & (no_new2 >= cfg.no_new_category_stop)
                    & jnp.any(seen2, axis=1))
        if cfg.termination == "bound":
            range_done = next_bound > radius_key
        else:
            range_done = has_in2 & (out_cnt2 >= cfg.out_range_stop)
        done = (cat_done | range_done) & (p_next >= cfg.min_probes)
        active2 = active & ~done & (p_next < max_probes)
        active2 = _apply_budget(active2, probes2, probe_budget, qn)
        return (r + 1, out_ids2, out_keys2, count2, has_in2, out_cnt2,
                seen2, counts2, kth2, no_new2, probes2, evals2, active2)

    init = (jnp.int32(0),
            jnp.full((qn, capacity), -1, jnp.int32),
            jnp.full((qn, capacity), INF),
            jnp.zeros((qn,), jnp.int32), jnp.zeros((qn,), jnp.bool_),
            jnp.zeros((qn,), jnp.int32),
            jnp.zeros((qn, C), jnp.bool_), jnp.zeros((qn, C), jnp.int32),
            jnp.full((qn, C, K), INF), jnp.zeros((qn,), jnp.int32),
            jnp.zeros((qn,), jnp.int32), jnp.zeros((qn,), jnp.int32),
            _active_init(qvalid, qn))
    (_, out_ids, out_keys, count, _hi, _oc, seen, _cn, _kth, _nn, probes,
     evals, _a) = jax.lax.while_loop(cond, body, init)
    valid = out_ids >= 0
    sims = jnp.where(valid,
                     -out_keys if index.metric.is_similarity() else out_keys,
                     0.0)
    stats = {"probes": probes, "distance_evals": evals,
             "categories_seen": jnp.sum(seen, axis=1)}
    return out_ids, sims, valid, count, stats
