"""Blocked Lloyd k-means in JAX (IVF coarse quantizer training).

TPU-shaped: the assignment step is a dense (chunk × nlist) matmul, chunked so
the distance matrix never exceeds a VMEM/HBM-friendly working set.  Training
subsamples the corpus (standard IVF practice — FAISS trains on ~256 points per
centroid) and the final full assignment is a single blocked pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _pairwise_sqdist(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(n,d),(k,d) -> (n,k) squared L2, matmul-dominant form (MXU-friendly)."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)
    return x2 - 2.0 * (x @ c.T) + c2[None, :]


@functools.partial(jax.jit, static_argnames=("chunk",))
def assign(x: jnp.ndarray, centroids: jnp.ndarray, chunk: int = 16384) -> jnp.ndarray:
    """Nearest-centroid assignment, blocked over rows."""
    n = x.shape[0]
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    blocks = xp.reshape(-1, chunk, x.shape[1])

    def body(carry, xb):
        d = _pairwise_sqdist(xb, centroids)
        return carry, jnp.argmin(d, axis=1).astype(jnp.int32)

    _, out = jax.lax.scan(body, None, blocks)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("nlist", "iters", "chunk"))
def _lloyd(x: jnp.ndarray, init: jnp.ndarray, nlist: int, iters: int,
           chunk: int) -> jnp.ndarray:
    def step(centroids, _):
        a = assign(x, centroids, chunk=chunk)
        sums = jax.ops.segment_sum(x, a, num_segments=nlist)
        counts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype), a,
                                     num_segments=nlist)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # keep dead centroids where they were (FAISS re-seeds; this is enough
        # for synthetic corpora and keeps the step shape-stable)
        new = jnp.where((counts > 0)[:, None], new, centroids)
        return new, counts
    centroids, _ = jax.lax.scan(step, init, None, length=iters)
    return centroids


def kmeans(key: jax.Array, x: jnp.ndarray, nlist: int, iters: int = 8,
           train_points_per_centroid: int = 256, chunk: int = 16384) -> jnp.ndarray:
    """Train ``nlist`` centroids on (a subsample of) ``x``. Returns (nlist, d)."""
    n = x.shape[0]
    max_train = min(n, nlist * train_points_per_centroid)
    if max_train < n:
        idx = jax.random.choice(key, n, shape=(max_train,), replace=False)
        xt = x[idx]
    else:
        xt = x
    init_idx = jax.random.choice(jax.random.fold_in(key, 1), xt.shape[0],
                                 shape=(nlist,), replace=xt.shape[0] < nlist)
    init = xt[init_idx]
    return _lloyd(xt, init, nlist, iters, min(chunk, xt.shape[0]))
