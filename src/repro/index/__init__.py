from .flat import FlatIndex
from .ivf import IVFIndex, build_ivf
from .kmeans import kmeans

__all__ = ["FlatIndex", "IVFIndex", "build_ivf", "kmeans"]
