"""ANN index structures: exact flat scans, the IVF index with
CHASE-style probes, and the live delta segment (DESIGN.md §4, §12)."""
from .flat import FlatIndex
from .ivf import IVFIndex, build_ivf
from .kmeans import kmeans

__all__ = ["FlatIndex", "IVFIndex", "build_ivf", "kmeans"]
