"""Brute-force (exact) index: ground truth for recall and the fallback scan.

Implements the same probe API as :class:`IVFIndex` so physical operators are
index-polymorphic.  This is also the "LingoDB-V" baseline's scan: compiled,
fused, but index-less.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from ..core.schema import Metric
from ..core.expr import distance_values, in_range, order_key

NEG_ID = jnp.int32(-1)


def masked_topk(keys: jnp.ndarray, ids: jnp.ndarray, mask: jnp.ndarray,
                k: int) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Smallest-k by key among masked rows. Returns (keys, ids, valid)."""
    big = jnp.asarray(jnp.inf, keys.dtype)
    keyed = jnp.where(mask, keys, big)
    neg, idx = jax.lax.top_k(-keyed, k)      # top_k takes largest
    sel_keys = -neg
    sel_ids = ids[idx]
    valid = jnp.isfinite(sel_keys)
    return sel_keys, jnp.where(valid, sel_ids, NEG_ID), valid


@dataclasses.dataclass
class FlatIndex:
    """Exact scan over an (N, d) corpus with a given metric."""
    metric: Metric
    vectors: jnp.ndarray

    @property
    def num_rows(self) -> int:
        """Corpus row count."""
        return int(self.vectors.shape[0])

    def topk(self, query: jnp.ndarray, k: int,
             row_mask: jnp.ndarray | None = None):
        """Exact filtered top-k.  Returns (ids, sims(raw metric), valid)."""
        raw = distance_values(self.metric, self.vectors, query)
        keys = order_key(self.metric, raw)
        n = self.vectors.shape[0]
        mask = jnp.ones((n,), jnp.bool_) if row_mask is None else row_mask
        ids = jnp.arange(n, dtype=jnp.int32)
        sel_keys, sel_ids, valid = masked_topk(keys, ids, mask, k)
        sims = jnp.where(valid,
                         -sel_keys if self.metric.is_similarity() else sel_keys,
                         0.0)
        return sel_ids, sims, valid

    def range_mask(self, query: jnp.ndarray, radius,
                   row_mask: jnp.ndarray | None = None):
        """Exact range query. Returns ((N,) hit mask, (N,) raw sims)."""
        raw = distance_values(self.metric, self.vectors, query)
        hit = in_range(self.metric, raw, radius)
        if row_mask is not None:
            hit = hit & row_mask
        return hit, raw

    # distance evaluation count (for the paper's "number of similarity
    # computations" reporting)
    def probe_cost(self) -> int:
        """Distance evaluations per query (always N for a flat scan)."""
        return self.num_rows
