from .analysis import collective_bytes_from_hlo, roofline_terms
from .hw import TPU_V5E

__all__ = ["collective_bytes_from_hlo", "roofline_terms", "TPU_V5E"]
