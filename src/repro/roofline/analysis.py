"""Roofline-term derivation from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips × peak)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

``cost_analysis()`` reports whole-program FLOPs/bytes (already per-partition
in SPMD: the numbers are for the per-device module; we multiply back to
totals).  Collective bytes are NOT in cost_analysis: we parse the compiled
per-device HLO text and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction."""
from __future__ import annotations

import dataclasses
import re
from typing import Any

from .hw import HWSpec, TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES)
    + r")(?:-start|-done)?\(([^)]*)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind operand bytes from a (per-device) HLO module.
    `-done` ops are skipped so async pairs are not double-counted."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _INSTR_RE.search(s)
        if not m:
            continue
        if f"{m.group(1)}-done" in s:
            continue
        kind, operands = m.group(1), m.group(2)
        total = sum(_shape_bytes(d, dims)
                    for d, dims in _SHAPE_RE.findall(operands))
        out[kind] += total
    return out


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_total: float
    hlo_bytes_total: float
    collective_bytes_per_device: float
    model_flops: float

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def step_time_lower_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        if self.hlo_flops_total <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops_total

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-based MFU bound at the roofline step time."""
        if self.step_time_lower_bound_s <= 0:
            return 0.0
        return self.compute_s * self.useful_flops_fraction \
            / self.step_time_lower_bound_s


def roofline_terms(cost: dict, collective: dict[str, int], chips: int,
                   model_flops: float, hw: HWSpec = TPU_V5E,
                   flops_are_per_device: bool = True) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    if flops_are_per_device:
        total_flops = flops * chips
        total_bytes = byts * chips
    else:
        total_flops, total_bytes = flops, byts
    coll_dev = float(sum(collective.values()))
    return RooflineTerms(
        compute_s=total_flops / (chips * hw.peak_flops_bf16),
        memory_s=total_bytes / (chips * hw.hbm_bw),
        collective_s=coll_dev / hw.ici_link_bw,
        hlo_flops_total=total_flops,
        hlo_bytes_total=total_bytes,
        collective_bytes_per_device=coll_dev,
        model_flops=model_flops,
    )
