"""Roofline report: dry-run JSONs -> EXPERIMENTS.md §Dry-run / §Roofline
markdown tables."""
from __future__ import annotations

import glob
import json
import os


def load_records(dryrun_dir: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.extend(json.load(f))
    return recs


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def _fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def dryrun_table(recs: list[dict], mesh: str | None = None) -> str:
    rows = ["| arch | shape | mesh | status | peak bytes/device "
            "(arg+tmp+out−alias) | fits 16GB | HLO GFLOPs/dev | "
            "collective/dev | compile |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if mesh and r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"SKIP (sub-quadratic required) | — | — | — | — | — |")
            continue
        if r["status"] == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | — | — | — | — | — |")
            continue
        m = r["memory"]
        total = r.get("peak_bytes",
                      m["argument_bytes"] + m["temp_bytes"]
                      + m["output_bytes"] - m.get("alias_bytes", 0))
        coll = sum(r["collective_bytes"].values())
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{_fmt_b(total)} ({_fmt_b(m['argument_bytes'])}+"
            f"{_fmt_b(m['temp_bytes'])}+{_fmt_b(m['output_bytes'])}"
            f"−{_fmt_b(m.get('alias_bytes', 0))}) | "
            f"{'yes' if r['fits_hbm'] else 'NO'} | "
            f"{r['cost']['flops_per_device']/1e9:.1f} | "
            f"{_fmt_b(coll)} | {r['compile_s']:.0f}s |")
    return "\n".join(rows)


def roofline_table(recs: list[dict], mesh: str = "single") -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "MODEL_FLOPS | useful frac | roofline frac | what would move the "
            "dominant term |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(ro['compute_s'])} | "
            f"{_fmt_s(ro['memory_s'])} | {_fmt_s(ro['collective_s'])} | "
            f"**{ro['dominant']}** | {ro['model_flops']:.2e} | "
            f"{ro['useful_flops_fraction']:.3f} | "
            f"{ro['roofline_fraction']:.3f} | {advice(r)} |")
    return "\n".join(rows)


def advice(r: dict) -> str:
    ro = r["roofline"]
    dom = ro["dominant"]
    kind = r["kind"]
    if dom == "memory" and kind == "decode":
        return ("decode reads the whole KV cache per token — quantize KV / "
                "batch more requests per read")
    if dom == "memory" and ro["useful_flops_fraction"] < 0.7:
        return ("remat recompute + microbatch weight re-reads dominate — "
                "fewer microbatches / selective remat policy")
    if dom == "memory":
        return "fuse residual/norm traffic; larger per-device batch"
    if dom == "collective":
        if r["collective_bytes"].get("all-gather", 0) > \
                r["collective_bytes"].get("all-reduce", 0):
            return ("FSDP weight all-gathers dominate — gather once per step "
                    "(not per microbatch) or widen TP")
        return ("TP activation all-reduces dominate — overlap with compute "
                "(latency-hiding scheduler) or reduce TP degree")
    return "already compute-bound: increase arithmetic intensity per chip"


def pick_hillclimb(recs: list[dict]) -> dict:
    """worst roofline fraction, most collective-bound, most representative."""
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "single"
          and r["kind"] == "train"]
    ok_all = [r for r in recs if r["status"] == "ok"
              and r["mesh"] == "single"]
    worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(ok_all, key=lambda r: r["roofline"]["collective_s"])
    return {"worst_fraction": (worst["arch"], worst["shape"]),
            "most_collective": (coll["arch"], coll["shape"])}


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args(argv)
    recs = load_records(args.dryrun_dir)
    print("## Dry-run table (%s)\n" % args.mesh)
    print(dryrun_table(recs, args.mesh))
    print("\n## Roofline table (%s)\n" % args.mesh)
    print(roofline_table(recs, args.mesh))
    print("\nhillclimb picks:", pick_hillclimb(recs))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
