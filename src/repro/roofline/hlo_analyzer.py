"""Trip-count-aware HLO text analyzer.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE — under scan-over-layers
every per-layer FLOP/byte is undercounted by the trip count, and collective
bytes inside the loop vanish.  This analyzer parses the compiled per-device
HLO text, builds the computation call graph (while bodies, fusions, calls,
conditionals), extracts loop trip counts from the while-condition constant,
and propagates execution multipliers from ENTRY — yielding scan-corrected:

  * dot/convolution FLOPs,
  * bytes touched (operands + outputs per instruction),
  * collective bytes by kind (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute), with reduce-scatter accounting for its
    group-size input factor.

This is also the profiling tool the §Perf loop reads (per-computation
breakdowns via ``report()``).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_BYTES_OPS = frozenset({
    "fusion", "dot", "convolution", "copy", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "reduce", "reduce-window",
    "sort", "concatenate", "pad", "slice", "transpose", "select-and-scatter",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "custom-call", "cholesky", "triangular-solve",
    "rng", "rng-bit-generator",
})

_COMP_NAME = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)")
# tuple types may contain /*index=N*/ comments (with '='), so the type group
# matches to the first ')' — tuple element types never contain parens.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_REPLICA_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_elems_bytes(text: str) -> tuple[int, int]:
    """Sum elements/bytes over all shapes appearing in a type string."""
    elems = byts = 0
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_entry: bool = False


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        # computation headers start at column 0 and open a brace
        if not line.startswith(" ") and line.endswith("{") and "->" in line:
            m = _COMP_NAME.match(line.strip())
            if m:
                cur = Computation(m.group(1), [],
                                  is_entry=line.startswith("ENTRY"))
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2).strip(),
                                    m.group(3), m.group(4)))
    return comps


def _dot_flops(instr: Instr, symtab: dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(instr.out_type)
    # contracting size from lhs operand shape + contracting dims attr
    mc = _CONTRACT.search(instr.rest)
    operands = _operand_names(instr.rest)
    if mc and operands:
        lhs_type = symtab.get(operands[0], "")
        dims = _SHAPE.search(lhs_type)
        if dims:
            shape = [int(x) for x in dims.group(2).split(",") if x]
            contract = 1
            for ci in mc.group(1).split(","):
                if ci and int(ci) < len(shape):
                    contract *= shape[int(ci)]
            return 2.0 * out_elems * contract
    return 2.0 * out_elems  # fallback


_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")


def _operand_names(rest: str) -> list[str]:
    # operand list ends at the first "), " attribute boundary
    depth, end = 1, len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_NAME.findall(rest[:end])


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the while condition (jax scans compare the
    induction variable against the trip count)."""
    best = 1
    for ins in cond.instrs:
        for m in _CONST_INT.finditer(ins.out_type + " " + ins.rest):
            best = max(best, int(m.group(1)))
        if ins.op == "constant":
            m2 = re.search(r"constant\((\d+)\)", f"{ins.op}({ins.rest}")
            if m2:
                best = max(best, int(m2.group(1)))
    return best


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    per_comp_flops: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    @property
    def collective_total(self) -> float:
        return float(sum(self.collective_bytes.values()))


def analyze(text: str) -> HLOCost:
    comps = parse_hlo(text)
    cost = HLOCost()
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return cost

    def walk(comp: Computation, mult: float, seen_stack: tuple):
        if comp.name in seen_stack:   # defensive: no recursion in HLO
            return
        symtab = {i.name: i.out_type for i in comp.instrs}
        for ins in comp.instrs:
            out_e, out_b = _shape_elems_bytes(ins.out_type)
            opnd_b = sum(_shape_elems_bytes(symtab.get(o, ""))[1]
                         for o in _operand_names(ins.rest))
            # HBM-traffic model for the TPU target: count kernel-boundary ops
            # (fusions, dots, data movement, reductions, collectives).  Bare
            # elementwise/convert/broadcast at HLO top level would be fused
            # into neighbors by the TPU compiler — counting them models the
            # CPU backend's artifacts, not the target's memory traffic.
            kind_name = ins.name if ins.op == "fusion" else ins.op
            if "dynamic-update-slice" in kind_name or "scatter" in kind_name:
                # read update + read/write the destination window (dest is
                # aliased in place); update ≈ smallest operand
                ops_b = [_shape_elems_bytes(symtab.get(o, ""))[1]
                         for o in _operand_names(ins.rest)]
                upd = min([b for b in ops_b if b > 0], default=out_b)
                cost.bytes += mult * 3 * upd
            elif ("slice" in kind_name or "gather" in kind_name
                  and "all-gather" not in kind_name):
                # reads only the slice, not the whole operand
                cost.bytes += mult * 2 * out_b
            elif ins.op in _BYTES_OPS:
                cost.bytes += mult * (out_b + opnd_b)
            if ins.op in ("dot", "convolution"):
                f = _dot_flops(ins, symtab)
                cost.flops += mult * f
                cost.per_comp_flops[comp.name] += mult * f
            base = ins.op
            for kind in _COLLECTIVES:
                if base == kind or base == kind + "-start":
                    b = out_b
                    if kind == "reduce-scatter":
                        m = _REPLICA_GROUPS.search(ins.rest)
                        if m:
                            b *= int(m.group(2))
                    elif kind == "all-gather":
                        pass   # result already the gathered size
                    cost.collective_bytes[kind] += mult * b
            # recurse into called computations
            if ins.op == "while":
                body = cond = None
                for cm in _CALL_ATTR.finditer(ins.rest):
                    pass
                mb = re.search(r"body=%?([\w\.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                if mb and mb.group(1) in comps:
                    trips = 1
                    if mc and mc.group(1) in comps:
                        trips = _trip_count(comps[mc.group(1)])
                    walk(comps[mb.group(1)], mult * trips,
                         seen_stack + (comp.name,))
            elif ins.op in ("fusion", "call", "custom-call", "map", "reduce",
                            "reduce-window", "scatter", "select-and-scatter",
                            "sort", "all-reduce", "reduce-scatter"):
                m = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", ins.rest)
                if m and m.group(1) in comps:
                    sub = comps[m.group(1)]
                    # fusion bodies: count dots (rare) but skip elementwise
                    for sins in sub.instrs:
                        if sins.op in ("dot", "convolution"):
                            stab = {i.name: i.out_type for i in sub.instrs}
                            f = _dot_flops(sins, stab)
                            cost.flops += mult * f
                            cost.per_comp_flops[sub.name] += mult * f
            elif ins.op == "conditional":
                mb = _BRANCHES.search(ins.rest)
                if mb:
                    for nm in _OPERAND_NAME.findall(mb.group(1)):
                        if nm in comps:
                            walk(comps[nm], mult, seen_stack + (comp.name,))

    walk(entry, 1.0, ())
    return cost


def report(text: str, top: int = 12) -> str:
    cost = analyze(text)
    lines = [f"flops={cost.flops:.3e} bytes={cost.bytes:.3e} "
             f"collective={cost.collective_total:.3e}"]
    for kind, b in sorted(cost.collective_bytes.items()):
        if b:
            lines.append(f"  {kind:20s} {b:.3e} B")
    lines.append("top computations by flops:")
    for name, f in sorted(cost.per_comp_flops.items(), key=lambda kv: -kv[1])[
            :top]:
        lines.append(f"  {name:48s} {f:.3e}")
    return "\n".join(lines)
