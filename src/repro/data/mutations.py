"""Live corpus: crash-consistent streaming ingest and deletes (DESIGN.md §12).

:class:`LiveCorpus` makes a registered (table, vector column) pair mutable
without re-prepare.  Layout is two fixed-capacity segments — static shapes
are the TPU discipline, so mutations never change any compiled plan's array
shapes:

* **main segment** — a (cap_main, d) padded copy of the corpus plus every
  scalar column, a validity lane (the tombstone bitmap), and user-id slots.
  Deletes just clear validity bits: the tombstone mask folds into the same
  (Q, N) row-mask layout every kernel and IVF probe already threads, so a
  dead row is inert exactly the way a pad row is.
* **delta segment** — a (delta_cap, d) append-only buffer for inserts,
  scanned by the flat batched kernel and merged into the main result as one
  extra local level of the hierarchical per-query merge
  (:func:`repro.dist.collectives.merge_topk_level`).

Durability: every mutation first appends a JSON-lines record to a
write-ahead log with monotonic LSNs minted by the Catalog version clock
(``Catalog.bump_live`` — the LSN-vs-catalog-version rule: one clock drives
both plan re-binding and replay ordering); the append is fsynced before
the LSN is acknowledged.  ``snapshot()`` checkpoints the full segment
state via :mod:`repro.checkpoint.checkpointer` (atomic tmp-dir + rename
commit) at the current LSN; :func:`recover` restores the newest committed
snapshot, replays WAL records with higher LSNs, and truncates at most one
torn (half-flushed) tail line OFF THE FILE so post-recovery appends start
a fresh record instead of merging with the partial bytes.  A crash at ANY
of the :data:`repro.serving.faults.CRASH_SITES` therefore recovers to a
state whose query results are bit-identical to an unfailed replay.

Concurrency: all mutations (and ``snapshot``/``plan_arrays``) serialize on
one internal lock, so racing writers — e.g. the serving front door running
mutations on a thread pool — get distinct LSNs, distinct slots, and a WAL
whose record order equals LSN order; a plan re-bind never observes a
half-applied mutation.

``compact()`` folds delta rows and tombstones back into the main segment:
survivors are laid out canonically (sorted by user id, zero tail), the IVF
is re-clustered with a fixed seed and fixed list capacity, and the swap
happens under the version clock — in-flight compiled plans re-bind the new
arrays with zero retraces, and because the canonical layout is a pure
function of the logical corpus, a compacted state is bit-identical to a
fresh :func:`attach_live` on the same logical rows.
"""
from __future__ import annotations

import collections
import json
import os
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import checkpointer
from ..core.schema import Catalog, ColumnKind, Metric
from ..index.ivf import build_ivf
from ..serving.faults import FaultInjector, InjectedCrashError
from ..serving.resilience import (MutationError, validate_delete,
                                  validate_insert)

_SCALAR_KINDS = (ColumnKind.INT, ColumnKind.FLOAT, ColumnKind.BOOL,
                 ColumnKind.CATEGORY)


def _ceil8(n: int) -> int:
    return max(8, -(-int(n) // 8) * 8)


class LiveCorpus:
    """Mutable (table, vector column) state: segments, WAL, snapshots.

    Construct via :func:`attach_live` or :func:`recover` — both register
    the instance with the catalog.  All segment state is host numpy;
    :meth:`plan_arrays` materializes (and caches) the device copies that
    compiled plans re-bind in place."""

    def __init__(self, catalog: Catalog, meta: dict, path: str,
                 faults: FaultInjector | None = None):
        self.catalog = catalog
        self.table = meta["table"]
        self.column = meta["column"]
        self.dim = int(meta["dim"])
        self.cap_main = int(meta["cap_main"])
        self.delta_cap = int(meta["delta_cap"])
        self.nlist = meta["nlist"]
        self.seed = int(meta["seed"])
        self.iters = int(meta["iters"])
        self.keep_last_k = int(meta.get("keep_last_k", 3))
        self.metric = Metric[meta["metric"]]
        self.col_dtypes = {n: np.dtype(d) for n, d in meta["cols"].items()}
        self.path = path
        self._faults = faults
        self.lsn = 0
        self.compact_lsn = 0
        self.tombstones = 0
        self.main_vec = np.zeros((self.cap_main, self.dim), np.float32)
        self.main_valid = np.zeros((self.cap_main,), bool)
        self.main_uids = np.full((self.cap_main,), -1, np.int64)
        self.cols = {n: np.zeros((self.cap_main,), dt)
                     for n, dt in self.col_dtypes.items()}
        self.delta_vec = np.zeros((self.delta_cap, self.dim), np.float32)
        self.delta_valid = np.zeros((self.delta_cap,), bool)
        self.delta_uids = np.full((self.delta_cap,), -1, np.int64)
        self.dcols = {n: np.zeros((self.delta_cap,), dt)
                      for n, dt in self.col_dtypes.items()}
        self.delta_count = 0
        self._uid_loc: dict[int, tuple[str, int]] = {}
        self._dev: dict[str, Any] = {}
        # serializes mutations against each other and against plan re-binds
        # (the serving front door runs mutations on a thread pool)
        self._lock = threading.RLock()

    # -- plumbing -----------------------------------------------------------

    @property
    def wal_path(self) -> str:
        """Path of the JSON-lines write-ahead log."""
        return os.path.join(self.path, "wal.jsonl")

    @property
    def ckpt_dir(self) -> str:
        """Snapshot directory (checkpointer steps keyed by LSN)."""
        return os.path.join(self.path, "ckpt")

    def _crash(self, site: str) -> None:
        if self._faults is not None:
            self._faults.crash_point(site)

    def _wal_append(self, rec: dict, torn_site: str | None) -> None:
        """Durably append one record (flushed + fsynced before the LSN is
        acknowledged); ``torn_site`` arms the half-written tail-line crash
        (flush a prefix, then die) that recovery must shed."""
        line = json.dumps(rec, separators=(",", ":"))
        if (torn_site is not None and self._faults is not None
                and self._faults.armed(torn_site)):
            with open(self.wal_path, "a") as f:
                f.write(line[: max(1, len(line) // 2)])
                f.flush()
            self._faults.counters["crashes"] += 1
            raise InjectedCrashError(f"injected crash at {torn_site!r} "
                                     f"(half-flushed WAL line)")
        with open(self.wal_path, "a") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())

    def _wal_append_group(self, recs: list, torn_site: str | None) -> None:
        """Durably append a GROUP of records with one flush+fsync (the
        group-commit path: N records, one durability round-trip).  The
        armed torn crash flushes every line but the last plus half of the
        last — the worst tail a group commit can leave, so recovery must
        keep the complete prefix and shed only the torn suffix."""
        lines = [json.dumps(r, separators=(",", ":")) for r in recs]
        if (torn_site is not None and self._faults is not None
                and self._faults.armed(torn_site)):
            with open(self.wal_path, "a") as f:
                for line in lines[:-1]:
                    f.write(line + "\n")
                f.write(lines[-1][: max(1, len(lines[-1]) // 2)])
                f.flush()
            self._faults.counters["crashes"] += 1
            raise InjectedCrashError(f"injected crash at {torn_site!r} "
                                     f"(half-flushed group-commit tail)")
        with open(self.wal_path, "a") as f:
            f.write("".join(line + "\n" for line in lines))
            f.flush()
            os.fsync(f.fileno())

    def _bump(self) -> int:
        return self.catalog.bump_live(self.table, self.column)

    def _invalidate(self, *keys: str) -> None:
        for k in keys:
            self._dev.pop(k, None)

    def _rebuild_uid_map(self) -> None:
        self._uid_loc = {}
        for s in np.flatnonzero(self.main_valid):
            self._uid_loc[int(self.main_uids[s])] = ("m", int(s))
        for s in np.flatnonzero(self.delta_valid):
            self._uid_loc[int(self.delta_uids[s])] = ("d", int(s))

    def _state_tree(self) -> dict:
        """The full durable state as a flat-keyed pytree (snapshot unit)."""
        tree = {"main_vec": self.main_vec, "main_valid": self.main_valid,
                "main_uids": self.main_uids, "delta_vec": self.delta_vec,
                "delta_valid": self.delta_valid,
                "delta_uids": self.delta_uids,
                "lsn": np.int64(self.lsn),
                "compact_lsn": np.int64(self.compact_lsn),
                "delta_count": np.int64(self.delta_count),
                "tombstones": np.int64(self.tombstones),
                "cols": dict(self.cols), "dcols": dict(self.dcols)}
        return tree

    def _load_state_tree(self, tree: dict) -> None:
        # copies: restore() hands back device arrays whose numpy views are
        # read-only, and segment state must stay mutable host memory
        self.main_vec = np.array(tree["main_vec"], np.float32)
        self.main_valid = np.array(tree["main_valid"], bool)
        self.main_uids = np.array(tree["main_uids"], np.int64)
        self.delta_vec = np.array(tree["delta_vec"], np.float32)
        self.delta_valid = np.array(tree["delta_valid"], bool)
        self.delta_uids = np.array(tree["delta_uids"], np.int64)
        self.lsn = int(tree["lsn"])
        self.compact_lsn = int(tree["compact_lsn"])
        self.delta_count = int(tree["delta_count"])
        self.tombstones = int(tree["tombstones"])
        self.cols = {n: np.array(v, self.col_dtypes[n])
                     for n, v in tree["cols"].items()}
        self.dcols = {n: np.array(v, self.col_dtypes[n])
                      for n, v in tree["dcols"].items()}

    # -- mutations ----------------------------------------------------------

    def _normalize_columns(self, columns: dict | None, n: int) -> dict:
        out = {}
        for name, vals in (columns or {}).items():
            if name not in self.col_dtypes:
                raise MutationError(f"unknown scalar column {name!r}; "
                                    f"live columns: "
                                    f"{sorted(self.col_dtypes)}")
            arr = np.asarray(vals).astype(self.col_dtypes[name])
            arr = np.broadcast_to(np.atleast_1d(arr), (n,)).copy()
            if (np.issubdtype(arr.dtype, np.floating)
                    and not np.all(np.isfinite(arr))):
                raise MutationError(f"non-finite values for column {name!r}")
            out[name] = arr
        for name, dt in self.col_dtypes.items():
            out.setdefault(name, np.zeros((n,), dt))
        return out

    def insert(self, ids, vectors, columns: dict | None = None) -> int:
        """Admit a batch of new rows into the delta segment; returns the LSN.

        Typed rejections (:mod:`repro.serving.resilience`) fire BEFORE the
        WAL append — a rejected insert has no side effects.  Visibility is
        immediate: the next ``ensure_fresh`` re-binds the delta arrays
        (zero retraces) and every Q1-Q6 plan merges the new rows."""
        with self._lock:
            ids, vectors = validate_insert(
                ids, vectors, self.dim, self._uid_loc,
                self.delta_cap - self.delta_count, self.delta_cap)
            cols = self._normalize_columns(columns, len(ids))
            rec = {"op": "insert", "ids": [int(i) for i in ids],
                   "vecs": [[float(x) for x in v] for v in vectors],
                   "cols": {n: np.asarray(v).tolist()
                            for n, v in cols.items()}}
            self._crash("wal.pre_append")
            rec["lsn"] = lsn = self._bump()
            self._wal_append(rec, torn_site="wal.torn_append")
            self._crash("wal.post_append")
            self._apply_insert(ids, vectors, cols, lsn)
            return lsn

    def _apply_insert(self, ids, vectors, cols, lsn: int) -> None:
        n = len(ids)
        slots = np.arange(self.delta_count, self.delta_count + n)
        self.delta_vec[slots] = vectors
        self.delta_valid[slots] = True
        self.delta_uids[slots] = ids
        for name, vals in cols.items():
            self.dcols[name][slots] = vals
        for uid, s in zip(ids, slots):
            self._uid_loc[int(uid)] = ("d", int(s))
        self.delta_count += n
        self.lsn = lsn
        self._invalidate("live_delta_vec", "live_delta_valid", "live_dcols")

    def insert_batch(self, batches) -> list[int]:
        """Group-commit: admit several insert batches with ONE WAL fsync.

        Each element of ``batches`` is ``(ids, vectors)`` or
        ``(ids, vectors, columns)``; each becomes its own WAL record with
        its own LSN (minted in order, applied in order) — but the whole
        group shares a single flush+fsync, so N batches pay one durability
        round-trip instead of N.  Admission is all-or-nothing: every group
        is validated up front (including cross-group duplicate ids and
        cumulative delta headroom), so a rejected group rejects the whole
        call with no side effects.  Crash semantics (DESIGN.md §12): a
        torn group-commit tail (``wal.group_commit`` crash site) loses
        only the un-synced suffix — recovery replays the durable prefix,
        bit-identical to having run those prefix inserts one by one."""
        with self._lock:
            pending: dict[int, tuple] = {}
            free = self.delta_cap - self.delta_count
            norm = []
            for group in batches:
                ids, vectors = group[0], group[1]
                columns = group[2] if len(group) > 2 else None
                ids, vectors = validate_insert(
                    ids, vectors, self.dim,
                    collections.ChainMap(pending, self._uid_loc),
                    free, self.delta_cap)
                cols = self._normalize_columns(columns, len(ids))
                for uid in ids:
                    pending[int(uid)] = ("pending", -1)
                free -= len(ids)
                norm.append((ids, vectors, cols))
            self._crash("wal.pre_append")
            recs, lsns = [], []
            for ids, vectors, cols in norm:
                rec = {"op": "insert", "ids": [int(i) for i in ids],
                       "vecs": [[float(x) for x in v] for v in vectors],
                       "cols": {n: np.asarray(v).tolist()
                                for n, v in cols.items()}}
                rec["lsn"] = lsn = self._bump()
                lsns.append(lsn)
                recs.append(rec)
            self._wal_append_group(recs, torn_site="wal.group_commit")
            self._crash("wal.post_append")
            for (ids, vectors, cols), lsn in zip(norm, lsns):
                self._apply_insert(ids, vectors, cols, lsn)
            return lsns

    def delete(self, ids) -> int:
        """Tombstone a batch of live rows; returns the LSN.

        A main-segment delete clears a validity bit that every scan path
        already ANDs into its row mask; a delta-segment delete clears the
        matching delta-validity bit.  No data moves until ``compact()``."""
        with self._lock:
            ids = validate_delete(ids, self._uid_loc)
            rec = {"op": "delete", "ids": [int(i) for i in ids]}
            self._crash("wal.pre_append")
            rec["lsn"] = lsn = self._bump()
            self._wal_append(rec, torn_site="wal.torn_append")
            self._crash("wal.post_append")
            self._apply_delete(ids, lsn)
            return lsn

    def _apply_delete(self, ids, lsn: int) -> None:
        touched_main = touched_delta = False
        for uid in ids:
            seg, slot = self._uid_loc.pop(int(uid))
            if seg == "m":
                self.main_valid[slot] = False
                touched_main = True
            else:
                self.delta_valid[slot] = False
                touched_delta = True
            self.tombstones += 1
        self.lsn = lsn
        if touched_main:
            self._invalidate("live_main_valid")
        if touched_delta:
            self._invalidate("live_delta_valid")

    def snapshot(self) -> str:
        """Checkpoint the full segment state at the current LSN (atomic
        tmp-dir + rename commit via the checkpointer); returns the path."""
        with self._lock:
            self._crash("snapshot.pre_commit")
            out = checkpointer.save(self.ckpt_dir, self.lsn,
                                    self._state_tree(),
                                    keep_last_k=self.keep_last_k)
            self._crash("snapshot.post_commit")
            return out

    # -- compaction ---------------------------------------------------------

    def _canonical_state(self) -> dict:
        """The compacted state: survivors (main ∪ delta, minus tombstones)
        sorted by user id into slots 0..n-1, zero tail, empty delta.  A pure
        function of the logical corpus — which is what makes a compacted
        live corpus bit-identical to a fresh attach on the same rows."""
        m = np.flatnonzero(self.main_valid)
        d = np.flatnonzero(self.delta_valid)
        uids = np.concatenate([self.main_uids[m], self.delta_uids[d]])
        vecs = np.concatenate([self.main_vec[m], self.delta_vec[d]])
        n = len(uids)
        if n > self.cap_main:
            raise MutationError(
                f"main segment capacity {self.cap_main} cannot hold {n} "
                f"live rows; re-attach with a larger capacity")
        order = np.argsort(uids)
        tree = {"main_vec": np.zeros_like(self.main_vec),
                "main_valid": np.zeros_like(self.main_valid),
                "main_uids": np.full_like(self.main_uids, -1),
                "delta_vec": np.zeros_like(self.delta_vec),
                "delta_valid": np.zeros_like(self.delta_valid),
                "delta_uids": np.full_like(self.delta_uids, -1),
                "delta_count": np.int64(0), "tombstones": np.int64(0),
                "cols": {}, "dcols": {}}
        tree["main_vec"][:n] = vecs[order]
        tree["main_valid"][:n] = True
        tree["main_uids"][:n] = uids[order]
        for name in self.cols:
            merged = np.concatenate([self.cols[name][m],
                                     self.dcols[name][d]])
            col = np.zeros_like(self.cols[name])
            col[:n] = merged[order]
            tree["cols"][name] = col
            tree["dcols"][name] = np.zeros_like(self.dcols[name])
        return tree

    def compact(self) -> int:
        """Fold deltas + tombstones into the main segment; returns the LSN.

        Durability order: compute the canonical state, log one ``compact``
        WAL record (replay recomputes it deterministically), checkpoint the
        post-compaction state at the compact LSN, THEN swap in memory and
        re-register the rebuilt IVF under the version clock — a reader
        never observes a half-compacted corpus, and in-flight plans re-bind
        with zero retraces (index ``nlist``/``cap`` are pinned)."""
        with self._lock:
            staged = self._canonical_state()
            self._crash("compact.pre_log")
            lsn = self._bump()
            self._wal_append({"op": "compact", "lsn": lsn}, torn_site=None)
            self._crash("compact.post_log")
            staged["lsn"] = np.int64(lsn)
            staged["compact_lsn"] = np.int64(lsn)
            checkpointer.save(self.ckpt_dir, lsn, staged,
                              keep_last_k=self.keep_last_k)
            self._crash("compact.pre_swap")
            self._swap_compacted(staged, lsn)
            return lsn

    def _swap_compacted(self, staged: dict, lsn: int) -> None:
        self._load_state_tree(staged)
        self.lsn = lsn
        self.compact_lsn = lsn
        self._rebuild_uid_map()
        self._dev.clear()
        self._register_index()

    def _register_index(self) -> None:
        """(Re)build the IVF over the FULL padded main segment with pinned
        (seed, nlist, cap): same shapes, same static meta — the re-bind
        path stays retrace-free — and deterministic given the canonical
        layout."""
        if self.nlist is None:
            return
        ivf = build_ivf(jax.random.PRNGKey(self.seed),
                        jnp.asarray(self.main_vec), int(self.nlist),
                        metric=self.metric, iters=self.iters,
                        cap=_ceil8(self.cap_main))
        self.catalog.register_index(self.table, self.column, ivf)

    # -- read side ----------------------------------------------------------

    def plan_arrays(self) -> dict:
        """Device arrays for compiled plans, cached per segment piece so a
        delta-only mutation re-uploads only the delta arrays on re-bind.
        Runs under the mutation lock: a re-bind sees either the pre- or the
        post-mutation segments, never a half-applied state."""
        def dev(key, host):
            if key not in self._dev:
                self._dev[key] = jnp.asarray(host)
            return self._dev[key]

        with self._lock:
            if "live_cols" not in self._dev:
                self._dev["live_cols"] = {n: jnp.asarray(v)
                                          for n, v in self.cols.items()}
            if "live_dcols" not in self._dev:
                self._dev["live_dcols"] = {n: jnp.asarray(v)
                                           for n, v in self.dcols.items()}
            return {"corpus": dev("corpus", self.main_vec),
                    "live_main_valid": dev("live_main_valid",
                                           self.main_valid),
                    "live_delta_vec": dev("live_delta_vec", self.delta_vec),
                    "live_delta_valid": dev("live_delta_valid",
                                            self.delta_valid),
                    "live_cols": self._dev["live_cols"],
                    "live_dcols": self._dev["live_dcols"]}

    def freshness(self) -> dict:
        """Observable corpus freshness (surfaced by ``explain()``): delta
        rows awaiting compaction, tombstone count, and the LSN frontier."""
        with self._lock:
            return {"delta_rows": int(self.delta_valid.sum()),
                    "tombstones": int(self.tombstones),
                    "live_rows": int(self.main_valid.sum()
                                     + self.delta_valid.sum()),
                    "lsn": int(self.lsn),
                    "last_compact_lsn": int(self.compact_lsn)}

    def user_ids(self, slot_ids) -> np.ndarray:
        """Map plan-result slot ids (main slot, or cap_main + delta slot;
        -1 invalid) back to user ids."""
        slots = np.asarray(slot_ids)
        flat = slots.reshape(-1)
        out = np.full(flat.shape, -1, np.int64)
        with self._lock:
            main = (flat >= 0) & (flat < self.cap_main)
            out[main] = self.main_uids[flat[main]]
            delta = flat >= self.cap_main
            out[delta] = self.delta_uids[flat[delta] - self.cap_main]
        return out.reshape(slots.shape)


def _write_meta(path: str, meta: dict) -> None:
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)


def attach_live(catalog: Catalog, table: str, column: str, path: str, *,
                delta_cap: int = 256, cap_main: int | None = None,
                nlist: int | None = None, seed: int = 0, iters: int = 8,
                ids=None, keep_last_k: int = 3,
                faults: FaultInjector | None = None) -> LiveCorpus:
    """Make (table, column) mutable: build the live segments from the
    frozen table, write meta + an LSN-0-equivalent base snapshot, register
    with the catalog, and (when ``nlist`` is given, or an IVF was already
    registered) build the live IVF over the padded main segment.

    Registration bumps the table's version on purpose: plans compiled
    against the frozen layout raise ``StalePlanError`` and transparently
    re-prepare onto the live lowering.  ``ids`` assigns user ids to the
    existing rows (default: row positions).  Mutations are visible ONLY
    through plans that scan ``column`` — other vector columns of the table
    keep frozen-snapshot semantics (documented limitation, DESIGN.md §12).
    """
    tab = catalog.table(table)
    spec = tab.schema[column]
    if spec.kind != ColumnKind.VECTOR:
        raise ValueError(f"{table}.{column} is not a vector column")
    vectors = np.asarray(tab[column], np.float32)
    n0, dim = vectors.shape
    if cap_main is None:
        cap_main = _ceil8(n0 + 4 * delta_cap)
    cap_main = _ceil8(cap_main)
    if cap_main < n0:
        raise ValueError(f"cap_main {cap_main} < existing rows {n0}")
    existing = catalog.index_for(table, column)
    if nlist is None and existing is not None:
        nlist = int(existing.nlist)
    col_names = [n for n, t in tab.schema.columns.items()
                 if t.kind in _SCALAR_KINDS]
    meta = {"table": table, "column": column, "dim": int(dim),
            "cap_main": int(cap_main), "delta_cap": int(delta_cap),
            "nlist": None if nlist is None else int(nlist),
            "seed": int(seed), "iters": int(iters),
            "keep_last_k": int(keep_last_k), "metric": spec.metric.name,
            "cols": {n: np.asarray(tab[n]).dtype.str for n in col_names}}
    uids = (np.arange(n0, dtype=np.int64) if ids is None
            else np.asarray(ids, np.int64))
    # validate BEFORE touching disk: a rejected attach must leave no
    # partial on-disk state (a bare meta.json would make a later recover()
    # fail with 'no committed snapshot' instead of 'never attached')
    if uids.shape != (n0,):
        raise ValueError(f"attach ids must have shape ({n0},), "
                         f"got {tuple(uids.shape)}")
    if len(np.unique(uids)) != n0:
        raise ValueError("attach ids must be unique")
    os.makedirs(path, exist_ok=True)
    _write_meta(path, meta)
    live = LiveCorpus(catalog, meta, path, faults=faults)
    live.main_vec[:n0] = vectors
    live.main_valid[:n0] = np.asarray(tab.valid)
    live.main_uids[:n0] = uids
    for name in col_names:
        live.cols[name][:n0] = np.asarray(tab[name])
    live._rebuild_uid_map()
    catalog.register_live(table, column, live)
    live.lsn = catalog.version(("live", table, column))
    open(live.wal_path, "w").close()
    checkpointer.save(live.ckpt_dir, live.lsn, live._state_tree(),
                      keep_last_k=keep_last_k)
    live._register_index()
    return live


def _read_wal(wal_path: str) -> tuple[list[dict], int]:
    """Parse the WAL; returns ``(records, durable_bytes)``.

    ``durable_bytes`` is the length of the longest prefix ending at a
    complete newline-terminated record — at most one torn (half-flushed,
    unterminated) tail line past it is shed.  Every successful append
    terminates its record, so a corrupt *terminated* line is a hard
    error anywhere in the file."""
    if not os.path.exists(wal_path):
        return [], 0
    with open(wal_path, "rb") as f:
        chunks = f.read().split(b"\n")
    out, durable = [], 0
    # every chunk but the last was newline-terminated; the last is either
    # b"" (file ends cleanly) or the torn tail of a mid-append crash
    for i, chunk in enumerate(chunks[:-1]):
        if chunk.strip():
            try:
                out.append(json.loads(chunk))
            except json.JSONDecodeError:
                raise MutationError(f"corrupt WAL record at line {i + 1}")
        durable += len(chunk) + 1
    return out, durable


def recover(catalog: Catalog, table: str, column: str, path: str, *,
            faults: FaultInjector | None = None) -> LiveCorpus:
    """Rebuild a live corpus from disk alone after a crash.

    Restores the newest committed snapshot, replays WAL records with LSNs
    past it (``compact`` records recompute the canonical state
    deterministically), truncates any torn (half-flushed) tail line off
    the WAL so the next append starts a fresh record, fast-forwards the
    catalog clock past every replayed LSN, and re-registers corpus + IVF.
    The recovered state's query results are bit-identical to an unfailed
    replay of the same mutation sequence — the chaos suite asserts exactly
    that at every crash site."""
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    if meta["table"] != table or meta["column"] != column:
        raise MutationError(
            f"live state at {path} is for {meta['table']}.{meta['column']}, "
            f"not {table}.{column}")
    live = LiveCorpus(catalog, meta, path, faults=faults)
    step = checkpointer.latest_step(live.ckpt_dir)
    if step is None:
        raise MutationError(f"no committed snapshot under {live.ckpt_dir}")
    tree = checkpointer.restore(live.ckpt_dir, step, live._state_tree())
    live._load_state_tree(tree)
    live._rebuild_uid_map()
    records, durable = _read_wal(live.wal_path)
    if (os.path.exists(live.wal_path)
            and os.path.getsize(live.wal_path) > durable):
        # shed the torn tail ON DISK too: a later append must start a fresh
        # line, not merge with the partial bytes into one corrupt record
        with open(live.wal_path, "rb+") as f:
            f.truncate(durable)
            os.fsync(f.fileno())
    max_lsn = live.lsn
    for rec in records:
        lsn = int(rec["lsn"])
        max_lsn = max(max_lsn, lsn)
        if lsn <= live.lsn:
            continue                       # already folded into the snapshot
        if rec["op"] == "insert":
            ids = np.asarray(rec["ids"], np.int64)
            vecs = np.asarray(rec["vecs"], np.float32)
            cols = {n: np.asarray(v, live.col_dtypes[n])
                    for n, v in rec["cols"].items()}
            live._apply_insert(ids, vecs, cols, lsn)
        elif rec["op"] == "delete":
            live._apply_delete(np.asarray(rec["ids"], np.int64), lsn)
        elif rec["op"] == "compact":
            staged = live._canonical_state()
            staged["lsn"] = np.int64(lsn)
            staged["compact_lsn"] = np.int64(lsn)
            live._load_state_tree(staged)
            live._rebuild_uid_map()
        else:
            raise MutationError(f"unknown WAL op {rec['op']!r}")
    catalog.advance_clock(max_lsn)
    catalog.register_live(table, column, live)
    live._register_index()
    return live
