"""Synthetic LAION-shaped corpus (paper §7.1, Table 2).

The evaluation dataset (laion1m + queries, 512-d CLIP embeddings, mutually
exclusive) is reproduced synthetically in this offline container with the same
*schema* and the geometric property IVF/HNSW both depend on: embeddings drawn
from a Gaussian mixture (clustered, anisotropic), L2-normalized like CLIP
vectors.  Selectivity levels are calibrated by quantiles exactly as §7.1.

Tables:
  laion(sample_id, url:int surrogate, text:int surrogate, height, width,
        nsfw:category{0,1,2}, similarity, calorie_level:category, vec)
  queries(id, cuisine:category, preferred_*, capture_date, vec)
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..core.schema import (Catalog, Metric, Schema, Table, category_col,
                           float_col, int_col, vector_col)


def _make_modes(rng: np.random.Generator, n_modes: int,
                dim: int) -> np.ndarray:
    modes = rng.standard_normal((n_modes, dim)).astype(np.float32)
    modes /= np.linalg.norm(modes, axis=1, keepdims=True)
    return modes


def _mixture_vectors(rng: np.random.Generator, n: int, dim: int,
                     n_modes: int, spread: float = 0.35,
                     modes: np.ndarray | None = None) -> np.ndarray:
    """Gaussian mixture on the unit sphere.  ``spread`` is the noise NORM
    relative to the unit mode vector (per-coordinate sigma = spread/sqrt(d)),
    so cluster tightness is dimension-independent — at d=512 an unscaled
    sigma would swamp the mode signal entirely.  Pass shared ``modes`` so
    corpus and queries live in the SAME clusters (mutually-exclusive rows,
    shared distribution — the LAION/queries relationship)."""
    if modes is None:
        modes = _make_modes(rng, n_modes, dim)
    which = rng.integers(0, modes.shape[0], size=n)
    sigma = spread / np.sqrt(dim)
    x = modes[which] + sigma * rng.standard_normal((n, dim)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return x.astype(np.float32)


def selectivity_threshold(column: np.ndarray, selectivity: float) -> float:
    """Quantile calibration (§7.1): value v s.t. P(col < v) ≈ selectivity."""
    return float(np.quantile(column, selectivity))


def make_laion_catalog(n_rows: int = 100_000, n_queries: int = 100,
                       dim: int = 128, n_modes: int = 64,
                       num_categories: int = 8, seed: int = 0,
                       metric: Metric = Metric.INNER_PRODUCT,
                       query_spread: float = 0.15) -> Catalog:
    """Synthetic LAION-shaped catalog (§7.1): a mixture-of-modes vector
    corpus with correlated scalar columns, registered under the table
    aliases the Q1–Q6 benchmark SQL expects (laion/products/images/
    recipes/movies share one table; queries/users another)."""
    rng = np.random.default_rng(seed)
    modes = _make_modes(rng, n_modes, dim)
    vec = _mixture_vectors(rng, n_rows, dim, n_modes, modes=modes)
    # queries sit near mode centers (image-retrieval realism: a query image
    # resembles its cluster) — mirrors LAION queries being CLIP embeddings
    # of the same visual distribution; SAME modes as the corpus
    qvec = _mixture_vectors(rng, n_queries, dim, n_modes,
                            spread=query_spread, modes=modes)

    height = rng.integers(64, 2048, size=n_rows).astype(np.int32)
    width = rng.integers(64, 2048, size=n_rows).astype(np.int32)
    nsfw = rng.choice(3, size=n_rows, p=[0.9, 0.07, 0.03]).astype(np.int32)
    similarity = rng.beta(2.0, 4.0, size=n_rows).astype(np.float32)
    price = (rng.lognormal(3.5, 1.0, size=n_rows)).astype(np.float32)
    capture_date = rng.integers(0, 3650, size=n_rows).astype(np.int32)
    calorie = rng.integers(0, num_categories, size=n_rows).astype(np.int32)
    cuisine = rng.integers(0, num_categories, size=n_rows).astype(np.int32)
    rating = rng.integers(0, 5, size=n_rows).astype(np.int32)
    release_year = rng.integers(1980, 2026, size=n_rows).astype(np.int32)

    laion_schema = Schema({
        "sample_id": int_col(jnp.int64),
        "height": int_col(), "width": int_col(),
        "nsfw": category_col(3),
        "similarity": float_col(),
        "price": float_col(),
        "capture_date": int_col(),
        "calorie_level": category_col(num_categories),
        "cuisine": category_col(num_categories),
        "rating": category_col(5),
        "release_year": int_col(),
        "vec": vector_col(dim, metric),
        "embedding": vector_col(dim, metric),
    }, primary_key="sample_id")
    laion = Table(laion_schema, {
        "sample_id": jnp.arange(n_rows, dtype=jnp.int64),
        "height": jnp.asarray(height), "width": jnp.asarray(width),
        "nsfw": jnp.asarray(nsfw), "similarity": jnp.asarray(similarity),
        "price": jnp.asarray(price),
        "capture_date": jnp.asarray(capture_date),
        "calorie_level": jnp.asarray(calorie),
        "cuisine": jnp.asarray(cuisine),
        "rating": jnp.asarray(rating),
        "release_year": jnp.asarray(release_year),
        "vec": jnp.asarray(vec),
        "embedding": jnp.asarray(vec),
    })

    q_pref_rating = rng.integers(0, 5, size=n_queries).astype(np.int32)
    q_pref_year = rng.integers(1990, 2020, size=n_queries).astype(np.int32)
    q_cuisine = rng.integers(0, num_categories, size=n_queries).astype(np.int32)
    q_capture = rng.integers(0, 3650, size=n_queries).astype(np.int32)
    queries_schema = Schema({
        "id": int_col(jnp.int64),
        "preferred_rating": category_col(5),
        "preferred_release_year": int_col(),
        "cuisine": category_col(num_categories),
        "capture_date": int_col(),
        "embedding": vector_col(dim, metric),
        "vec": vector_col(dim, metric),
    }, primary_key="id")
    queries = Table(queries_schema, {
        "id": jnp.arange(n_queries, dtype=jnp.int64),
        "preferred_rating": jnp.asarray(q_pref_rating),
        "preferred_release_year": jnp.asarray(q_pref_year),
        "cuisine": jnp.asarray(q_cuisine),
        "capture_date": jnp.asarray(q_capture),
        "embedding": jnp.asarray(qvec),
        "vec": jnp.asarray(qvec),
    })

    cat = Catalog()
    cat.register("laion", laion)
    cat.register("products", laion)     # Q1 template alias
    cat.register("images", laion)       # Q2/Q3 template alias
    cat.register("recipes", laion)      # Q5/Q6 template alias
    cat.register("movies", laion)       # Q4 template alias
    cat.register("queries", queries)
    cat.register("users", queries)      # Q4 template alias
    return cat
