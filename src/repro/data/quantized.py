"""Quantized corpus twins for the bandwidth-bound scan path (DESIGN.md §13).

A :class:`QuantizedCorpus` is a derived, device-resident twin of a vector
column: the same (N, D) rows stored as int8 (per-row symmetric scale) or
bf16, plus the per-row metadata the quantized kernels and the range-query
slack bounds need.  Twins are built once at attach/first-prepare time and
registered on the :class:`~repro.core.schema.Catalog`, so prepared plans
re-bind them through ``ensure_fresh`` without retracing.

Per-row contract (``x`` the fp32 row, ``x̂`` its dequantization):

* **int8**: ``s = max_j |x_j| / 127`` (``s = 1`` for an all-zero row),
  ``q_j = round(x_j / s)`` ∈ [−127, 127], ``x̂_j = s · q_j``, and the
  componentwise error obeys ``|x_j − x̂_j| ≤ s / 2 = half_step``.
* **bf16**: ``q_j = bf16(x_j)`` (round-to-nearest, 8 significand bits →
  unit roundoff 2⁻⁸), ``scales ≡ 1`` so ONE kernel serves both modes
  (``1.0 · x`` is a bitwise identity), and
  ``|x_j − x̂_j| ≤ 2⁻⁸ · |x_j| ≤ 2⁻⁸ · max_j |x_j| = half_step``.

``row_l1``/``row_l2`` are norms of the *dequantized* rows — the range
slack bounds (kernels/quant.py) are stated in terms of x̂, which the
kernel actually scores.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax.numpy as jnp

MODES = ("int8", "bf16")


@dataclasses.dataclass(frozen=True)
class QuantizedCorpus:
    """Device-resident quantized twin of one vector column."""
    mode: str                 # "int8" | "bf16"
    qvecs: jnp.ndarray        # (N, D) int8 | bfloat16
    scales: jnp.ndarray       # (N, 1) fp32 dequant scales (ones for bf16)
    half_step: jnp.ndarray    # (N,) fp32 componentwise |x − x̂| bound
    row_l1: jnp.ndarray       # (N,) fp32 ‖x̂‖₁
    row_l2: jnp.ndarray       # (N,) fp32 ‖x̂‖₂

    def plan_arrays(self, prefix: str = "") -> Dict[str, Any]:
        """The array bundle prepared plans bind (ensure_fresh re-binds the
        same keys, so a re-registered twin never retraces)."""
        return {prefix + "qvecs": self.qvecs,
                prefix + "qscales": self.scales,
                prefix + "qhalf": self.half_step,
                prefix + "ql1": self.row_l1,
                prefix + "ql2": self.row_l2}


def quantize_corpus(vecs: jnp.ndarray, mode: str) -> QuantizedCorpus:
    """Build the quantized twin of an fp32 (N, D) corpus."""
    if mode not in MODES:
        raise ValueError(f"unknown quantization mode {mode!r}; "
                         f"expected one of {MODES}")
    vecs = jnp.asarray(vecs, jnp.float32)
    if vecs.ndim != 2:
        raise ValueError(f"expected (N, D) corpus, got {vecs.shape}")
    amax = jnp.max(jnp.abs(vecs), axis=1)                      # (N,)
    if mode == "int8":
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)         # (N,)
        q = jnp.clip(jnp.round(vecs / scale[:, None]), -127, 127)
        q = q.astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale[:, None]
        half = jnp.where(amax > 0, scale * 0.5, 0.0)
    else:
        q = vecs.astype(jnp.bfloat16)
        scale = jnp.ones_like(amax)
        deq = q.astype(jnp.float32)
        half = amax * jnp.float32(2.0 ** -8)
    row_l1 = jnp.sum(jnp.abs(deq), axis=1)
    row_l2 = jnp.sqrt(jnp.sum(deq * deq, axis=1))
    return QuantizedCorpus(mode=mode, qvecs=q, scales=scale[:, None],
                           half_step=half, row_l1=row_l1, row_l2=row_l2)
