"""Datasets and mutations: the LAION-style synthetic catalog and the
live-corpus (delta/tombstone/WAL) mutation layer (DESIGN.md §12)."""
from .laion import make_laion_catalog, selectivity_threshold

__all__ = ["make_laion_catalog", "selectivity_threshold"]
