from .laion import make_laion_catalog, selectivity_threshold

__all__ = ["make_laion_catalog", "selectivity_threshold"]
