"""Deterministic synthetic data pipeline with checkpointable cursor.

Production posture: batches are a pure function of (seed, step) — any host can
regenerate any shard of any step, which is what makes restart/elastic-resize
trivially consistent (no data-loader state beyond the cursor integer that
lives inside TrainState).  Shard-aware: each host materializes only its
addressable slice of the global batch (``host_slice``)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    """Synthetic-stream shape knobs (batch/sequence/vocab sizing)."""
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    vocab_size: int = 512
    input_mode: str = "tokens"
    d_model: int = 64              # embeddings mode


class SyntheticLM:
    """Markov-ish synthetic token stream (structured enough that loss
    decreases during the example training run)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # low-entropy bigram table => learnable structure
        self.bigram = rng.integers(0, cfg.vocab_size,
                                   size=(cfg.vocab_size,)).astype(np.int32)

    def batch_at(self, step: int, host_start: int = 0,
                 host_count: int | None = None) -> dict:
        """Deterministic batch for ``step`` (optionally a host shard slice):
        the same (seed, step) always yields the same tokens/labels."""
        cfg = self.cfg
        count = host_count if host_count is not None else cfg.global_batch
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) % (2**63))
        # skip to host slice deterministically
        starts = rng.integers(0, cfg.vocab_size,
                              size=(cfg.global_batch,)).astype(np.int32)
        starts = starts[host_start:host_start + count]
        toks = np.empty((count, cfg.seq_len), np.int32)
        toks[:, 0] = starts
        noise = rng.random((cfg.global_batch, cfg.seq_len))
        noise = noise[host_start:host_start + count]
        for t in range(1, cfg.seq_len):
            follow = self.bigram[toks[:, t - 1]]
            rand = ((toks[:, t - 1].astype(np.int64) * 7919 + t)
                    % cfg.vocab_size).astype(np.int32)
            toks[:, t] = np.where(noise[:, t] < 0.8, follow, rand)
        labels = np.roll(toks, -1, axis=1)
        if cfg.input_mode == "tokens":
            return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        embrng = np.random.default_rng(cfg.seed + 17)
        table = embrng.standard_normal(
            (cfg.vocab_size, cfg.d_model)).astype(np.float32)
        return {"embeds": jnp.asarray(table[toks]),
                "labels": jnp.asarray(labels)}

    def iterate(self, start_step: int = 0):
        """Endless (step, batch) stream beginning at ``start_step``."""
        step = start_step
        while True:
            yield step, self.batch_at(step)
            step += 1
