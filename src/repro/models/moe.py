"""Top-k routed mixture-of-experts MLP with capacity-based dispatch.

Two execution paths, same math:

* **local** (single device / no mesh rules): sort-based capacity dispatch —
  token→expert assignments ranked per expert (bincount + exclusive offsets),
  scattered into a dense (E, cap, d) buffer, grouped GEMMs, gathered back.

* **shard_map** (production meshes): GSPMD cannot partition the dispatch
  scatter (it replicates the buffer and all-reduces it every layer — measured
  at ~16 GB of all-reduce per MoE invocation on grok before this path
  existed).  The explicit formulation exploits that activations are
  *replicated over the model axis* under DP×TP: every model shard already
  holds all local tokens, so each shard dispatches only to the experts it
  owns ('expert' mode: E/model_size experts; 'ff' mode: the f/model_size
  slice of every expert) entirely locally, and one ``psum`` over the model
  axis combines partial outputs — the same wire cost as a dense TP MLP.
  FSDP-sharded expert weights are all-gathered over the data axis first
  (ZeRO-3 semantics).

Compute is ∝ top_k (active params) either way; tokens overflowing an
expert's capacity are dropped (GShard semantics).  Tests compare both paths
against the dense-dispatch oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..dist.sharding import constrain, current_mesh, current_rules
from .config import ModelConfig
from .layers import dense_init


def moe_init(key, cfg: ModelConfig) -> dict:
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff_expert
    dt = cfg.pdtype()
    ks = jax.random.split(key, 5)
    k1, k2, k3 = jax.random.split(ks[0], 3)
    scale_in = 1.0 / jnp.sqrt(d)
    scale_out = 1.0 / jnp.sqrt(f)
    p = {
        "router": dense_init(ks[1], d, e.num_experts, dt),
        "wi": (jax.random.normal(k1, (e.num_experts, d, f), jnp.float32)
               * scale_in).astype(dt),
        "wg": (jax.random.normal(k2, (e.num_experts, d, f), jnp.float32)
               * scale_in).astype(dt),
        "wo": (jax.random.normal(k3, (e.num_experts, f, d), jnp.float32)
               * scale_out).astype(dt),
    }
    if e.num_shared_experts:
        fs = f * e.num_shared_experts
        p["shared_wi"] = dense_init(ks[2], d, fs, dt)
        p["shared_wg"] = dense_init(ks[3], d, fs, dt)
        p["shared_wo"] = dense_init(ks[4], fs, d, dt)
    return p


# ---------------------------------------------------------------------------
# Local capacity dispatch (single shard; also the body of the shard_map path)
# ---------------------------------------------------------------------------

def _dispatch_compute(x_flat, top_w, top_idx, wi, wg, wo, num_experts: int,
                      expert_offset, cap: int, compute_dtype):
    """Capacity-dispatch x_flat (T,d) for experts [offset, offset+E_local).

    top_idx are GLOBAL expert ids; assignments outside this shard's expert
    range are dropped locally (they're handled by the owning shard).
    Returns (T, d) partial output (zeros for tokens fully routed elsewhere)."""
    T, d = x_flat.shape
    K = top_w.shape[-1]
    e_local = wi.shape[0]

    expert_flat = top_idx.reshape(T * K) - expert_offset
    weight_flat = top_w.reshape(T * K)
    mine = (expert_flat >= 0) & (expert_flat < e_local)
    expert_key = jnp.where(mine, expert_flat, e_local)   # sort strangers last
    token_flat = jnp.arange(T * K, dtype=jnp.int32) // K

    order = jnp.argsort(expert_key, stable=True)
    sorted_e = expert_key[order]
    counts = jnp.bincount(expert_key, length=e_local + 1)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(T * K, dtype=jnp.int32) - offsets[sorted_e]

    x_gathered = x_flat[token_flat[order]].astype(compute_dtype)
    buf = jnp.zeros((e_local, cap, d), compute_dtype)
    ok = sorted_e < e_local
    se = jnp.where(ok, sorted_e, e_local)                # row e_local dropped
    buf = buf.at[se, rank_sorted].set(
        jnp.where(ok[:, None], x_gathered, 0), mode="drop")

    h = jnp.einsum("ecd,edf->ecf", buf, wi,
                   preferred_element_type=jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", buf, wg,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * h).astype(compute_dtype)
    y_e = jnp.einsum("ecf,efd->ecd", h, wo,
                     preferred_element_type=jnp.float32).astype(compute_dtype)

    in_cap = ok & (rank_sorted < cap)
    y_sorted = jnp.where(in_cap[:, None],
                         y_e[jnp.minimum(se, e_local - 1),
                             jnp.minimum(rank_sorted, cap - 1)], 0.0)
    inv = jnp.argsort(order, stable=True)
    y_assign = y_sorted[inv]
    contrib = y_assign.astype(jnp.float32) * weight_flat[:, None]
    return jax.ops.segment_sum(contrib, token_flat, num_segments=T)


def _route(x_flat, router, K: int):
    logits = (x_flat @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    return probs, top_w, top_idx


def _aux_loss(e, probs, top_idx, dp_axes=()):
    """Switch aux loss.  Under shard_map the per-expert density and router
    probability are pmean'd over the DP axes BEFORE the (nonlinear) product —
    mean-of-shard-aux is not the global aux."""
    T = probs.shape[0]
    onehot_density = jnp.bincount(
        top_idx.reshape(-1), length=e.num_experts).astype(jnp.float32) \
        / (T * e.top_k)
    mean_prob = jnp.mean(probs, axis=0)
    if dp_axes:
        onehot_density = jax.lax.pmean(onehot_density, dp_axes)
        mean_prob = jax.lax.pmean(mean_prob, dp_axes)
    return e.num_experts * jnp.sum(onehot_density * mean_prob) \
        * e.router_aux_coef


def _moe_local(p, cfg: ModelConfig, x, capacity_factor: float):
    e = cfg.moe
    b, s, d = x.shape
    T = b * s
    cap = max(8, int(capacity_factor * T * e.top_k / e.num_experts))
    x_flat = x.reshape(T, d)
    probs, top_w, top_idx = _route(x_flat, p["router"], e.top_k)
    out_flat = _dispatch_compute(x_flat, top_w, top_idx, p["wi"], p["wg"],
                                 p["wo"], e.num_experts, 0, cap, cfg.cdtype())
    out = out_flat.reshape(b, s, d)
    if e.num_shared_experts:
        xe = x_flat.astype(cfg.cdtype())
        hs = jax.nn.silu(xe @ p["shared_wg"]) * (xe @ p["shared_wi"])
        out = out + (hs @ p["shared_wo"]).reshape(b, s, d).astype(out.dtype)
    return out.astype(x.dtype), _aux_loss(e, probs, top_idx)


# ---------------------------------------------------------------------------
# shard_map path (production meshes)
# ---------------------------------------------------------------------------

def _weight_specs(e, rules):
    """PartitionSpecs of the MoE weights under the active rules."""
    def ax(name):
        v = rules.get(name)
        return v

    if e.shard_mode == "expert" and ax("experts"):
        wi = P(ax("experts"), ax("expert_ff_in"), ax("moe_ff"))
        wo = P(ax("experts"), ax("moe_ff"), ax("expert_ff_in"))
    else:
        wi = P(None, ax("expert_ff_in"), ax("moe_ff"))
        wo = P(None, ax("moe_ff"), ax("expert_ff_in"))
    return wi, wo


def _moe_shard_map(p, cfg: ModelConfig, x, capacity_factor: float):
    e = cfg.moe
    mesh = current_mesh()
    rules = current_rules()
    dp = rules.get("batch")
    dp_axes = tuple(dp) if isinstance(dp, (tuple, list)) else (
        (dp,) if dp else ())
    model_ax = "model"
    b, s, d = x.shape
    wi_spec, wo_spec = _weight_specs(e, rules)
    x_spec = P(dp if dp else None, None, None)
    expert_mode = e.shard_mode == "expert" and rules.get("experts")
    model_size = mesh.shape[model_ax]
    e_local = e.num_experts // model_size if expert_mode else e.num_experts
    fsdp_axis = rules.get("mlp_embed")

    def body(x_l, router, wi, wg, wo, *shared):
        bl, sl, _ = x_l.shape
        T = bl * sl
        cap = max(8, int(capacity_factor * T * e.top_k
                         / max(e.num_experts, 1)))
        # ZeRO-3: reassemble the weight shards held on the DP axis
        if fsdp_axis is not None:
            axes = (fsdp_axis,) if isinstance(fsdp_axis, str) else fsdp_axis
            for a in axes:
                router = jax.lax.all_gather(router, a, axis=0, tiled=True)
                wi = jax.lax.all_gather(wi, a, axis=1, tiled=True)
                wg = jax.lax.all_gather(wg, a, axis=1, tiled=True)
                wo = jax.lax.all_gather(wo, a, axis=2, tiled=True)
        x_flat = x_l.reshape(T, d)
        probs, top_w, top_idx = _route(x_flat, router, e.top_k)
        if expert_mode:
            offset = jax.lax.axis_index(model_ax) * e_local
        else:
            offset = jnp.int32(0)
        out_flat = _dispatch_compute(x_flat, top_w, top_idx, wi, wg, wo,
                                     e.num_experts, offset, cap,
                                     cfg.cdtype())
        # partial outputs: expert mode sums shards' disjoint expert sets;
        # ff mode sums the f-slices — one psum either way
        out_flat = jax.lax.psum(out_flat, model_ax)
        out = out_flat.reshape(bl, sl, d).astype(x_l.dtype)
        if e.num_shared_experts:
            swi, swg, swo = shared
            if fsdp_axis is not None:
                axes = (fsdp_axis,) if isinstance(fsdp_axis, str) \
                    else fsdp_axis
                for a in axes:
                    swi = jax.lax.all_gather(swi, a, axis=0, tiled=True)
                    swg = jax.lax.all_gather(swg, a, axis=0, tiled=True)
                    swo = jax.lax.all_gather(swo, a, axis=1, tiled=True)
            xe = x_flat.astype(cfg.cdtype())
            hs = jax.nn.silu(xe @ swg) * (xe @ swi)
            hs = jax.lax.psum(hs @ swo, model_ax) if swo.shape[0] != \
                e.d_ff_expert * e.num_shared_experts else hs @ swo
            out = out + hs.reshape(bl, sl, d).astype(out.dtype)
        aux = _aux_loss(e, probs, top_idx, dp_axes)
        return out, aux

    mlp_spec = P(rules.get("mlp_embed"), rules.get("ff"))
    mlp_spec_o = P(rules.get("ff"), rules.get("mlp_embed"))
    in_specs = [x_spec, P(rules.get("embed"), None), wi_spec, wi_spec,
                wo_spec]
    args = [x, p["router"], p["wi"], p["wg"], p["wo"]]
    if e.num_shared_experts:
        in_specs += [mlp_spec, mlp_spec, mlp_spec_o]
        args += [p["shared_wi"], p["shared_wg"], p["shared_wo"]]
    fn = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=(x_spec, P()), check_rep=False)
    return fn(*args)


def moe_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray,
              capacity_factor: float = 1.25):
    """x: (B, S, d) -> (out, aux_loss)."""
    mesh = current_mesh()
    rules = current_rules()
    if mesh is not None and rules is not None and "model" in mesh.axis_names:
        return _moe_shard_map(p, cfg, x, capacity_factor)
    return _moe_local(p, cfg, x, capacity_factor)


def moe_apply_dense(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    """Dense-dispatch oracle (every expert computes every token): O(E) FLOPs,
    used only by tests to validate the capacity dispatch above."""
    e = cfg.moe
    b, s, d = x.shape
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, e.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(top_idx, e.num_experts, dtype=jnp.float32)
    combine = jnp.einsum("bske,bsk->bse", onehot, top_w)
    xe = x.astype(jnp.float32)
    h = jnp.einsum("bsd,edf->bsef", xe, p["wi"].astype(jnp.float32))
    g = jnp.einsum("bsd,edf->bsef", xe, p["wg"].astype(jnp.float32))
    h = jax.nn.silu(g) * h
    y = jnp.einsum("bsef,efd->bsed", h, p["wo"].astype(jnp.float32))
    out = jnp.einsum("bsed,bse->bsd", y, combine)
    if e.num_shared_experts:
        hs = jax.nn.silu(xe @ p["shared_wg"].astype(jnp.float32)) \
            * (xe @ p["shared_wi"].astype(jnp.float32))
        out = out + hs @ p["shared_wo"].astype(jnp.float32)
    return out.astype(x.dtype)
