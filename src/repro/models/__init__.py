from .config import ModelConfig, MoEConfig, SSMConfig
from .transformer import (decode_step, forward, init_cache, init_params,
                          lm_loss)

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "decode_step", "forward",
           "init_cache", "init_params", "lm_loss"]
