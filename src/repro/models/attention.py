"""GQA attention: full / sliding-window / alternating patterns, logit
softcap, QK-norm, QKV bias, RoPE; memory-bounded chunked prefill and
single-token cached decode.

Memory discipline: scores are never materialized (B, H, S, S) — the query axis
is chunked with ``lax.scan`` so the live intermediate is (B, H, cq, S_kv),
which is what makes the 32k prefill cells compile within HBM on the production
mesh (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from .config import ModelConfig
from .layers import dense_init, rms_norm, rotary, softcap

NEG = -2.3819763e38  # large negative for masked logits (bf16-safe)


def attn_init(key, cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd()
    dt = cfg.pdtype()
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, kv * hd, dt),
        "wv": dense_init(ks[2], d, kv * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def _project_qkv(p: dict, cfg: ModelConfig, x: jnp.ndarray, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd()
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    v = constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _expand_kv(x: jnp.ndarray, h: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, H, hd) by group repetition.

    GQA computes with KV heads repeated to the full head count.  This is the
    TP-friendly layout: the head axis (divisible by the model axis for every
    assigned arch) shards cleanly, whereas the (KV, G) split (e.g. grok's
    8×6 over a 16-way axis) cannot propagate sharding and replicates the
    score tensor.  Exact — repetition does not change the math."""
    b, s, kv, hd = x.shape
    if kv == h:
        return x
    return jnp.repeat(x, h // kv, axis=2)


def _masked_attend(q, k, v, q_pos, k_pos, cfg: ModelConfig,
                   window: Optional[int]):
    """q: (B, cq, H, hd); k/v: (B, S, H, hd); positions 1-D per axis.
    Returns (B, cq, H, hd)."""
    scale = cfg.hd() ** -0.5
    scores = jnp.einsum("bqhe,bshe->bhqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = constrain(scores, ("batch", "heads", None, None))
    scores = softcap(scores, cfg.attn_logit_softcap)
    mask = k_pos[None, :] <= q_pos[:, None]                 # causal
    if window is not None:
        mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
    scores = jnp.where(mask[None, None], scores, NEG)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshe->bqhe", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def attn_forward(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                 positions: jnp.ndarray, pattern: str) -> jnp.ndarray:
    """Full-sequence (training / prefill) path with q-chunking."""
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd()
    window = cfg.sliding_window if pattern == "local" else None
    q, k, v = _project_qkv(p, cfg, x, positions)
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)

    # positions: (S,) shared across the batch
    cq = cfg.q_chunk if (s % cfg.q_chunk == 0 and s > cfg.q_chunk) else s
    if cq == s:
        out = _masked_attend(q, k, v, positions, positions, cfg, window)
    else:
        nchunks = s // cq
        qc = q.reshape(b, nchunks, cq, h, hd).transpose(1, 0, 2, 3, 4)
        pc = positions.reshape(nchunks, cq)

        def body(_, args):
            qi, pi = args
            oi = _masked_attend(qi, k, v, pi, positions, cfg, window)
            return None, oi

        _, outs = jax.lax.scan(body, None, (qc, pc))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    out = out.reshape(b, s, h * hd)
    out = constrain(out, ("batch", "seq", "heads"))
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# Cached decode
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KVCacheSpec:
    max_seq: int

    def init(self, cfg: ModelConfig, batch: int, n_attn_layers: int,
             dtype=None) -> dict:
        kv, hd = cfg.num_kv_heads, cfg.hd()
        dt = dtype or cfg.cdtype()
        shape = (n_attn_layers, batch, self.max_seq, kv, hd)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
                "pos": jnp.zeros((), jnp.int32)}


def attn_decode(p: dict, cfg: ModelConfig, x: jnp.ndarray, cache_k, cache_v,
                cache_kpos, pos: jnp.ndarray, pattern: str):
    """One-token decode with a ring-buffer KV cache.

    x: (B, 1, d); cache_k/v: (B, S_cap, KV, hd); cache_kpos: (S_cap,) absolute
    position of each cache entry (-1 = empty); pos (): tokens already decoded.
    Sliding-window layers allocate S_cap = window and wrap — the property that
    bounds long_500k memory on SWA archs.  Keys are stored post-RoPE at their
    absolute position (RoPE's relative property keeps q·k correct under ring
    overwrite).  Returns (out, new_k, new_v, new_kpos)."""
    b, one, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd()
    g = h // kv
    window = cfg.sliding_window if pattern == "local" else None
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    s_cap = cache_k.shape[1]
    widx = pos % s_cap
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), widx, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), widx, axis=1)
    cache_kpos = jax.lax.dynamic_update_slice_in_dim(
        cache_kpos, jnp.full((1,), pos, jnp.int32), widx, axis=0)
    cache_k = constrain(cache_k, ("batch", "kv_seq", "kv_heads", "head_dim"))
    cache_v = constrain(cache_v, ("batch", "kv_seq", "kv_heads", "head_dim"))
    # decode is bandwidth-bound: keep KV *grouped* (no head expansion — the
    # training path expands for TP-friendly sharding, but here that would
    # multiply cache reads by h/kv and force a reshard copy of the cache)
    g = h // kv
    qg = q.reshape(b, 1, kv, g, hd)
    # align q's layout with the cache (kv_heads/head_dim on the model axis):
    # resharding q is a few KB; misalignment makes GSPMD all-gather the
    # ENTIRE K cache per layer per token (measured 2.1GB/layer on gemma3
    # decode_32k — §Perf HC3)
    qg = constrain(qg, ("batch", None, "kv_heads", None, "head_dim"))
    scale = hd ** -0.5
    scores = jnp.einsum("bqnge,bsne->bngqs", qg, cache_k.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    scores = constrain(scores, ("batch", None, None, None, "kv_seq"))
    scores = softcap(scores, cfg.attn_logit_softcap)
    mask = (cache_kpos >= 0) & (cache_kpos <= pos)
    if window is not None:
        mask = mask & (pos - cache_kpos < window)
    scores = jnp.where(mask[None, None, None, None], scores, NEG)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngqs,bsne->bqnge", w.astype(cache_v.dtype),
                     cache_v).astype(x.dtype)
    out = out.reshape(b, 1, h * hd)
    return out @ p["wo"], cache_k, cache_v, cache_kpos
