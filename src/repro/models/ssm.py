"""Mamba2 (SSD — state-space duality) block, chunked scan + recurrent decode.

Faithful to the SSD formulation (arXiv:2405.21060 §6): scalar-per-head decay
A, per-token dt via softplus, shared B/C across head channels (like GQA with
one KV group).  The chunked algorithm computes the intra-chunk term as a
masked quasi-attention matmul and carries inter-chunk SSM states with a
``lax.scan`` — the TPU-friendly dual form, which is exactly why Mamba2 is
MXU-amenable while Mamba1 is not.

Decode is the recurrent dual: constant-size state
(B, H, P, N) updated per token — the property that makes the long_500k cells
feasible for ssm/hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from .config import ModelConfig
from .layers import dense_init, rms_norm


def ssm_init(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nheads = d_in // s.head_dim
    dt = cfg.pdtype()
    ks = jax.random.split(key, 8)
    # in_proj emits [z (gate), x, B, C, dt]
    p = {
        "in_z": dense_init(ks[0], d, d_in, dt),
        "in_x": dense_init(ks[1], d, d_in, dt),
        "in_B": dense_init(ks[2], d, s.d_state, dt),
        "in_C": dense_init(ks[3], d, s.d_state, dt),
        "in_dt": dense_init(ks[4], d, nheads, dt),
        "dt_bias": jnp.zeros((nheads,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(dt),
        "D": jnp.ones((nheads,), dt),
        "conv_w": (jax.random.normal(ks[5], (s.d_conv, d_in), jnp.float32)
                   * (1.0 / jnp.sqrt(s.d_conv))).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "norm": jnp.zeros((d_in,), dt),
        "out": dense_init(ks[6], d_in, d, dt),
    }
    return p


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal conv over seq. x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssm_forward(p: dict, cfg: ModelConfig, u: jnp.ndarray) -> jnp.ndarray:
    """Training/prefill path. u: (B, S, d_model)."""
    s = cfg.ssm
    bsz, S, d = u.shape
    d_in = s.expand * d
    H = d_in // s.head_dim
    P, N = s.head_dim, s.d_state

    z = u @ p["in_z"]
    x = _causal_conv(u @ p["in_x"], p["conv_w"], p["conv_b"])
    Bm = (u @ p["in_B"]).astype(jnp.float32)                     # (B,S,N)
    Cm = (u @ p["in_C"]).astype(jnp.float32)                     # (B,S,N)
    dt = jax.nn.softplus((u @ p["in_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,)
    xh = x.reshape(bsz, S, H, P).astype(jnp.float32)
    x = constrain(x, ("batch", "seq", "ff"))

    L = s.chunk if (S % s.chunk == 0 and S > s.chunk) else S
    nc = S // L
    # reshape to chunks
    xc = xh.reshape(bsz, nc, L, H, P)
    Bc = Bm.reshape(bsz, nc, L, N)
    Cc = Cm.reshape(bsz, nc, L, N)
    dtc = dt.reshape(bsz, nc, L, H)

    dA = dtc * A                                                  # (B,nc,L,H)
    cum = jnp.cumsum(dA, axis=2)                                  # (B,nc,L,H)

    # intra-chunk: Y_intra[t] = sum_{r<=t} C_t·B_r * exp(cum_t - cum_r) dt_r x_r
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]           # (B,nc,L,L,H)
    causal = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp: non-causal entries have seg > 0 (A < 0 makes cum
    # decreasing), and exp overflow would poison the backward with inf*0=nan
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    # §Perf HC2: the (B,nc,L,L,H) tensors dominate the memory roofline term
    # (traffic ∝ S·L·H); they carry decay factors in [0,1] and similarity
    # weights — the model's compute dtype (bf16 on the production configs)
    # is ample, and halves the dominant traffic
    wdt = cfg.cdtype()
    decay = jnp.exp(seg).astype(wdt)
    cb = jnp.einsum("bctn,bcrn->bctr", Cc, Bc).astype(wdt)
    w = cb[..., None] * decay                                     # (B,nc,L,L,H)
    y_intra = jnp.einsum("bctrh,bcrh,bcrhp->bcthp", w,
                         dtc.astype(wdt), xc.astype(wdt),
                         preferred_element_type=jnp.float32)

    # chunk-final states: S_c = sum_r exp(cum_L - cum_r) dt_r B_r x_r^T
    decay_tail = jnp.exp(cum[:, :, -1:, :] - cum)                 # (B,nc,L,H)
    state_c = jnp.einsum("bcrh,bcrh,bcrn,bcrhp->bchnp",
                         decay_tail, dtc, Bc, xc)                 # per-chunk
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # (B,nc,H)

    def carry_body(state, args):
        st_c, dec_c = args                                        # (B,H,N,P),(B,H)
        out_state = state                                         # state BEFORE chunk
        new = state * dec_c[..., None, None] + st_c
        return new, out_state

    st = jnp.moveaxis(state_c, 1, 0)                              # (nc,B,H,N,P)
    dc = jnp.moveaxis(chunk_decay, 1, 0)                          # (nc,B,H)
    init = jnp.zeros((bsz, H, N, P), jnp.float32)
    _, prev_states = jax.lax.scan(carry_body, init, (st, dc))     # (nc,B,H,N,P)
    prev_states = jnp.moveaxis(prev_states, 0, 1)                 # (B,nc,H,N,P)

    # inter-chunk: Y_inter[t] = C_t · (exp(cum_t) * prev_state)
    y_inter = jnp.einsum("bctn,bcth,bchnp->bcthp",
                         Cc, jnp.exp(cum), prev_states)

    y = (y_intra + y_inter).reshape(bsz, S, H, P)
    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, S, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    return y @ p["out"]


def ssm_decode(p: dict, cfg: ModelConfig, u: jnp.ndarray, conv_buf, state):
    """Recurrent one-token step.

    u: (B, 1, d); conv_buf: (B, d_conv-1, d_in) trailing inputs;
    state: (B, H, N, P).  Returns (y, conv_buf', state')."""
    s = cfg.ssm
    bsz, _, d = u.shape
    d_in = s.expand * d
    H = d_in // s.head_dim
    P, N = s.head_dim, s.d_state

    z = u[:, 0] @ p["in_z"]
    x_lin = u[:, 0] @ p["in_x"]                                  # (B,d_in)
    window = jnp.concatenate([conv_buf, x_lin[:, None, :]], axis=1)
    w = p["conv_w"]
    xconv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                       w.astype(jnp.float32))
    x = jax.nn.silu(xconv + p["conv_b"].astype(jnp.float32))
    new_buf = window[:, 1:, :]

    Bm = (u[:, 0] @ p["in_B"]).astype(jnp.float32)               # (B,N)
    Cm = (u[:, 0] @ p["in_C"]).astype(jnp.float32)
    dt = jax.nn.softplus((u[:, 0] @ p["in_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x.reshape(bsz, H, P)
    dA = jnp.exp(dt * A)                                         # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, Bm, xh)
    state = state * dA[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm, state)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    return (y @ p["out"])[:, None, :], new_buf, state


def ssm_cache_init(cfg: ModelConfig, batch: int, n_ssm_layers: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    return {
        "conv": jnp.zeros((n_ssm_layers, batch, s.d_conv - 1, d_in),
                          cfg.cdtype()),
        "state": jnp.zeros((n_ssm_layers, batch, H, s.d_state, s.head_dim),
                           jnp.float32),
    }
