"""Shared neural layers (pure-JAX, functional param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rotary(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """RoPE over the last dim. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def mlp_init(key, cfg, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.pdtype()
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "glu":
        return {"wi": dense_init(ks[0], d, f, dt),
                "wg": dense_init(ks[1], d, f, dt),
                "wo": dense_init(ks[2], f, d, dt)}
    return {"wi": dense_init(ks[0], d, f, dt),
            "wo": dense_init(ks[2], f, d, dt)}


def mlp_apply(params: dict, x: jnp.ndarray, mlp_type: str) -> jnp.ndarray:
    h = x @ params["wi"]
    if mlp_type == "glu":
        g = x @ params["wg"]
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, ("batch", "seq", "ff"))
    return h @ params["wo"]
