"""Model configuration for every assigned architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # sharding strategy: 'expert' shards the expert dim over the model axis
    # (needs num_experts % axis == 0), 'ff' tensor-shards inside each expert
    shard_mode: str = "expert"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64           # Mamba2 P (channels per SSM head)
    chunk: int = 128             # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | ssm | hybrid | moe | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None            # default d_model // num_heads
    # layer pattern, repeated to cover num_layers: entries 'full' | 'local' | 'ssm'
    layer_pattern: tuple = ("full",)
    sliding_window: Optional[int] = None
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mlp_type: str = "glu"                     # 'glu' (SwiGLU) | 'gelu'
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 0                # zamba2: shared block period
    input_mode: str = "tokens"                # 'tokens' | 'embeddings'
    tie_embeddings: bool = True
    embed_scale: bool = False                 # gemma-style sqrt(d) scaling
    rms_eps: float = 1e-6
    # precision policy
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # attention chunking (memory-bounded prefill/training)
    q_chunk: int = 1024
    # remat policy: 'none' | 'block' (checkpoint each layer block)
    remat: str = "block"
    # which shapes support sub-quadratic long context (DESIGN.md table)
    supports_long_context: bool = False

    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def pattern_for_layer(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def num_params_estimate(self) -> int:
        """Analytic parameter count (for 6ND roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd()
        n_attn_layers = sum(
            1 for i in range(self.num_layers)
            if self.pattern_for_layer(i) != "ssm")
        n_ssm_layers = self.num_layers - n_attn_layers
        attn = n_attn_layers * (
            d * hd * (self.num_heads + 2 * self.num_kv_heads)  # qkv
            + self.num_heads * hd * d)                          # out
        if self.moe:
            e = self.moe
            per_layer = (e.num_experts + e.num_shared_experts) \
                * 3 * d * e.d_ff_expert + d * e.num_experts
            mlp = self.num_layers * per_layer
        else:
            mult = 3 if self.mlp_type == "glu" else 2
            mlp = n_attn_layers * mult * d * self.d_ff
        if self.ssm:
            s = self.ssm
            d_in = s.expand * d
            per = (d * (2 * d_in + 2 * s.d_state + d_in // s.head_dim)
                   + d_in * d)
            ssm = n_ssm_layers * per
        else:
            ssm = 0
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        norms = 2 * self.num_layers * d + d
        if self.shared_attn_every:
            shared = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                + self.num_heads * hd * d + (3 * d * self.d_ff if self.d_ff else 0)
        else:
            shared = 0
        return attn + mlp + ssm + embed + norms + shared

    def active_params_estimate(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.moe:
            return self.num_params_estimate()
        e = self.moe
        total = self.num_params_estimate()
        all_expert = self.num_layers * e.num_experts * 3 * self.d_model \
            * e.d_ff_expert
        active_expert = self.num_layers * (e.top_k + e.num_shared_experts) \
            * 3 * self.d_model * e.d_ff_expert
        return total - all_expert + active_expert
