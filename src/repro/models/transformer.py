"""Generic decoder-only LM assembled from :class:`ModelConfig`.

Covers all ten assigned architectures through composition:
* attention patterns per layer ("full" / "local"), alternating via
  ``layer_pattern`` (gemma2/gemma3), SWA (danube), GQA everywhere;
* MoE MLPs (grok, moonshot) with capacity dispatch;
* Mamba2 SSD blocks ("ssm" pattern, mamba2) and the Zamba2 hybrid
  (SSM backbone + weight-shared attention block every N layers);
* token or precomputed-embedding inputs (musicgen/chameleon frontends are
  stubs per the assignment).

HLO discipline: layers are scanned over *pattern periods* — parameters are
stacked per period-slot and the body replays the slot sequence — so the
compiled module is O(period) in size, not O(num_layers).  ``remat='block'``
checkpoints each period (the activation policy the dry-run assumes).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from .attention import attn_decode, attn_forward, attn_init
from .config import ModelConfig
from .layers import mlp_apply, mlp_init, rms_norm, softcap
from .moe import moe_apply, moe_init
from .ssm import ssm_cache_init, ssm_decode, ssm_forward, ssm_init


# ---------------------------------------------------------------------------
# Block specs
# ---------------------------------------------------------------------------

def block_kinds(cfg: ModelConfig) -> list[str]:
    """Per-layer kind sequence ('full' | 'local' | 'ssm'), len num_layers."""
    return [cfg.pattern_for_layer(i) for i in range(cfg.num_layers)]


def _period(cfg: ModelConfig) -> int:
    return len(cfg.layer_pattern)


def _num_periods(cfg: ModelConfig) -> tuple[int, int]:
    p = _period(cfg)
    return cfg.num_layers // p, cfg.num_layers % p


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype()
    d = cfg.d_model
    if kind == "ssm":
        return {"norm": jnp.zeros((d,), dt), "ssm": ssm_init(ks[0], cfg)}
    p = {"norm1": jnp.zeros((d,), dt), "attn": attn_init(ks[0], cfg),
         "norm2": jnp.zeros((d,), dt)}
    if cfg.moe is not None:
        p["moe"] = moe_init(ks[1], cfg)
    elif cfg.d_ff:
        p["mlp"] = mlp_init(ks[1], cfg)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    nper, ntail = _num_periods(cfg)
    pat = cfg.layer_pattern
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    dt = cfg.pdtype()
    d = cfg.d_model

    params["embed"] = (jax.random.normal(ks[0], (cfg.vocab_size, d),
                                         jnp.float32) * 0.02).astype(dt)
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(ks[1], (cfg.vocab_size, d),
                                               jnp.float32) * 0.02).astype(dt)
    params["final_norm"] = jnp.zeros((d,), dt)

    def init_slot(kind, key, n):
        return jax.vmap(lambda k: _block_init(k, cfg, kind))(
            jax.random.split(key, n))

    if nper > 0:
        params["period"] = {
            f"s{j}": init_slot(pat[j], jax.random.fold_in(ks[2], j), nper)
            for j in range(len(pat))}
    tail_ks = jax.random.split(ks[3], max(ntail, 1))
    params["tail"] = [
        _block_init(tail_ks[i], cfg, pat[i % len(pat)])
        for i in range(ntail)]

    if cfg.shared_attn_every:
        # Zamba2: one weight-shared attention+MLP block
        params["shared"] = {
            "norm1": jnp.zeros((d,), dt),
            "attn": attn_init(ks[4], cfg),
            "norm2": jnp.zeros((d,), dt),
            "mlp": mlp_init(ks[5], cfg),
        }
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _apply_block(p: dict, cfg: ModelConfig, kind: str, x, positions):
    if kind == "ssm":
        return x + ssm_forward(p["ssm"], cfg, rms_norm(x, p["norm"],
                                                       cfg.rms_eps)), 0.0
    h = attn_forward(p["attn"], cfg, rms_norm(x, p["norm1"], cfg.rms_eps),
                     positions, kind)
    x = x + h
    aux = 0.0
    if cfg.moe is not None:
        m, aux = moe_apply(p["moe"], cfg, rms_norm(x, p["norm2"], cfg.rms_eps),
                           capacity_factor=cfg.moe.capacity_factor)
        x = x + m
    elif cfg.d_ff:
        x = x + mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.rms_eps),
                          cfg.mlp_type)
    return x, aux


def _apply_shared(params: dict, cfg: ModelConfig, x, positions):
    sp = params["shared"]
    x = x + attn_forward(sp["attn"], cfg,
                         rms_norm(x, sp["norm1"], cfg.rms_eps), positions,
                         "full")
    x = x + mlp_apply(sp["mlp"], rms_norm(x, sp["norm2"], cfg.rms_eps),
                      cfg.mlp_type)
    return x


def forward(params: dict, cfg: ModelConfig, tokens=None, embeds=None):
    """Returns (logits (B,S,V), aux_loss)."""
    if cfg.input_mode == "tokens":
        x = params["embed"][tokens].astype(cfg.cdtype())
        b, s = tokens.shape
    else:
        x = embeds.astype(cfg.cdtype())
        b, s, _ = embeds.shape
    if cfg.embed_scale:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.arange(s, dtype=jnp.int32)
    pat = cfg.layer_pattern
    nper, ntail = _num_periods(cfg)

    def period_body(carry, pparams):
        x, aux = carry
        for j, kind in enumerate(pat):
            x, a = _apply_block(pparams[f"s{j}"], cfg, kind, x, positions)
            aux = aux + a
        if cfg.shared_attn_every:
            x = _apply_shared(params, cfg, x, positions)
        # period-boundary carry: 'seq_act' maps to the model axis on the
        # production mesh (Megatron-SP) so the remat-saved carry stack is
        # seq-sharded — 16x less HBM for the 64-layer archs; the all-gather
        # it implies at the next period start is the standard SP trade.
        x = constrain(x, ("batch", "seq_act", "embed"))
        return (x, aux), None

    body = period_body
    if cfg.remat == "block":
        body = jax.checkpoint(period_body, prevent_cse=False)

    aux = jnp.zeros((), jnp.float32)
    if nper > 0:
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["period"])
    for i in range(ntail):
        x, a = _apply_block(params["tail"][i], cfg, pat[i % len(pat)], x,
                            positions)
        aux = aux + a

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(x.dtype))
    logits = softcap(logits, cfg.final_logit_softcap)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return logits, aux


def lm_loss(params: dict, cfg: ModelConfig, tokens=None, embeds=None,
            labels=None, loss_chunk: int = 512):
    """Next-token cross-entropy, seq-chunked so fp32 LSE never materializes
    the full (B,S,V) in fp32. Returns scalar loss."""
    logits, aux = forward(params, cfg, tokens=tokens, embeds=embeds)
    b, s, v = logits.shape
    if labels is None:
        labels = jnp.roll(tokens, -1, axis=1)
    c = loss_chunk if (s % loss_chunk == 0 and s > loss_chunk) else s
    nch = s // c
    lg = logits.reshape(b, nch, c, v).transpose(1, 0, 2, 3)
    lb = labels.reshape(b, nch, c).transpose(1, 0, 2)

    def body(acc, args):
        lgi, lbi = args
        lgi = lgi.astype(jnp.float32)
        lse = jax.nn.logsumexp(lgi, axis=-1)
        gold = jnp.take_along_axis(lgi, lbi[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (lg, lb))
    return total / (b * s) + aux


# ---------------------------------------------------------------------------
# Cached decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Per-slot caches, stacked over periods (mirrors the scan layout)."""
    kinds = cfg.layer_pattern
    nper, ntail = _num_periods(cfg)
    kv, hd = cfg.num_kv_heads, cfg.hd()
    dt = cfg.cdtype()

    def slot_cache(kind, n):
        if kind == "ssm":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            H = d_in // s.head_dim
            return {"conv": jnp.zeros((n, batch, s.d_conv - 1, d_in), dt),
                    "state": jnp.zeros((n, batch, H, s.d_state, s.head_dim),
                                       jnp.float32)}
        # local layers only need window-sized ring KV; global layers need full
        seq = max_seq if kind == "full" else min(
            max_seq, (cfg.sliding_window or max_seq))
        return {"k": jnp.zeros((n, batch, seq, kv, hd), dt),
                "v": jnp.zeros((n, batch, seq, kv, hd), dt),
                "kpos": jnp.full((n, seq), -1, jnp.int32)}

    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if nper > 0:
        cache["period"] = {f"s{j}": slot_cache(kinds[j], nper)
                           for j in range(len(kinds))}
    cache["tail"] = [slot_cache(kinds[i % len(kinds)], 1)
                     for i in range(ntail)]
    if cfg.shared_attn_every:
        cache["shared"] = {
            "k": jnp.zeros((nper, batch, max_seq, kv, hd), dt),
            "v": jnp.zeros((nper, batch, max_seq, kv, hd), dt),
            "kpos": jnp.full((nper, max_seq), -1, jnp.int32)}
    return cache


def _decode_block(p, cfg: ModelConfig, kind: str, x, cache_slot, pos):
    if kind == "ssm":
        h, conv, state = ssm_decode(p["ssm"], cfg,
                                    rms_norm(x, p["norm"], cfg.rms_eps),
                                    cache_slot["conv"], cache_slot["state"])
        return x + h, {"conv": conv, "state": state}
    h, ck, cv, ckp = attn_decode(p["attn"], cfg,
                                 rms_norm(x, p["norm1"], cfg.rms_eps),
                                 cache_slot["k"], cache_slot["v"],
                                 cache_slot["kpos"], pos, kind)
    x = x + h
    if cfg.moe is not None:
        m, _ = moe_apply(p["moe"], cfg, rms_norm(x, p["norm2"], cfg.rms_eps),
                         capacity_factor=cfg.moe.capacity_factor)
        x = x + m
    elif cfg.d_ff:
        x = x + mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.rms_eps),
                          cfg.mlp_type)
    return x, {"k": ck, "v": cv, "kpos": ckp}


def decode_step(params: dict, cfg: ModelConfig, cache: dict, tokens=None,
                embeds=None):
    """One-token decode. tokens: (B, 1) int32 / embeds: (B, 1, d).
    Returns (logits (B, 1, V), new_cache)."""
    pos = cache["pos"]
    if cfg.input_mode == "tokens":
        x = params["embed"][tokens].astype(cfg.cdtype())
    else:
        x = embeds.astype(cfg.cdtype())
    if cfg.embed_scale:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    pat = cfg.layer_pattern
    nper, ntail = _num_periods(cfg)
    new_cache: dict[str, Any] = {"pos": pos + 1}

    if nper > 0:
        def body(carry, xs):
            x = carry
            pparams, pcache = xs
            out_cache = {}
            for j, kind in enumerate(pat):
                x, cs = _decode_block(pparams[f"s{j}"], cfg, kind, x,
                                      pcache[f"s{j}"], pos)
                out_cache[f"s{j}"] = cs
            return x, out_cache

        if cfg.shared_attn_every:
            # shared attn needs its own (non-scanned) KV cache; run periods
            # unrolled-with-fori is wrong here, so scan slots only and apply
            # shared block via a second pass — for zamba2 we instead unroll
            # periods (few: <=7) keeping HLO modest.
            x2 = x
            out_period = {}
            shared_cache = cache["shared"]
            for t in range(nper):
                pparams = jax.tree.map(lambda v, t=t: v[t], params["period"])
                pcache = jax.tree.map(lambda v, t=t: v[t], cache["period"])
                oc = {}
                for j, kind in enumerate(pat):
                    x2, cs = _decode_block(pparams[f"s{j}"], cfg, kind, x2,
                                           pcache[f"s{j}"], pos)
                    oc[f"s{j}"] = cs
                sp = params["shared"]
                h, sk, sv, skp = attn_decode(
                    sp["attn"], cfg, rms_norm(x2, sp["norm1"], cfg.rms_eps),
                    shared_cache["k"][t], shared_cache["v"][t],
                    shared_cache["kpos"][t], pos, "full")
                x2 = x2 + h
                x2 = x2 + mlp_apply(sp["mlp"],
                                    rms_norm(x2, sp["norm2"], cfg.rms_eps),
                                    cfg.mlp_type)
                shared_cache = {
                    "k": shared_cache["k"].at[t].set(sk),
                    "v": shared_cache["v"].at[t].set(sv),
                    "kpos": shared_cache["kpos"].at[t].set(skp)}
                out_period[t] = oc
            x = x2
            new_cache["shared"] = shared_cache
            # restack per-slot caches
            new_cache["period"] = jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0),
                *[out_period[t] for t in range(nper)])
        else:
            x, period_cache = jax.lax.scan(
                body, x, (params["period"], cache["period"]))
            new_cache["period"] = period_cache

    new_tail = []
    for i in range(ntail):
        tp = params["tail"][i]
        tc = jax.tree.map(lambda v: v[0], cache["tail"][i])
        x, cs = _decode_block(tp, cfg, pat[i % len(pat)], x, tc, pos)
        new_tail.append(jax.tree.map(lambda v: v[None], cs))
    new_cache["tail"] = new_tail

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(x.dtype))
    logits = softcap(logits, cfg.final_logit_softcap)
    return logits, new_cache
