"""Train-state pytree: params + optimizer moments + data-pipeline cursor.

Registered as a pytree so the whole state flows through pjit, checkpointing
and resharding as one object."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: dict
    step: jnp.ndarray                  # global step (int32)
    data_cursor: jnp.ndarray           # data-pipeline position (int64-ish)
    rng: jax.Array

    @classmethod
    def create(cls, params, opt, rng):
        return cls(params=params, opt=opt,
                   step=jnp.zeros((), jnp.int32),
                   data_cursor=jnp.zeros((), jnp.int32),
                   rng=rng)
