"""Train-step builders: pjit-automatic DP/TP and the explicit shard_map
variant with int8 error-feedback gradient compression.

Distributed-optimization features:
* microbatch gradient accumulation (scan) — decouples global batch from
  per-device memory,
* bf16 gradient reduction by default (params/compute bf16 ⇒ AD emits bf16
  grads; the cross-replica reduction XLA inserts moves half the bytes),
* opt-in int8+error-feedback compressed all-reduce (shard_map DP axis):
  grads are quantized per-tensor to int8 with a shared scale, psum'd in int8's
  f32 carrier, dequantized, and the quantization error is fed back next step
  (1-bit-Adam-style memory), cutting DP collective bytes ~4x vs bf16,
* remat policy comes from the model config ('block' checkpoints each pattern
  period inside the layer scan).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import lm_loss
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_update
from .train_state import TrainState


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    accum_dtype: str = "float32"        # bf16 halves the accumulator HBM for
                                        # 100B+ archs (documented trade-off)
    compress_grads: bool = False        # int8 error-feedback DP all-reduce
    dp_axis: str = "data"               # shard_map axis for compressed mode


def _loss_fn(params, cfg: ModelConfig, batch):
    if cfg.input_mode == "tokens":
        return lm_loss(params, cfg, tokens=batch["tokens"],
                       labels=batch.get("labels"))
    return lm_loss(params, cfg, embeds=batch["embeds"],
                   labels=batch["labels"])


def build_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                     step_cfg: TrainStepConfig = TrainStepConfig()) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    The returned function is pjit-ready: shard specs are applied by the
    launcher via in_shardings/out_shardings + logical rules context."""

    def grads_of(params, batch):
        if step_cfg.microbatches <= 1:
            loss, grads = jax.value_and_grad(_loss_fn)(params, cfg, batch)
            return loss, grads

        adt = jnp.dtype(step_cfg.accum_dtype)

        def mb(carry, mb_batch):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(_loss_fn)(params, cfg, mb_batch)
            grad_acc = jax.tree.map(
                lambda a, g: a + g.astype(adt), grad_acc, grads)
            return (loss_acc + loss, grad_acc), None

        def split(x):
            return x.reshape((step_cfg.microbatches,
                              x.shape[0] // step_cfg.microbatches)
                             + x.shape[1:])

        mb_batches = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
        (loss, grads), _ = jax.lax.scan(mb, (jnp.zeros((), jnp.float32), zero),
                                        mb_batches)
        inv = 1.0 / step_cfg.microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = grads_of(state.params, batch)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics["loss"] = loss
        new_state = TrainState(
            params=new_params, opt=new_opt, step=state.step + 1,
            data_cursor=state.data_cursor + 1, rng=state.rng)
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Compressed-gradient DP (shard_map explicit collectives)
# ---------------------------------------------------------------------------

def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: Any, errors: Any, axis: str):
    """int8 error-feedback all-reduce over a shard_map axis.

    Each replica adds its residual error, quantizes to int8, psums the int8
    payload (as f32 carrier for the reduction) and the per-tensor scales, and
    keeps the new quantization error for the next step."""
    n = jax.lax.psum(1, axis)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        err = g32 - dequantize_int8(q, scale)
        summed = jax.lax.psum(q.astype(jnp.float32) * scale, axis)
        return summed / n, err

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(errors)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (td.unflatten([o[0] for o in outs]),
            td.unflatten([o[1] for o in outs]))


def build_compressed_dp_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                             mesh, dp_axis: str = "data"):
    """shard_map train step: batch sharded over ``dp_axis``, params
    replicated, gradient all-reduce int8-compressed with error feedback.

    State gains an ``err`` pytree (the feedback memory)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def local_step(params, opt, err, batch):
        loss, grads = jax.value_and_grad(_loss_fn)(params, cfg, batch)
        grads, err = compressed_psum(grads, err, dp_axis)
        loss = jax.lax.pmean(loss, dp_axis)
        new_params, new_opt, metrics = adamw_update(opt_cfg, params, grads,
                                                    opt)
        metrics["loss"] = loss
        return new_params, new_opt, err, metrics

    batch_spec = {"tokens": P(dp_axis), "labels": P(dp_axis)} \
        if cfg.input_mode == "tokens" else \
        {"embeds": P(dp_axis), "labels": P(dp_axis)}
    rep = P()
    fn = shard_map(
        local_step, mesh=mesh,
        in_specs=(rep, rep, rep, batch_spec),
        out_specs=(rep, rep, rep, rep),
        check_rep=False)
    return jax.jit(fn)
