"""AdamW from scratch: dtype-configurable moments (bf16 moments make the
314B-param cell fit one pod's HBM — see DESIGN.md §5), decoupled weight
decay, global-norm clipping, warmup+cosine schedule."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    m_dtype: str = "float32"      # bf16 for ≥100B archs
    v_dtype: str = "float32"


def warmup_cosine(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr_peak * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(cfg: AdamWConfig, params: Any) -> dict:
    m = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.dtype(cfg.m_dtype)),
                     params)
    v = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.dtype(cfg.v_dtype)),
                     params)
    return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: dict) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = warmup_cosine(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (delta + cfg.weight_decay * p32)
        return (p32.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
