from .optimizer import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from .step import TrainStepConfig, build_train_step
from .train_state import TrainState

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "warmup_cosine",
           "TrainStepConfig", "build_train_step", "TrainState"]
