"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run sets ``xla_force_host_platform_device_count`` before any jax
initialization; smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """jax.make_mesh when device count matches; explicit slice otherwise
    (lets CI build tiny meshes on 8 fake devices)."""
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return Mesh(np.array(devs[:n]).reshape(shape), axes)
