import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, with zero device allocation:
  * compiled.memory_analysis()  — proves the cell fits per-device HBM,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline,
  * collective bytes parsed from the per-device compiled HLO,
  * the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out experiments/dryrun.json
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k --mesh single
CI smoke (8 fake devices):
  REPRO_DRYRUN_XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k \
      --mesh tiny --smoke-config
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, SHAPES, get_config, get_shape
from ..configs.shapes import ShapeConfig
from ..dist.sharding import logical_axis_rules
from ..models import init_cache, init_params, forward
from ..models.config import ModelConfig
from ..roofline.analysis import roofline_terms
from ..roofline.hlo_analyzer import analyze as analyze_hlo
from ..roofline.hw import TPU_V5E
from ..serving.decode import build_serve_step
from ..training import AdamWConfig, TrainState, adamw_init, build_train_step
from .inputs import input_specs
from .mesh import make_mesh, make_production_mesh
from .shardspec import (batch_logical_axes, cache_logical_axes,
                        moe_rules_patch, param_logical_axes, rules_for,
                        tree_shardings)

BIG_PARAM_THRESHOLD = 50e9    # bf16 optimizer moments above this


def _mesh_for(kind: str):
    if kind == "single":
        return make_production_mesh(multi_pod=False)
    if kind == "multi":
        return make_production_mesh(multi_pod=True)
    if kind == "tiny":
        return make_mesh((2, 2), ("data", "model"))
    if kind == "tiny_multi":
        return make_mesh((2, 2, 2), ("pod", "data", "model"))
    raise ValueError(kind)


def _opt_config(cfg: ModelConfig) -> AdamWConfig:
    big = cfg.num_params_estimate() > BIG_PARAM_THRESHOLD
    return AdamWConfig(m_dtype="bfloat16" if big else "float32",
                       v_dtype="bfloat16" if big else "float32")


def model_flops_for(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.active_params_estimate()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: one token


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Build + lower the cell's step function. Returns (lowered, chips)."""
    rules = moe_rules_patch(cfg, rules_for(cfg, shape, mesh))
    specs = input_specs(cfg, shape)
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))

    with mesh, logical_axis_rules(rules, mesh):
        if shape.kind == "train":
            opt_cfg = _opt_config(cfg)
            from ..training import TrainStepConfig
            nparams = cfg.num_params_estimate()
            # microbatch policy (validated against per-cell peak HBM):
            # >100B: 8; >3B or SSM/hybrid (SSD chunk tensors ∝ tokens): 4
            if nparams > 100e9:
                mb = 8
            elif nparams > 3e9 or cfg.ssm is not None:
                mb = 4
            else:
                mb = 1
            if shape.global_batch % mb:
                mb = 1
            accum = "bfloat16" if nparams > 100e9 else "float32"
            train_step = build_train_step(
                cfg, opt_cfg,
                TrainStepConfig(microbatches=mb, accum_dtype=accum))

            def make_state(key):
                params = init_params(key, cfg)
                return TrainState.create(params, adamw_init(opt_cfg, params),
                                         key)

            state_shapes = jax.eval_shape(make_state, jax.random.key(0))
            state_sh = tree_shardings(state_shapes, mesh, rules,
                                      param_logical_axes)
            batch_sh = tree_shardings(specs, mesh, rules, batch_logical_axes)
            lowered = jax.jit(
                train_step, in_shardings=(state_sh, batch_sh),
                donate_argnums=(0,)).lower(state_shapes, specs)
            return lowered, chips

        params_shapes = jax.eval_shape(
            lambda k: init_params(k, cfg), jax.random.key(0))
        params_sh = tree_shardings(params_shapes, mesh, rules,
                                   param_logical_axes)

        in_key = "tokens" if cfg.input_mode == "tokens" else "embeds"
        x_spec = specs[in_key]
        x_sh = tree_shardings({in_key: x_spec}, mesh, rules,
                              batch_logical_axes)[in_key]

        if shape.kind == "prefill":
            def prefill_step(params, x):
                kw = {in_key: x}
                logits, _ = forward(params, cfg, **kw)
                return logits[:, -1, :]

            lowered = jax.jit(prefill_step,
                              in_shardings=(params_sh, x_sh)).lower(
                params_shapes, x_spec)
            return lowered, chips

        # decode
        serve_step = build_serve_step(cfg)

        def decode_fn(params, cache, x):
            kw = {in_key: x}
            return serve_step(params, cache, **kw)

        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        cache_sh = tree_shardings(cache_shapes, mesh, rules,
                                  cache_logical_axes)
        lowered = jax.jit(decode_fn,
                          in_shardings=(params_sh, cache_sh, x_sh),
                          donate_argnums=(1,)).lower(
            params_shapes, cache_shapes, x_spec)
        return lowered, chips


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             smoke_config: bool = False) -> dict:
    cfg = get_config(arch, smoke=smoke_config)
    shape = get_shape(shape_name, smoke=smoke_config)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "kind": shape.kind}
    if shape_name == "long_500k" and not cfg.supports_long_context:
        rec["status"] = "skipped"
        rec["reason"] = ("pure full-attention arch: long_500k requires "
                         "sub-quadratic attention (DESIGN.md "
                         "§Arch-applicability)")
        return rec
    t0 = time.time()
    try:
        mesh = _mesh_for(mesh_kind)
        lowered, chips = lower_cell(cfg, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):   # older jax: one dict per device
            cost = cost[0] if cost else {}
        # trip-count-aware analysis (cost_analysis counts scan bodies once)
        hlo = analyze_hlo(compiled.as_text())
        coll = {k: float(v) for k, v in hlo.collective_bytes.items()}
        mf = model_flops_for(cfg, shape)
        terms = roofline_terms({"flops": hlo.flops,
                                "bytes accessed": hlo.bytes},
                               coll, chips, mf)
        rec.update({
            "status": "ok",
            "chips": chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            },
            "cost": {"flops_per_device": hlo.flops,
                     "bytes_per_device": hlo.bytes,
                     "xla_flops_per_device": float(cost.get("flops", 0.0)),
                     "xla_bytes_per_device": float(cost.get("bytes accessed",
                                                            0.0))},
            "collective_bytes": coll,
            "roofline": {
                "compute_s": terms.compute_s,
                "memory_s": terms.memory_s,
                "collective_s": terms.collective_s,
                "dominant": terms.dominant,
                "model_flops": terms.model_flops,
                "hlo_flops_total": terms.hlo_flops_total,
                "useful_flops_fraction": terms.useful_flops_fraction,
                "roofline_fraction": terms.roofline_fraction,
                "step_lower_bound_s": terms.step_time_lower_bound_s,
            },
        })
        m = rec["memory"]
        # donation aliases the output onto the input buffers (alias_bytes):
        # peak live bytes = args + temp + (non-aliased output)
        peak = (m["argument_bytes"] + m["temp_bytes"]
                + m["output_bytes"] - m["alias_bytes"])
        rec["peak_bytes"] = peak
        rec["fits_hbm"] = bool(peak <= TPU_V5E.hbm_bytes)
        del compiled, lowered
    except Exception as e:    # noqa: BLE001 — sweep must survive cell bugs
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both", "tiny", "tiny_multi"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--smoke-config", action="store_true",
                    help="reduced model configs (CI)")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both"
              else [args.mesh])

    records = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, args.smoke_config)
                records.append(rec)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" frac={r['roofline_fraction']:.3f}"
                             f" compile={rec['compile_s']:.1f}s")
                elif status == "error":
                    extra = " " + rec["error"][:160]
                print(f"[dryrun] {arch:24s} {shape:12s} {mesh_kind:6s} "
                      f"{status}{extra}", flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"[dryrun] wrote {len(records)} records to {args.out}")
    bad = [r for r in records if r["status"] == "error"]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
