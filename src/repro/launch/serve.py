"""Serving launcher: batched generation with optional CHASE hybrid retrieval.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 2 --prompt-len 16 --gen 16 --rag
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import init_params
from ..serving.decode import generate
from ..serving.rag import HybridRetriever


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--rag", action="store_true",
                    help="hybrid retrieval (CHASE VKNN-SF) before decode")
    ap.add_argument("--rag-docs", type=int, default=2000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} is embeddings-mode; use the "
                         "hybrid_serving example for frontend-stub serving")
    key = jax.random.key(args.seed)
    params = init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)

    prefix = prompts
    if args.rag:
        rng = np.random.default_rng(args.seed)
        docs = rng.standard_normal((args.rag_docs, cfg.d_model)).astype(
            np.float32)
        docs /= np.linalg.norm(docs, axis=1, keepdims=True)
        fresh = rng.random(args.rag_docs).astype(np.float32)
        safety = rng.integers(0, 4, args.rag_docs).astype(np.int32)
        retriever = HybridRetriever.build(jnp.asarray(docs),
                                          jnp.asarray(fresh),
                                          jnp.asarray(safety), k=4)
        # query embedding = mean prompt embedding (stub encoder)
        qemb = jnp.mean(params["embed"][prompts].astype(jnp.float32), axis=1)
        qemb = qemb / (jnp.linalg.norm(qemb, axis=-1, keepdims=True) + 1e-6)
        ids, sims, valid = retriever.retrieve_batch(np.asarray(qemb),
                                                    min_freshness=0.25,
                                                    safety_class=0)
        print(f"[serve] retrieved docs per request: "
              f"{np.asarray(ids).tolist()}")
        # doc ids map to doc token prefixes (stub: hash to token ids)
        doc_tokens = (np.asarray(ids) * 7919 % cfg.vocab_size).astype(np.int32)
        prefix = jnp.concatenate([jnp.asarray(doc_tokens), prompts], axis=1)

    t0 = time.time()
    out = generate(params, cfg, prefix, args.gen)
    out = jax.block_until_ready(out)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"[serve] generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print(np.asarray(out))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
