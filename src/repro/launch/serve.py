"""Serving launcher: the resilient asyncio front door for hybrid queries,
plus batched LM generation with optional CHASE retrieval.

Front door (DESIGN.md §11) — an in-process stand-in for the network edge of
a CHASE deployment:

  PYTHONPATH=src python -m repro.launch.serve --front-door --requests 64

:class:`QueryServer` stacks the full resilience pipeline over one prepared
statement: **admission control** (bounded in-flight watermark ->
:class:`~repro.serving.resilience.BackpressureError` with a retry-after
hint), **bind validation** (poisoned payloads rejected at the door),
**deadlines** (expired requests shed before execution), and **graceful
degradation** (probe budgets step down under queue pressure; served results
report degraded mode in ``explain()``).  ``await server.submit(binds)``
resolves to the request's :class:`~repro.api.result.Result` or raises its
typed serving error — never a hang.

LM decode path (unchanged):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 2 --prompt-len 16 --gen 16 --rag
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..serving.resilience import (AdmissionConfig, AdmissionController,
                                  DegradePolicy, validate_binds)
from ..serving.scheduler import ResilientScheduler, SchedulerConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Front-door knobs: admission + scheduler + degradation policy.

    ``idle_tick_ms`` bounds how long the drain loop sleeps with work queued
    (the liveness backstop: even if no submit ever kicks the loop again, a
    queued request is examined within one tick)."""
    admission: AdmissionConfig = AdmissionConfig()
    scheduler: SchedulerConfig = SchedulerConfig()
    policy: DegradePolicy | None = DegradePolicy()
    idle_tick_ms: float = 50.0


class QueryServer:
    """Asyncio front door over a :class:`~repro.serving.scheduler.ResilientScheduler`.

    One server serves one prepared statement (the deployment unit).  Use as
    an async context manager::

        async with QueryServer(stmt, config) as server:
            res = await server.submit({"qv": q, "p": 0.5}, deadline_ms=20)

    ``submit`` applies the admission pipeline inline (backpressure, bind
    validation) and then awaits the request's outcome; the background drain
    loop coalesces queued requests and runs batches on the default executor
    thread so the event loop never blocks on a kernel."""

    def __init__(self, statement, config: ServeConfig | None = None,
                 faults=None):
        self.config = config if config is not None else ServeConfig()
        self.scheduler = ResilientScheduler(statement,
                                            self.config.scheduler,
                                            policy=self.config.policy,
                                            faults=faults)
        self.admission = AdmissionController(self.config.admission)
        self.faults = faults
        self._futures: dict[int, asyncio.Future] = {}
        self._kick: asyncio.Event | None = None
        self._loop_task: asyncio.Task | None = None
        self._running = False

    @property
    def statement(self):
        """The prepared Statement this server deploys."""
        return self.scheduler.statement

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "QueryServer":
        """Start the background drain loop (idempotence-guarded)."""
        if self._running:
            raise RuntimeError("server already started")
        self._kick = asyncio.Event()
        self._running = True
        self._loop_task = asyncio.create_task(self._drain_loop())
        return self

    async def stop(self) -> None:
        """Graceful shutdown: stop admitting, drain everything queued,
        resolve every in-flight future (no request is left dangling)."""
        if not self._running:
            return
        self._running = False
        self._kick.set()
        await self._loop_task
        loop = asyncio.get_running_loop()
        done = await loop.run_in_executor(None, self.scheduler.flush)
        for rid in done:
            self._resolve(rid)
        for rid, fut in list(self._futures.items()):
            if not fut.done():
                fut.set_exception(RuntimeError(
                    f"server stopped with request {rid} unresolved"))
            self._futures.pop(rid, None)

    async def __aenter__(self) -> "QueryServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- request path -------------------------------------------------------

    async def submit(self, binds: dict, *, deadline_ms: float | None = None,
                     priority: int | None = None) -> Any:
        """Admit, enqueue, and await one request.

        Raises :class:`~repro.serving.resilience.BackpressureError` at the
        door when in-flight depth is at the watermark,
        :class:`~repro.serving.resilience.PoisonedBindError` on non-finite
        payloads, :class:`~repro.serving.resilience.DeadlineExceededError`
        if the request expires while queued, and whatever the execution
        itself raised (contained per batch).  Otherwise resolves to the
        request's :class:`~repro.api.result.Result` view."""
        if not self._running:
            raise RuntimeError("server is not running (use `async with` "
                               "or call start())")
        self.admission.admit(len(self._futures))
        if self.faults is not None:
            binds, _poisoned = self.faults.maybe_poison(binds)
        validate_binds(binds)
        hints = getattr(self.statement, "hints", None)
        if deadline_ms is None and hints is not None:
            deadline_ms = hints.deadline_ms
        if priority is None:
            priority = getattr(hints, "priority", 0) if hints else 0
        rid = self.scheduler.submit_request(binds, deadline_ms=deadline_ms,
                                            priority=priority)
        fut = asyncio.get_running_loop().create_future()
        self._futures[rid] = fut
        self._kick.set()
        return await fut

    async def submit_mutation(self, op: str, ids=None, vectors=None,
                              columns=None) -> int:
        """Admit and apply one corpus mutation against the served
        statement's live corpus (DESIGN.md §12); returns the mutation's LSN.

        ``op`` is ``"insert"`` (requires ``ids`` + ``vectors``),
        ``"delete"`` (requires ``ids``), or ``"compact"``.  Mutations share
        the query admission watermark — a server drowning in reads also
        backpressures writes (:class:`BackpressureError`) — and payloads are
        validated at the door by the corpus itself (typed
        :class:`~repro.serving.resilience.MutationError` subclasses).  The
        WAL append + segment update run on the executor thread so the event
        loop never blocks on disk; :class:`~repro.data.mutations.LiveCorpus`
        serializes mutations (and plan re-binds) on its internal lock, so
        concurrent submits get distinct LSNs and slots with WAL order equal
        to LSN order, and queries racing a mutation see either the pre- or
        post-mutation corpus, never a torn state."""
        from ..core.compiler import _scan_of
        from ..serving.resilience import MutationError
        if not self._running:
            raise RuntimeError("server is not running (use `async with` "
                               "or call start())")
        self.admission.admit(len(self._futures))
        stmt = self.statement
        live = stmt._db.catalog.live_for(*_scan_of(stmt.compiled.analysis))
        if live is None:
            raise MutationError(
                "served statement's table has no live corpus attached; "
                "call db.attach_live(...) before submitting mutations")
        if op == "insert":
            call = lambda: live.insert(ids, vectors, columns)
        elif op == "delete":
            call = lambda: live.delete(ids)
        elif op == "compact":
            call = lambda: live.compact()
        else:
            raise MutationError(
                f"unknown mutation op {op!r}; expected "
                f"'insert', 'delete', or 'compact'")
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(None, call)

    def snapshot(self) -> dict:
        """Admission + scheduler + load (+ fault) counters in one view."""
        return {"admission": self.admission.snapshot(),
                "in_flight": len(self._futures),
                **self.scheduler.snapshot()}

    # -- internals ----------------------------------------------------------

    def _resolve(self, rid: int) -> None:
        fut = self._futures.pop(rid, None)
        if fut is None or fut.done():
            return
        try:
            out = self.scheduler.result(rid)
        except Exception as e:
            fut.set_exception(e)
        else:
            fut.set_result(out)

    async def _drain_loop(self) -> None:
        loop = asyncio.get_running_loop()
        sched = self.scheduler
        while self._running:
            self._kick.clear()
            done = await loop.run_in_executor(None, sched.poll)
            for rid in done:
                self._resolve(rid)
            if sched.pending():
                # work queued but not yet due: sleep to (at most) the
                # coalescing window so the due-check lands on time
                await asyncio.sleep(
                    min(self.config.scheduler.max_wait_ms,
                        self.config.idle_tick_ms) * 1e-3)
            else:
                try:
                    await asyncio.wait_for(
                        self._kick.wait(),
                        timeout=self.config.idle_tick_ms * 1e-3)
                except asyncio.TimeoutError:
                    pass


# -- demo traffic -----------------------------------------------------------


def _build_demo_statement(n_rows: int, seed: int):
    """A small VKNN-SF deployment: LAION-style catalog + IVF index."""
    from ..api import connect
    from ..core import Metric
    from ..data import make_laion_catalog
    from ..index import build_ivf
    from ..index.ivf import ProbeConfig

    cat = make_laion_catalog(n_rows=n_rows, n_queries=8, dim=16, n_modes=8,
                             seed=seed)
    idx = build_ivf(jax.random.key(seed), cat.table("laion")["vec"],
                    nlist=32, metric=Metric.INNER_PRODUCT, iters=3)
    cat.register_index("products", "embedding", idx)
    db = connect(cat, engine="chase",
                 probe=ProbeConfig(max_probes=32, probe_batch=2,
                                   termination="counter"))
    stmt = db.prepare("SELECT sample_id FROM products WHERE price < ${p} "
                      "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")
    return cat, stmt


async def _front_door_demo(args) -> int:
    cat, stmt = _build_demo_statement(args.rows, args.seed)
    rng = np.random.default_rng(args.seed)
    qs = np.asarray(cat.table("queries")["embedding"]).astype(np.float32)
    config = ServeConfig(
        admission=AdmissionConfig(max_queue_depth=args.watermark),
        scheduler=SchedulerConfig(max_batch=16, max_wait_ms=1.0,
                                  default_deadline_ms=args.deadline_ms),
        policy=DegradePolicy(steps=((8, 8), (16, 4)), hysteresis=2))
    outcomes = {"ok": 0, "degraded": 0, "backpressure": 0, "deadline": 0}

    async def one(i: int) -> None:
        from ..serving.resilience import (BackpressureError,
                                          DeadlineExceededError)
        binds = {"qv": qs[i % qs.shape[0]], "p": np.float32(1e9)}
        try:
            # staggered arrivals: early requests see a shallow queue (full
            # effort), the later burst pushes into degraded territory
            await asyncio.sleep(i * 0.001 if i < args.requests // 2 else 0)
            res = await server.submit(binds)
        except BackpressureError:
            outcomes["backpressure"] += 1
        except DeadlineExceededError:
            outcomes["deadline"] += 1
        else:
            rep = res.explain()
            outcomes["degraded" if rep.degraded else "ok"] += 1

    t0 = time.perf_counter()
    async with QueryServer(stmt, config) as server:
        server.scheduler.warm({"qv": qs[0], "p": np.float32(1e9)}, [1, 16])
        await asyncio.gather(*(one(i) for i in range(args.requests)))
        snap = server.snapshot()
    dt = time.perf_counter() - t0
    print(f"[front-door] {args.requests} requests in {dt:.2f}s")
    print(f"[front-door] outcomes: {outcomes}")
    print(f"[front-door] snapshot: {snap}")
    return 0


def main(argv=None) -> int:
    """CLI: --front-door resilience demo, or the LM decode path."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="LM decode path: model architecture")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--rag", action="store_true",
                    help="hybrid retrieval (CHASE VKNN-SF) before decode")
    ap.add_argument("--rag-docs", type=int, default=2000)
    ap.add_argument("--front-door", action="store_true",
                    help="resilient hybrid-query front-door demo")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rows", type=int, default=1500)
    ap.add_argument("--watermark", type=int, default=64)
    ap.add_argument("--deadline-ms", type=float, default=200.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.front_door:
        return asyncio.run(_front_door_demo(args))
    if not args.arch:
        ap.error("--arch is required unless --front-door is given")

    from ..configs import get_config
    from ..models import init_params
    from ..serving.decode import generate
    from ..serving.rag import HybridRetriever

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} is embeddings-mode; use the "
                         "hybrid_serving example for frontend-stub serving")
    key = jax.random.key(args.seed)
    params = init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)

    prefix = prompts
    if args.rag:
        rng = np.random.default_rng(args.seed)
        docs = rng.standard_normal((args.rag_docs, cfg.d_model)).astype(
            np.float32)
        docs /= np.linalg.norm(docs, axis=1, keepdims=True)
        fresh = rng.random(args.rag_docs).astype(np.float32)
        safety = rng.integers(0, 4, args.rag_docs).astype(np.int32)
        retriever = HybridRetriever.build(jnp.asarray(docs),
                                          jnp.asarray(fresh),
                                          jnp.asarray(safety), k=4)
        # query embedding = mean prompt embedding (stub encoder)
        qemb = jnp.mean(params["embed"][prompts].astype(jnp.float32), axis=1)
        qemb = qemb / (jnp.linalg.norm(qemb, axis=-1, keepdims=True) + 1e-6)
        ids, sims, valid = retriever.retrieve_batch(np.asarray(qemb),
                                                    min_freshness=0.25,
                                                    safety_class=0)
        print(f"[serve] retrieved docs per request: "
              f"{np.asarray(ids).tolist()}")
        # doc ids map to doc token prefixes (stub: hash to token ids)
        doc_tokens = (np.asarray(ids) * 7919 % cfg.vocab_size).astype(np.int32)
        prefix = jnp.concatenate([jnp.asarray(doc_tokens), prompts], axis=1)

    t0 = time.time()
    out = generate(params, cfg, prefix, args.gen)
    out = jax.block_until_ready(out)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"[serve] generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print(np.asarray(out))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
