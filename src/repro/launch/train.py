"""Training launcher.

Local (CPU/1-device) run:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt

Production posture: same entrypoint under a 16x16 or 2x16x16 mesh — the mesh
is selected by --mesh, shardings come from launch/shardspec.py, restart is
automatic from the newest manifested checkpoint (fault tolerance), and
--compress-grads enables the int8 error-feedback DP all-reduce.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import Checkpointer, latest_step, restore
from ..configs import get_config
from ..data.pipeline import DataConfig, SyntheticLM
from ..dist.sharding import logical_axis_rules
from ..models import init_params
from ..models.config import ModelConfig
from ..training import (AdamWConfig, TrainState, TrainStepConfig, adamw_init,
                        build_train_step)
from .mesh import make_mesh, make_production_mesh
from .shardspec import (batch_logical_axes, moe_rules_patch,
                        param_logical_axes, rules_for, tree_shardings)
from ..configs.shapes import ShapeConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi", "tiny"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg: ModelConfig = get_config(args.arch, smoke=args.smoke)
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    step_cfg = TrainStepConfig(microbatches=args.microbatches)
    data_cfg = DataConfig(seed=args.seed, global_batch=args.global_batch,
                          seq_len=args.seq_len, vocab_size=cfg.vocab_size,
                          input_mode=cfg.input_mode, d_model=cfg.d_model)
    data = SyntheticLM(data_cfg)

    mesh = None
    rules = {}
    if args.mesh != "none":
        mesh = {"single": lambda: make_production_mesh(),
                "multi": lambda: make_production_mesh(multi_pod=True),
                "tiny": lambda: make_mesh((2, 2), ("data", "model"))}[
            args.mesh]()
        shape = ShapeConfig("cli", "train", args.seq_len, args.global_batch)
        rules = moe_rules_patch(cfg, rules_for(cfg, shape, mesh))

    def run():
        train_step = build_train_step(cfg, opt_cfg, step_cfg)
        key = jax.random.key(args.seed)
        params = init_params(key, cfg)
        state = TrainState.create(params, adamw_init(opt_cfg, params), key)

        start = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = Checkpointer(args.ckpt_dir)
            last = latest_step(args.ckpt_dir)
            if last is not None:
                state = restore(args.ckpt_dir, last, state)
                start = last
                print(f"[train] resumed from step {last}")

        jstep = jax.jit(train_step, donate_argnums=(0,))
        t0 = time.time()
        for step in range(start, args.steps):
            batch = data.batch_at(step)
            state, metrics = jstep(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(f"[train] step={step} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"({(time.time()-t0):.1f}s)", flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step + 1, state)
        if ckpt:
            ckpt.wait()
            ckpt.save_async(args.steps, state)
            ckpt.wait()
        return state

    if mesh is not None:
        with mesh, logical_axis_rules(rules, mesh):
            run()
    else:
        run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
