"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero device allocation — the dry-run lowers
against these.  ``[audio]``/``[vlm]`` archs get precomputed frame/patch
embeddings per the assignment (the modality frontend is a stub)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.shapes import ShapeConfig
from ..models.config import ModelConfig


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_mode == "tokens":
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdtype()),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_mode == "tokens":
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdtype())}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    if cfg.input_mode == "tokens":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.cdtype())}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
