"""Per-arch / per-shape sharding policy for the production meshes.

This encodes the real placement decisions (DESIGN.md §5):
* DP over ('pod','data'); TP over 'model'; FSDP (weights' embed axis over
  'data') for ≥10B archs;
* MoE: experts→model when divisible (moonshot 64/16), else per-expert d_ff
  TP (grok 8 experts);
* decode KV cache: kv_heads→model when divisible, else head_dim→model when
  divisible, else kv_seq→model (danube's 8 kv × 120 hd);
* long_500k (batch=1): batch unsharded, KV seq sharded over the DP axes —
  distributed-softmax decode;
* every explicit sharding passes a divisibility guard (non-divisible axes
  drop to replicated rather than relying on GSPMD padding).
"""
from __future__ import annotations

import math
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.shapes import ShapeConfig
from ..dist.sharding import logical_to_spec
from ..models.config import ModelConfig

FSDP_PARAM_THRESHOLD = 10e9


def mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def rules_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    multi = "pod" in mesh.axis_names
    model = mesh.shape["model"]
    dp_axes = ("pod", "data") if multi else ("data",)
    dp_total = mesh_axis_size(mesh, dp_axes)
    # FSDP for ≥10B archs in training AND inference.  §Perf HC3 measured the
    # TP-only-inference alternative and REFUTED it: replicating weights over
    # the data axis grows the per-token weight-read memory term (gemma2
    # decode 94→264 ms) and overflows HBM for MoE archs — sharded weights +
    # gathers is the better decode layout once the q/cache alignment fix
    # removed the spurious cache gathers.
    fsdp = cfg.num_params_estimate() >= FSDP_PARAM_THRESHOLD

    r: dict[str, Any] = {
        "batch": dp_axes if shape.global_batch % dp_total == 0 else None,
        "seq": None,
        # Megatron-SP: the period-boundary residual carry shards its seq dim
        # over the model axis during training/prefill (remat stack / 16)
        "seq_act": "model" if (shape.kind in ("train", "prefill")
                               and shape.seq_len % model == 0) else None,
        "heads": "model",
        "kv_heads": None,
        "head_dim": None,
        "kv_seq": None,
        "embed": "data" if fsdp else None,
        "mlp_embed": "data" if fsdp else None,
        "ff": "model",
        "vocab": "model" if cfg.vocab_size % model == 0 else None,
        "experts": None,
        "expert_ff": None,
        "moe_cap": dp_axes,
        "d_state": None,
        "ff_heads": None,
    }
    if cfg.ssm is not None:
        ssm_heads = cfg.ssm.expand * cfg.d_model // cfg.ssm.head_dim
        if ssm_heads % model == 0:
            r["ff_heads"] = "model"
    if multi and fsdp:
        r["embed"] = ("pod", "data")
        r["mlp_embed"] = ("pod", "data")

    # decode cache placement priority
    if cfg.num_kv_heads % model == 0:
        r["kv_heads"] = "model"
    elif cfg.hd() % model == 0:
        r["head_dim"] = "model"
    else:
        r["kv_seq"] = "model"
    if shape.kind == "decode" and r["batch"] is None:
        # long-context decode: shard the KV sequence over the idle DP axes
        kv = r["kv_seq"]
        extra = dp_axes
        r["kv_seq"] = (extra + (kv,)) if isinstance(kv, str) else extra

    if cfg.moe is not None:
        if cfg.moe.shard_mode == "expert" and cfg.moe.num_experts % model == 0:
            r["experts"] = "model"
            r["expert_ff"] = "data" if fsdp else None
        else:
            r["experts"] = None
            r["expert_ff"] = "model"
            # grok: per-expert tensor parallelism; 'ff' already model for
            # the shared-expert MLPs
    return r


# ---------------------------------------------------------------------------
# Parameter / state / batch logical-axis maps
# ---------------------------------------------------------------------------

_ATTN_AXES = {
    "wq": ("embed", "heads"), "wk": ("embed", "heads"),
    "wv": ("embed", "heads"), "wo": ("heads", "embed"),
    "bq": ("heads",), "bk": ("heads",), "bv": ("heads",),
    "q_norm": (None,), "k_norm": (None,),
}
_MLP_AXES = {
    "wi": ("mlp_embed", "ff"), "wg": ("mlp_embed", "ff"),
    "wo": ("ff", "mlp_embed"),
}
_MOE_AXES = {
    "router": ("embed", None),
    "wi": ("experts", "expert_ff_in", "moe_ff"),
    "wg": ("experts", "expert_ff_in", "moe_ff"),
    "wo": ("experts", "moe_ff", "expert_ff_in"),
    "shared_wi": ("mlp_embed", "ff"), "shared_wg": ("mlp_embed", "ff"),
    "shared_wo": ("ff", "mlp_embed"),
}
_SSM_AXES = {
    "in_z": ("mlp_embed", "ff"), "in_x": ("mlp_embed", "ff"),
    "in_B": ("embed", None), "in_C": ("embed", None),
    "in_dt": ("embed", None), "dt_bias": (None,), "A_log": (None,),
    "D": (None,), "conv_w": (None, "ff"), "conv_b": ("ff",),
    "norm": ("ff",), "out": ("ff", "mlp_embed"),
}


def param_logical_axes(path: Sequence, leaf) -> tuple:
    """Logical axes for a model parameter leaf, inferred from its path."""
    keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    name = keys[-1]
    parents = set(keys[:-1])
    if name in ("embed", "unembed"):
        return ("vocab", "embed")
    if name == "final_norm":
        return (None,)
    if "attn" in parents and name in _ATTN_AXES:
        axes = _ATTN_AXES[name]
    elif "moe" in parents and name in _MOE_AXES:
        axes = _MOE_AXES[name]
    elif "ssm" in parents and name in _SSM_AXES:
        axes = _SSM_AXES[name]
    elif name in _MLP_AXES and ("mlp" in parents or "shared" in parents):
        axes = _MLP_AXES[name]
    elif name in ("norm", "norm1", "norm2"):
        axes = (None,)
    else:
        axes = (None,) * leaf.ndim
    # stacked period slots have a leading layer axis
    pad = leaf.ndim - len(axes)
    return (None,) * pad + tuple(axes)


def moe_rules_patch(cfg: ModelConfig, rules: dict) -> dict:
    """Resolve the MoE weight logical names against the shard mode."""
    r = dict(rules)
    if cfg.moe is None:
        return r
    fsdp_axes = r.get("mlp_embed")     # 'data' (or (pod,data)) when FSDP on
    if cfg.moe.shard_mode == "expert" and r.get("experts"):
        r["expert_ff_in"] = fsdp_axes
        r["moe_ff"] = None
    else:
        # per-expert TP (grok): d_ff over model; FSDP shards the expert
        # input dim over the DP axes so 3×(E·d·f) state spreads 256-way
        r["expert_ff_in"] = fsdp_axes
        r["moe_ff"] = "model"
    return r


def cache_logical_axes(path: Sequence, leaf) -> tuple:
    keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    name = keys[-1]
    if name in ("k", "v"):
        return (None, "batch", "kv_seq", "kv_heads", "head_dim")
    if name == "kpos":
        return (None, "kv_seq")
    if name == "conv":
        return (None, "batch", None, "ff")
    if name == "state":
        return (None, "batch", "ff_heads", None, None)
    return (None,) * leaf.ndim


def batch_logical_axes(path: Sequence, leaf) -> tuple:
    keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    name = keys[-1]
    if name in ("tokens", "labels"):
        return ("batch", None)
    if name == "embeds":
        return ("batch", None, None)
    return (None,) * leaf.ndim


def _axis_size_in(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return mesh.shape[entry]


def safe_named_sharding(mesh: Mesh, rules: Mapping, logical_axes: tuple,
                        shape: tuple) -> NamedSharding:
    """logical axes -> NamedSharding with a divisibility guard: any axis whose
    mesh factor doesn't divide the dim drops to replicated."""
    spec = list(logical_to_spec(logical_axes, rules))
    while len(spec) < len(shape):
        spec.append(None)
    fixed = []
    for dim, entry in zip(shape, spec[:len(shape)]):
        size = _axis_size_in(mesh, entry)
        fixed.append(entry if (size > 1 and dim % size == 0)
                     or size == 1 else None)
    return NamedSharding(mesh, P(*fixed))


def tree_shardings(tree, mesh: Mesh, rules: Mapping, axes_fn):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        axes = axes_fn(path, leaf)
        shp = getattr(leaf, "shape", ())
        out.append(safe_named_sharding(mesh, rules, axes, tuple(shp)))
    return jax.tree_util.tree_unflatten(treedef, out)
