"""Logical-axis sharding rules plus the engine's corpus-sharding handles.

Two independent facilities live here:

* **Logical-axis rules** (`logical_axis_rules` / `constrain`): model code
  names axes ("batch", "heads", ...) and the launch layer binds those names
  to physical mesh axes via a rules dict.  Without an active mesh every
  helper is a no-op passthrough, so single-device smoke tests and the query
  engine never pay a sharding tax.  The rules dict maps logical name ->
  mesh axis (str), tuple of mesh axes, or None (replicated); see
  ``launch.shardspec.rules_for`` for the production tables.
* **Corpus sharding for distributed hybrid queries** (DESIGN.md §10):
  :class:`DistSpec` is the *fingerprintable* mesh description that rides
  ``EngineOptions.dist`` (a plan compiled for one mesh must miss the plan
  cache on any other mesh), :func:`resolve_mesh` turns a spec into a live
  ``jax.sharding.Mesh``, and :class:`ShardedCorpus` is the row-sharded
  corpus handle the catalog can register so every plan compiled against a
  (table, column) reuses ONE device placement.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import threading
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_STATE = threading.local()


def _stack() -> list:
    if not hasattr(_STATE, "stack"):
        _STATE.stack = []
    return _STATE.stack


@contextlib.contextmanager
def logical_axis_rules(rules: Mapping[str, Any], mesh: Mesh | None = None):
    """Activate a logical->mesh axis mapping for the enclosed region."""
    _stack().append((dict(rules), mesh))
    try:
        yield
    finally:
        _stack().pop()


def current_rules() -> dict | None:
    """The innermost active logical-axis rules dict, or None."""
    s = _stack()
    return s[-1][0] if s else None


def current_mesh() -> Mesh | None:
    """The innermost active mesh bound by logical_axis_rules, or None."""
    s = _stack()
    return s[-1][1] if s else None


def logical_to_spec(logical_axes: Sequence, rules: Mapping[str, Any]) -> tuple:
    """Map logical axis names through the rules to PartitionSpec entries."""
    out = []
    for name in logical_axes:
        entry = rules.get(name) if name is not None else None
        if isinstance(entry, (list, tuple)):
            entry = tuple(entry) if entry else None
        out.append(entry)
    return tuple(out)


def _entry_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        size = 1
        for a in entry:
            size *= mesh.shape[a]
        return size
    return mesh.shape[entry]


def constrain(x, logical_axes: Sequence):
    """``with_sharding_constraint`` by logical names; passthrough when no
    rules/mesh are active or an axis size does not divide the dim."""
    s = _stack()
    if not s:
        return x
    rules, mesh = s[-1]
    if rules is None or mesh is None:
        return x
    spec = list(logical_to_spec(logical_axes, rules))
    while len(spec) < x.ndim:
        spec.append(None)
    fixed = []
    for dim, entry in zip(x.shape, spec[: x.ndim]):
        size = _entry_size(mesh, entry)
        fixed.append(entry if (size > 1 and dim % size == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*fixed)))


# ---------------------------------------------------------------------------
# Corpus sharding for distributed hybrid queries (DESIGN.md §10)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistSpec:
    """Fingerprintable mesh description for ``EngineOptions.dist``.

    A live ``jax.sharding.Mesh`` holds device objects and cannot key a plan
    cache; ``DistSpec`` captures exactly what shapes compilation — the mesh
    shape and the axis names the corpus rows shard over — with a stable
    ``repr`` that folds into ``EngineOptions.fingerprint()``.  Changing the
    mesh (shape OR axis names) therefore misses the normalized plan cache
    and compiles fresh sharded executables (tests/test_dist_batch.py).

    ``mesh_shape[i]`` is the device count along ``axes[i]``; the total shard
    count is their product.  Hierarchical merges run innermost axis first
    (``axes[-1]``), then outward — ``merge_depth`` is ``len(axes)``."""
    mesh_shape: tuple[int, ...] = (1,)
    axes: tuple[str, ...] = ("data",)

    def __post_init__(self):
        object.__setattr__(self, "mesh_shape", tuple(self.mesh_shape))
        object.__setattr__(self, "axes", tuple(self.axes))
        if len(self.mesh_shape) != len(self.axes):
            raise ValueError(
                f"mesh_shape {self.mesh_shape} and axes {self.axes} must "
                f"have the same length")
        if not self.axes:
            raise ValueError("DistSpec needs at least one mesh axis")
        if len(set(self.axes)) != len(self.axes):
            raise ValueError(f"duplicate mesh axis names: {self.axes}")
        if any((not isinstance(s, int)) or s < 1 for s in self.mesh_shape):
            raise ValueError(
                f"mesh_shape entries must be ints >= 1, got {self.mesh_shape}")

    @property
    def num_shards(self) -> int:
        """Total corpus shard count (product of the mesh axis sizes)."""
        return math.prod(self.mesh_shape)

    @property
    def merge_depth(self) -> int:
        """Hierarchical-merge levels: one per mesh axis (innermost first)."""
        return len(self.axes)


@functools.lru_cache(maxsize=None)
def resolve_mesh(spec: DistSpec) -> Mesh:
    """Build (once per spec) the live mesh a :class:`DistSpec` describes.

    Uses the first ``spec.num_shards`` local devices; raises with the
    ``xla_force_host_platform_device_count`` hint when the host has fewer
    (CI simulates shard counts with fake CPU devices — see
    benchmarks/q10_sharded_qps.py).  Cached so every plan compiled against
    one spec shares one mesh object (and device placement)."""
    n = spec.num_shards
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"DistSpec {spec} needs {n} devices, have {len(devs)} — run "
            f"under XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"to simulate shards on CPU")
    return Mesh(np.array(devs[:n]).reshape(spec.mesh_shape), spec.axes)


@dataclasses.dataclass(frozen=True)
class ShardedCorpus:
    """A row-sharded corpus + its global row ids, pinned to one mesh.

    The handle the catalog registers per (table, vector column)
    (``Catalog.register_sharded``) so that every plan compiled with a
    matching ``EngineOptions.dist`` reuses ONE device placement instead of
    re-slicing the corpus per prepare.  Rows are zero-padded up to a
    multiple of the shard count (``num_rows`` keeps the real count); pad
    rows carry ``row_id = -1`` and are masked out of every scan by the
    distributed collectives' mask normalization."""
    mesh: Mesh
    axes: tuple[str, ...]
    corpus: jnp.ndarray        # (Npad, d), rows sharded over ``axes``
    row_ids: jnp.ndarray       # (Npad,), global ids; -1 on pad rows
    num_rows: int              # real (pre-padding) row count

    @classmethod
    def build(cls, mesh: Mesh, corpus, axes: Sequence[str] = ("data",)
              ) -> "ShardedCorpus":
        """Row-shard ``corpus`` over ``axes``, zero-padding to divisibility."""
        axes = tuple(axes)
        shards = math.prod(mesh.shape[a] for a in axes)
        n = int(corpus.shape[0])
        pad = (-n) % shards
        arr = jnp.asarray(corpus, jnp.float32)
        ids = jnp.arange(n, dtype=jnp.int32)
        if pad:
            arr = jnp.concatenate(
                [arr, jnp.zeros((pad, arr.shape[1]), arr.dtype)])
            ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)])
        return cls(
            mesh, axes,
            jax.device_put(arr, NamedSharding(mesh, PartitionSpec(axes, None))),
            jax.device_put(ids, NamedSharding(mesh, PartitionSpec(axes))),
            n)

    @property
    def num_shards(self) -> int:
        """Corpus shard count (product of this handle's axis sizes)."""
        return math.prod(self.mesh.shape[a] for a in self.axes)

    @property
    def padded_rows(self) -> int:
        """Row count after divisibility padding (``corpus.shape[0]``)."""
        return int(self.corpus.shape[0])

    @property
    def spec(self) -> DistSpec:
        """The :class:`DistSpec` this handle's mesh corresponds to (the
        catalog's registry key — engine dist meshes are dedicated, so the
        handle's axes must be exactly the mesh's axes)."""
        return DistSpec(tuple(int(s) for s in self.mesh.devices.shape),
                        tuple(self.mesh.axis_names))

    def matches(self, spec: DistSpec) -> bool:
        """True iff this handle's mesh is the one ``spec`` describes."""
        return (self.axes == spec.axes
                and tuple(self.mesh.devices.shape) == spec.mesh_shape
                and tuple(self.mesh.axis_names) == spec.axes)
