"""Logical-axis sharding: model code names axes ("batch", "heads", ...) and
the launch layer binds those names to physical mesh axes via a rules dict.

Without an active mesh every helper is a no-op passthrough, so single-device
smoke tests and the query engine never pay a sharding tax.  The rules dict
maps logical name -> mesh axis (str), tuple of mesh axes, or None
(replicated); see ``launch.shardspec.rules_for`` for the production tables.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_STATE = threading.local()


def _stack() -> list:
    if not hasattr(_STATE, "stack"):
        _STATE.stack = []
    return _STATE.stack


@contextlib.contextmanager
def logical_axis_rules(rules: Mapping[str, Any], mesh: Mesh | None = None):
    """Activate a logical->mesh axis mapping for the enclosed region."""
    _stack().append((dict(rules), mesh))
    try:
        yield
    finally:
        _stack().pop()


def current_rules() -> dict | None:
    s = _stack()
    return s[-1][0] if s else None


def current_mesh() -> Mesh | None:
    s = _stack()
    return s[-1][1] if s else None


def logical_to_spec(logical_axes: Sequence, rules: Mapping[str, Any]) -> tuple:
    """Map logical axis names through the rules to PartitionSpec entries."""
    out = []
    for name in logical_axes:
        entry = rules.get(name) if name is not None else None
        if isinstance(entry, (list, tuple)):
            entry = tuple(entry) if entry else None
        out.append(entry)
    return tuple(out)


def _entry_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        size = 1
        for a in entry:
            size *= mesh.shape[a]
        return size
    return mesh.shape[entry]


def constrain(x, logical_axes: Sequence):
    """``with_sharding_constraint`` by logical names; passthrough when no
    rules/mesh are active or an axis size does not divide the dim."""
    s = _stack()
    if not s:
        return x
    rules, mesh = s[-1]
    if rules is None or mesh is None:
        return x
    spec = list(logical_to_spec(logical_axes, rules))
    while len(spec) < x.ndim:
        spec.append(None)
    fixed = []
    for dim, entry in zip(x.shape, spec[: x.ndim]):
        size = _entry_size(mesh, entry)
        fixed.append(entry if (size > 1 and dim % size == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*fixed)))
