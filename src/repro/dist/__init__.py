"""Distribution layer: logical-axis sharding rules and query collectives.

``sharding``    — logical axis names -> mesh axes (the model/engine code only
                  speaks logical names; the launch layer binds them to a mesh).
``collectives`` — sharded-corpus hybrid-query primitives (per-shard fused
                  scan + hierarchical top-k / range merges).
"""
from . import collectives, sharding

__all__ = ["collectives", "sharding"]
