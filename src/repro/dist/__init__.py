"""Distribution layer: logical-axis sharding rules and query collectives.

``sharding``    — logical axis names -> mesh axes (the model/engine code only
                  speaks logical names; the launch layer binds them to a
                  mesh), plus the engine's corpus-sharding handles:
                  :class:`DistSpec` (the fingerprintable mesh description
                  that rides ``EngineOptions.dist``) and
                  :class:`ShardedCorpus` (the row-sharded corpus handle the
                  catalog registers).
``collectives`` — sharded-corpus hybrid-query primitives: per-shard fused
                  scans + hierarchical top-k / range merges, single-query
                  (DESIGN.md §5) and query-batched (DESIGN.md §10).
"""
from . import collectives, sharding
from .sharding import DistSpec, ShardedCorpus, resolve_mesh

__all__ = ["collectives", "sharding", "DistSpec", "ShardedCorpus",
           "resolve_mesh"]
