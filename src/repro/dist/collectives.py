"""Sharded-corpus hybrid-query collectives (DESIGN.md §5).

The corpus rows live sharded over one or more mesh axes; each device runs the
*fused* local scan (distance + filter + top-k/range) over its shard, then only
K (id, key) candidate pairs per shard cross the interconnect — the merge wire
cost is K·shards·8 bytes regardless of corpus size, which is what makes
scale-out hybrid search cheap.

``distributed_topk(mesh, metric, k, axes)`` returns a shard_map'd callable
``fn(sh_corpus, sh_ids, q, sh_mask) -> (ids, sims, valid)`` whose result is
replicated on every device (bitwise equal to the single-host flat scan up to
top-k tie order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..core.expr import distance_values, in_range, order_key
from ..core.schema import Metric
from ..index.flat import masked_topk


def shard_corpus(mesh: Mesh, corpus: jnp.ndarray,
                 axes: tuple[str, ...] = ("data",)):
    """Row-shard a corpus (and its global row ids) over ``axes``.

    Rows must divide the axes' total size (pad upstream otherwise).
    Returns (sharded corpus, sharded global ids)."""
    n = corpus.shape[0]
    sharding = NamedSharding(mesh, P(axes))
    ids = jnp.arange(n, dtype=jnp.int32)
    return (jax.device_put(corpus, NamedSharding(mesh, P(axes, None))),
            jax.device_put(ids, sharding))


def distributed_topk(mesh: Mesh, metric: Metric, k: int,
                     axes: tuple[str, ...] = ("data",)):
    """Filtered exact top-k over a row-sharded corpus.

    Per-shard fused scan+filter+top-k, then a hierarchical candidate merge:
    all_gather the K local winners across the innermost shard axis, re-select,
    and repeat outward — each level moves only K pairs per participant."""

    def local(corpus, ids, q, mask):
        raw = distance_values(metric, corpus, q)
        keys = order_key(metric, raw)
        sel_keys, sel_ids, _ = masked_topk(keys, ids, mask, k)
        # hierarchical merge: innermost axis first, then outward (pod-level)
        for ax in reversed(axes):
            ck = jax.lax.all_gather(sel_keys, ax, tiled=True)
            ci = jax.lax.all_gather(sel_ids, ax, tiled=True)
            sel_keys, sel_ids, _ = masked_topk(ck, ci, jnp.isfinite(ck), k)
        valid = jnp.isfinite(sel_keys)
        sims = jnp.where(valid,
                         -sel_keys if metric.is_similarity() else sel_keys,
                         0.0)
        return jnp.where(valid, sel_ids, -1), sims, valid

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(), P(axes)),
        out_specs=(P(), P(), P()),
        check_rep=False)


def distributed_range(mesh: Mesh, metric: Metric, capacity: int,
                      axes: tuple[str, ...] = ("data",)):
    """Filtered range query over a row-sharded corpus.

    Each shard emits up to ``capacity`` in-range candidates (compacted
    locally); the gather concatenates per-shard buffers, so the global result
    holds up to capacity*shards hits, ordered best-first per shard."""

    def local(corpus, ids, q, radius, mask):
        raw = distance_values(metric, corpus, q)
        keys = order_key(metric, raw)
        hit = mask & in_range(metric, raw, radius)
        cap = min(capacity, corpus.shape[0])
        sel_keys, sel_ids, _ = masked_topk(keys, ids, hit, cap)
        count = jnp.sum(hit.astype(jnp.int32)).reshape(1)
        for ax in reversed(axes):
            sel_keys = jax.lax.all_gather(sel_keys, ax, tiled=True)
            sel_ids = jax.lax.all_gather(sel_ids, ax, tiled=True)
            count = jax.lax.all_gather(count, ax, tiled=True)
        valid = jnp.isfinite(sel_keys)
        sims = jnp.where(valid,
                         -sel_keys if metric.is_similarity() else sel_keys,
                         0.0)
        return (jnp.where(valid, sel_ids, -1), sims, valid,
                jnp.sum(count))

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(), P(), P(axes)),
        out_specs=(P(), P(), P(), P()),
        check_rep=False)
