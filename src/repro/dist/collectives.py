"""Sharded-corpus hybrid-query collectives (DESIGN.md §5, §10).

The corpus rows live sharded over one or more mesh axes; each device runs the
*fused* local scan (distance + filter + top-k/range) over its shard, then only
K (id, key) candidate pairs per shard cross the interconnect — the merge wire
cost is K·shards·8 bytes **per query** regardless of corpus size, which is
what makes scale-out hybrid search interconnect-cheap.

Two generations of primitives live here:

* **Single-query** (:func:`distributed_topk` / :func:`distributed_range`):
  one query vector per call, the per-shard scan is a masked matvec.  These
  are the DESIGN.md §5 seed primitives, kept as the simple reference.
* **Query-batched** (:func:`distributed_topk_batch` /
  :func:`distributed_range_batch`, DESIGN.md §10): each device scans its
  shard for ALL Q queries at once through the query-tiled fused Pallas
  kernels (kernels/ops.py), so the shard × query composition amortizes the
  per-shard corpus stream over BLOCK_Q queries.  The size-bucket ``qvalid``
  lane threads through to every shard — a pad query emits no candidates and
  zero counters on every device — and the hierarchical per-query merge
  (``all_gather`` the (Q, K) local winners along the innermost mesh axis,
  column-parallel re-select, repeat outward) moves K·Q pairs per
  participant per level.

Every returned callable is ``shard_map``'d over ``mesh`` and replicates its
outputs; wrap in ``jax.jit`` (or call from a jitted pipeline — the physical
builders do) for execution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..core.expr import distance_values, in_range, order_key
from ..core.schema import Metric
from ..index.flat import masked_topk


def shard_corpus(mesh: Mesh, corpus: jnp.ndarray,
                 axes: tuple[str, ...] = ("data",)):
    """Row-shard a corpus (and its global row ids) over ``axes``.

    Rows must divide the axes' total size (pad upstream otherwise).
    Returns (sharded corpus, sharded global ids)."""
    n = corpus.shape[0]
    sharding = NamedSharding(mesh, P(axes))
    ids = jnp.arange(n, dtype=jnp.int32)
    return (jax.device_put(corpus, NamedSharding(mesh, P(axes, None))),
            jax.device_put(ids, sharding))


def distributed_topk(mesh: Mesh, metric: Metric, k: int,
                     axes: tuple[str, ...] = ("data",)):
    """Filtered exact top-k over a row-sharded corpus.

    Per-shard fused scan+filter+top-k, then a hierarchical candidate merge:
    all_gather the K local winners across the innermost shard axis, re-select,
    and repeat outward — each level moves only K pairs per participant."""

    def local(corpus, ids, q, mask):
        raw = distance_values(metric, corpus, q)
        keys = order_key(metric, raw)
        sel_keys, sel_ids, _ = masked_topk(keys, ids, mask, k)
        # hierarchical merge: innermost axis first, then outward (pod-level)
        for ax in reversed(axes):
            ck = jax.lax.all_gather(sel_keys, ax, tiled=True)
            ci = jax.lax.all_gather(sel_ids, ax, tiled=True)
            sel_keys, sel_ids, _ = masked_topk(ck, ci, jnp.isfinite(ck), k)
        valid = jnp.isfinite(sel_keys)
        sims = jnp.where(valid,
                         -sel_keys if metric.is_similarity() else sel_keys,
                         0.0)
        return jnp.where(valid, sel_ids, -1), sims, valid

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(), P(axes)),
        out_specs=(P(), P(), P()),
        check_rep=False)


def distributed_range(mesh: Mesh, metric: Metric, capacity: int,
                      axes: tuple[str, ...] = ("data",)):
    """Filtered range query over a row-sharded corpus.

    Each shard emits up to ``capacity`` in-range candidates (compacted
    locally); the gather concatenates per-shard buffers, so the global result
    holds up to capacity*shards hits, ordered best-first per shard."""

    def local(corpus, ids, q, radius, mask):
        raw = distance_values(metric, corpus, q)
        keys = order_key(metric, raw)
        hit = mask & in_range(metric, raw, radius)
        cap = min(capacity, corpus.shape[0])
        sel_keys, sel_ids, _ = masked_topk(keys, ids, hit, cap)
        count = jnp.sum(hit.astype(jnp.int32)).reshape(1)
        for ax in reversed(axes):
            sel_keys = jax.lax.all_gather(sel_keys, ax, tiled=True)
            sel_ids = jax.lax.all_gather(sel_ids, ax, tiled=True)
            count = jax.lax.all_gather(count, ax, tiled=True)
        valid = jnp.isfinite(sel_keys)
        sims = jnp.where(valid,
                         -sel_keys if metric.is_similarity() else sel_keys,
                         0.0)
        return (jnp.where(valid, sel_ids, -1), sims, valid,
                jnp.sum(count))

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(), P(), P(axes)),
        out_specs=(P(), P(), P(), P()),
        check_rep=False)


# ---------------------------------------------------------------------------
# Query-batched collectives (DESIGN.md §10): shard rows x tile queries
# ---------------------------------------------------------------------------

def merge_topk_level(metric: Metric,
                     keys_a: jnp.ndarray, gids_a: jnp.ndarray,
                     keys_b: jnp.ndarray, gids_b: jnp.ndarray,
                     k: int):
    """One level of the hierarchical per-query candidate merge, as a plain
    (non-collective) function: concatenate two (Q, k_a)/(Q, k_b) candidate
    sets column-wise and row-wise re-select the best ``k``.

    This is exactly what :func:`_merge_topk` does per mesh axis, with the
    ``all_gather`` replaced by a local ``concatenate`` — the live-corpus
    delta segment (DESIGN.md §12) is merged into the main top-k as one
    extra, device-local "shard level" through this primitive.

    ``keys_*`` are ascending order keys with ``+inf`` on empty lanes;
    ``gids_*`` the matching global ids with ``-1`` on empty lanes.  Ties
    resolve to the lowest concatenated column index (``jax.lax.top_k`` is
    stable), so with A = main and B = delta, an empty delta segment leaves
    A's result bit-identical.  Output is padded/truncated to exactly
    (Q, k).  Returns (ids, sims raw-metric, valid)."""
    keys = jnp.concatenate([keys_a, keys_b], axis=1)
    gids = jnp.concatenate([gids_a, gids_b], axis=1)
    neg, idx = jax.lax.top_k(-keys, min(k, keys.shape[1]))
    keys = -neg
    gids = jnp.take_along_axis(gids, idx, axis=1)
    if keys.shape[1] < k:
        pad = k - keys.shape[1]
        keys = jnp.pad(keys, ((0, 0), (0, pad)), constant_values=jnp.inf)
        gids = jnp.pad(gids, ((0, 0), (0, pad)), constant_values=-1)
    valid = jnp.isfinite(keys)
    sims = jnp.where(valid, -keys if metric.is_similarity() else keys, 0.0)
    return jnp.where(valid, gids, -1), sims, valid


def _merge_topk(metric: Metric, keys: jnp.ndarray, gids: jnp.ndarray,
                k: int, axes: tuple[str, ...]):
    """Hierarchical per-query candidate merge (runs INSIDE shard_map).

    ``keys``/``gids`` are this shard's (Q, k_local) winners (order keys
    ascending, +inf on empty lanes; global row ids, -1 on empty lanes).
    Per mesh axis, innermost first: ``all_gather`` the candidate columns
    (tiled along axis 1 — K·Q pairs per participant), row-wise re-select
    the best ``k``, repeat outward.  Returns replicated
    (ids (Q, k), sims raw-metric, valid)."""
    for ax in reversed(axes):
        keys = jax.lax.all_gather(keys, ax, axis=1, tiled=True)
        gids = jax.lax.all_gather(gids, ax, axis=1, tiled=True)
        # clamp per level: an early level's gathered width can undercut k
        # when per-shard buffers are capacity-starved (keeping everything is
        # lossless; later levels widen back past k — see the range merge)
        neg, idx = jax.lax.top_k(-keys, min(k, keys.shape[1]))  # row-wise
        keys = -neg
        gids = jnp.take_along_axis(gids, idx, axis=1)
    valid = jnp.isfinite(keys)
    sims = jnp.where(valid, -keys if metric.is_similarity() else keys, 0.0)
    return jnp.where(valid, gids, -1), sims, valid


def _mask_spec(axes: tuple[str, ...], per_query_mask: bool):
    """shard_map in_spec for the row mask: (Q, Npad) per-query masks shard
    along dim 1; a shared (Npad,) mask (the no-predicate case — only the
    divisibility-pad rows are excluded) shards along its only dim and never
    materializes a (Q, N) array."""
    return P(None, axes) if per_query_mask else P(axes)


def distributed_topk_batch(mesh: Mesh, metric: Metric, k: int,
                           axes: tuple[str, ...] = ("data",),
                           interpret: bool | None = None,
                           per_query_mask: bool = True):
    """Batched filtered exact top-k over a row-sharded corpus.

    The shard × tile composition: each device runs the query-tiled fused
    scan (``kernels.ops.fused_scan_topk_batch`` — distance + filter + top-k
    in one kernel) over its shard for ALL Q queries, then the hierarchical
    per-query merge re-selects K winners per mesh axis (innermost first).
    Only K·Q (id, key) pairs per shard cross the interconnect per level.

    Returns a ``shard_map``'d callable
    ``fn(sh_corpus, sh_ids, qs, sh_mask, qvalid) -> (ids, sims, valid)``:

    * ``sh_corpus`` (Npad, d) rows sharded over ``axes``; ``sh_ids`` (Npad,)
      the matching global row ids (-1 on divisibility-pad rows) — both as
      laid out by :class:`~repro.dist.sharding.ShardedCorpus`;
    * ``qs`` (Q, d) replicated query batch;
    * ``sh_mask`` — the fused predicate of the scan, pad rows False: a
      (Q, Npad) bool per-query mask (``per_query_mask=True``), or, for
      plans with NO row predicate, a shared (Npad,) bool mask
      (``per_query_mask=False`` — typically ``row_ids >= 0``, so no
      (Q, N) array is ever materialized or moved);
    * ``qvalid`` (Q,) bool — the size-bucket pad-query lane: an invalid
      query emits no candidates (all ids -1) and no hits on ANY shard.

    Outputs are (Q, k), replicated.  At shards=1 the merge is an identity
    re-selection over an already-sorted candidate list, so results are
    bit-identical to a single-device ``fused_scan_topk_batch`` call."""

    def local(corpus, ids, qs, mask, qvalid):
        from ..kernels.ops import fused_scan_topk_batch
        lids, lsims, lvalid = fused_scan_topk_batch(
            corpus, qs, k, mask, metric, interpret=interpret, qvalid=qvalid)
        gids = jnp.where(lvalid, ids[jnp.maximum(lids, 0)], -1)
        keys = jnp.where(lvalid, order_key(metric, lsims), jnp.inf)
        return _merge_topk(metric, keys, gids, k, axes)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(None, None),
                  _mask_spec(axes, per_query_mask), P(None)),
        out_specs=(P(None, None), P(None, None), P(None, None)),
        check_rep=False)


def distributed_topk_batch_q(mesh: Mesh, metric: Metric, k: int,
                             axes: tuple[str, ...] = ("data",),
                             interpret: bool | None = None,
                             per_query_mask: bool = True,
                             rescore_factor: int = 2):
    """Quantized twin of :func:`distributed_topk_batch` (DESIGN.md §13).

    Each device streams its int8/bf16 shard through the quantized
    segmented kernel and rescores its own top-(rescore_factor·k)
    candidates against its fp32 shard LOCALLY — so the (id, key) pairs
    entering the hierarchical merge are already exact fp32 keys, bitwise
    the keys the fp32 path would ship, and the merge (and its shards=1
    bit-identity guarantee) is unchanged.  The interconnect still moves
    only K·Q pairs per shard per level; the bandwidth saving is on the
    per-device HBM corpus stream.

    Returns a ``shard_map``'d callable ``fn(sh_corpus, sh_qvecs,
    sh_scales, sh_ids, qs, sh_mask, qvalid) -> (ids, sims, valid)`` with
    ``sh_qvecs``/``sh_scales`` the row-sharded
    :class:`~repro.data.quantized.QuantizedCorpus` arrays (same row
    layout as ``sh_corpus``) and everything else as in the fp32 twin."""

    def local(corpus, qvecs, scales, ids, qs, mask, qvalid):
        from ..kernels.quant import fused_scan_topk_batch_q
        lids, lsims, lvalid = fused_scan_topk_batch_q(
            corpus, qvecs, scales, qs, k, mask, metric,
            rescore_factor=rescore_factor, interpret=interpret,
            qvalid=qvalid)
        gids = jnp.where(lvalid, ids[jnp.maximum(lids, 0)], -1)
        keys = jnp.where(lvalid, order_key(metric, lsims), jnp.inf)
        return _merge_topk(metric, keys, gids, k, axes)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None), P(axes, None), P(axes),
                  P(None, None), _mask_spec(axes, per_query_mask), P(None)),
        out_specs=(P(None, None), P(None, None), P(None, None)),
        check_rep=False)


def distributed_range_batch(mesh: Mesh, metric: Metric, capacity: int,
                            axes: tuple[str, ...] = ("data",),
                            interpret: bool | None = None,
                            per_query_mask: bool = True):
    """Batched filtered range query over a row-sharded corpus.

    Each device runs the query-tiled fused range scan + per-query
    compaction (``kernels.ops.fused_range_topk_batch``) over its shard,
    emitting up to ``min(capacity, shard_rows)`` best-first in-range
    candidates per query; the hierarchical merge then re-truncates the
    concatenated per-shard buffers back to the best ``capacity`` per query
    at every mesh axis.  Because each shard's buffer is a superset of its
    contribution to the global best-``capacity`` set, the merged result is
    EXACTLY the global best-first truncation — the result shape (Q,
    capacity) is shard-count-independent, and per-query hit counts are
    ``psum``'d so ``count`` stays exact even past capacity truncation.

    Returns a ``shard_map``'d callable
    ``fn(sh_corpus, sh_ids, qs, radius, sh_mask, qvalid) ->
    (ids, sims, valid, count)`` with ``radius`` a (Q,) raw-metric vector
    and the other arguments/layouts (including the shared-mask
    ``per_query_mask=False`` form) as in :func:`distributed_topk_batch`.
    ``count`` is (Q,) total in-range hits BEFORE truncation (0 for invalid
    queries).  At shards=1 results are bit-identical to a single-device
    ``fused_range_topk_batch`` call."""

    def local(corpus, ids, qs, radius, mask, qvalid):
        from ..kernels.ops import fused_range_topk_batch
        cap_local = min(capacity, corpus.shape[0])
        lids, lsims, lvalid, lcount = fused_range_topk_batch(
            corpus, qs, radius, mask, metric, cap_local,
            interpret=interpret, qvalid=qvalid)
        gids = jnp.where(lvalid, ids[jnp.maximum(lids, 0)], -1)
        keys = jnp.where(lvalid, order_key(metric, lsims), jnp.inf)
        out_ids, sims, valid = _merge_topk(metric, keys, gids, capacity, axes)
        count = lcount
        for ax in reversed(axes):
            count = jax.lax.psum(count, ax)
        return out_ids, sims, valid, count

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(None, None), P(None),
                  _mask_spec(axes, per_query_mask), P(None)),
        out_specs=(P(None, None), P(None, None), P(None, None), P(None)),
        check_rep=False)


def distributed_range_batch_q(mesh: Mesh, metric: Metric, capacity: int,
                              axes: tuple[str, ...] = ("data",),
                              interpret: bool | None = None,
                              per_query_mask: bool = True,
                              rescore_factor: int = 2):
    """Quantized twin of :func:`distributed_range_batch` (DESIGN.md §13).

    Per-shard slack-band classification + local fp32 boundary rescore
    (``kernels.quant.fused_range_topk_batch_q``), so the merged candidate
    keys AND the ``psum``'d hit counts are exact — bitwise what the fp32
    twin ships at shards=1.  Signature adds the quantized per-row arrays:
    ``fn(sh_corpus, sh_qvecs, sh_scales, sh_half, sh_l1, sh_l2, sh_ids,
    qs, radius, sh_mask, qvalid) -> (ids, sims, valid, count)``."""

    def local(corpus, qvecs, scales, half, l1, l2, ids, qs, radius, mask,
              qvalid):
        from ..kernels.quant import fused_range_topk_batch_q
        cap_local = min(capacity, corpus.shape[0])
        lids, lsims, lvalid, lcount = fused_range_topk_batch_q(
            corpus, qvecs, scales, half, l1, l2, qs, radius, mask, metric,
            cap_local, rescore_factor=rescore_factor, interpret=interpret,
            qvalid=qvalid)
        gids = jnp.where(lvalid, ids[jnp.maximum(lids, 0)], -1)
        keys = jnp.where(lvalid, order_key(metric, lsims), jnp.inf)
        out_ids, sims, valid = _merge_topk(metric, keys, gids, capacity, axes)
        count = lcount
        for ax in reversed(axes):
            count = jax.lax.psum(count, ax)
        return out_ids, sims, valid, count

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None), P(axes, None), P(axes),
                  P(axes), P(axes), P(axes), P(None, None), P(None),
                  _mask_spec(axes, per_query_mask), P(None)),
        out_specs=(P(None, None), P(None, None), P(None, None), P(None)),
        check_rep=False)
