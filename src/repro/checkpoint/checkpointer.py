"""Fault-tolerant checkpointing: async, atomic, reshardable.

Design (1000+-node posture, single-host mechanics here):
* each host writes only its addressable shards (``.npz`` per host) — no
  cross-host traffic at save time;
* a manifest (json) commits the step atomically via rename; readers only
  trust manifested steps, so a mid-save crash is invisible;
* async: serialization happens on a background thread off the train loop
  (device→host copy is the only sync part);
* restore takes a *target sharding tree* — restoring onto a different mesh
  (elastic resize, pod loss) just means device_put with the new shardings:
  data was saved host-complete, so any mesh can consume it;
* keep_last_k garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _is_prng_key(leaf) -> bool:
    try:
        return jax.dtypes.issubdtype(leaf.dtype, jax.dtypes.prng_key)
    except (AttributeError, TypeError):
        return False


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    flat = {}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in leaves:
        key = "/".join(str(p) for p in path)
        if _is_prng_key(leaf):
            flat[key + "__prngkey"] = np.asarray(jax.random.key_data(leaf))
        else:
            flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: Any, keep_last_k: int = 3) -> str:
    """Synchronous save (the async path wraps this)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "host_0.npz"), **flat)
    manifest = {"step": step, "time": time.time(),
                "keys": sorted(flat.keys()), "hosts": 1}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit
    _gc(ckpt_dir, keep_last_k)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            manifest = os.path.join(ckpt_dir, name, "manifest.json")
            if os.path.exists(manifest):
                out.append(int(name.split("_", 1)[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = latest_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional matching pytree of NamedSharding — this is the
    elastic-resize path: the same host-complete arrays are device_put onto
    whatever mesh is currently alive."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "host_0.npz"))
    leaves, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (p, leaf), shard in zip(leaves, shard_leaves):
        key = "/".join(str(x) for x in p)
        if key + "__prngkey" in data:
            restored = jax.random.wrap_key_data(data[key + "__prngkey"])
            out.append(restored)
            continue
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), out)


class Checkpointer:
    """Async wrapper: offloads serialization to a background thread."""

    def __init__(self, ckpt_dir: str, keep_last_k: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep_last_k
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        # sync device->host copy (typed PRNG keys handled by _flatten)
        host_tree = jax.tree.map(
            lambda x: x if _is_prng_key(x) else np.asarray(x), tree)

        def _run():
            try:
                save(self.ckpt_dir, step, host_tree, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
