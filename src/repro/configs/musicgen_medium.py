"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

[arXiv:2306.05284; hf]  Assigned spec: 48L d_model=1536 24H (GQA kv=24 = MHA)
d_ff=6144 vocab=2048.  The EnCodec frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings; the 4-codebook delay
pattern is collapsed to a single stream with one 2048-way head (DESIGN.md)."""
import dataclasses

from ..models.config import ModelConfig

ARCH_ID = "musicgen-medium"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio",
        num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
        d_ff=6144, vocab_size=2048,
        layer_pattern=("full",), mlp_type="gelu",
        input_mode="embeddings", tie_embeddings=False,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        supports_long_context=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        full_config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, q_chunk=32,
        param_dtype="float32", compute_dtype="float32", remat="none")
