"""qwen2-1.5b [dense] — GQA with QKV bias.

[arXiv:2407.10671; hf]  Assigned spec: 28L d_model=1536 12H (GQA kv=2)
d_ff=8960 vocab=151936.  Pure full attention => long_500k skipped (DESIGN.md
§Arch-applicability)."""
import dataclasses

from ..models.config import ModelConfig

ARCH_ID = "qwen2-1.5b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        d_ff=8960, vocab_size=151936,
        layer_pattern=("full",), qkv_bias=True,
        rope_theta=1_000_000.0, tie_embeddings=True, mlp_type="glu",
        param_dtype="bfloat16", compute_dtype="bfloat16",
        supports_long_context=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        full_config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, q_chunk=32,
        param_dtype="float32", compute_dtype="float32", remat="none")
