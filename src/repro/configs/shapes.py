"""Assigned input-shape presets (the 4 LM shapes × 10 archs = 40 cells)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# smoke-scale counterparts (same kinds, CPU-sized)
SMOKE_SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 64, 2),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 96, 1),
    "decode_32k": ShapeConfig("decode_32k", "decode", 64, 2),
    "long_500k": ShapeConfig("long_500k", "decode", 128, 1),
}
