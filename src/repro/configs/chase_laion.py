"""The paper's own workload config: LAION-shaped hybrid-query corpus.

§7.1 scaled to this container (full-scale values in comments); the benchmark
harness consumes this to reproduce Tables 3/4/6/7 and Figures 8/9."""
import dataclasses

from ..core.schema import Metric
from ..index.ivf import ProbeConfig


@dataclasses.dataclass(frozen=True)
class ChaseBenchConfig:
    n_rows: int = 100_000          # paper: 1_000_000
    n_queries: int = 32            # paper: 100 (join benches vmapped over the
                                   # whole queries table; 32 keeps the 1-CPU
                                   # container's wall-clock sane)
    dim: int = 512                 # paper: 512 (CLIP)
    n_modes: int = 256             # synthetic cluster structure
    num_categories: int = 8
    metric: Metric = Metric.INNER_PRODUCT
    nlist: int = 256               # IVF lists (≈ HNSW M=16/ef=48 regime)
    kmeans_iters: int = 10
    k_top: int = 50                # Q1/Q4 K
    k_category: int = 10           # Q5/Q6 K
    range_match_target: int = 120  # §7.1: radius tuned to ~120 matches
    selectivities: tuple = (1.0, 0.9, 0.7, 0.5, 0.3, 0.03)
    probe: ProbeConfig = ProbeConfig(max_probes=64, capacity=4096,
                                     stop_after_no_improve=6,
                                     out_range_stop=4, min_probes=8)
    seed: int = 0


def bench_config() -> ChaseBenchConfig:
    return ChaseBenchConfig()


def smoke_bench_config() -> ChaseBenchConfig:
    return ChaseBenchConfig(n_rows=5000, n_queries=8, dim=64, n_modes=32,
                            nlist=32, kmeans_iters=3,
                            probe=ProbeConfig(max_probes=24, capacity=1024))
