"""grok-1-314b [moe] — 8 experts top-2.

[hf:xai-org/grok-1; unverified]  Assigned spec: 64L d_model=6144 48H (GQA
kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.  Expert count (8) does not divide
the 16-way model axis, so experts tensor-shard their d_ff instead
(shard_mode='ff'; DESIGN.md §5)."""
import dataclasses

from ..models.config import ModelConfig, MoEConfig

ARCH_ID = "grok-1-314b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=32768, vocab_size=131072,
        layer_pattern=("full",), attn_logit_softcap=30.0,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32768,
                      shard_mode="ff"),
        tie_embeddings=True,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        supports_long_context=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        full_config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, q_chunk=32,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                      shard_mode="ff", capacity_factor=8.0),
        param_dtype="float32", compute_dtype="float32", remat="none")
