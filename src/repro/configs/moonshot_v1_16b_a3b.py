"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  Assigned spec: 48L d_model=2048 16H
(GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.  DeepSeek-style shared
experts (2) kept; the first-layer-dense variant is simplified to uniform MoE
(DESIGN.md).  64 experts shard over the 16-way model axis (4/device)."""
import dataclasses

from ..models.config import ModelConfig, MoEConfig

ARCH_ID = "moonshot-v1-16b-a3b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=163840,
        layer_pattern=("full",),
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                      num_shared_experts=2, shard_mode="expert"),
        tie_embeddings=True,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        supports_long_context=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        full_config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=32, vocab_size=512, q_chunk=32,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      num_shared_experts=1, shard_mode="expert",
                      capacity_factor=8.0),
        param_dtype="float32", compute_dtype="float32", remat="none")
