"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from . import (chameleon_34b, chase_laion, gemma2_27b, gemma3_12b,
               grok1_314b, h2o_danube3_4b, mamba2_370m, moonshot_v1_16b_a3b,
               musicgen_medium, qwen2_1_5b, zamba2_1_2b)
from .shapes import SHAPES, SMOKE_SHAPES, ShapeConfig

_MODULES = {
    m.ARCH_ID: m
    for m in (gemma3_12b, h2o_danube3_4b, gemma2_27b, qwen2_1_5b,
              mamba2_370m, zamba2_1_2b, grok1_314b, moonshot_v1_16b_a3b,
              musicgen_medium, chameleon_34b)
}

ARCH_IDS = list(_MODULES)


def get_config(arch: str, smoke: bool = False):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    m = _MODULES[arch]
    return m.smoke_config() if smoke else m.full_config()


def get_shape(name: str, smoke: bool = False) -> ShapeConfig:
    table = SMOKE_SHAPES if smoke else SHAPES
    return table[name]


def cells(include_skipped: bool = True):
    """All 40 (arch, shape) cells; marks long_500k skips per DESIGN.md."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            skipped = (shape == "long_500k"
                       and not cfg.supports_long_context)
            if include_skipped or not skipped:
                out.append((arch, shape, skipped))
    return out


__all__ = ["ARCH_IDS", "get_config", "get_shape", "cells", "SHAPES",
           "SMOKE_SHAPES", "ShapeConfig", "chase_laion"]
