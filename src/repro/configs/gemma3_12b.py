"""gemma3-12b [dense] — 5:1 local:global interleave, 128k context.

[hf:google/gemma-3-1b-pt family; unverified]  Assigned spec: 48L d_model=3840
16H (GQA kv=8) d_ff=15360 vocab=262144.  head_dim=256 per the public gemma3
configs (3840/16=240 is not MXU-lane aligned; noted in DESIGN.md)."""
import dataclasses

from ..models.config import ModelConfig

ARCH_ID = "gemma3-12b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
        head_dim=256, d_ff=15360, vocab_size=262144,
        layer_pattern=("local", "local", "local", "local", "local", "full"),
        sliding_window=1024, rope_theta=1_000_000.0,
        embed_scale=True, tie_embeddings=True, mlp_type="glu",
        param_dtype="bfloat16", compute_dtype="bfloat16",
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        full_config(), num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, sliding_window=16, q_chunk=32,
        param_dtype="float32", compute_dtype="float32", remat="none")
