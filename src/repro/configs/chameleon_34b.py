"""chameleon-34b [vlm] — early-fusion, unified text+VQ-image vocabulary.

[arXiv:2405.09818; unverified]  Assigned spec: 48L d_model=8192 64H (GQA kv=8)
d_ff=22016 vocab=65536.  QK-norm per the public config (training-stability
fix).  The VQ image tokenizer is a STUB: inputs are token ids in the unified
vocab (image patches pre-tokenized by ``input_specs()``)."""
import dataclasses

from ..models.config import ModelConfig

ARCH_ID = "chameleon-34b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=22016, vocab_size=65536,
        layer_pattern=("full",), qk_norm=True,
        tie_embeddings=False,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        supports_long_context=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        full_config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, q_chunk=32,
        param_dtype="float32", compute_dtype="float32", remat="none")
