"""zamba2-1.2b [hybrid] — Mamba2 backbone + weight-shared attention block.

[arXiv:2411.15242; hf]  Assigned spec: 38L d_model=2048 32H (GQA kv=32 = MHA)
d_ff=8192 vocab=32000, ssm_state=64.  The shared transformer block (attention
+ MLP, one set of weights) is applied after every 6 SSM layers, per the Zamba2
scheme; the per-invocation LoRA deltas are omitted (DESIGN.md)."""
import dataclasses

from ..models.config import ModelConfig, SSMConfig

ARCH_ID = "zamba2-1.2b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        layer_pattern=("ssm",) * 6, shared_attn_every=6,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
        tie_embeddings=True,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        full_config(), num_layers=8, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, layer_pattern=("ssm",) * 6,
        shared_attn_every=6, q_chunk=32,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
        param_dtype="float32", compute_dtype="float32", remat="none")
