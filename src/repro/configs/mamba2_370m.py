"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  Assigned spec: 48L d_model=1024 (attn-free)
d_ff=0 vocab=50280, ssm_state=128."""
import dataclasses

from ..models.config import ModelConfig, SSMConfig

ARCH_ID = "mamba2-370m"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        num_layers=48, d_model=1024, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=50280,
        layer_pattern=("ssm",),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128),
        tie_embeddings=True,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        full_config(), num_layers=4, d_model=64, vocab_size=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
        param_dtype="float32", compute_dtype="float32", remat="none")
