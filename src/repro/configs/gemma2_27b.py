"""gemma2-27b [dense] — local/global alternating attention, logit softcaps.

[arXiv:2408.00118; hf]  Assigned spec: 46L d_model=4608 32H (GQA kv=16)
d_ff=36864 vocab=256000.  head_dim=128 per the public config; attn softcap
50.0, final softcap 30.0."""
import dataclasses

from ..models.config import ModelConfig

ARCH_ID = "gemma2-27b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
        head_dim=128, d_ff=36864, vocab_size=256000,
        layer_pattern=("local", "full"), sliding_window=4096,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        embed_scale=True, tie_embeddings=True, mlp_type="glu",
        param_dtype="bfloat16", compute_dtype="bfloat16",
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        full_config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, sliding_window=16, q_chunk=32,
        param_dtype="float32", compute_dtype="float32", remat="none")
