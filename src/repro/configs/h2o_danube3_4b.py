"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]  Assigned spec: 24L d_model=3840 32H (GQA kv=8)
d_ff=10240 vocab=32000, SWA."""
import dataclasses

from ..models.config import ModelConfig

ARCH_ID = "h2o-danube-3-4b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
        d_ff=10240, vocab_size=32000,
        layer_pattern=("local",), sliding_window=4096,
        rope_theta=10000.0, tie_embeddings=False, mlp_type="glu",
        param_dtype="bfloat16", compute_dtype="bfloat16",
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        full_config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, sliding_window=16, q_chunk=32,
        param_dtype="float32", compute_dtype="float32", remat="none")
