"""Unified execution hints — one frozen knob surface for the session API.

Before the session API, the execution knobs were scattered: ``probe_budget``
rode an ad-hoc kwarg on ``execute_bucketed``, the effort pilot lived in
``SchedulerConfig``, and the join lowering override hid inside
``EngineOptions``.  :class:`ExecutionHints` consolidates them, validates
them eagerly (at construction and again against the prepared plan at
execute time), and is frozen/hashable so a hint set can key derived plan
variants in the cache.
"""
from __future__ import annotations

import dataclasses

_JOIN_LOWERINGS = (None, "batch", "perleft")


@dataclasses.dataclass(frozen=True)
class ExecutionHints:
    """How one ``Statement.execute`` call should run.

    * ``probe_budget`` — per-query IVF cluster budget (the straggler valve):
      an int applies to every query, a sequence gives one budget per query.
      Batched executions only (the single-query pipeline has no budget lane).
    * ``pilot_budget`` — > 0 enables two-phase effort-bucketed execution
      (pilot probe round, then re-run only the heavy remainder); bit-identical
      to the lock-step run.  Batched executions only.
    * ``exact_shape`` — route a batch through the exact-shape
      ``execute_batch`` executable (one trace per distinct Q) instead of the
      size-bucketed serving path.  The bit-parity reference for tests.
    * ``join_lowering`` — override ``EngineOptions.join_lowering`` for this
      statement.  Compile-affecting: a differing override re-prepares through
      the plan cache (a distinct options fingerprint is a distinct entry).
    * ``rescore_factor`` — override ``EngineOptions.rescore_factor`` for
      this statement: the candidate multiple c of the quantized scan's
      fused fp32 rescore (DESIGN.md §13; only meaningful when the plan
      compiled with ``EngineOptions.quant``).  Compile-affecting like
      ``join_lowering``: a differing override re-prepares through the plan
      cache.  Raise it on adversarial near-tie corpora where the default
      candidate set is too small for bit-exactness.
    * ``deadline_ms`` / ``priority`` — serving-tier hints (DESIGN.md §11):
      when a statement is served through a scheduler the request carries this
      relative deadline (shed if still queued past it) and drain priority.
      Inert on direct ``Statement.execute`` calls — there is no queue to
      wait in, so a direct call can never expire while queued.
    * ``no_opt`` — opt out of the adaptive optimizer for this call
      (DESIGN.md §14): run the plain lock-step bucketed path even on an
      adaptive session.  Redundant with any explicit execution knob — the
      advisor already yields whenever ``probe_budget`` / ``pilot_budget`` /
      ``exact_shape`` is set (hints always beat the advisor).
    """
    probe_budget: "int | tuple[int, ...] | None" = None
    pilot_budget: int = 0
    exact_shape: bool = False
    join_lowering: str | None = None
    rescore_factor: int | None = None
    deadline_ms: float | None = None
    priority: int = 0
    no_opt: bool = False

    def __post_init__(self):
        pb = self.probe_budget
        if pb is not None and not isinstance(pb, int):
            # normalize array-likes to a hashable tuple so hints stay frozen
            try:
                pb = tuple(int(v) for v in pb)
            except TypeError:
                raise TypeError(
                    f"probe_budget must be an int or a sequence of ints, "
                    f"got {self.probe_budget!r}") from None
            object.__setattr__(self, "probe_budget", pb)
        if isinstance(pb, int) and pb < 1:
            raise ValueError(f"probe_budget must be >= 1, got {pb}")
        if isinstance(pb, tuple) and any(v < 1 for v in pb):
            raise ValueError(f"per-query probe_budget entries must be >= 1, "
                             f"got {pb}")
        if self.pilot_budget < 0:
            raise ValueError(
                f"pilot_budget must be >= 0, got {self.pilot_budget}")
        if self.join_lowering not in _JOIN_LOWERINGS:
            raise ValueError(
                f"join_lowering must be one of {_JOIN_LOWERINGS[1:]}, "
                f"got {self.join_lowering!r}")
        if self.rescore_factor is not None and (
                not isinstance(self.rescore_factor, int)
                or self.rescore_factor < 1):
            raise ValueError(
                f"rescore_factor must be an int >= 1, "
                f"got {self.rescore_factor!r}")
        if self.exact_shape and self.pilot_budget > 0:
            raise ValueError(
                "exact_shape and pilot_budget are mutually exclusive: "
                "effort bucketing rides the size-bucketed executor")
        if self.exact_shape and self.probe_budget is not None:
            raise ValueError(
                "exact_shape and probe_budget are mutually exclusive: the "
                "exact-shape executable has no probe-budget lane")
        if self.pilot_budget > 0 and self.probe_budget is not None:
            raise ValueError(
                "pilot_budget and probe_budget are mutually exclusive: "
                "effort bucketing IS a probe-budget schedule (the pilot caps "
                "phase 1; phase 2 re-runs the heavy remainder unbudgeted)")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be positive, got {self.deadline_ms}")

    # -- plan-dependent validation (called by Statement) --------------------

    def validate_for_plan(self, batch_native: bool, batch_reason: str) -> None:
        """Reject hints the prepared plan cannot honor (better a loud error
        at execute time than a silently ignored budget)."""
        if not batch_native and self.probe_budget is not None:
            raise ValueError(
                f"probe_budget cannot be honored: the plan's batched "
                f"lowering is {batch_reason} (no probe-budget lane); drop "
                f"the hint or use join_lowering='batch'")

    def validate_for_single(self) -> None:
        """Batch-only hints are errors on the single-query path."""
        for name in ("probe_budget", "pilot_budget", "exact_shape"):
            val = getattr(self, name)
            if val not in (None, 0, False):
                raise ValueError(
                    f"{name} applies to batched execution; a single bind "
                    f"dict runs the single-query pipeline (pass a "
                    f"one-element binds list to run it batched)")
