"""The session API: ``connect(catalog) -> Database``, ``db.prepare(sql) ->
Statement``, ONE ``Statement.execute`` front door.

Motivation (DESIGN.md §9): the engine grew three differently-shaped execute
surfaces (``CompiledQuery.__call__`` / ``execute_batch`` /
``execute_bucketed``) plus ad-hoc kwargs for the execution knobs, and every
caller (scheduler, RAG retriever, benchmarks) re-wrapped them.  The session
API is the single front door:

* ``Statement.execute(binds)`` routes automatically — a single bind dict
  runs the single-query pipeline, a list of dicts (or a stacked dict with a
  leading Q axis) runs the size-bucketed serving path; the exact-shape batch
  executable stays reachable via ``ExecutionHints(exact_shape=True)`` (the
  bit-parity reference).
* ``Database`` fronts a **normalized plan cache**: the key is the
  canonicalized logical-plan fingerprint (whitespace / parameter-rename /
  conjunct-order variants of one query collapse to one key) plus the
  ``EngineOptions`` fingerprint plus the canonicalized static binds.  A hit
  reuses the ``CompiledPlan`` AND its ``BucketedExecutor`` bucket cache —
  preparing a variant compiles zero new executables.
* ``db.serve(statement)`` wraps :class:`~repro.serving.scheduler.BatchScheduler`
  for async submit/poll serving on the same cached executables.

Every path returns structured :class:`~repro.api.result.Result` /
:class:`~repro.api.result.ResultBatch` objects with an ``explain()`` handle
reporting cache hit, chosen lowering, and live executor state.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any

import numpy as np

from ..core.compiler import (CompiledQuery, StalePlanError, compile_plan,
                             fingerprint_digest, plan_fingerprint,
                             _scan_of, _stacked_qn)
from ..core.expr import Param
from ..core.physical import EngineOptions
from ..core.schema import Catalog
from ..core.sql import parse_sql
from .hints import ExecutionHints
from .result import ExplainReport, Result, ResultBatch

NO_HINTS = ExecutionHints()


@dataclasses.dataclass(frozen=True)
class CacheInfo:
    """Plan-cache statistics snapshot (functools-style).

    ``aot`` is the persistent disk cache's counter snapshot
    (hits / misses / corrupt / stale / errors / saves — DESIGN.md §15)
    when the session connected with ``aot_cache_path``, else None."""
    hits: int
    misses: int
    entries: int
    evictions: int = 0
    max_entries: "int | None" = None
    aot: "dict | None" = None


@dataclasses.dataclass
class _CacheEntry:
    """One normalized plan: the compiled artifact plus ITS parameter names in
    canonical slot order (variants translate their names slot-by-slot).

    ``evicted`` flips when the LRU bound (or a stale-plan invalidation)
    drops the entry from the cache: Statements still holding it re-prepare
    transparently on their next execute and release the dead compiled
    object (so eviction actually frees the executables)."""
    compiled: CompiledQuery
    param_order: tuple[str, ...]
    fingerprint: str
    evicted: bool = False


def connect(catalog: Catalog, options: EngineOptions | None = None,
            max_cached_plans: int | None = 128, adaptive: bool = False,
            stats_path: str | None = None,
            aot_cache_path: str | None = None,
            **option_overrides) -> "Database":
    """Open a session over a catalog — the one front door to the engine.

    ``option_overrides`` are convenience kwargs onto :class:`EngineOptions`
    (``connect(cat, engine="chase", use_pallas=True)``);
    ``max_cached_plans`` bounds the normalized plan cache (LRU; None =
    unbounded).  ``adaptive=True`` attaches a
    :class:`~repro.opt.LoweringAdvisor` (DESIGN.md §14): batched executions
    feed runtime stats back and get predicted probe budgets, hints always
    winning; ``stats_path`` persists/restores the stats store there.
    ``aot_cache_path`` names a directory for the persistent AOT plan cache
    (DESIGN.md §15): compiled bucket executables are persisted
    write-through and restored on restart with zero retraces, so a fresh
    process preparing a previously-seen statement is warm."""
    if option_overrides:
        options = dataclasses.replace(options or EngineOptions(),
                                      **option_overrides)
    return Database(catalog, options or EngineOptions(),
                    max_cached_plans=max_cached_plans, adaptive=adaptive,
                    stats_path=stats_path, aot_cache_path=aot_cache_path)


class Database:
    """A connection-like session: catalog + options + normalized plan cache.

    The cache is LRU-bounded (``max_cached_plans``): long-running sessions
    preparing many distinct statements evict the least-recently-prepared
    plan instead of holding every executable ever compiled.  A
    :class:`Statement` still holding an evicted entry re-prepares through
    the cache transparently on its next execute."""

    def __init__(self, catalog: Catalog, options: EngineOptions | None = None,
                 max_cached_plans: int | None = 128, adaptive: bool = False,
                 stats_path: str | None = None,
                 aot_cache_path: str | None = None):
        if max_cached_plans is not None and max_cached_plans < 1:
            raise ValueError(
                f"max_cached_plans must be >= 1 or None, "
                f"got {max_cached_plans}")
        self.catalog = catalog
        self.options = options or EngineOptions()
        self.max_cached_plans = max_cached_plans
        self.advisor = None
        if adaptive:
            from ..opt import LoweringAdvisor
            self.advisor = LoweringAdvisor(catalog, stats_path=stats_path)
        self.aot_cache = None
        if aot_cache_path is not None:
            from ..core.aot import AOTPlanCache
            self.aot_cache = AOTPlanCache(aot_cache_path)
        self._cache: "collections.OrderedDict[tuple, _CacheEntry]" = (
            collections.OrderedDict())
        self._hits = 0
        self._misses = 0
        self._evictions = 0

    # -- prepared statements ------------------------------------------------

    def prepare(self, sql: str, hints: ExecutionHints | None = None,
                options: EngineOptions | None = None,
                **static_binds) -> "Statement":
        """Parse, normalize, and compile (or reuse) a statement.

        ``hints`` become the statement's default execution hints; a
        ``join_lowering`` hint is compile-affecting and folds into the
        options fingerprint (its own cache entry).  ``static_binds`` resolve
        shape-forming parameters (K values) and are part of the cache key in
        canonical slot order, so ``LIMIT ${K}`` with K=4 and K=8 are two
        entries while a renamed K parameter is still one."""
        hints = hints or NO_HINTS
        base_options = options or self.options
        eff_options = base_options
        if hints.join_lowering is not None:
            eff_options = dataclasses.replace(
                eff_options, join_lowering=hints.join_lowering)
        if hints.rescore_factor is not None:
            eff_options = dataclasses.replace(
                eff_options, rescore_factor=hints.rescore_factor)
        plan = parse_sql(sql)
        fp, param_order = plan_fingerprint(plan)
        key = (fp, eff_options.fingerprint(),
               self._static_key(static_binds, param_order))
        entry = self._cache.get(key)
        if entry is not None:
            try:
                # catalog-version check on the hit path: a structurally
                # stale entry (table re-registered, index presence flipped)
                # must recompile, not resurface frozen closures
                entry.compiled.ensure_fresh()
            except StalePlanError:
                self._evict(key)
                entry = None
        if entry is None:
            self._misses += 1
            compiled = compile_plan(sql, plan, self.catalog, eff_options,
                                    dict(static_binds))
            if self.aot_cache is not None:
                # route the fresh executor through the persistent cache:
                # previously-persisted buckets load with zero traces, cold
                # buckets export + persist write-through — which is what
                # makes LRU eviction evict to disk, not to nothing
                from ..core.aot import AOTBinding
                compiled.executor.attach_aot(AOTBinding(
                    self.aot_cache, key, self.catalog, compiled._dep_keys))
            entry = _CacheEntry(compiled, param_order, fp)
            self._cache[key] = entry
            self._trim()
            cache_hit = False
        else:
            self._hits += 1
            self._cache.move_to_end(key)
            cache_hit = True
        return Statement(self, sql, entry, param_order, hints, cache_hit,
                         base_options, dict(static_binds))

    def execute(self, sql: str, binds=None,
                hints: ExecutionHints | None = None, **static_binds):
        """One-shot convenience: ``prepare`` (cached) + ``execute``."""
        return self.prepare(sql, hints=hints, **static_binds).execute(binds)

    def serve(self, statement: "Statement | str", config=None, *,
              max_batch: int = 64, max_wait_ms: float = 2.0,
              pilot_budget: int = 0, policy=None, faults=None,
              **static_binds):
        """An async submit/poll server over one prepared statement.

        Wraps :class:`~repro.serving.scheduler.BatchScheduler`: requests
        coalesce under the deadline rule and drain through the statement's
        size-bucketed executor cache (``pilot_budget`` > 0 adds two-phase
        effort-bucketed IVF probing).  Passing a ``policy``
        (:class:`~repro.serving.resilience.DegradePolicy`) or ``faults``
        (:class:`~repro.serving.faults.FaultInjector`) upgrades to a
        :class:`~repro.serving.scheduler.ResilientScheduler` with graceful
        degradation under overload (DESIGN.md §11)."""
        from ..serving.scheduler import (BatchScheduler, ResilientScheduler,
                                         SchedulerConfig)
        if isinstance(statement, str):
            statement = self.prepare(statement, **static_binds)
        elif static_binds:
            raise TypeError(
                f"static binds {sorted(static_binds)} cannot be applied to "
                f"an already-prepared Statement; pass them to prepare(), or "
                f"pass the SQL string to serve()")
        if config is None:
            config = SchedulerConfig(max_batch=max_batch,
                                     max_wait_ms=max_wait_ms,
                                     pilot_budget=pilot_budget)
        if policy is not None or faults is not None:
            return ResilientScheduler(statement, config, policy=policy,
                                      faults=faults)
        return BatchScheduler(statement, config, advisor=self.advisor)

    def advise(self, sql: str, selectivity: float = 1.0,
               **static_binds) -> dict:
        """Prepare-time lowering advice for ``sql``: cost-model scores of
        the flat / IVF / quantized lanes for this plan's corpus under a
        selectivity estimate, plus the recommended lane and the calibrated
        constants (DESIGN.md §14).  Advisory — execute-time adaptive
        decisions stay within bit-identical effort lanes; use the
        recommendation to pick ``EngineOptions`` at prepare time."""
        st = self.prepare(sql, **static_binds)
        advisor = self.advisor
        if advisor is None:
            from ..opt import LoweringAdvisor
            advisor = LoweringAdvisor(self.catalog)
        return advisor.score_plan(st.compiled, selectivity=selectivity)

    def cache_info(self) -> CacheInfo:
        """Hits / misses / live entries / evictions of the plan cache, plus
        the disk-cache counter snapshot when ``aot_cache_path`` is set."""
        return CacheInfo(self._hits, self._misses, len(self._cache),
                         self._evictions, self.max_cached_plans,
                         aot=(None if self.aot_cache is None
                              else self.aot_cache.stats()))

    # -- live corpus mutations (DESIGN.md §12) ------------------------------

    def attach_live(self, table: str, column: str, path, **kw):
        """Attach a :class:`~repro.data.mutations.LiveCorpus` to a (table,
        vector column) pair, making ``db.insert`` / ``db.delete`` available
        and every subsequently prepared plan on the pair delta-aware.
        Delegates to :func:`repro.data.mutations.attach_live` (same kwargs:
        ``delta_cap``, ``cap_main``, ``nlist``, ``seed``, ``ids``, ...)."""
        from ..data.mutations import attach_live
        return attach_live(self.catalog, table, column, path, **kw)

    def _live_handle(self, table: str, column: str | None):
        """Resolve the LiveCorpus for a mutation call (typed error when the
        pair has none attached, or the column is ambiguous)."""
        from ..serving.resilience import MutationError
        if column is None:
            cols = self.catalog.live_columns(table)
            if len(cols) != 1:
                raise MutationError(
                    f"table {table!r} has {len(cols)} live vector columns "
                    f"({sorted(cols)}); pass column= explicitly" if cols else
                    f"table {table!r} has no live corpus attached; call "
                    f"db.attach_live(table, column, path) first")
            column = cols[0]
        live = self.catalog.live_for(table, column)
        if live is None:
            raise MutationError(
                f"no live corpus attached to ({table!r}, {column!r}); call "
                f"db.attach_live(table, column, path) first")
        return live

    def insert(self, table: str, ids, vectors, columns=None, *,
               column: str | None = None) -> int:
        """Insert rows into a live corpus — visible to every prepared plan
        on its next execute with zero retraces.  Returns the mutation's LSN.
        ``column`` may be omitted when the table has exactly one live vector
        column."""
        return self._live_handle(table, column).insert(ids, vectors, columns)

    def delete(self, table: str, ids, *, column: str | None = None) -> int:
        """Tombstone rows of a live corpus by user id (visible on next
        execute, zero retraces).  Returns the mutation's LSN."""
        return self._live_handle(table, column).delete(ids)

    def compact(self, table: str, *, column: str | None = None) -> int:
        """Fold a live corpus's deltas + tombstones back into the main
        segment (re-clustering the IVF index when one is registered) and
        return the compaction's LSN."""
        return self._live_handle(table, column).compact()

    def freshness(self, table: str, *, column: str | None = None) -> dict:
        """The live corpus's freshness counters (delta rows, tombstones,
        LSNs) — the same dict ``explain()`` reports per statement."""
        return self._live_handle(table, column).freshness()

    # -- internals ----------------------------------------------------------

    def _evict(self, key: tuple) -> None:
        entry = self._cache.pop(key, None)
        if entry is not None:
            entry.evicted = True
            self._evictions += 1

    def _trim(self) -> None:
        if self.max_cached_plans is None:
            return
        while len(self._cache) > self.max_cached_plans:
            self._evict(next(iter(self._cache)))

    @staticmethod
    def _static_key(static_binds: dict, param_order: tuple[str, ...]) -> tuple:
        """Static binds keyed by canonical parameter SLOT (rename-proof)."""
        def slot(name: str):
            return (param_order.index(name) if name in param_order
                    else ("name", name))

        def val(v: Any):
            try:
                hash(v)
                return v
            except TypeError:
                return repr(np.asarray(v).tolist())

        return tuple(sorted(
            ((slot(k), val(v)) for k, v in static_binds.items()),
            key=repr))


class Statement:
    """A prepared statement: the cached plan + this statement's bind-name
    translation.  One ``execute`` front door for every execution shape."""

    def __init__(self, db: Database, sql: str, entry: _CacheEntry,
                 param_order: tuple[str, ...], hints: ExecutionHints,
                 cache_hit: bool, base_options: EngineOptions,
                 static_binds: dict):
        self._db = db
        self.sql = sql
        self._entry = entry
        self._param_order = param_order
        self.hints = hints
        self.cache_hit = cache_hit
        # what prepare() saw BEFORE hint folding — a join_lowering re-route
        # must re-prepare with the same options base and static binds
        self._base_options = base_options
        self._static_binds = static_binds
        # this statement's param name -> the cached plan's name, slot-aligned
        self._rename = {a: b for a, b in zip(param_order, entry.param_order)
                        if a != b}

    # -- delegation surface (also the BatchScheduler contract) --------------

    @property
    def compiled(self) -> CompiledQuery:
        """The (shared, cached) compiled handle behind this statement."""
        return self._entry.compiled

    @property
    def executor(self):
        """The shared BucketedExecutor (bucket cache) of the cached plan."""
        return self._entry.compiled.executor

    @property
    def batch_native(self) -> bool:
        """True when the plan's batched lowering is native (no vmap)."""
        return self._entry.compiled.batch_native

    def ensure_fresh(self) -> None:
        """Make this statement's entry current before execution.

        Two recoveries, both transparent to the caller (DESIGN.md §11):

        * the entry was **evicted** from the LRU-bounded plan cache — drop
          the dead reference and re-prepare through the cache (releasing the
          evicted executables for real);
        * the catalog moved **structurally** under the plan
          (:class:`~repro.core.compiler.StalePlanError`) — re-prepare, which
          recompiles against the current catalog.  Plain index replacements
          never reach here: ``CompiledQuery.ensure_fresh`` re-binds them in
          place with zero retraces."""
        if not self._entry.evicted:
            try:
                self._entry.compiled.ensure_fresh()
                return
            except StalePlanError:
                pass
        fresh = self._db.prepare(self.sql, hints=self.hints,
                                 options=self._base_options,
                                 **self._static_binds)
        self._entry = fresh._entry
        self._rename = fresh._rename
        self.cache_hit = fresh.cache_hit

    def _stack_binds(self, binds_list, stacked) -> dict:
        if binds_list is not None:
            binds_list = [self._renamed(b) for b in binds_list]
        if stacked:
            stacked = self._renamed(stacked)
        return self.compiled._stack_binds(binds_list, stacked)

    # -- execution ----------------------------------------------------------

    def execute(self, binds=None, hints: ExecutionHints | None = None):
        """THE execute front door.

        * dict of scalar-per-query binds  -> single-query pipeline,
        * list/tuple of bind dicts        -> size-bucketed batch,
        * stacked dict (leading Q axis)   -> size-bucketed batch,
        * ``hints.exact_shape=True``      -> exact-shape batch executable.

        Returns :class:`Result` (single) or :class:`ResultBatch` (batch);
        both are bit-identical to the legacy ``CompiledQuery`` surfaces."""
        self.ensure_fresh()
        hints = self.hints if hints is None else hints
        if (hints.join_lowering is not None
                and hints.join_lowering != self.compiled.options.join_lowering
                ) or (hints.rescore_factor is not None
                      and hints.rescore_factor
                      != self.compiled.options.rescore_factor):
            # compile-affecting hint: re-route through the plan cache (a
            # distinct options fingerprint is a distinct — cached — entry),
            # carrying this statement's options base and static binds
            return self._db.prepare(
                self.sql, hints=hints, options=self._base_options,
                **self._static_binds).execute(binds, hints=hints)
        if binds is None:
            binds = {}
        if isinstance(binds, (list, tuple)):
            return self._execute_batch([self._renamed(b) for b in binds],
                                       None, hints)
        if not isinstance(binds, dict):
            raise TypeError(
                f"binds must be a dict (single query), a list of dicts, or "
                f"a stacked dict with a leading Q axis; got {type(binds)}")
        renamed = self._renamed(binds)
        if self._is_stacked(renamed):
            return self._execute_batch(None, renamed, hints)
        hints.validate_for_single()
        out = self.compiled._jitted(self.compiled._arrays, dict(renamed))
        report = self._report_fn(path="single", num_queries=1, hints=hints)
        return Result(out, report)

    def _execute_batch(self, binds_list, stacked_binds,
                       hints: ExecutionHints):
        compiled = self.compiled
        hints.validate_for_plan(compiled.batch_native,
                                compiled.plan.batch_reason)
        binds = compiled._stack_binds(binds_list, stacked_binds or {})
        qn = _stacked_qn(binds)
        probe_budget = hints.probe_budget
        if isinstance(probe_budget, tuple):
            if len(probe_budget) != qn:
                raise ValueError(
                    f"per-query probe_budget has {len(probe_budget)} "
                    f"entries for a batch of {qn} queries")
            probe_budget = np.asarray(probe_budget, np.int32)
        effort = None
        opt = None
        advisor = self._db.advisor
        if hints.exact_shape:
            path = "batch"
            out = compiled._batch_jitted(compiled._arrays, binds)
        elif hints.pilot_budget > 0:
            from ..serving.scheduler import run_effort_bucketed
            path = "effort"
            out, effort = run_effort_bucketed(compiled, binds,
                                              hints.pilot_budget)
        elif (advisor is not None and advisor.enabled and not hints.no_opt
                and probe_budget is None and compiled.batch_native):
            # the adaptive path (DESIGN.md §14): hints always win — this
            # branch is only reachable when the caller set NO execution
            # knob, so the advisor never overrides an explicit choice
            from ..serving.scheduler import run_effort_bucketed
            path = "opt"
            out, effort = run_effort_bucketed(compiled, binds, 0,
                                              advisor=advisor)
            opt = effort.pop("opt", None)
        else:
            path = "bucketed"
            out = compiled.executor(binds, probe_budget=probe_budget)
        bucket = (compiled.executor.bucket_for(qn)
                  if path in ("bucketed", "effort", "opt") else None)
        report = self._report_fn(path=path, bucket=bucket, num_queries=qn,
                                 hints=hints, effort=effort, opt=opt)
        return ResultBatch(out, report, qn)

    # -- explain ------------------------------------------------------------

    def explain(self) -> ExplainReport:
        """Live statement-level report (no execution context)."""
        return self._report_fn()()

    def _report_fn(self, **exec_fields):
        """Build an explain closure: called lazily so ``buckets`` and
        ``trace_counts`` reflect the executor state WHEN explain() runs."""
        def build() -> ExplainReport:
            c = self.compiled
            ex = c.executor
            dist = c.options.dist
            # freshness is read WHEN explain() runs (like trace_counts), so
            # the report reflects mutations that landed after execution
            live = self._db.catalog.live_for(*_scan_of(c.analysis))
            return ExplainReport(
                sql=self.sql,
                engine=c.options.engine,
                query_class=c.analysis.query_class.value,
                plan_key=fingerprint_digest(self._entry.fingerprint),
                cache_hit=self.cache_hit,
                batch_native=c.batch_native,
                batch_lowering=c.plan.batch_reason,
                buckets=tuple(ex.buckets),
                trace_counts=dict(ex.trace_counts),
                logical_plan=c.logical_plan.pretty(),
                rewritten_plan=c.rewritten_plan.pretty(),
                shards=None if dist is None else dist.num_shards,
                merge_depth=None if dist is None else dist.merge_depth,
                freshness=None if live is None else live.freshness(),
                aot=(None if self._db.aot_cache is None else
                     {**self._db.aot_cache.stats(),
                      "loaded": dict(ex.aot_loaded)}),
                **exec_fields)

        return build

    # -- internals ----------------------------------------------------------

    def _renamed(self, binds: dict) -> dict:
        unknown = [k for k in binds if k not in self._param_order]
        if unknown:
            raise ValueError(
                f"unknown bind parameter(s) {sorted(unknown)}; this "
                f"statement's parameters are {sorted(self._param_order)}")
        if not self._rename:
            return binds
        return {self._rename.get(k, k): v for k, v in binds.items()}

    def _is_stacked(self, binds: dict) -> bool:
        """A dict routes to the batch path iff it is stacked: the query
        vector carries (Q, D), or — for plans whose query expression is a
        plan column (joins) — any bind carries a leading Q axis."""
        qe = self.compiled.analysis.query_expr
        if isinstance(qe, Param) and qe.name in binds:
            return np.ndim(binds[qe.name]) >= 2
        return any(np.ndim(v) >= 1 for v in binds.values())

    def __repr__(self):
        return (f"Statement(class={self.compiled.analysis.query_class.value}, "
                f"plan={fingerprint_digest(self._entry.fingerprint)}, "
                f"cache_hit={self.cache_hit})")
