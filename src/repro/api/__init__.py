"""Session API — the one front door to the CHASE engine (DESIGN.md §9).

    from repro.api import connect, ExecutionHints

    db = connect(catalog)                      # session + normalized plan cache
    stmt = db.prepare(sql, K=10)               # cached across textual variants
    res = stmt.execute({"qv": q, "p": 12.0})   # single -> Result
    batch = stmt.execute([b1, b2, b3])         # list -> bucketed ResultBatch
    print(batch.explain())                     # cache hit, lowering, buckets
    server = db.serve(stmt)                    # async submit/poll scheduler

Distributed plans ride the same front door: ``connect(catalog,
options=EngineOptions(dist=DistSpec(mesh_shape=(4,))))`` row-shards the
scanned corpus over 4 devices and every execute path (single / bucketed /
exact-shape) runs the shard × tile composition of DESIGN.md §10;
``explain()`` reports the shard count and merge depth, and a mesh change
misses the plan cache.

Legacy shim: :func:`repro.core.compile_query` still works and returns the
same bit-identical results — but compiles fresh on every call instead of
hitting the plan cache.
"""
from ..core.aot import AOTCacheWarning
from ..dist.sharding import DistSpec
from .database import CacheInfo, Database, Statement, connect
from .hints import ExecutionHints
from .result import ExplainReport, Result, ResultBatch

__all__ = ["connect", "Database", "Statement", "CacheInfo", "DistSpec",
           "ExecutionHints", "ExplainReport", "Result", "ResultBatch",
           "AOTCacheWarning"]
