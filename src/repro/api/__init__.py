"""Session API — the one front door to the CHASE engine (DESIGN.md §9).

    from repro.api import connect, ExecutionHints

    db = connect(catalog)                      # session + normalized plan cache
    stmt = db.prepare(sql, K=10)               # cached across textual variants
    res = stmt.execute({"qv": q, "p": 12.0})   # single -> Result
    batch = stmt.execute([b1, b2, b3])         # list -> bucketed ResultBatch
    print(batch.explain())                     # cache hit, lowering, buckets
    server = db.serve(stmt)                    # async submit/poll scheduler

Legacy shim: :func:`repro.core.compile_query` still works and returns the
same bit-identical results — but compiles fresh on every call instead of
hitting the plan cache.
"""
from .database import CacheInfo, Database, Statement, connect
from .hints import ExecutionHints
from .result import ExplainReport, Result, ResultBatch

__all__ = ["connect", "Database", "Statement", "CacheInfo",
           "ExecutionHints", "ExplainReport", "Result", "ResultBatch"]
