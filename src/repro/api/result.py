"""Structured results for the session API.

The legacy execute surfaces returned raw pytrees whose keys varied by query
class (``ids`` vs ``qid``/``tid``, optional ``count``/``rank``).  The session
API wraps every execution in :class:`Result` / :class:`ResultBatch`:

* the raw tree stays reachable (``res.data`` and ``res["ids"]``) so the
  wrappers are bit-transparent — parity tests compare leaves directly;
* uniform accessors (``ids``, ``order_keys``, ``valid``, ``counters``) work
  across all six query classes;
* ``explain()`` returns a live :class:`ExplainReport` — plan-cache hit,
  chosen batch lowering, and the *current* ``BucketedExecutor`` state
  (compiled buckets, trace counts), so serving regressions are diagnosable
  without a debugger.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from .hints import ExecutionHints


@dataclasses.dataclass(frozen=True)
class ExplainReport:
    """One execution's (or prepared statement's) explain snapshot.

    ``buckets`` / ``trace_counts`` reflect the executor state at the moment
    ``explain()`` was called — live, not frozen at prepare time."""
    sql: str
    engine: str
    query_class: str
    plan_key: str                       # fingerprint digest (cache identity)
    cache_hit: bool
    batch_native: bool
    batch_lowering: str                 # human-readable chosen lowering
    buckets: tuple[int, ...]            # compiled bucket executables (sorted)
    trace_counts: dict[int, int]        # bucket -> times (re)traced
    logical_plan: str
    rewritten_plan: str
    path: str | None = None             # single | batch | bucketed | effort
    bucket: int | None = None           # bucket this execution ran in
    num_queries: int | None = None
    hints: ExecutionHints | None = None
    effort: dict | None = None          # n_light / n_heavy split, if any
    opt: dict | None = None             # advisor decision (DESIGN.md §14)
    shards: int | None = None           # corpus shard count (dist plans)
    merge_depth: int | None = None      # hierarchical-merge levels (dist)
    degraded: dict | None = None        # overload level/budget, if degraded
    freshness: dict | None = None       # live-corpus state, if one attached
    aot: dict | None = None             # persistent-plan-cache counters +
                                        # per-bucket disk loads (§15)

    def render(self) -> str:
        """Multi-line text form (what ``print(explain())`` shows)."""
        out = [f"-- engine: {self.engine}",
               f"-- class:  {self.query_class}",
               f"-- plan:   {self.plan_key} "
               f"({'cache hit' if self.cache_hit else 'compiled'})",
               f"-- batch:  {self.batch_lowering}"]
        if self.shards is not None:
            out.append(f"-- dist:   shards={self.shards} "
                       f"merge_depth={self.merge_depth}")
        out.append(f"-- buckets: {list(self.buckets)} "
                   f"trace_counts={self.trace_counts}")
        if self.path is not None:
            exec_line = f"-- exec:   path={self.path}"
            if self.bucket is not None:
                exec_line += f" bucket={self.bucket}"
            if self.num_queries is not None:
                exec_line += f" queries={self.num_queries}"
            out.append(exec_line)
        if self.effort is not None:
            out.append(f"-- effort: {self.effort}")
        if self.opt is not None:
            out.append(f"-- opt:    {self.opt}")
        if self.aot is not None:
            out.append(f"-- aot:    hits={self.aot.get('hits')} "
                       f"misses={self.aot.get('misses')} "
                       f"corrupt={self.aot.get('corrupt')} "
                       f"stale={self.aot.get('stale')} "
                       f"saves={self.aot.get('saves')} "
                       f"loaded={self.aot.get('loaded')}")
        if self.degraded is not None:
            out.append(f"-- DEGRADED: overload level="
                       f"{self.degraded.get('level')} "
                       f"probe_budget={self.degraded.get('probe_budget')}")
        if self.freshness is not None:
            out.append(f"-- live:   delta_rows="
                       f"{self.freshness.get('delta_rows')} "
                       f"tombstones={self.freshness.get('tombstones')} "
                       f"lsn={self.freshness.get('lsn')} "
                       f"last_compact_lsn="
                       f"{self.freshness.get('last_compact_lsn')}")
        out += ["-- logical plan:", self.logical_plan,
                "-- rewritten plan:", self.rewritten_plan]
        return "\n".join(out)

    def __str__(self) -> str:
        return self.render()


class Result:
    """A single query's structured result (leaves have no leading Q axis)."""

    def __init__(self, data: dict, explain_fn: Callable[[], ExplainReport]):
        self.data = data
        self._explain_fn = explain_fn

    # -- raw-tree transparency ---------------------------------------------
    def __getitem__(self, key: str):
        return self.data[key]

    def __contains__(self, key: str) -> bool:
        return key in self.data

    def keys(self):
        """Raw output-tree keys (dict-transparent surface)."""
        return self.data.keys()

    def get(self, key: str, default=None):
        """dict.get over the raw output tree."""
        return self.data.get(key, default)

    # -- uniform accessors --------------------------------------------------
    @property
    def ids(self):
        """Result row ids (``ids`` for single-table classes, ``tid`` —
        the right-side target ids — for the join families)."""
        return self.data["ids"] if "ids" in self.data else self.data["tid"]

    @property
    def order_keys(self):
        """Raw similarity/distance values the ordering ran on (the map
        operator's ``__sim`` — never recomputed downstream)."""
        return self.data["sim"]

    @property
    def valid(self):
        """Per-result validity mask (False lanes are empty buffer slots)."""
        return self.data["valid"]

    @property
    def counters(self) -> dict:
        """Per-query execution counters (probes, distance evals, ...)."""
        return self.data.get("stats", {})

    def explain(self) -> ExplainReport:
        """Live execution report (cache hit, lowering, executor state)."""
        return self._explain_fn()

    def __repr__(self):
        keys = ",".join(sorted(self.data))
        return f"{type(self).__name__}(keys=[{keys}])"


class ResultBatch(Result):
    """A batched execution's structured result: every leaf carries a leading
    Q axis; ``len()`` is the number of queries and ``query(i)`` slices one
    query's view (host-side — never triggers a recompile)."""

    def __init__(self, data: dict, explain_fn: Callable[[], ExplainReport],
                 num_queries: int):
        super().__init__(data, explain_fn)
        self.num_queries = num_queries

    def __len__(self) -> int:
        return self.num_queries

    def query(self, i: int) -> Result:
        """One query's view of the batch (host-side slice; no recompile)."""
        if not -self.num_queries <= i < self.num_queries:
            raise IndexError(f"query index {i} out of range for batch of "
                             f"{self.num_queries}")

        def slice_leaf(v: Any):
            return np.asarray(v)[i]

        import jax
        return Result(jax.tree.map(slice_leaf, self.data), self._explain_fn)
