"""Q12 — live-corpus freshness: insert→visible latency, scan QPS under
delta fill, and the compaction pause (DESIGN.md §12).

Replaces the orphaned Fig. 9 ablation (updateState on/off) with the
measurement the delta/tombstone subsystem actually needs defended:

* **zero-delta overhead** — the live lowering (shared validity-lane
  masks, runtime-skipped delta merge) on the BENCH_batch flat workload
  (same corpus size, dim, k, batch sweep).  The acceptance gate holds
  live zero-delta QPS within 20% of the committed frozen flat-scan QPS
  (``scripts/bench_gate.py``).  ``cap_main`` is provisioned on the scan
  kernel's 1024-row tile boundary: pad rows inside the last tile are
  masked for free, so tile-aligned headroom costs nothing, while one row
  past the boundary buys a whole extra tile (+50% on this corpus).
* **insert→visible latency** — wall time from ``insert()`` (WAL append +
  segment update) to a query observing the new row through an
  already-prepared plan (re-bind, zero retraces).
* **QPS vs delta fill** — batched scan throughput at 0 / 50 / 100% of
  ``delta_cap`` pending rows.
* **compaction pause** — ``compact()`` wall time (canonicalize + WAL +
  checkpoint + swap), with and without an IVF rebuild.

Writes ``BENCH_live.json`` (consumed by the acceptance gate).

Standalone:  PYTHONPATH=src python -m benchmarks.q12_live_freshness
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import numpy as np

from .common import BenchEnv, Row, timeit

BATCHES = (1, 8, 64, 256)
FLAT_ROWS = 2000               # mirrors q7_batch_qps FLAT_ROWS exactly
DELTA_CAP = 256
CAP_MAIN = 2048                # FLAT_ROWS rounded up to the kernel tile
SQL = ("SELECT sample_id FROM products "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT {K}")
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_live.json")


def _queries(base: np.ndarray, q: int) -> np.ndarray:
    rng = np.random.default_rng(7)
    reps = -(-q // base.shape[0])
    qs = np.tile(base, (reps, 1))[:q]
    return (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)


def _fresh_vectors(n: int, dim: int, seed: int = 13) -> np.ndarray:
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((n, dim)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def run(env: BenchEnv, rows: list, batches=BATCHES) -> dict:
    from repro.api import ExecutionHints, connect
    from repro.data import make_laion_catalog
    from repro.data.mutations import attach_live

    K = min(env.cfg.k_top, 10)
    sql = SQL.replace("{K}", str(K))
    cat = make_laion_catalog(n_rows=min(env.cfg.n_rows, FLAT_ROWS),
                             n_queries=8, dim=env.cfg.dim, n_modes=16,
                             seed=env.cfg.seed)
    qvecs = np.asarray(cat.table("queries")["embedding"])
    tmp = tempfile.mkdtemp(prefix="bench_live_")
    report: dict = {"flat_rows": min(env.cfg.n_rows, FLAT_ROWS),
                    "dim": env.cfg.dim, "k": K, "delta_cap": DELTA_CAP,
                    "cap_main": CAP_MAIN,
                    "zero_delta": [], "delta_fill": []}
    try:
        # frozen twin: the SAME catalog recipe without a live binding,
        # measured back-to-back with the live runs so the regression ratio
        # shares one machine state (cross-run interpret-mode noise on this
        # workload exceeds the 20% gate)
        fcat = make_laion_catalog(n_rows=min(env.cfg.n_rows, FLAT_ROWS),
                                  n_queries=8, dim=env.cfg.dim, n_modes=16,
                                  seed=env.cfg.seed)
        fdb = connect(fcat, engine="brute", use_pallas=True)
        fstmt = fdb.prepare(sql)
        live = attach_live(cat, "products", "embedding",
                           os.path.join(tmp, "a"), delta_cap=DELTA_CAP,
                           cap_main=CAP_MAIN)
        db = connect(cat, engine="brute", use_pallas=True)
        stmt = db.prepare(sql)
        exact = ExecutionHints(exact_shape=True)

        # -- zero-delta batch sweep (the frozen-flat-parity workload) -----
        # b1 rides the batch lowering at Q=1 (compiler._single_via_batch:
        # live plans have no dedicated single pipeline), but the Q=1 +
        # 1-D validity-lane fast path routes it through the single-query
        # fused kernel, so it no longer pays the (Q, N) mask broadcast
        # and gates alongside the batched rows
        base_qps = None
        for b in batches:
            qs = _queries(qvecs, b)
            if b == 1:
                fms = timeit(lambda: fstmt.execute({"qv": qs[0]}).data,
                             repeats=9)
                ms = timeit(lambda: stmt.execute({"qv": qs[0]}).data,
                            repeats=9)
            else:
                fms = timeit(lambda: fstmt.execute({"qv": qs},
                                                   hints=exact).data,
                             repeats=3)
                ms = timeit(lambda: stmt.execute({"qv": qs},
                                                 hints=exact).data, repeats=3)
            qps = 1e3 * b / ms
            base_qps = base_qps if base_qps is not None else qps
            entry = {"batch": b, "ms": round(ms, 3), "qps": round(qps, 1),
                     "frozen_ms": round(fms, 3),
                     "frozen_qps": round(1e3 * b / fms, 1),
                     "overhead_vs_frozen": round(ms / fms - 1, 3),
                     "speedup_vs_b1": round(qps / base_qps, 2)}
            report["zero_delta"].append(entry)
            rows.append(Row(f"q12_zero_delta_b{b}", ms, qps=entry["qps"]))

        # -- insert -> visible latency ------------------------------------
        dim = env.cfg.dim
        fresh = _fresh_vectors(64, dim)
        lat = []
        for i in range(16):
            uid = 10_000 + i
            t0 = time.perf_counter()
            live.insert([uid], fresh[i:i + 1])
            out = stmt.execute({"qv": fresh[i]})
            seen = live.user_ids(np.asarray(out.ids))
            lat.append(1e3 * (time.perf_counter() - t0))
            assert uid in seen.tolist(), "inserted row not visible"
        report["insert_visible_ms"] = {
            "p50": round(float(np.percentile(lat, 50)), 3),
            "p95": round(float(np.percentile(lat, 95)), 3), "n": len(lat)}
        rows.append(Row("q12_insert_visible",
                        float(np.percentile(lat, 50)),
                        p95_ms=report["insert_visible_ms"]["p95"]))
        live.delete(list(range(10_000, 10_016)))
        live.compact()

        # -- QPS vs delta fill --------------------------------------------
        qs64 = _queries(qvecs, 64)
        for frac in (0.0, 0.5, 1.0):
            want = int(frac * DELTA_CAP)
            have = live.freshness()["delta_rows"]
            if want > have:
                uids = np.arange(20_000 + have, 20_000 + want)
                live.insert(uids, _fresh_vectors(want - have, dim,
                                                 seed=17 + want))
            ms = timeit(lambda: stmt.execute({"qv": qs64},
                                             hints=exact).data, repeats=3)
            entry = {"fill": frac, "delta_rows": want, "batch": 64,
                     "ms": round(ms, 3), "qps": round(1e3 * 64 / ms, 1)}
            report["delta_fill"].append(entry)
            rows.append(Row(f"q12_fill{int(100 * frac)}", ms,
                            qps=entry["qps"]))

        # -- compaction pause ---------------------------------------------
        # fold only what cap_main can seat (tile-aligned headroom is 48
        # rows past FLAT_ROWS); the pause is dominated by the segment
        # rewrite + checkpoint, not the fold count
        live.delete(list(range(20_048, 20_000 + report["delta_fill"][-1]
                               ["delta_rows"])))
        t0 = time.perf_counter()
        live.compact()
        pause = 1e3 * (time.perf_counter() - t0)
        report["compact_pause_ms"] = round(pause, 3)
        rows.append(Row("q12_compact_pause", pause))

        # with an IVF rebuild (the serving-shaped corpus carries one)
        ivf_live = attach_live(cat, "images", "embedding",
                               os.path.join(tmp, "b"),
                               delta_cap=DELTA_CAP, nlist=32, iters=3)
        ivf_live.insert(np.arange(30_000, 30_064),
                        _fresh_vectors(64, dim, seed=23))
        t0 = time.perf_counter()
        ivf_live.compact()
        pause_ivf = 1e3 * (time.perf_counter() - t0)
        report["compact_pause_ivf_ms"] = round(pause_ivf, 3)
        rows.append(Row("q12_compact_pause_ivf", pause_ivf))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    import argparse

    from .common import get_env

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-scale catalog (default: smoke)")
    args = ap.parse_args()
    env = get_env(smoke=not args.full)
    rows: list[Row] = []
    report = run(env, rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
