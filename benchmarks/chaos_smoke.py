"""Seeded chaos smoke for the resilient serving tier (DESIGN.md §11).

Not a latency benchmark: a *correctness-under-faults* harness, run from CI
(``python -m benchmarks.run --chaos``; scripts/smoke.sh wires it in).  Two
phases per seed:

* **Deterministic phase** — a virtual-clock
  :class:`~repro.serving.scheduler.ResilientScheduler` driven through a
  scripted request sequence with every fault class enabled
  (latency spikes consuming virtual time, injected kernel errors, poisoned
  binds, mid-flight catalog bumps swapping the IVF index).  Asserts:

  - **no loss**: every submitted request resolves to exactly one typed
    outcome (result, DeadlineExceededError, InjectedKernelError, or
    PoisonedBindError at the door) — nothing hangs, nothing vanishes;
  - **counters exact**: executed + failed + shed == submitted, failed
    batches are exactly the injected kernel errors, plan re-binds never
    exceed catalog bumps;
  - **no stale result**: after the last catalog bump, a probe query through
    the (cached) statement is bit-identical to a freshly prepared plan on
    the current catalog;
  - **determinism**: the same seed replayed produces identical fault
    counters, identical outcome classes, and bit-identical served results.

* **Asyncio phase** — a real :class:`~repro.launch.serve.QueryServer`
  under a burst bigger than its admission watermark, the whole phase inside
  ``asyncio.wait_for`` (a hang fails the harness, not the CI timeout).
  Asserts every request resolves, overflow is rejected with an *explicit*
  :class:`~repro.serving.resilience.BackpressureError` carrying a positive
  ``retry_after_ms`` (never a timeout), and admission counters add up.

* **Live-corpus crash phase** (DESIGN.md §12) — a scripted mutation
  sequence on a :class:`~repro.data.mutations.LiveCorpus` is killed at
  every WAL / snapshot / compaction crash site
  (:data:`repro.serving.faults.CRASH_SITES`), then recovered from disk
  alone into a fresh catalog.  Asserts the recovered state tree is
  bit-identical to an unfailed replay at the recovered LSN — inserts and
  deletes either committed entirely or vanished entirely, at every kill
  point.

Standalone:  PYTHONPATH=src python -m benchmarks.chaos_smoke [--seeds N]
"""
from __future__ import annotations

import argparse
import asyncio
import sys

import numpy as np

SQL = ("SELECT sample_id FROM products WHERE price < ${p} "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")

N_REQUESTS = 32
ASYNC_BURST = 24
ASYNC_WATERMARK = 8
ASYNC_TIMEOUT_S = 120.0


class _VirtualClock:
    """Monotonic virtual time in seconds; faults/services advance it."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt

    def sleep(self, dt: float) -> None:     # FaultInjector sleep_fn
        self.advance(dt)


def _build(seed: int):
    """Small deployment: catalog + prepared statement + a spare index the
    catalog-bump fault swaps in (a 'background rebuild landing')."""
    import jax

    from repro.api import connect
    from repro.core import Metric
    from repro.data import make_laion_catalog
    from repro.index import build_ivf
    from repro.index.ivf import ProbeConfig

    cat = make_laion_catalog(n_rows=600, n_queries=8, dim=16, n_modes=8,
                             seed=seed)
    vecs = cat.table("laion")["vec"]
    idx_a = build_ivf(jax.random.key(seed), vecs, nlist=16,
                      metric=Metric.INNER_PRODUCT, iters=2)
    idx_b = build_ivf(jax.random.key(seed + 1), vecs, nlist=16,
                      metric=Metric.INNER_PRODUCT, iters=3)
    cat.register_index("products", "embedding", idx_a)
    db = connect(cat, engine="chase",
                 probe=ProbeConfig(max_probes=16, probe_batch=2,
                                   termination="counter"))
    stmt = db.prepare(SQL)
    return cat, db, stmt, (idx_a, idx_b)


def _requests(cat, n: int, seed: int):
    rng = np.random.default_rng([seed, 17])
    base = np.asarray(cat.table("queries")["embedding"]).astype(np.float32)
    reps = -(-n // base.shape[0])
    qs = np.tile(base, (reps, 1))[:n]
    qs = (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)
    gaps = rng.uniform(0.0, 4e-3, n)      # virtual inter-arrival gaps (s)
    return [{"qv": qs[i], "p": np.float32(1e9)} for i in range(n)], gaps


def _run_deterministic(seed: int, spec=None):
    """One scripted virtual-clock scenario; returns (outcomes, snapshots,
    results) for determinism comparison."""
    from repro.serving import (DegradePolicy, FaultInjector, FaultSpec,
                               PoisonedBindError, validate_binds)
    from repro.serving.scheduler import ResilientScheduler, SchedulerConfig

    cat, db, stmt, (idx_a, idx_b) = _build(seed)
    clock = _VirtualClock()
    if spec is None:
        spec = FaultSpec(seed=seed, latency_spike_p=0.25,
                         latency_spike_ms=40.0, kernel_error_p=0.2,
                         poison_bind_p=0.1, catalog_bump_p=0.25)
    flip = {"next": idx_b}

    def bump():
        cat.register_index("products", "embedding", flip["next"])
        flip["next"] = idx_a if flip["next"] is idx_b else idx_b

    faults = FaultInjector(spec, bump_fn=bump, sleep_fn=clock.sleep)
    sched = ResilientScheduler(
        stmt,
        SchedulerConfig(max_batch=4, max_wait_ms=5.0,
                        default_deadline_ms=20.0),
        clock=clock,
        policy=DegradePolicy(steps=((6, 4),), hysteresis=2),
        faults=faults)
    binds_list, gaps = _requests(cat, N_REQUESTS, seed)

    outcomes: dict[int, str] = {}
    results: dict[int, np.ndarray] = {}
    rids: list[int] = []
    n_poisoned = 0
    for i, binds in enumerate(binds_list):
        clock.advance(float(gaps[i]))
        # the front-door admission pipeline, inline (submit-side faults)
        binds, _ = faults.maybe_poison(binds)
        try:
            validate_binds(binds)
        except PoisonedBindError:
            n_poisoned += 1
            outcomes[-1 - i] = "poisoned"
            continue
        rids.append(sched.submit_request(binds))
        if i % 6 == 5:                    # bursty: poll every 6th arrival
            for rid in sched.poll():
                clock.advance(2e-3)       # virtual batch service time
                _classify(sched, rid, outcomes, results)
    clock.advance(5e-3)
    for rid in sched.flush():
        clock.advance(2e-3)
        _classify(sched, rid, outcomes, results)

    c = sched.counters
    f = faults.snapshot()
    # -- no loss / counters exact ------------------------------------------
    assert len(outcomes) == N_REQUESTS, (len(outcomes), N_REQUESTS)
    assert c["submitted"] == N_REQUESTS - n_poisoned
    assert c["executed"] + c["failed"] + c["shed_deadline"] == c["submitted"]
    kinds = {k: sum(1 for v in outcomes.values() if v == k)
             for k in ("ok", "deadline", "kernel", "poisoned")}
    assert kinds["poisoned"] == n_poisoned == f["poisoned_binds"]
    assert kinds["kernel"] == c["failed"]
    assert kinds["deadline"] == c["shed_deadline"]
    assert (f["kernel_errors"] == 0) == (c["failed"] == 0)
    # -- invalidation bookkeeping ------------------------------------------
    assert stmt.compiled.rebinds <= f["catalog_bumps"]
    # -- no stale result: cached statement == freshly prepared plan --------
    probe = {"qv": binds_list[0]["qv"], "p": np.float32(1e9)}
    got = stmt.execute(probe)
    fresh = db.prepare(SQL).execute(probe)
    np.testing.assert_array_equal(np.asarray(got.ids),
                                  np.asarray(fresh.ids))
    return outcomes, {"sched": dict(c), "faults": f}, results


def _classify(sched, rid, outcomes, results):
    from repro.serving import DeadlineExceededError
    from repro.serving.faults import InjectedKernelError
    try:
        res = sched.result(rid)
    except DeadlineExceededError:
        outcomes[rid] = "deadline"
    except InjectedKernelError:
        outcomes[rid] = "kernel"
    else:
        outcomes[rid] = "ok"
        results[rid] = np.asarray(res.ids)


async def _run_async(seed: int) -> dict:
    """Burst a QueryServer past its admission watermark; classify every
    outcome (explicit errors only — a hang trips the wait_for)."""
    from repro.launch.serve import QueryServer, ServeConfig
    from repro.serving import (AdmissionConfig, BackpressureError,
                               DeadlineExceededError, DegradePolicy,
                               FaultInjector, FaultSpec, PoisonedBindError)
    from repro.serving.faults import InjectedKernelError
    from repro.serving.scheduler import SchedulerConfig

    cat, db, stmt, (idx_a, idx_b) = _build(seed)
    faults = FaultInjector(
        FaultSpec(seed=seed, kernel_error_p=0.15, poison_bind_p=0.05,
                  catalog_bump_p=0.2),
        bump_fn=lambda: cat.register_index("products", "embedding", idx_b))
    config = ServeConfig(
        admission=AdmissionConfig(max_queue_depth=ASYNC_WATERMARK,
                                  retry_after_ms=5.0),
        scheduler=SchedulerConfig(max_batch=4, max_wait_ms=1.0,
                                  default_deadline_ms=2000.0),
        policy=DegradePolicy(steps=((4, 4),), hysteresis=1),
        idle_tick_ms=10.0)
    qs = np.asarray(cat.table("queries")["embedding"]).astype(np.float32)
    counts = {"ok": 0, "backpressure": 0, "deadline": 0, "kernel": 0,
              "poisoned": 0}

    async with QueryServer(stmt, config, faults=faults) as server:
        server.scheduler.warm({"qv": qs[0], "p": np.float32(1e9)}, [1, 4])

        async def one(i: int):
            return await server.submit(
                {"qv": qs[i % qs.shape[0]], "p": np.float32(1e9)})

        settled = await asyncio.gather(
            *(one(i) for i in range(ASYNC_BURST)), return_exceptions=True)
        snap = server.snapshot()

    for out in settled:
        if isinstance(out, BackpressureError):
            assert out.retry_after_ms > 0      # explicit shed, never a timeout
            counts["backpressure"] += 1
        elif isinstance(out, DeadlineExceededError):
            counts["deadline"] += 1
        elif isinstance(out, InjectedKernelError):
            counts["kernel"] += 1
        elif isinstance(out, PoisonedBindError):
            counts["poisoned"] += 1
        elif isinstance(out, BaseException):
            raise AssertionError(f"untyped serving outcome: {out!r}")
        else:
            counts["ok"] += 1
    assert sum(counts.values()) == ASYNC_BURST
    assert counts["backpressure"] > 0, "burst never tripped admission"
    adm = snap["admission"]
    assert adm["rejected"] == counts["backpressure"]
    # admission counts the door decision; poisoned payloads are admitted
    # first, then rejected by bind validation
    assert adm["admitted"] == ASYNC_BURST - adm["rejected"]
    return {**counts, "snapshot": snap}


def _run_live_recovery(seed: int) -> dict:
    """Kill a scripted mutation sequence at every crash site; recover from
    disk into a fresh catalog and compare bitwise against an unfailed
    replay at the same LSN (the compact twin of tests/test_live_chaos.py)."""
    import copy
    import shutil
    import tempfile

    import jax.numpy as jnp

    from repro.core.schema import (Catalog, Metric, Schema, Table,
                                   float_col, int_col, vector_col)
    from repro.data.mutations import attach_live, recover
    from repro.serving.faults import (CRASH_SITES, FaultInjector, FaultSpec,
                                      InjectedCrashError)

    dim, n0 = 8, 48

    def mk_catalog():
        rng = np.random.default_rng(seed)
        vecs = rng.standard_normal((n0, dim)).astype(np.float32)
        vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
        schema = Schema({"sample_id": int_col(jnp.int64),
                         "vec": vector_col(dim, Metric.L2)})
        cat = Catalog()
        cat.register("items", Table(schema, {
            "sample_id": jnp.arange(n0, dtype=jnp.int64),
            "vec": jnp.asarray(vecs)}))
        return cat

    rng = np.random.default_rng([seed, 29])

    def v(n):
        x = rng.standard_normal((n, dim)).astype(np.float32)
        return x / np.linalg.norm(x, axis=1, keepdims=True)

    fresh = [v(5), v(3), v(2), v(3), v(2)]
    group = [(np.arange(400, 403), fresh[3]),
             (np.arange(410, 412), fresh[4])]
    base = [lambda l: l.insert(np.arange(100, 105), fresh[0]),
            lambda l: l.delete([3, 102]),
            lambda l: l.snapshot(),
            lambda l: l.insert(np.arange(200, 203), fresh[1]),
            lambda l: l.compact(),
            lambda l: l.insert(np.arange(300, 302), fresh[2]),
            lambda l: l.delete([200, 10]),
            lambda l: l.compact()]
    seq = base + [lambda l: l.insert_batch(group)]
    # the replay expands the group commit into sequential inserts — same
    # LSNs, same state (so a torn group tail recovers to a recorded LSN)
    replay_seq = base + [lambda l, g=g: l.insert(g[0], g[1]) for g in group]

    def attach(cat, path, faults=None):
        return attach_live(cat, "items", "vec", path, delta_cap=16,
                           seed=0, iters=3, faults=faults)

    def tree_equal(a, b, ctx):
        assert a.keys() == b.keys(), (ctx, sorted(a), sorted(b))
        for key in a:
            if isinstance(a[key], dict):
                tree_equal(a[key], b[key], f"{ctx}.{key}")
            else:
                np.testing.assert_array_equal(
                    np.asarray(a[key]), np.asarray(b[key]),
                    err_msg=f"{ctx} leaf {key}")

    tmp = tempfile.mkdtemp(prefix="chaos_live_")
    recovered = 0
    try:
        # unfailed replay: state tree after every op, keyed by the LSN it
        # left the corpus at (identically-built catalogs mint identical
        # LSNs, so the durable frontier lines up bitwise)
        replay = attach(mk_catalog(), f"{tmp}/replay")
        states = {replay.lsn: copy.deepcopy(replay._state_tree())}
        for step in replay_seq:
            step(replay)
            states[replay.lsn] = copy.deepcopy(replay._state_tree())

        for site in CRASH_SITES:
            faults = FaultInjector(FaultSpec(seed=seed, crash_site=site,
                                             crash_at=1))
            path = f"{tmp}/{site.replace('.', '_')}"
            live = attach(mk_catalog(), path, faults=faults)
            try:
                for step in seq:
                    step(live)
            except InjectedCrashError:
                pass
            else:
                raise AssertionError(f"crash site {site} never fired")
            rec = recover(mk_catalog(), "items", "vec", path)
            assert rec.lsn in states, (site, rec.lsn, sorted(states))
            tree_equal(rec._state_tree(), states[rec.lsn], site)
            recovered += 1
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return {"sites": recovered}


def run_chaos(n_seeds: int = 3) -> None:
    from repro.serving import FaultSpec

    for seed in range(n_seeds):
        out1, snap1, res1 = _run_deterministic(seed)
        out2, snap2, res2 = _run_deterministic(seed)
        # determinism: same seed => same faults, same outcomes, same bits
        assert snap1 == snap2, (snap1, snap2)
        assert sorted(out1.values()) == sorted(out2.values())
        for rid, ids in res1.items():
            np.testing.assert_array_equal(ids, res2[rid])
        # unfaulted control: all-zero spec serves every request
        out0, snap0, _ = _run_deterministic(seed, spec=FaultSpec(seed=seed))
        assert all(v in ("ok", "deadline") for v in out0.values())
        assert snap0["faults"] == {"latency_spikes": 0, "kernel_errors": 0,
                                   "poisoned_binds": 0, "catalog_bumps": 0,
                                   "crashes": 0}
        kinds = {k: sum(1 for v in out1.values() if v == k)
                 for k in ("ok", "deadline", "kernel", "poisoned")}
        print(f"[chaos] seed={seed} sync outcomes={kinds} "
              f"faults={snap1['faults']} OK", flush=True)
        counts = asyncio.run(asyncio.wait_for(_run_async(seed),
                                              timeout=ASYNC_TIMEOUT_S))
        snap = counts.pop("snapshot")
        print(f"[chaos] seed={seed} async outcomes={counts} "
              f"faults={snap.get('faults')} OK", flush=True)
        rec = _run_live_recovery(seed)
        print(f"[chaos] seed={seed} live recovery sites={rec['sites']} "
              f"bit-identical OK", flush=True)
    print(f"[chaos] {n_seeds} seeds passed (no hangs, no stale results, "
          f"counters exact, crash recovery bit-identical)", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args(argv)
    run_chaos(args.seeds)
    return 0


if __name__ == "__main__":
    sys.exit(main())
