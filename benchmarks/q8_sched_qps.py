"""Q8 — serving latency/QPS under Poisson arrivals (the scheduler bench).

Two measurements of the size-bucketed execution stack (DESIGN.md §8):

* **Arrival sweep**: Poisson request arrivals at 3 rates (relative to the
  measured batch-service capacity) through three serving policies on the
  SAME compiled plan:
    - ``naive``   — per-request loop: one single-query pipeline call each
      (the pre-batching deployment shape; no queueing wins, no batch wins),
    - ``fixed_q`` — static batching: wait for exactly MAX_BATCH requests
      (remainder waits for the last arrival), execute at that fixed Q —
      great amortization, unbounded fill-wait at low rates,
    - ``sched``   — the :class:`BatchScheduler` deadline policy: drain on a
      full batch OR when the oldest request waited ``max_wait_ms``, execute
      through the per-bucket executor cache.
  All three run on one virtual clock with REAL measured execution times;
  reported: p50/p95 latency and QPS.
* **Effort row**: the q34-shaped heterogeneous-LEFT workload — join left
  rows as a query batch (the PR-2 flattening), residual predicate
  selectivity spanning permissive to needle-selective, so lock-step IVF
  rounds couple light lefts to stragglers.  Compares one lock-step bucketed
  execution against :func:`run_effort_bucketed` (pilot = p75 of a warmup
  run's per-query probe counters + 1 — the scheduler's effort-calibration
  heuristic); the acceptance gate is effort > lock-step in interpret mode.

Writes ``BENCH_sched.json``.

Standalone:  PYTHONPATH=src python -m benchmarks.q8_sched_qps [--full]
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import EngineOptions, compile_query

from .common import BenchEnv, Row

SCHED_ROWS = 2000    # arrival-sweep catalog (interpret-mode friendly)
EFFORT_ROWS = 8000   # effort row needs rounds expensive enough to matter
N_LEFT = 64          # heterogeneous-left workload width
N_REQ = 64           # requests per simulated rate
MAX_BATCH = 32
MAX_WAIT_MS = 5.0
RATE_MULTIPLIERS = (0.3, 1.0, 3.0)   # x measured batch capacity
K = 10
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_sched.json")

SQL = ("SELECT sample_id FROM images WHERE capture_date > ${d} "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT {K}")


def _catalog(env: BenchEnv, n_rows: int, n_queries: int, nlist: int):
    import jax

    from repro.data import make_laion_catalog
    from repro.index import build_ivf

    cat = make_laion_catalog(n_rows=n_rows, n_queries=n_queries,
                             dim=env.cfg.dim, n_modes=16, seed=env.cfg.seed)
    idx = build_ivf(jax.random.key(env.cfg.seed), cat.table("laion")["vec"],
                    nlist=nlist, metric=env.cfg.metric, iters=4)
    for name in ("laion", "products", "images", "recipes", "movies"):
        cat.register_index(name, "vec", idx)
        cat.register_index(name, "embedding", idx)
    return cat


def _block(out):
    import jax
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return out


def _timed(fn):
    t0 = time.perf_counter()
    _block(fn())
    return time.perf_counter() - t0


def _requests(cat, n: int, sel_lo=0.2, sel_hi=0.8, seed=11):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    base = np.asarray(cat.table("queries")["embedding"])
    dates = np.asarray(cat.table("laion")["capture_date"])
    reps = -(-n // base.shape[0])
    qs = np.tile(base, (reps, 1))[:n]
    qs = (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)
    ds = np.quantile(dates, rng.uniform(sel_lo, sel_hi, n)).astype(np.int32)
    return [dict(qv=jnp.asarray(qs[i]), d=jnp.asarray(ds[i]))
            for i in range(n)]


def _stats(records) -> dict:
    from repro.serving.scheduler import latency_stats
    stats = latency_stats(records)
    return {"p50_ms": stats["p50_ms"], "p95_ms": stats["p95_ms"],
            "qps": stats["qps"]}


def _sim_naive(q, arrivals, binds_list) -> dict:
    from repro.serving.scheduler import SimRecord
    server_free, records = 0.0, []
    for r, (t, b) in enumerate(zip(arrivals, binds_list)):
        start = max(server_free, float(t))
        finish = start + _timed(lambda: q(**b))
        records.append(SimRecord(r, float(t), start, finish, 1))
        server_free = finish
    return _stats(records)


def _sim_fixed(q, arrivals, binds_list, batch: int) -> dict:
    from repro.serving.scheduler import SimRecord
    n = len(binds_list)
    server_free, records = 0.0, []
    i = 0
    while i < n:
        j = min(i + batch, n)
        start = max(server_free, float(arrivals[j - 1]))  # wait for the fill
        chunk = binds_list[i:j] + [binds_list[j - 1]] * (batch - (j - i))
        finish = start + _timed(
            lambda: q.execute_batch(binds_list=[
                {k: np.asarray(v) for k, v in b.items()} for b in chunk]))
        for r in range(i, j):
            records.append(SimRecord(r, float(arrivals[r]), start, finish,
                                     j - i))
        server_free = finish
        i = j
    return _stats(records)


def _sim_sched(q, arrivals, binds_list) -> dict:
    from repro.serving.scheduler import BatchScheduler, SchedulerConfig
    sched = BatchScheduler(q, SchedulerConfig(max_batch=MAX_BATCH,
                                              max_wait_ms=MAX_WAIT_MS))
    records = sched.simulate(np.asarray(arrivals, np.float64), binds_list)
    return _stats(records)


def _arrival_sweep(env: BenchEnv, rows: list, report: dict) -> None:
    # index-less fused-kernel workload: the path where batch amortization is
    # real in interpret mode (q7: flat b64 ≈ 6-7x b1), so the POLICY
    # difference is visible — naive pays per-request kernel launches,
    # fixed_q pays fill-wait, the scheduler pays neither
    cat = _catalog(env, SCHED_ROWS, 8, 32)
    sql = SQL.replace("{K}", str(K))
    q = compile_query(sql, cat, EngineOptions(engine="brute",
                                              use_pallas=True))
    reqs = _requests(cat, N_REQ)
    # warm every executable the sweep touches (compile out of the clock)
    _block(q(**reqs[0]))
    _block(q.execute_batch(binds_list=[
        {k: np.asarray(v) for k, v in reqs[0].items()}] * MAX_BATCH))
    b = 1
    while b <= MAX_BATCH:                      # every bucket a drain can hit
        _block(q.execute_bucketed(binds_list=[
            {k: np.asarray(v) for k, v in reqs[0].items()}] * b))
        b *= 2
    # capacity: batch-service rate of the fixed batch
    t_batch = min(_timed(lambda: q.execute_batch(binds_list=[
        {k: np.asarray(v) for k, v in r.items()}
        for r in reqs[:MAX_BATCH]])) for _ in range(3))
    capacity = MAX_BATCH / t_batch
    rng = np.random.default_rng(env.cfg.seed)
    report["poisson"] = []
    for mult in RATE_MULTIPLIERS:
        rate = capacity * mult
        arrivals = np.sort(rng.exponential(1.0 / rate, N_REQ).cumsum())
        entry = {"rate_multiplier": mult, "rate_qps": round(rate, 1)}
        for name, sim in (("naive", _sim_naive),
                          ("fixed_q", lambda q_, a, b: _sim_fixed(
                              q_, a, b, MAX_BATCH)),
                          ("sched", _sim_sched)):
            entry[name] = sim(q, arrivals, reqs)
            rows.append(Row(f"q8_{name}_x{mult}",
                            entry[name]["p50_ms"],
                            p95_ms=entry[name]["p95_ms"],
                            qps=entry[name]["qps"],
                            rate_qps=entry["rate_qps"]))
        report["poisson"].append(entry)


def _effort_row(env: BenchEnv, rows: list, report: dict) -> None:
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.serving.scheduler import run_effort_bucketed
    cat = _catalog(env, EFFORT_ROWS, N_LEFT, 64)
    probe = dataclasses.replace(env.cfg.probe, probe_batch=2, max_probes=64)
    sql = SQL.replace("{K}", str(K))
    q = compile_query(sql, cat, EngineOptions(engine="chase", probe=probe))
    # q34-shaped heterogeneous LEFT rows: most residual predicates are
    # permissive, a few are needle-selective -> classic straggler coupling
    rng = np.random.default_rng(env.cfg.seed)
    dates = np.asarray(cat.table("laion")["capture_date"])
    sel = np.concatenate([rng.uniform(0.0, 0.5, N_LEFT - 8),
                          np.full(8, 0.9995)])
    rng.shuffle(sel)
    qs = np.asarray(cat.table("queries")["embedding"])[:N_LEFT]
    binds = q._stack_binds(None, dict(
        qv=jnp.asarray(qs),
        d=jnp.asarray(np.quantile(dates, sel).astype(np.int32))))
    lock = _block(q.executor(binds))
    probes = np.asarray(lock["stats"]["probes"])
    pilot = int(np.percentile(probes, 75)) + 1    # effort calibration
    eff, info = run_effort_bucketed(q, binds, pilot_budget=pilot)
    assert np.array_equal(np.asarray(lock["ids"]), np.asarray(eff["ids"])), \
        "effort-bucketed result diverged from lock-step"
    t_lock = 1e3 * min(_timed(lambda: q.executor(binds)) for _ in range(5))
    t_eff = 1e3 * min(
        _timed(lambda: run_effort_bucketed(q, binds, pilot_budget=pilot)[0])
        for _ in range(5))
    report["effort"] = {
        "workload": "q34_hetero_left", "n_left": N_LEFT,
        "right_rows": EFFORT_ROWS, "pilot_budget": pilot,
        "n_light": info["n_light"], "n_heavy": info["n_heavy"],
        "ms_lockstep": round(t_lock, 2), "ms_effort": round(t_eff, 2),
        "speedup": round(t_lock / t_eff, 2),
    }
    rows.append(Row("q8_effort_vs_lockstep", t_eff,
                    ms_lockstep=round(t_lock, 2),
                    speedup=report["effort"]["speedup"],
                    n_heavy=info["n_heavy"], pilot=pilot))


def run(env: BenchEnv, rows: list) -> dict:
    report: dict = {"dim": env.cfg.dim, "k": K, "max_batch": MAX_BATCH,
                    "max_wait_ms": MAX_WAIT_MS, "n_requests": N_REQ,
                    "sched_rows": SCHED_ROWS, "effort_rows": EFFORT_ROWS}
    _arrival_sweep(env, rows, report)
    _effort_row(env, rows, report)
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    import argparse
    import sys

    from .common import get_env

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-scale catalog (default: smoke)")
    args = ap.parse_args()
    env = get_env(smoke=not args.full)
    rows: list[Row] = []
    report = run(env, rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
    eff = report["effort"]
    print(f"\neffort-bucketed vs lock-step on {eff['workload']}: "
          f"{eff['speedup']}x (pilot={eff['pilot_budget']}, "
          f"{eff['n_heavy']}/{eff['n_heavy'] + eff['n_light']} heavy)",
          file=sys.stderr)
