"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--smoke] [--only q1,q4,...]

Prints ``name,us_per_call,derived`` CSV (derived carries recall / counters).
Engine modes reproduce the paper's comparison systems as query plans
(DESIGN.md §3); 'interpreted' rows are measured on a subsample and scaled
(flagged in the derived column).
"""
from __future__ import annotations

import argparse
import sys

from . import (counters, q1_vknn, q2_range, q3_distjoin, q4_knnjoin,
               q5q6_category, q7_batch_qps, q8_sched_qps, q9_prepare_cache,
               q10_sharded_qps, q11_overload, q12_live_freshness,
               q13_quant_qps, q14_adaptive, q34_join_qps)
from .common import Row, get_env

BENCHES = {
    "q1": q1_vknn.run,
    "q2": q2_range.run,
    "q3": q3_distjoin.run,
    "q4": q4_knnjoin.run,
    "q5q6": q5q6_category.run,
    "q7": q7_batch_qps.run,
    "q8": q8_sched_qps.run,
    "q9": q9_prepare_cache.run,
    "q10": q10_sharded_qps.run,
    "q11": q11_overload.run,
    "q12": q12_live_freshness.run,
    "q13": q13_quant_qps.run,
    "q14": q14_adaptive.run,
    "q34": q34_join_qps.run,
    "t5": counters.run,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus (CI-scale)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sweep: tiny corpus + fast subset "
                         "(q1, q7, q8 scheduler, q9 cache, q10 sharded, "
                         "q12 live freshness, q13 quantized scan, q14 "
                         "adaptive optimizer, q34 joins, t5) — what "
                         "scripts/smoke.sh runs")
    ap.add_argument("--only", default=None,
                    help="comma list of bench keys: " + ",".join(BENCHES))
    ap.add_argument("--chaos", action="store_true",
                    help="seeded chaos smoke of the resilient serving tier "
                         "(no hangs, no stale results, counters exact)")
    ap.add_argument("--chaos-seeds", type=int, default=3)
    args = ap.parse_args(argv)
    if args.chaos:
        from . import chaos_smoke
        chaos_smoke.run_chaos(args.chaos_seeds)
        return
    env = get_env(smoke=args.smoke or args.quick)
    if args.only:
        keys = args.only.split(",")
    elif args.quick:
        keys = ["q1", "q7", "q8", "q9", "q10", "q11", "q12", "q13", "q14",
                "q34", "t5"]
    else:
        keys = list(BENCHES)
    rows: list[Row] = []
    print("name,us_per_call,derived")
    for key in keys:
        before = len(rows)
        BENCHES[key](env, rows)
        for r in rows[before:]:
            print(r.csv(), flush=True)


if __name__ == "__main__":
    main()
