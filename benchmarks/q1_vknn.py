"""Paper Table 3: Q1 (VKNN-SF) — time + recall × 6 selectivities × engines."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import EngineOptions, compile_query
from repro.core.interpreter import run_interpreted
from repro.data import make_laion_catalog

from .common import SELECTIVITIES, BenchEnv, Row, recall_sets, timeit

SQL_FILTERED = ("SELECT sample_id FROM products WHERE price < ${p} "
                "ORDER BY DISTANCE(embedding, ${qv}) LIMIT {K}")
SQL_PLAIN = ("SELECT sample_id FROM products "
             "ORDER BY DISTANCE(embedding, ${qv}) LIMIT {K}")

ENGINES = ("chase", "vbase", "pase", "brute")


def run(env: BenchEnv, rows: list, n_queries: int = 16,
        interpreter_rows: int = 2000):
    n_queries = min(n_queries, env.qvecs.shape[0])
    K = env.cfg.k_top
    probe = env.cfg.probe
    for sel in SELECTIVITIES:
        thr = env.price_thresholds[sel]
        sql = (SQL_PLAIN if sel == 1.0 else SQL_FILTERED).replace(
            "{K}", str(K))
        mask = None if sel == 1.0 else (env.price < thr)
        # exact ground truth per query
        gts = []
        for qi in range(n_queries):
            s = env.sims[qi].copy()
            if mask is not None:
                s[~mask] = -np.inf
            gts.append(np.argpartition(-s, K)[:K][np.argsort(
                -s[np.argpartition(-s, K)[:K]])])
        for engine in ENGINES:
            q = compile_query(sql, env.catalog,
                              EngineOptions(engine=engine, probe=probe))

            def call(qi=0):
                binds = {"qv": env.qvecs[qi]}
                if sel < 1.0:
                    binds["p"] = thr
                return q(**binds)

            ms = timeit(lambda: call(0), repeats=3)
            recalls = []
            for qi in range(n_queries):
                out = call(qi)
                recalls.append(recall_sets(out["ids"], out["valid"],
                                           gts[qi]))
            rows.append(Row(f"q1_sel{sel}_{engine}", ms,
                            recall=round(float(np.mean(recalls)), 4),
                            evals=int(out["stats"]["distance_evals"])))
        # interpreted engine on a subsample (clearly labeled + scaled)
        small = make_laion_catalog(n_rows=interpreter_rows, n_queries=2,
                                   dim=env.cfg.dim, n_modes=16,
                                   seed=env.cfg.seed)
        import time as _t
        binds = {"qv": env.qvecs[0]}
        if sel < 1.0:
            binds["p"] = thr
        t0 = _t.perf_counter()
        run_interpreted(sql, small, binds)
        t = (_t.perf_counter() - t0) * 1e3
        scale = env.cfg.n_rows / interpreter_rows
        rows.append(Row(f"q1_sel{sel}_interpreted", t * scale,
                        measured_ms_on_subsample=round(t, 1),
                        subsample=interpreter_rows, scaled=True))
