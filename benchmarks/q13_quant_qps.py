"""Q13 — quantized flat-scan QPS (int8 / bf16 corpus, fused fp32 rescore).

The quantized scan kernels (DESIGN.md §13) stream an int8 or bf16 corpus
tile through the same MXU layout as the fp32 batch kernel and rescore the
top-(c·K) candidates in fp32, so the result is BIT-IDENTICAL to the fp32
scan while the corpus read moves 4x (int8) or 2x (bf16) fewer bytes.
This bench sweeps batch ∈ {1, 8, 64, 256} over the BENCH_batch flat
workload for fp32 / bf16 / int8 and, for every (mode, batch) point,
hard-asserts recall == 1.0 against the fp32 run BEFORE timing — a
quantized row that is not exact never gets a QPS number.

Bandwidth accounting: each row carries the model bytes the scan must move
(corpus + scales + queries + fp32 rescore gather), the achieved GB/s at
the measured time, and that as a fraction of TPU v5e HBM peak
(``roofline/hw.py``); the b64 rows additionally run the compiled HLO
through ``roofline/hlo_analyzer`` and publish a v5e roofline bound
(``roofline/analysis.roofline_terms``).  Interpret-mode caveat: on CPU
emulation the achieved fractions are honest but tiny — the model-bytes
column is the machine-independent part, and is what shrinks 4x.

Writes ``BENCH_quant.json``.  The acceptance gate (scripts/bench_gate.py)
holds every (mode, batch) QPS within tolerance of the committed baseline
AND requires int8 b64 >= 1.5x fp32 b64 within one run.

Standalone:  PYTHONPATH=src python -m benchmarks.q13_quant_qps [--full]
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import EngineOptions, compile_query
from repro.roofline import analysis as roofline_analysis
from repro.roofline import hlo_analyzer
from repro.roofline.hw import TPU_V5E

from .common import BenchEnv, Row, timeit

BATCHES = (1, 8, 64, 256)
MODES = ("fp32", "bf16", "int8")
RESCORE_FACTOR = 3   # c=2 (the engine default) loses one candidate in
                     # 2560 on this 16k-row corpus at b256; c=3 restores
                     # exactness while keeping the fp32 replay (whose cost
                     # scales with c·K·SEG rows per query) small next to
                     # the corpus stream
SQL = ("SELECT sample_id FROM products "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT {K}")
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_quant.json")

FLAT_ROWS = 16384  # deliberately LARGER than q7's 2000-row flat catalog:
                   # the quantized scan's win is corpus BYTES MOVED, so the
                   # corpus must not fit in cache (at 2k rows x 64 dims the
                   # fp32 corpus is 512 KB and every mode runs at cache
                   # speed, hiding the 4x int8 traffic saving the gate
                   # asserts; at 16k rows the fp32 stream is 4 MB and the
                   # int8 kernel wins >= 1.5x even on the CPU emulation)

_ITEMSIZE = {"fp32": 4, "bf16": 2, "int8": 1}


def _queries(base: np.ndarray, q: int) -> np.ndarray:
    rng = np.random.default_rng(7)
    reps = -(-q // base.shape[0])
    qs = np.tile(base, (reps, 1))[:q]
    return (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)


def _model_bytes(mode: str, n: int, dim: int, q: int, k: int) -> int:
    """Bytes the flat scan must move per execution: the quantized (or
    fp32) corpus stream, per-row scales, the query tile, and — for the
    quantized modes — the fp32 gather of the c*K rescore candidates."""
    b = n * dim * _ITEMSIZE[mode] + q * dim * 4
    if mode != "fp32":
        b += n * 4                                  # per-row scales
        b += RESCORE_FACTOR * k * q * dim * 4       # fp32 rescore gather
    return b


def _recall(out, ref, k: int) -> float:
    """Mean top-k id recall of ``out`` against the fp32 reference."""
    ids = np.atleast_2d(np.asarray(out["ids"]))
    rds = np.atleast_2d(np.asarray(ref["ids"]))
    v = np.atleast_2d(np.asarray(ref["valid"]))
    hits = tot = 0
    for i in range(ids.shape[0]):
        want = set(rds[i][v[i]].tolist())
        if not want:
            continue
        hits += len(want & set(ids[i].tolist()))
        tot += len(want)
    return hits / tot if tot else 1.0


def _hlo_roofline(q, qs, model_flops: float) -> dict | None:
    """Compiled-HLO cost of the b64 executable -> v5e roofline terms."""
    try:
        text = q.lower_batch(qv=qs).compile().as_text()
        cost = hlo_analyzer.analyze(text)
        terms = roofline_analysis.roofline_terms(
            {"flops": cost.flops, "bytes accessed": cost.bytes},
            {}, chips=1, model_flops=model_flops)
        return {"hlo_gflops": round(cost.flops / 1e9, 3),
                "hlo_gbytes": round(cost.bytes / 1e9, 3),
                "v5e_step_us": round(1e6 * terms.step_time_lower_bound_s, 3),
                "v5e_dominant": terms.dominant}
    except Exception as e:                           # interpret-mode HLO can
        return {"error": type(e).__name__}          # defeat the parser; the
                                                    # model columns still land


def run(env: BenchEnv, rows: list, batches=BATCHES) -> dict:
    from repro.data import make_laion_catalog

    K = min(env.cfg.k_top, 10)
    sql = SQL.replace("{K}", str(K))
    n = FLAT_ROWS        # NOT min(env.n_rows, ...): see FLAT_ROWS comment
    cat = make_laion_catalog(n_rows=n, n_queries=8, dim=env.cfg.dim,
                             n_modes=16, seed=env.cfg.seed)
    qvecs = np.asarray(cat.table("queries")["embedding"])
    dim = env.cfg.dim
    report: dict = {"n_rows": n, "dim": dim, "k": K,
                    "rescore_factor": RESCORE_FACTOR, "workloads": {},
                    "hbm_peak_gbps": round(TPU_V5E.hbm_bw / 1e9, 1)}

    compiled = {}
    for mode in MODES:
        opts = EngineOptions(engine="brute", use_pallas=True,
                             quant=None if mode == "fp32" else mode,
                             rescore_factor=RESCORE_FACTOR)
        compiled[mode] = compile_query(sql, cat, opts)

    for mode in MODES:
        q = compiled[mode]
        entries = []
        for b in batches:
            qs = _queries(qvecs, b)
            if b == 1:
                out = q(qv=qs[0])
                ref = compiled["fp32"](qv=qs[0])
            else:
                out = q.execute_batch(qv=qs)
                ref = compiled["fp32"].execute_batch(qv=qs)
            # exactness is the contract, not a tolerance: no QPS number
            # without recall 1.0 against the fp32 scan
            recall = _recall(out, ref, K)
            assert recall == 1.0, (
                f"quantized scan lost exactness: mode={mode} batch={b} "
                f"recall={recall:.4f} (must be 1.0)")
            if b == 1:
                ms = timeit(lambda: q(qv=qs[0]), repeats=9)
            else:
                ms = timeit(lambda: q.execute_batch(qv=qs), repeats=3)
            qps = 1e3 * b / ms
            mb = _model_bytes(mode, n, dim, b, K)
            achieved = mb / (ms / 1e3) / 1e9
            entry = {"batch": b, "ms": round(ms, 3), "qps": round(qps, 1),
                     "recall": recall,
                     "model_mbytes": round(mb / 1e6, 3),
                     "achieved_gbps": round(achieved, 3),
                     "frac_hbm_peak": round(achieved * 1e9
                                            / TPU_V5E.hbm_bw, 6)}
            if b == 64:
                flops = 2.0 * n * dim * b
                if mode != "fp32":
                    flops += 2.0 * RESCORE_FACTOR * K * dim * b
                entry["roofline"] = _hlo_roofline(q, qs, flops)
            entries.append(entry)
            rows.append(Row(f"q13_{mode}_b{b}", ms, qps=entry["qps"]))
        report["workloads"][mode] = entries

    def b64(mode):
        return next(e["qps"] for e in report["workloads"][mode]
                    if e["batch"] == 64)

    report["speedup_b64"] = {m: round(b64(m) / b64("fp32"), 2)
                             for m in MODES if m != "fp32"}
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    import argparse

    from .common import get_env

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-scale catalog (default: smoke)")
    args = ap.parse_args()
    env = get_env(smoke=not args.full)
    rows: list[Row] = []
    report = run(env, rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
    print("speedup_b64:", report["speedup_b64"])
