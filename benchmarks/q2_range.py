"""Paper Table 4: Q2 (DR-SF) — time + recall × selectivities × engines.
PASE/pgvector cannot route range queries to the ANN index (§2.3) => their
engine mode falls back to the compiled brute scan, as in the paper."""
from __future__ import annotations

import numpy as np

from repro.core import EngineOptions, compile_query

from .common import SELECTIVITIES, BenchEnv, Row, recall_sets, timeit

SQL_FILTERED = ("SELECT sample_id FROM images "
                "WHERE DISTANCE(embedding, ${qv}) <= ${r} "
                "AND price < ${p}")
SQL_PLAIN = ("SELECT sample_id FROM images "
             "WHERE DISTANCE(embedding, ${qv}) <= ${r}")

ENGINES = ("chase", "vbase", "pase")


def run(env: BenchEnv, rows: list, n_queries: int = 16):
    n_queries = min(n_queries, env.qvecs.shape[0])
    probe = env.cfg.probe
    radius = env.radius_topk
    for sel in SELECTIVITIES:
        thr = env.price_thresholds[sel]
        sql = SQL_PLAIN if sel == 1.0 else SQL_FILTERED
        mask = None if sel == 1.0 else (env.price < thr)
        gt_sets = []
        for qi in range(n_queries):
            hit = env.sims[qi] >= radius
            if mask is not None:
                hit &= mask
            gt_sets.append(np.flatnonzero(hit))
        for engine in ENGINES:
            q = compile_query(sql, env.catalog,
                              EngineOptions(engine=engine, probe=probe))

            def call(qi=0):
                binds = {"qv": env.qvecs[qi], "r": radius}
                if sel < 1.0:
                    binds["p"] = thr
                return q(**binds)

            ms = timeit(lambda: call(0), repeats=3)
            recalls = []
            for qi in range(n_queries):
                out = call(qi)
                recalls.append(recall_sets(out["ids"], out["valid"],
                                           gt_sets[qi]))
            rows.append(Row(f"q2_sel{sel}_{engine}", ms,
                            recall=round(float(np.mean(recalls)), 4),
                            evals=int(out["stats"]["distance_evals"])))
