"""Q9 — prepared-statement plan-cache economics (the session-API bench).

Plan reuse across requests is the dominant cost of "multiple hybrid
queries" serving workloads: a cold ``prepare`` pays parse + analyze +
rewrite + trace + XLA compile, while a warm one pays parse + fingerprint
only.  This bench measures that gap on the session API
(:mod:`repro.api`) and verifies the cache normalizes across textual
variants:

* ``prepare_cold``   — first-ever prepare of Q1 (full compile, includes the
  first execute's jit),
* ``prepare_warm``   — re-prepare of the *same text* (cache hit),
* ``prepare_variant``— re-prepare of a whitespace + param-renamed +
  conjunct-reordered variant (MUST also hit: zero new executables,
  asserted via ``trace_counts``),
* ``execute_hit``    — a bucketed batch execute through a variant statement
  (rename translation on the hot path, reusing the original's bucket
  executable),
* ``restart_cold`` / ``restart_warm`` — SUBPROCESS prepare + first batch
  execute latency, without vs with a populated persistent AOT plan cache
  (DESIGN.md §15): three children run back-to-back (cold, untimed
  populate, warm), so the ``restart.speedup`` ratio never rides cross-run
  machine noise.  The warm child hard-asserts zero retraces.
  ``scripts/bench_gate.py`` gates ``speedup >= 10``.

Writes ``BENCH_api.json``.

Standalone:  PYTHONPATH=src python -m benchmarks.q9_prepare_cache [--full]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.api import connect
from repro.core import EngineOptions

from .common import BenchEnv, Row

K = 10
N_BATCH = 8
REPEATS = 50
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_api.json")

SQL = ("SELECT sample_id FROM products "
       "WHERE price < ${max_price} AND nsfw <> ${mid} "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 10")
# whitespace + renamed params + swapped conjuncts: one plan-cache entry
SQL_VARIANT = """
SELECT sample_id
FROM products
WHERE nsfw <> ${m} AND price < ${cap}
ORDER BY DISTANCE(embedding, ${vec})
LIMIT 10
"""


CHILD_MARK = "Q9_CHILD_JSON:"


def _child_binds(env: BenchEnv) -> list:
    return [{"qv": env.qvecs[i % len(env.qvecs)],
             "max_price": env.price_thresholds[0.5], "mid": 0}
            for i in range(N_BATCH)]


def child_main(role: str, aot_dir: str, full: bool) -> None:
    """Subprocess body: build the seeded env (untimed), then time ONE
    prepare + first batch execute — the restart cost a serving process
    actually pays.  ``cold`` runs without a cache; ``populate`` / ``warm``
    attach ``aot_dir`` (DESIGN.md §15).  The warm child hard-asserts zero
    retraces: if the persistent cache misses, the bench fails loud."""
    import jax

    from .common import get_env
    env = get_env(smoke=not full)
    db = connect(env.catalog,
                 EngineOptions(engine="chase", probe=env.cfg.probe),
                 aot_cache_path=(None if role == "cold" else aot_dir))
    binds = _child_binds(env)
    t0 = time.perf_counter()
    stmt = db.prepare(SQL)
    out = stmt.execute(binds)
    jax.block_until_ready(out["ids"])
    ms = 1e3 * (time.perf_counter() - t0)
    traces = sum(stmt.executor.trace_counts.values())
    if role == "warm" and traces:
        raise SystemExit(f"warm restart retraced ({traces} traces) — the "
                         f"persistent AOT cache missed")
    print(CHILD_MARK + json.dumps({"role": role, "ms": round(ms, 3),
                                   "traces": traces}))


def _spawn(role: str, aot_dir: str, full: bool) -> dict:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    child_env = dict(os.environ)
    child_env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                               + child_env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.q9_prepare_cache",
           "--child", role, "--aot", aot_dir] + (["--full"] if full else [])
    proc = subprocess.run(cmd, cwd=repo, env=child_env,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"q9 restart child {role!r} failed:\n"
                           f"{proc.stdout}\n{proc.stderr}")
    for line in proc.stdout.splitlines():
        if line.startswith(CHILD_MARK):
            return json.loads(line[len(CHILD_MARK):])
    raise RuntimeError(f"q9 restart child {role!r} printed no result line")


def restart_bench(env: BenchEnv, rows: list) -> dict:
    """Cold vs AOT-warm restart latency: three subprocesses back-to-back
    (cold, untimed populate, warm) over one temporary cache dir."""
    from repro.configs.chase_laion import smoke_bench_config
    full = env.cfg.n_rows != smoke_bench_config().n_rows
    with tempfile.TemporaryDirectory(prefix="q9aot-") as aot_dir:
        cold = _spawn("cold", aot_dir, full)
        _spawn("populate", aot_dir, full)      # untimed: persists entries
        warm = _spawn("warm", aot_dir, full)
    speedup = cold["ms"] / max(warm["ms"], 1e-6)
    rows.append(Row("q9_restart_cold", cold["ms"]))
    rows.append(Row("q9_restart_warm", warm["ms"],
                    speedup=round(speedup, 1)))
    return {"cold_ms": cold["ms"], "warm_ms": warm["ms"],
            "cold_traces": cold["traces"], "warm_traces": warm["traces"],
            "speedup": round(speedup, 2)}


def _timed_ms(fn, repeats: int = REPEATS) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return 1e3 * float(np.median(times))


def run(env: BenchEnv, rows: list) -> dict:
    import jax

    db = connect(env.catalog, EngineOptions(engine="chase",
                                            probe=env.cfg.probe))
    binds = {"qv": env.qvecs[0], "max_price": env.price_thresholds[0.5],
             "mid": 0}
    vbinds_list = [{"vec": env.qvecs[i % len(env.qvecs)] + 1e-3 * i,
                    "cap": env.price_thresholds[0.5], "m": 0}
                   for i in range(N_BATCH)]

    t0 = time.perf_counter()
    stmt = db.prepare(SQL)
    out = stmt.execute(binds)
    jax.block_until_ready(out["ids"])
    cold_ms = 1e3 * (time.perf_counter() - t0)

    warm_ms = _timed_ms(lambda: db.prepare(SQL))
    variant_ms = _timed_ms(lambda: db.prepare(SQL_VARIANT))
    vstmt = db.prepare(SQL_VARIANT)
    assert vstmt.cache_hit and vstmt.compiled is stmt.compiled, \
        "variant prepare missed the normalized plan cache"

    # warm the bucket, then time the variant's bucketed execute (rename
    # translation + pad/slice on the hot path)
    jax.block_until_ready(vstmt.execute(vbinds_list)["ids"])
    traces_before = dict(stmt.executor.trace_counts)
    exec_ms = _timed_ms(lambda: vstmt.execute(vbinds_list), repeats=10)
    assert stmt.executor.trace_counts == traces_before, \
        "variant execute retraced an executable"

    info = db.cache_info()
    report = {
        "n_rows": env.cfg.n_rows, "dim": env.cfg.dim, "k": K,
        "n_batch": N_BATCH,
        "prepare_cold_ms": round(cold_ms, 3),
        "prepare_warm_ms": round(warm_ms, 4),
        "prepare_variant_ms": round(variant_ms, 4),
        "execute_hit_ms": round(exec_ms, 3),
        "cold_over_warm": round(cold_ms / max(warm_ms, 1e-6), 1),
        "cache": {"hits": info.hits, "misses": info.misses,
                  "entries": info.entries},
    }
    report["restart"] = restart_bench(env, rows)
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    rows.append(Row("q9_prepare_cold", cold_ms))
    rows.append(Row("q9_prepare_warm", warm_ms,
                    cold_over_warm=report["cold_over_warm"]))
    rows.append(Row("q9_prepare_variant", variant_ms,
                    cache_hit=1))
    rows.append(Row("q9_execute_hit_b8", exec_ms))
    return report


if __name__ == "__main__":
    import argparse

    from .common import get_env

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-scale catalog (default: smoke)")
    ap.add_argument("--child", choices=("cold", "populate", "warm"),
                    help="restart-bench subprocess role (internal)")
    ap.add_argument("--aot", default="",
                    help="AOT cache dir for --child populate/warm")
    args = ap.parse_args()
    if args.child:
        child_main(args.child, args.aot, args.full)
        raise SystemExit(0)
    env = get_env(smoke=not args.full)
    rows: list[Row] = []
    report = run(env, rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
    print(f"\ncold prepare {report['prepare_cold_ms']:.1f} ms vs warm "
          f"{report['prepare_warm_ms']:.3f} ms "
          f"({report['cold_over_warm']}x); variant hit "
          f"{report['prepare_variant_ms']:.3f} ms; restart cold "
          f"{report['restart']['cold_ms']:.1f} ms vs AOT-warm "
          f"{report['restart']['warm_ms']:.1f} ms "
          f"({report['restart']['speedup']}x)")
