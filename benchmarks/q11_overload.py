"""Q11 — goodput under overload: graceful degradation vs naive queueing.

The resilience claim of DESIGN.md §11, measured: when Poisson arrivals run
at a multiple of the measured batch-service capacity, a scheduler that
(a) sheds requests whose deadline already passed and (b) steps the
per-query IVF ``probe_budget`` down as the queue deepens (the
:class:`~repro.serving.resilience.LoadController` policy) serves strictly
more *deadline-met* requests per second than naive queueing, which runs
every request at full effort in arrival order and lets the backlog blow
through every deadline.

Both policies replay the SAME arrival trace and binds on the same compiled
plan (one virtual clock, REAL measured batch execution times — the q8
protocol); only the drain policy differs.  Reported per policy:

* ``qps_met``       — deadline-met completions / span (the goodput),
* ``goodput_ratio`` — qps_met / measured full-effort capacity (the
  machine-independent number the regression gate checks),
* p50/p95 latency of completed requests.

The benchmark HARD-ASSERTS ``degraded.qps_met > naive.qps_met`` — graceful
degradation that does not beat naive queueing under overload is a bug, not
a data point.  Writes ``BENCH_serve.json`` (gated by scripts/bench_gate.py
on ``goodput_ratio``).

Standalone:  PYTHONPATH=src python -m benchmarks.q11_overload
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import BenchEnv, Row

N_ROWS = 2000
NLIST = 32
N_REQ = 96
MAX_BATCH = 16
MAX_WAIT_MS = 2.0
OVERLOAD_MULT = 2.5          # arrival rate = mult x measured capacity
DEADLINE_BATCHES = 1.5       # deadline = this many full-effort batch times
K = 4
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

SQL = ("SELECT sample_id FROM products WHERE price < ${p} "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")


def _build(env: BenchEnv):
    import jax

    from repro.api import connect
    from repro.data import make_laion_catalog
    from repro.index import build_ivf
    from repro.index.ivf import ProbeConfig

    cat = make_laion_catalog(n_rows=N_ROWS, n_queries=8, dim=env.cfg.dim,
                             n_modes=16, seed=env.cfg.seed)
    idx = build_ivf(jax.random.key(env.cfg.seed), cat.table("laion")["vec"],
                    nlist=NLIST, metric=env.cfg.metric, iters=4)
    cat.register_index("products", "embedding", idx)
    db = connect(cat, engine="chase",
                 probe=ProbeConfig(max_probes=NLIST, probe_batch=2,
                                   termination="counter"))
    return cat, db.prepare(SQL)


def _requests(cat, n: int, seed: int):
    rng = np.random.default_rng(seed)
    base = np.asarray(cat.table("queries")["embedding"]).astype(np.float32)
    price = np.asarray(cat.table("laion")["price"])
    reps = -(-n // base.shape[0])
    qs = np.tile(base, (reps, 1))[:n]
    qs = (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)
    # heterogeneous selectivity: straggler-coupled full-effort batches, so
    # the probe budget has real work to cut
    ps = np.quantile(price, rng.uniform(0.3, 1.0, n)).astype(np.float32)
    return [{"qv": qs[i], "p": np.float32(ps[i])} for i in range(n)]


def _timed_execute(stmt, batch, hints):
    import jax
    t0 = time.perf_counter()
    out = stmt.execute(batch, hints=hints)
    jax.block_until_ready(jax.tree.leaves(out.data)[0])
    return time.perf_counter() - t0


def _sim(stmt, arrivals, binds_list, deadline_s: float, policy) -> dict:
    """Virtual-clock overload replay of one drain policy.

    ``policy`` is a LoadController (the resilient scheduler: shed expired
    members at drain, degrade probe budget by queue depth) or None (naive
    queueing: full effort, arrival order, nothing shed)."""
    from repro.api.hints import ExecutionHints

    wait_s = MAX_WAIT_MS * 1e-3
    n = len(arrivals)
    server_free, i = 0.0, 0
    met, completed_lat, degraded_batches, shed = 0, [], 0, 0
    last_finish = 0.0
    while i < n:
        close = max(float(arrivals[i]) + wait_s, server_free)
        j = i
        while j < n and arrivals[j] <= close and (j - i) < MAX_BATCH:
            j += 1
        if j - i >= MAX_BATCH:
            start = max(server_free, float(arrivals[j - 1]))
        else:
            start = close
        members = list(range(i, j))
        hints = None
        if policy is not None:
            live = [r for r in members
                    if start <= float(arrivals[r]) + deadline_s]
            shed += len(members) - len(live)
            members = live
            depth = int(np.searchsorted(arrivals, start, side="right")) - i
            policy.observe(depth)
            budget = policy.probe_budget()
            if budget is not None:
                hints = ExecutionHints(probe_budget=budget)
                degraded_batches += 1
        if members:
            batch = [binds_list[r] for r in members]
            exec_s = _timed_execute(stmt, batch, hints)
            finish = start + exec_s
            last_finish = max(last_finish, finish)
            for r in members:
                lat = finish - float(arrivals[r])
                completed_lat.append(lat * 1e3)
                if finish <= float(arrivals[r]) + deadline_s:
                    met += 1
        i = j
    span = max(last_finish, float(arrivals[-1])) - float(arrivals[0])
    lats = np.asarray(completed_lat) if completed_lat else np.zeros(1)
    return {"met": met, "completed": len(completed_lat), "shed": shed,
            "degraded_batches": degraded_batches,
            "qps_met": round(met / span, 1) if span > 0 else 0.0,
            "p50_ms": round(float(np.percentile(lats, 50)), 2),
            "p95_ms": round(float(np.percentile(lats, 95)), 2)}


def run(env: BenchEnv, rows: list) -> None:
    from repro.api.hints import ExecutionHints
    from repro.serving.resilience import DegradePolicy, LoadController

    cat, stmt = _build(env)
    reqs = _requests(cat, N_REQ, env.cfg.seed)
    policy = DegradePolicy(steps=((MAX_BATCH // 2, 8), (MAX_BATCH, 3)),
                           hysteresis=2)
    # warm every executable either policy can touch: all buckets up to
    # MAX_BATCH, unbudgeted AND budgeted lanes (compile out of the clock)
    b = 1
    while b <= MAX_BATCH:
        stmt.execute(reqs[:1] * b)
        for _, budget in policy.steps:
            stmt.execute(reqs[:1] * b,
                         hints=ExecutionHints(probe_budget=budget))
        b *= 2
    # capacity: steady-state full-effort service time at MAX_BATCH — the
    # median over several passes of the real heterogeneous mix (a min right
    # after warm-up reads cold-cache noise; an inflated t_batch under-sets
    # the arrival rate and the whole "overload" evaporates)
    _timed_execute(stmt, reqs[:MAX_BATCH], None)
    samples = [_timed_execute(stmt, reqs[i:i + MAX_BATCH], None)
               for _ in range(2)
               for i in range(0, N_REQ - MAX_BATCH + 1, MAX_BATCH)]
    t_batch = float(np.median(samples))
    capacity = MAX_BATCH / t_batch
    deadline_s = DEADLINE_BATCHES * t_batch
    rng = np.random.default_rng(env.cfg.seed)
    rate = capacity * OVERLOAD_MULT
    arrivals = np.sort(rng.exponential(1.0 / rate, N_REQ).cumsum())

    naive = _sim(stmt, arrivals, reqs, deadline_s, None)
    resilient = _sim(stmt, arrivals, reqs, deadline_s,
                     LoadController(policy))
    for name, r in (("naive", naive), ("degraded", resilient)):
        r["policy"] = name
        r["goodput_ratio"] = round(r["qps_met"] / capacity, 3)
        rows.append(Row(f"q11_{name}", r["p50_ms"],
                        p95_ms=r["p95_ms"], qps_met=r["qps_met"],
                        met=r["met"], shed=r["shed"],
                        goodput_ratio=r["goodput_ratio"]))

    # the acceptance gate: degradation must BUY goodput under overload
    assert resilient["qps_met"] > naive["qps_met"], (
        f"graceful degradation did not beat naive queueing: "
        f"degraded {resilient['qps_met']} vs naive {naive['qps_met']} "
        f"deadline-met QPS at {OVERLOAD_MULT}x capacity")

    report = {"n_rows": N_ROWS, "dim": env.cfg.dim, "k": K, "nlist": NLIST,
              "max_batch": MAX_BATCH, "n_requests": N_REQ,
              "overload_mult": OVERLOAD_MULT,
              "deadline_batches": DEADLINE_BATCHES,
              "capacity_qps": round(capacity, 1),
              "deadline_ms": round(deadline_s * 1e3, 2),
              "rows": [naive, resilient]}
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=1)


def main() -> None:
    from .common import get_env
    rows: list = []
    run(get_env(smoke=True), rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())


if __name__ == "__main__":
    main()
