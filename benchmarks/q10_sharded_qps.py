"""Q10 — multi-device sharded batched scan QPS (DESIGN.md §10).

Sweeps the shard × tile composition: shards ∈ {1, 2, 4} (simulated with
fake CPU devices via ``xla_force_host_platform_device_count``) × request
batch Q ∈ {8, 64} on the fused flat VKNN workload, through the session
API's bucketed serving path (``EngineOptions.dist``).

Every run also asserts the acceptance invariants, not just times them:

* **shards=1 bit-parity** — the dist plan's bucketed output is
  bit-identical to the single-device bucketed path (ids, sims, valid,
  counters);
* **per-query counter exactness at every shard count** — each valid query
  reports exactly N distance evals (the shards' psum'd local counts) and
  the result id set matches the single-device reference.

Writes ``BENCH_dist.json`` (consumed by scripts/bench_gate.py: the
shards=1 rows gate fresh QPS within tolerance of the committed baseline;
multi-shard rows are tracked, not gated — on a CPU host the "devices" share
one socket, so shard scaling measures collective overhead, not speedup).

The sweep runs in a child process so the fake-device topology exists no
matter how the harness was launched:

  PYTHONPATH=src python -m benchmarks.q10_sharded_qps [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

SHARDS = (1, 2, 4)
BATCHES = (8, 64)
DEVICE_COUNT = max(SHARDS)
SQL = ("SELECT sample_id FROM products "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT {K}")
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_dist.json")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FLAT_ROWS = 2048   # like q7's flat workload: interpret-mode flat scans are
                   # CPU-emulated, so the sweep stays tiny & fixed (and the
                   # row count exercises exact shard divisibility at 2 and 4)


def _queries(base, q: int):
    """Tile+jitter the catalog's query set out to ``q`` vectors."""
    import numpy as np
    rng = np.random.default_rng(7)
    reps = -(-q // base.shape[0])
    qs = np.tile(base, (reps, 1))[:q]
    return (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)


def _child(n_rows: int, dim: int, k: int, seed: int) -> dict:
    """The measured sweep (runs under the fake-device topology)."""
    import numpy as np
    from repro.api import connect
    from repro.core import EngineOptions
    from repro.data import make_laion_catalog
    from repro.dist import DistSpec

    from .common import timeit
    from .counters import per_query_amortized

    sql = SQL.replace("{K}", str(k))
    cat = make_laion_catalog(n_rows=n_rows, n_queries=8, dim=dim,
                             n_modes=16, seed=seed)
    qbase = np.asarray(cat.table("queries")["embedding"])
    flat = EngineOptions(engine="brute", use_pallas=True)
    ref_stmt = connect(cat, flat).prepare(sql)

    report = {"n_rows": n_rows, "dim": dim, "k": k,
              "device_count": DEVICE_COUNT, "batches": list(BATCHES),
              "workloads": {"sharded": []},
              "parity": {"shards1_bitparity": False,
                         "counter_exact_shards": []}}
    entries = report["workloads"]["sharded"]
    base_qps: dict[int, float] = {}
    for shards in SHARDS:
        db = connect(cat, EngineOptions(
            engine="brute", use_pallas=True,
            dist=DistSpec(mesh_shape=(shards,))))
        stmt = db.prepare(sql)
        counters_exact = True
        for b in BATCHES:
            qs = _queries(qbase, b)
            out = stmt.execute({"qv": qs})
            ref = ref_stmt.execute({"qv": qs})
            # per-query counter exactness at EVERY shard count: each valid
            # query scans all N rows exactly once across the shards
            evals = np.asarray(out["stats"]["distance_evals"])
            counters_exact &= bool((evals == n_rows).all())
            for q in range(b):
                counters_exact &= (
                    set(np.asarray(out["ids"])[q].tolist())
                    == set(np.asarray(ref["ids"])[q].tolist()))
            if shards == 1:
                bits = all(
                    np.array_equal(np.asarray(out[key]),
                                   np.asarray(ref[key]))
                    for key in ("ids", "sim", "valid"))
                bits &= all(
                    np.array_equal(np.asarray(out["stats"][s]),
                                   np.asarray(ref["stats"][s]))
                    for s in out["stats"])
                report["parity"]["shards1_bitparity"] = bits
                if not bits:
                    raise AssertionError(
                        "shards=1 is NOT bit-identical to the "
                        "single-device bucketed path")
            ms = timeit(lambda: stmt.execute({"qv": qs}).data, repeats=3)
            qps = 1e3 * b / ms
            base_qps.setdefault(b, qps)
            derived = per_query_amortized(out.counters, b)
            derived.update(
                shards=shards, batch=b, qps=round(qps, 1),
                speedup_vs_shard1=round(qps / base_qps[b], 2),
                merge_bytes_per_query=k * shards * 8)
            entries.append({"shards": shards, "batch": b,
                            "ms": round(ms, 3), "qps": round(qps, 1),
                            **derived})
        if not counters_exact:
            raise AssertionError(
                f"per-query counters/results not exact at shards={shards}")
        report["parity"]["counter_exact_shards"].append(shards)
    return report


def run(env, rows: list) -> dict:
    """Harness entry: spawn the sweep under fake CPU devices, collect rows.

    A child process is required because the fake-device count must be set
    before jax initializes — the parent harness already booted jax on the
    real (1-device) topology."""
    from .common import Row

    cmd = [sys.executable, "-m", "benchmarks.q10_sharded_qps", "--child",
           "--rows", str(min(env.cfg.n_rows, FLAT_ROWS)),
           "--dim", str(env.cfg.dim), "--k", str(min(env.cfg.k_top, 10)),
           "--seed", str(env.cfg.seed)]
    child_env = dict(os.environ)
    child_env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={DEVICE_COUNT}")
    child_env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                               + os.pathsep
                               + child_env.get("PYTHONPATH", ""))
    r = subprocess.run(cmd, cwd=ROOT, env=child_env, capture_output=True,
                       text=True, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"q10 child failed:\n{r.stdout}\n{r.stderr}")
    with open(OUT_JSON) as f:
        report = json.load(f)
    for e in report["workloads"]["sharded"]:
        rows.append(Row(f"q10_s{e['shards']}_b{e['batch']}", e["ms"],
                        **{kk: vv for kk, vv in e.items()
                           if kk not in ("ms",)}))
    return report


def main(argv=None) -> None:
    """Standalone/child entry (see module docstring)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true",
                    help="run the measured sweep in THIS process (expects "
                         "the fake-device XLA flag already set)")
    ap.add_argument("--full", action="store_true",
                    help="full-scale dim/K (default: smoke)")
    ap.add_argument("--rows", type=int, default=FLAT_ROWS)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.child:
        report = _child(args.rows, args.dim, args.k, args.seed)
        with open(OUT_JSON, "w") as f:
            json.dump(report, f, indent=2)
        return
    # standalone: behave like the harness (spawn the fake-device child)
    from .common import get_env
    env = get_env(smoke=not args.full)
    rows = []
    report = run(env, rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
    print(f"\nparity: {report['parity']}", file=sys.stderr)


if __name__ == "__main__":
    main()
