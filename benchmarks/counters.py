"""Paper Table 5 analogue: execution-engine counters.

The paper measures branch misses / instructions via perf; the architecture-
neutral analogues measurable here:
  * interpreter: Next() virtual-call count, per-tuple distance evals,
    per-tuple predicate evals (the overhead §6 removes),
  * compiled: ONE executable invocation, HLO instruction count (static),
    distance evals (from the index scan stats).
"""
from __future__ import annotations

import numpy as np

from repro.core import EngineOptions, compile_query
from repro.core.interpreter import run_interpreted
from repro.data import make_laion_catalog

from .common import BenchEnv, Row

SQL = ("SELECT sample_id FROM products WHERE price < ${p} "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 50")


def per_query_amortized(stats: dict, batch_size: int) -> dict:
    """Normalize execution counters for a batched run.

    Batched operators report per-query (Q,) counter arrays (probes,
    distance_evals); single-query operators report scalars.  Returns
    ``{<counter>_total, <counter>_per_query}`` so BENCH_*.json rows make the
    amortization visible rather than burying it in wall-clock."""
    out = {}
    for key in ("distance_evals", "probes"):
        if key not in stats:
            continue
        v = np.asarray(stats[key])
        total = float(v.sum()) if v.ndim else float(v) * batch_size
        out[f"{key}_total"] = int(total)
        out[f"{key}_per_query"] = round(total / max(batch_size, 1), 1)
    return out


def per_left_amortized(stats: dict, n_left: int) -> dict:
    """Per-left-row amortization for the join families (Q3-Q6).

    Join builders report per-left (L,) counter arrays — (Q, L) under
    ``execute_batch``, where the per-left figure averages over bind sets
    too — so BENCH_join.json rows can show what one amortized MXU pipeline
    costs per left row instead of burying the win in wall-clock.  Scalars
    (pre-batching totals summed over ``n_left`` rows) pass through as
    totals."""
    out = {}
    for key in ("distance_evals", "probes"):
        if key not in stats:
            continue
        v = np.asarray(stats[key])
        denom = n_left if v.ndim == 0 else v.size
        out[f"{key}_total"] = int(v.sum())
        out[f"{key}_per_left"] = round(float(v.sum()) / max(denom, 1), 1)
    return out


JOIN_SQL = ("SELECT queries.id AS qid, images.sample_id AS tid "
            "FROM queries JOIN images "
            "ON DISTANCE(queries.embedding, images.embedding) <= ${r}")


def run(env: BenchEnv, rows: list, n_rows: int = 2000):
    small = make_laion_catalog(n_rows=n_rows, n_queries=2, dim=env.cfg.dim,
                               n_modes=16, seed=env.cfg.seed)
    from repro.index import build_ivf
    import jax
    idx = build_ivf(jax.random.key(0), small.table("laion")["vec"],
                    nlist=32, metric=env.cfg.metric, iters=3)
    small.register_index("products", "embedding", idx)
    small.register_index("images", "embedding", idx)   # t5 join row (Q3)
    qv = np.asarray(small.table("queries")["embedding"][0])
    thr = float(np.quantile(np.asarray(small.table("laion")["price"]), 0.5))

    _, counters = run_interpreted(SQL, small, {"p": thr, "qv": qv})
    rows.append(Row("t5_interpreted_next_calls", 0.0,
                    next_calls=counters.next_calls,
                    distance_evals=counters.distance_evals,
                    predicate_evals=counters.predicate_evals,
                    tuples_materialized=counters.tuples_materialized))

    q = compile_query(SQL, small, EngineOptions(engine="chase",
                                                probe=env.cfg.probe))
    out = q(p=thr, qv=qv)
    hlo_lines = sum(1 for line in q.lower(p=thr, qv=qv).as_text()
                    .splitlines() if "=" in line)
    rows.append(Row("t5_chase_compiled", 0.0,
                    executable_invocations=1,
                    hlo_instructions_static=hlo_lines,
                    distance_evals=int(out["stats"]["distance_evals"])))

    # batched execution: ONE executable invocation serves 8 bind sets; the
    # amortized per-query counters are what batching buys (q7 measures QPS)
    rng = np.random.default_rng(1)
    qs = qv[None, :] + 0.01 * rng.standard_normal(
        (8, qv.shape[0])).astype(np.float32)
    outb = q.execute_batch(qv=qs, p=thr)
    rows.append(Row("t5_chase_batched8", 0.0,
                    executable_invocations=1,
                    **per_query_amortized(outb["stats"], 8)))

    # join family: the left rows ARE the batch — one executable invocation
    # runs every per-left probe; counters amortize per left row
    nleft = small.table("queries").num_rows
    radius = float(np.quantile(
        np.asarray(small.table("queries")["embedding"])
        @ np.asarray(small.table("laion")["vec"]).T, 0.98))
    qj = compile_query(JOIN_SQL, small,
                       EngineOptions(engine="chase", probe=env.cfg.probe))
    outj = qj(r=radius)
    rows.append(Row("t5_chase_join_batched", 0.0,
                    executable_invocations=1, left_rows=nleft,
                    **per_left_amortized(outj["stats"], nleft)))
