"""Paper Table 5 analogue: execution-engine counters.

The paper measures branch misses / instructions via perf; the architecture-
neutral analogues measurable here:
  * interpreter: Next() virtual-call count, per-tuple distance evals,
    per-tuple predicate evals (the overhead §6 removes),
  * compiled: ONE executable invocation, HLO instruction count (static),
    distance evals (from the index scan stats).
"""
from __future__ import annotations

import numpy as np

from repro.core import EngineOptions, compile_query
from repro.core.interpreter import run_interpreted
from repro.data import make_laion_catalog

from .common import BenchEnv, Row

SQL = ("SELECT sample_id FROM products WHERE price < ${p} "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 50")


def run(env: BenchEnv, rows: list, n_rows: int = 2000):
    small = make_laion_catalog(n_rows=n_rows, n_queries=2, dim=env.cfg.dim,
                               n_modes=16, seed=env.cfg.seed)
    from repro.index import build_ivf
    import jax
    idx = build_ivf(jax.random.key(0), small.table("laion")["vec"],
                    nlist=32, metric=env.cfg.metric, iters=3)
    small.register_index("products", "embedding", idx)
    qv = np.asarray(small.table("queries")["embedding"][0])
    thr = float(np.quantile(np.asarray(small.table("laion")["price"]), 0.5))

    _, counters = run_interpreted(SQL, small, {"p": thr, "qv": qv})
    rows.append(Row("t5_interpreted_next_calls", 0.0,
                    next_calls=counters.next_calls,
                    distance_evals=counters.distance_evals,
                    predicate_evals=counters.predicate_evals,
                    tuples_materialized=counters.tuples_materialized))

    q = compile_query(SQL, small, EngineOptions(engine="chase",
                                                probe=env.cfg.probe))
    out = q(p=thr, qv=qv)
    hlo_lines = sum(1 for line in q.lower(p=thr, qv=qv).as_text()
                    .splitlines() if "=" in line)
    rows.append(Row("t5_chase_compiled", 0.0,
                    executable_invocations=1,
                    hlo_instructions_static=hlo_lines,
                    distance_evals=int(out["stats"]["distance_evals"])))
