"""Q7 — batched multi-query QPS (beyond-paper: the serving measurement).

The paper reports per-query latency; a serving engine cares about throughput
under a request batch.  This bench sweeps batch size ∈ {1, 8, 64, 256} over
two VKNN workloads:

* ``flat``  — index-less fused Pallas scan (brute + use_pallas): batch=1 is a
  Python loop issuing the single-query compiled pipeline per request (the
  pre-batching deployment shape); batch>1 is ONE ``execute_batch`` through
  the query-tiled kernel.
* ``ivf``   — chase engine with multi-cluster probe rounds (probe_batch=4):
  batched termination state advances Q queries in lock-step.

Reports QPS and per-query amortized distance evals, and writes
``BENCH_batch.json`` (consumed by the acceptance gate: flat-scan QPS at
batch=64 must be ≥ 5× batch=1).

Standalone:  PYTHONPATH=src python -m benchmarks.q7_batch_qps [--full]
(standalone default is the smoke catalog so the sweep stays CI-scale).
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core import EngineOptions, compile_query

from .common import BenchEnv, Row, timeit
from .counters import per_query_amortized

BATCHES = (1, 8, 64, 256)
SQL = ("SELECT sample_id FROM products "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT {K}")
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_batch.json")


FLAT_ROWS = 2000   # the acceptance workload's catalog: interpret-mode flat
                   # scans are CPU-emulated, so the sweep stays tiny & fixed


def _queries(base: np.ndarray, q: int) -> np.ndarray:
    """Tile+jitter a query set out to q vectors (QPS needs bigger batches
    than the catalog's query table carries)."""
    rng = np.random.default_rng(7)
    reps = -(-q // base.shape[0])
    qs = np.tile(base, (reps, 1))[:q]
    return (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)


def _workloads(env: BenchEnv):
    """(catalog, qvecs, options) per workload.

    ``flat`` runs on a dedicated FLAT_ROWS-row catalog (index-less scans cost
    O(N) per query in interpret mode); ``ivf`` probes the env catalog."""
    from repro.data import make_laion_catalog
    probe = dataclasses.replace(env.cfg.probe, probe_batch=4)
    small = make_laion_catalog(n_rows=min(env.cfg.n_rows, FLAT_ROWS),
                               n_queries=8, dim=env.cfg.dim, n_modes=16,
                               seed=env.cfg.seed)
    small_q = np.asarray(small.table("queries")["embedding"])
    return {
        "flat": (small, small_q,
                 EngineOptions(engine="brute", use_pallas=True)),
        "ivf": (env.catalog, env.qvecs,
                EngineOptions(engine="chase", probe=probe)),
    }


def run(env: BenchEnv, rows: list, batches=BATCHES) -> dict:
    K = min(env.cfg.k_top, 10)
    sql = SQL.replace("{K}", str(K))
    report: dict = {"n_rows": env.cfg.n_rows, "flat_rows": FLAT_ROWS,
                    "dim": env.cfg.dim, "k": K, "workloads": {}}
    for name, (catalog, qvecs, opts) in _workloads(env).items():
        q = compile_query(sql, catalog, opts)
        entries = []
        base_qps = None
        for b in batches:
            qs = _queries(qvecs, b)
            if b == 1:
                # per-request loop shape: one single-query pipeline call
                # (more repeats: the ratio denominator must be stable)
                ms = timeit(lambda: q(qv=qs[0]), repeats=9)
                out = q(qv=qs[0])
            else:
                ms = timeit(lambda: q.execute_batch(qv=qs), repeats=3)
                out = q.execute_batch(qv=qs)
            qps = 1e3 * b / ms
            base_qps = base_qps if base_qps is not None else qps
            derived = per_query_amortized(out["stats"], b)
            derived.update(batch=b, qps=round(qps, 1),
                           speedup_vs_b1=round(qps / base_qps, 2))
            entries.append({"batch": b, "ms": round(ms, 3),
                            "qps": round(qps, 1), **derived})
            rows.append(Row(f"q7_{name}_b{b}", ms, **derived))
        report["workloads"][name] = entries
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    import argparse
    import sys

    from .common import get_env

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-scale catalog (default: smoke)")
    args = ap.parse_args()
    env = get_env(smoke=not args.full)
    rows: list[Row] = []
    report = run(env, rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
    flat = report["workloads"]["flat"]
    b64 = next(e for e in flat if e["batch"] == 64)
    print(f"\nflat-scan speedup at batch=64: {b64['speedup_vs_b1']}x",
          file=sys.stderr)
