"""Paper Table 7: Q4 (entity-centric KNN join) — the 7500x headline.

chase      = R2 rewrite: per-left-row ANN top-k (Fig. 5b)
brute      = compiled masked top-k per row (LingoDB-V analogue)
brute_sort = the un-rewritten Fig. 5a plan: the window sorts the WHOLE
             partition per left row (|A|·|B|log|B|) — what PASE/VBASE/pgvector
             execute per §7.3.3."""
from __future__ import annotations

import numpy as np

from repro.core import EngineOptions, compile_query

from .common import BenchEnv, Row, timeit

SQL = """
SELECT qid, tid FROM (
 SELECT users.id AS qid, movies.sample_id AS tid,
 RANK() OVER (PARTITION BY users.id
   ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
 FROM users JOIN movies ON users.preferred_rating = movies.rating
) AS ranked WHERE ranked.rank <= {K}
"""

ENGINES = ("chase", "brute", "brute_sort")


def run(env: BenchEnv, rows: list):
    K = env.cfg.k_top
    sql = SQL.replace("{K}", str(K))
    probe = env.cfg.probe
    rating_q = np.asarray(env.catalog.table("queries")["preferred_rating"])
    rating_c = np.asarray(env.catalog.table("laion")["rating"])
    # exact ground truth
    gt = {}
    for qi in range(env.qvecs.shape[0]):
        s = env.sims[qi].copy()
        s[rating_c != rating_q[qi]] = -np.inf
        top = np.argpartition(-s, K)[:K]
        gt[qi] = set(top[np.isfinite(s[top])].tolist())
    for engine in ENGINES:
        q = compile_query(sql, env.catalog,
                          EngineOptions(engine=engine, probe=probe))
        ms = timeit(lambda: q(), repeats=3)
        out = q()
        tid = np.asarray(out["tid"])
        valid = np.asarray(out["valid"])
        recs = []
        for qi in range(tid.shape[0]):
            got = set(tid[qi][valid[qi]].tolist())
            recs.append(len(got & gt[qi]) / max(len(gt[qi]), 1))
        rows.append(Row(f"q4_{engine}", ms,
                        recall=round(float(np.mean(recs)), 4),
                        evals=int(np.asarray(
                            out["stats"]["distance_evals"]).sum())))
