"""Q3/Q4 — batch-native join throughput (the batched-join acceptance sweep).

The left side of a vector join IS a query batch (ISSUE 2 / Sanca et al.):
this bench sweeps left-table size L ∈ {8, 64, 256} and compares the two
physical lowerings of the join families on identical plans:

* ``perleft`` — the legacy inner loop: one single-query scan/probe per left
  row (``join_lowering='perleft'``).  On the flat path that is one
  matvec-shaped Pallas kernel pass per left row.
* ``batch``   — the batch-native lowering: all L left embeddings gathered
  into one (L, d) query batch through the query-tiled kernels
  (``fused_scan_topk_batch`` / ``fused_range_topk_batch``) or the
  multi-cluster IVF probes (``ivf_topk_batch`` / ``ivf_range_batch``).

Both lowerings are ONE compiled executable; the measured difference is
purely the operator shape (L tiny pipelines vs one amortized MXU pipeline).
Reports join QPS (left rows completed per second) and per-left amortized
distance-eval/probe counters, and writes ``BENCH_join.json`` (consumed by
the acceptance gate: flat-path Q3/Q4 batch QPS at L=64 must be ≥ 3× the
per-left loop in interpret mode).

Standalone:  PYTHONPATH=src python -m benchmarks.q34_join_qps [--full]
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import EngineOptions, compile_query

from .common import BenchEnv, Row, timeit
from .counters import per_left_amortized

LEFT_SIZES = (8, 64, 256)
JOIN_ROWS = 2000   # right-table size: interpret-mode scans are CPU-emulated,
                   # keep the sweep CI-scale (mirrors q7's FLAT_ROWS)
GATE_L = 64        # acceptance: flat speedup at this L must be >= 3x
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_join.json")

SQL_Q3 = """
SELECT queries.id AS qid, images.sample_id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${r}
AND images.capture_date > queries.capture_date
"""

SQL_Q4 = """
SELECT qid, tid FROM (
 SELECT users.id AS qid, movies.sample_id AS tid,
 RANK() OVER (PARTITION BY users.id
   ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
 FROM users JOIN movies ON users.preferred_rating = movies.rating
) AS ranked WHERE ranked.rank <= {K}
"""


def _catalog(env: BenchEnv, nleft: int):
    """A JOIN_ROWS-row catalog whose left (queries/users) table has L rows."""
    import dataclasses

    import jax

    from repro.data import make_laion_catalog
    from repro.index import build_ivf

    cat = make_laion_catalog(n_rows=min(env.cfg.n_rows, JOIN_ROWS),
                             n_queries=nleft, dim=env.cfg.dim, n_modes=16,
                             seed=env.cfg.seed, metric=env.cfg.metric)
    idx = build_ivf(jax.random.key(env.cfg.seed), cat.table("laion")["vec"],
                    nlist=32, metric=env.cfg.metric, iters=3)
    for name in ("laion", "products", "images", "recipes", "movies"):
        cat.register_index(name, "vec", idx)
        cat.register_index(name, "embedding", idx)
    sims = (np.asarray(cat.table("queries")["embedding"])
            @ np.asarray(cat.table("laion")["vec"]).T)
    # radius tuned to ~40 in-range rows per left row
    radius = float(np.median(np.partition(sims, -40, axis=1)[:, -40]))
    probe = dataclasses.replace(env.cfg.probe, probe_batch=4)
    return cat, radius, probe


def _workloads(radius, probe, k: int):
    """(sql, binds, opts-maker) per workload; flat rides the Pallas kernels
    in BOTH lowerings (perleft = one single-query kernel pass per left row),
    ivf rides the probe layer (perleft = one while_loop probe per left row)."""
    sql4 = SQL_Q4.replace("{K}", str(k))
    return {
        "q3_flat": (SQL_Q3, {"r": radius},
                    lambda low: EngineOptions(engine="brute", use_pallas=True,
                                              max_pairs=128,
                                              join_lowering=low)),
        "q4_flat": (sql4, {},
                    lambda low: EngineOptions(engine="brute", use_pallas=True,
                                              join_lowering=low)),
        "q3_ivf": (SQL_Q3, {"r": radius},
                   lambda low: EngineOptions(engine="chase", probe=probe,
                                             max_pairs=128,
                                             join_lowering=low)),
        "q4_ivf": (sql4, {},
                   lambda low: EngineOptions(engine="chase", probe=probe,
                                             join_lowering=low)),
    }


def run(env: BenchEnv, rows: list, left_sizes=LEFT_SIZES) -> dict:
    K = min(env.cfg.k_top, 10)
    report: dict = {"right_rows": JOIN_ROWS, "dim": env.cfg.dim, "k": K,
                    "gate_left_size": GATE_L, "workloads": {}}
    for nleft in left_sizes:
        cat, radius, probe = _catalog(env, nleft)
        for name, (sql, binds, mk_opts) in _workloads(radius, probe,
                                                      K).items():
            entry = {"left_rows": nleft}
            for low in ("perleft", "batch"):
                q = compile_query(sql, cat, mk_opts(low))
                ms = timeit(lambda: q(**binds), repeats=3)
                out = q(**binds)
                entry[f"ms_{low}"] = round(ms, 3)
                entry[f"qps_{low}"] = round(1e3 * nleft / ms, 1)
                if low == "batch":
                    entry.update(per_left_amortized(out["stats"], nleft))
            entry["speedup"] = round(entry["qps_batch"]
                                     / entry["qps_perleft"], 2)
            report["workloads"].setdefault(name, []).append(entry)
            rows.append(Row(f"q34_{name}_L{nleft}", entry["ms_batch"],
                            **{k: v for k, v in entry.items()
                               if k != "left_rows"}))
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    import argparse
    import sys

    from .common import get_env

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-scale catalog (default: smoke)")
    args = ap.parse_args()
    env = get_env(smoke=not args.full)
    rows: list[Row] = []
    report = run(env, rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
    for name in ("q3_flat", "q4_flat"):
        gate = next(e for e in report["workloads"][name]
                    if e["left_rows"] == GATE_L)
        print(f"\n{name} batch-vs-perleft speedup at L={GATE_L}: "
              f"{gate['speedup']}x", file=sys.stderr)
