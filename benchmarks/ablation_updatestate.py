"""Paper Fig. 9: updateState on/off while the query range grows.

With updateState the probe stops at the per-category convergence radius R2;
without it, execution time grows with R1."""
from __future__ import annotations

import numpy as np

from repro.core import EngineOptions, compile_query

from .common import BenchEnv, Row, timeit

SQL = """
SELECT qid, category FROM (
 SELECT sample_id AS qid, calorie_level AS category,
 RANK() OVER (PARTITION BY calorie_level
   ORDER BY DISTANCE(embedding, ${qv})) AS rank
 FROM recipes WHERE DISTANCE(embedding, ${qv}) <= ${r}
) AS ranked WHERE ranked.rank <= {K}
"""

# growing ranges: average match counts per query (R1 growing, paper's
# thresholds 0.8 -> 0.5)
MATCH_TARGETS = (120, 500, 2000, 8000)


def run(env: BenchEnv, rows: list):
    K = env.cfg.k_category
    sql = SQL.replace("{K}", str(K))
    probe = env.cfg.probe
    for target in MATCH_TARGETS:
        t = min(target, env.cfg.n_rows - 2)
        kth = np.partition(env.sims, -t, axis=1)[:, -t]
        radius = float(np.median(kth))
        for engine, label in (("chase", "with_updateState"),
                              ("chase_no_updatestate", "without")):
            q = compile_query(sql, env.catalog,
                              EngineOptions(engine=engine, probe=probe))
            ms = timeit(lambda: q(qv=env.qvecs[0], r=radius), repeats=3)
            out = q(qv=env.qvecs[0], r=radius)
            rows.append(Row(f"fig9_range{target}_{label}", ms,
                            probes=int(out["stats"]["probes"]),
                            evals=int(out["stats"]["distance_evals"])))
