"""Shared benchmark environment: LAION-shaped corpus + IVF index + ground
truth, selectivity calibration per §7.1, timing protocol."""
from __future__ import annotations

import dataclasses
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402

from repro.configs.chase_laion import (ChaseBenchConfig, bench_config,
                                       smoke_bench_config)    # noqa: E402
from repro.core import Metric                                 # noqa: E402
from repro.data import make_laion_catalog                     # noqa: E402
from repro.index import FlatIndex, build_ivf                  # noqa: E402

SELECTIVITIES = (1.0, 0.9, 0.7, 0.5, 0.3, 0.03)


@dataclasses.dataclass
class BenchEnv:
    cfg: ChaseBenchConfig
    catalog: object
    flat: FlatIndex
    qvecs: np.ndarray            # (Q, dim)
    sims: np.ndarray             # (Q, N) ground-truth similarities
    price: np.ndarray
    price_thresholds: dict       # selectivity -> threshold
    radius_topk: float           # tuned so avg matches ≈ range_match_target


_ENV = {}


def get_env(smoke: bool = False) -> BenchEnv:
    if smoke in _ENV:
        return _ENV[smoke]
    cfg = smoke_bench_config() if smoke else bench_config()
    t0 = time.time()
    catalog = make_laion_catalog(
        n_rows=cfg.n_rows, n_queries=cfg.n_queries, dim=cfg.dim,
        n_modes=cfg.n_modes, num_categories=cfg.num_categories,
        seed=cfg.seed, metric=cfg.metric)
    corpus = catalog.table("laion")["vec"]
    idx = build_ivf(jax.random.key(cfg.seed), corpus, nlist=cfg.nlist,
                    metric=cfg.metric, iters=cfg.kmeans_iters)
    for name in ("laion", "products", "images", "recipes", "movies"):
        catalog.register_index(name, "vec", idx)
        catalog.register_index(name, "embedding", idx)
    flat = FlatIndex(cfg.metric, corpus)
    qvecs = np.asarray(catalog.table("queries")["embedding"])
    sims = np.asarray(
        jnp.einsum("qd,nd->qn", jnp.asarray(qvecs), corpus))
    price = np.asarray(catalog.table("laion")["price"])
    thresholds = {s: float(np.quantile(price, s)) if s < 1.0 else None
                  for s in SELECTIVITIES}
    # radius: avg #matches == range_match_target (paper: ~120 per query)
    target = cfg.range_match_target
    per_query_kth = np.partition(sims, -target, axis=1)[:, -target]
    radius = float(np.median(per_query_kth))
    env = BenchEnv(cfg, catalog, flat, qvecs, sims, price, thresholds,
                   radius)
    print(f"[bench] env ready: N={cfg.n_rows} dim={cfg.dim} "
          f"nlist={cfg.nlist} radius={radius:.4f} "
          f"({time.time()-t0:.1f}s)", file=sys.stderr, flush=True)
    _ENV[smoke] = env
    return env


def timeit(fn, repeats: int = 5) -> float:
    """Median wall-clock ms over ``repeats`` (after a warmup/compile call)."""
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out)[0] if isinstance(out, dict)
                          else out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0]
                              if isinstance(out, dict) else out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e3)


def recall_sets(got_ids: np.ndarray, got_valid: np.ndarray,
                gt_ids: np.ndarray, gt_valid: np.ndarray | None = None
                ) -> float:
    got = set(np.asarray(got_ids)[np.asarray(got_valid)].tolist())
    if gt_valid is None:
        gt = set(np.asarray(gt_ids).tolist())
    else:
        gt = set(np.asarray(gt_ids)[np.asarray(gt_valid)].tolist())
    gt.discard(-1)
    got.discard(-1)
    if not gt:
        return 1.0
    return len(got & gt) / len(gt)


class Row:
    """One CSV record: name,us_per_call,derived."""

    def __init__(self, name: str, ms: float, **derived):
        self.name = name
        self.ms = ms
        self.derived = derived

    def csv(self) -> str:
        extra = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.name},{self.ms*1e3:.1f},{extra}"
