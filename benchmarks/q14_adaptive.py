"""Q14 — adaptive optimizer vs the static p75 pilot (DESIGN.md §14).

Two skewed-selectivity workloads where the static effort-calibration
heuristic (pilot = p75 of a warmup run's probe counters + 1, the q8 recipe)
leaves money on the table, timed under three policies on IDENTICAL compiled
plans — every policy is bit-exact, only the effort split moves:

* ``lockstep`` — one unbudgeted bucketed execution (stragglers couple).
* ``static``   — :func:`run_effort_bucketed` with the scalar p75 pilot:
  ~25% of the batch is heavy BY CONSTRUCTION every run, so phase 2 always
  re-runs a straggler subset unbudgeted.
* ``adaptive`` — :func:`run_effort_bucketed` with a warmed
  :class:`LoweringAdvisor`: the stats-predicted pilot (EMA p75 x headroom)
  covers the bulk of the batch, and on joins the per-left probe PROFILE
  budgets each left row individually — a scalar pilot cannot express that,
  and one heavy left re-runs its whole bind set in phase 2.

Workloads:

* ``single`` — the q8-shaped heterogeneous single-table batch: N_BATCH
  date-filter selectivities spanning permissive to needle-selective over
  one stacked top-k batch.
* ``join``   — Q3 distance join, B_SETS stacked bind sets over an L-row
  left table with naturally heterogeneous per-left fan-outs; the advisor's
  (L,) profile budgets send phase 2 to zero bind sets.

Writes ``BENCH_adaptive.json``; scripts/bench_gate.py gates the within-run
contract ``join.ratio_adaptive_vs_static >= 1.0`` (advisor at least matches
the static pilot, measured back-to-back so the ratio never rides cross-run
machine noise) plus fresh-vs-committed QPS on the adaptive rows.

Standalone:  PYTHONPATH=src python -m benchmarks.q14_adaptive [--full]
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.core import EngineOptions, compile_query

from .common import BenchEnv, Row

SINGLE_ROWS = 8000   # right-table rows for the single-table batch row
JOIN_ROWS = 2000     # right-table rows for the join row
N_BATCH = 64         # stacked queries in the single-table batch
N_LEFT = 16          # join left-table rows
B_SETS = 4           # join bind sets stacked per execution
K = 10
REPEATS = 5
OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_adaptive.json")

SQL_SINGLE = ("SELECT sample_id FROM images WHERE capture_date > ${d} "
              "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 10")
SQL_JOIN = """
SELECT queries.id AS qid, images.sample_id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${r}
AND images.capture_date > queries.capture_date
"""


def _catalog(env: BenchEnv, n_rows: int, n_queries: int, nlist: int):
    import jax

    from repro.data import make_laion_catalog
    from repro.index import build_ivf

    cat = make_laion_catalog(n_rows=n_rows, n_queries=n_queries,
                             dim=env.cfg.dim, n_modes=16, seed=env.cfg.seed)
    idx = build_ivf(jax.random.key(env.cfg.seed), cat.table("laion")["vec"],
                    nlist=nlist, metric=env.cfg.metric, iters=4)
    for name in ("laion", "products", "images", "recipes", "movies"):
        cat.register_index(name, "vec", idx)
        cat.register_index(name, "embedding", idx)
    return cat


def _block(out):
    import jax
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return out


def _timed_ms(fn, repeats: int = REPEATS) -> float:
    _block(fn())                                  # compile out of the clock
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e3)


def _policies(q, binds, advisor, n_queries: int, rows: list, report: dict,
              name: str, calib_binds=None) -> None:
    """Time lockstep / static-p75 / adaptive back-to-back on one plan.

    ``calib_binds`` (defaults to the measured binds) is what the STATIC
    pilot is calibrated from — the q8 recipe runs its warmup once at
    deploy time, so under workload drift the pilot is stale; the advisor
    re-learns from the live traffic it observes."""
    from repro.serving.scheduler import run_effort_bucketed

    calib = _block(q.executor(calib_binds if calib_binds is not None
                              else binds))
    pilot = int(np.percentile(np.asarray(calib["stats"]["probes"]), 75)) + 1
    lock = _block(q.executor(binds))
    # warm the advisor: cold lock-step observe, then one budgeted round
    for _ in range(2):
        out, info = run_effort_bucketed(q, binds, 0, advisor=advisor)
    assert info["opt"]["source"] in ("stats", "profile"), info
    import jax
    for x, y in zip(jax.tree.leaves(lock), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), \
            "adaptive diverged from lock-step"
    _, sinfo = run_effort_bucketed(q, binds, pilot)
    t_lock = _timed_ms(lambda: q.executor(binds))
    t_static = _timed_ms(
        lambda: run_effort_bucketed(q, binds, pilot)[0])
    t_adapt = _timed_ms(
        lambda: run_effort_bucketed(q, binds, 0, advisor=advisor)[0])
    entry = {
        "workload": name, "n_queries": n_queries,
        "static_pilot": pilot,
        "static_heavy": sinfo["n_heavy"], "adaptive_heavy": info["n_heavy"],
        "opt": info["opt"],
        "ms_lockstep": round(t_lock, 2), "ms_static": round(t_static, 2),
        "ms_adaptive": round(t_adapt, 2),
        "qps_adaptive": round(n_queries / (t_adapt / 1e3), 1),
        "qps_static": round(n_queries / (t_static / 1e3), 1),
        "ratio_adaptive_vs_static": round(t_static / t_adapt, 3),
    }
    report["rows"].append(entry)
    rows.append(Row(f"q14_{name}_adaptive", t_adapt,
                    ms_static=entry["ms_static"],
                    ms_lockstep=entry["ms_lockstep"],
                    ratio_vs_static=entry["ratio_adaptive_vs_static"],
                    heavy=f"{info['n_heavy']}<{sinfo['n_heavy']}"))


def _single_row(env: BenchEnv, rows: list, report: dict) -> None:
    import jax.numpy as jnp

    from repro.opt import LoweringAdvisor

    cat = _catalog(env, SINGLE_ROWS, N_BATCH, 64)
    probe = dataclasses.replace(env.cfg.probe, probe_batch=2, max_probes=64)
    q = compile_query(SQL_SINGLE, cat, EngineOptions(engine="chase",
                                                     probe=probe))
    # workload DRIFT: the static pilot is calibrated once, on permissive
    # deploy-time traffic (low probe counts -> small pilot); the measured
    # batch is needle-selective, so the stale pilot classifies most of it
    # heavy and phase 2 re-runs the bulk unbudgeted.  The advisor's EMA is
    # fed by the live traffic and re-predicts within two batches.
    rng = np.random.default_rng(env.cfg.seed)
    dates = np.asarray(cat.table("laion")["capture_date"])
    qs = np.asarray(cat.table("queries")["embedding"])[:N_BATCH]

    def _binds(sel):
        return q._stack_binds(None, dict(
            qv=jnp.asarray(qs),
            d=jnp.asarray(np.quantile(dates, sel).astype(np.int32))))

    calib = _binds(rng.uniform(0.0, 0.8, N_BATCH))       # deploy-time
    sel = np.concatenate([rng.uniform(0.9, 0.99, N_BATCH - 12),
                          rng.uniform(0.995, 0.9995, 12)])
    rng.shuffle(sel)
    live = _binds(sel)                                   # drifted traffic
    _policies(q, live, LoweringAdvisor(cat), N_BATCH, rows, report,
              "single_drift", calib_binds=calib)


def _join_row(env: BenchEnv, rows: list, report: dict) -> None:
    from repro.opt import LoweringAdvisor

    cat = _catalog(env, JOIN_ROWS, N_LEFT, 32)
    probe = dataclasses.replace(env.cfg.probe, probe_batch=2, max_probes=32)
    q = compile_query(SQL_JOIN, cat, EngineOptions(engine="chase",
                                                   probe=probe,
                                                   max_pairs=256))
    sims = (np.asarray(cat.table("queries")["embedding"])
            @ np.asarray(cat.table("laion")["vec"]).T)
    radius = float(np.median(np.partition(sims, -40, axis=1)[:, -40]))
    rng = np.random.default_rng(env.cfg.seed + 1)
    sets = [{"r": np.float32(radius * f)}
            for f in rng.uniform(0.9, 1.0, B_SETS)]
    binds = q._stack_binds(sets, {})
    _policies(q, binds, LoweringAdvisor(cat), B_SETS * N_LEFT, rows, report,
              "join")


def run(env: BenchEnv, rows: list) -> dict:
    report: dict = {"dim": env.cfg.dim, "k": K, "single_rows": SINGLE_ROWS,
                    "join_rows": JOIN_ROWS, "n_batch": N_BATCH,
                    "n_left": N_LEFT, "b_sets": B_SETS, "rows": []}
    _single_row(env, rows, report)
    _join_row(env, rows, report)
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    import argparse
    import sys

    from .common import get_env

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-scale catalog (default: smoke)")
    args = ap.parse_args()
    env = get_env(smoke=not args.full)
    rows: list[Row] = []
    report = run(env, rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
    for e in report["rows"]:
        print(f"\n{e['workload']}: adaptive {e['ms_adaptive']}ms vs static "
              f"pilot {e['ms_static']}ms "
              f"({e['ratio_adaptive_vs_static']}x, heavy "
              f"{e['adaptive_heavy']} vs {e['static_heavy']})",
              file=sys.stderr)
