"""Paper Fig. 8: Q5 (category partition) and Q6 (category join)."""
from __future__ import annotations

import numpy as np

from repro.core import EngineOptions, compile_query

from .common import BenchEnv, Row, recall_sets, timeit

SQL_Q5 = """
SELECT qid, category FROM (
 SELECT sample_id AS qid, calorie_level AS category,
 RANK() OVER (PARTITION BY calorie_level
   ORDER BY DISTANCE(embedding, ${qv})) AS rank
 FROM recipes
 WHERE DISTANCE(embedding, ${qv}) <= ${r} AND cuisine <> ${ex}
) AS ranked WHERE ranked.rank <= {K}
"""

SQL_Q6 = """
SELECT qid, category, tid FROM (
 SELECT queries.id AS qid, recipes.sample_id AS tid,
 recipes.calorie_level AS category,
 RANK() OVER (PARTITION BY queries.id, recipes.calorie_level
   ORDER BY DISTANCE(queries.embedding, recipes.embedding)) AS rank
 FROM queries JOIN recipes
 ON DISTANCE(queries.embedding, recipes.embedding) <= ${r}
 AND queries.cuisine <> recipes.cuisine
) AS ranked WHERE ranked.rank <= {K}
"""

ENGINES = ("chase", "vbase", "brute")


def run(env: BenchEnv, rows: list, n_queries: int = 8):
    n_queries = min(n_queries, env.qvecs.shape[0])
    K = env.cfg.k_category
    probe = env.cfg.probe
    cats = np.asarray(env.catalog.table("laion")["calorie_level"])
    cuisine = np.asarray(env.catalog.table("laion")["cuisine"])
    radius = env.radius_topk

    sql5 = SQL_Q5.replace("{K}", str(K))
    for engine in ENGINES:
        q = compile_query(sql5, env.catalog,
                          EngineOptions(engine=engine, probe=probe))

        def call(qi=0):
            return q(qv=env.qvecs[qi], r=radius, ex=3)

        ms = timeit(lambda: call(0), repeats=3)
        recalls = []
        for qi in range(n_queries):
            out = call(qi)
            hit = (env.sims[qi] >= radius) & (cuisine != 3)
            ok = 0.0
            C = env.cfg.num_categories
            for c in range(C):
                rows_c = np.flatnonzero(hit & (cats == c))
                want = set(rows_c[np.argsort(-env.sims[qi][rows_c])][:K]
                           .tolist())
                got = set(np.asarray(out["ids"])[c][
                    np.asarray(out["valid"])[c]].tolist())
                ok += len(got & want) / max(len(want), 1)
            recalls.append(ok / C)
        rows.append(Row(f"q5_{engine}", ms,
                        recall=round(float(np.mean(recalls)), 4),
                        probes=int(out["stats"]["probes"])))

    sql6 = SQL_Q6.replace("{K}", str(K))
    for engine in ENGINES:
        q = compile_query(sql6, env.catalog,
                          EngineOptions(engine=engine, probe=probe))
        ms = timeit(lambda: q(r=radius), repeats=3)
        out = q(r=radius)
        rows.append(Row(f"q6_{engine}", ms,
                        valid=int(np.asarray(out["valid"]).sum())))
