"""Paper Table 6: Q3 (distance join) — per-left-row range probes vs brute."""
from __future__ import annotations

import numpy as np

from repro.core import EngineOptions, compile_query

from .common import BenchEnv, Row, recall_sets, timeit

SQL = """
SELECT queries.id AS qid, images.sample_id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${r}
AND images.capture_date > queries.capture_date
"""

ENGINES = ("chase", "vbase", "brute")
SELS = (1.0, 0.5, 0.03)


def run(env: BenchEnv, rows: list, n_queries: int = 32):
    probe = env.cfg.probe
    n_queries = min(n_queries, env.qvecs.shape[0])
    qdate = np.asarray(env.catalog.table("queries")["capture_date"])
    cdate = np.asarray(env.catalog.table("laion")["capture_date"])
    for sel in SELS:
        # selectivity via the date residual: scale the join date predicate
        # (paper varies structured selectivity; here date quantile plays p)
        radius = env.radius_topk if sel >= 0.5 else float(
            np.quantile(env.sims, 1 - 20 / env.cfg.n_rows))
        for engine in ENGINES:
            q = compile_query(SQL, env.catalog,
                              EngineOptions(engine=engine, probe=probe,
                                            max_pairs=512))
            ms = timeit(lambda: q(r=radius), repeats=3)
            out = q(r=radius)
            # recall vs exact pairs
            got_pairs = set()
            qid = np.asarray(out["qid"])[np.asarray(out["valid"])]
            tid = np.asarray(out["tid"])[np.asarray(out["valid"])]
            got_pairs = set(zip(qid.tolist(), tid.tolist()))
            want = set()
            for qi in range(env.qvecs.shape[0]):
                hit = (env.sims[qi] >= radius) & (cdate > qdate[qi])
                for t in np.flatnonzero(hit)[:512]:
                    want.add((qi, int(t)))
            rec = len(got_pairs & want) / max(len(want), 1)
            rows.append(Row(f"q3_sel{sel}_{engine}", ms,
                            recall=round(rec, 4), pairs=len(got_pairs)))
