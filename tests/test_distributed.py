"""Multi-device tests (subprocess with 8 fake CPU devices): distributed
top-k merge, compressed-DP training, shard_map MoE parity, elastic reshard.

Marked ``slow``: each test boots a fresh interpreter with a fake 8-device
topology.  Deselected from the default suite (pytest.ini); run with
``pytest -m slow`` or ``pytest -m ""``."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_distributed_topk_matches_flat():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.dist.collectives import distributed_topk, shard_corpus
        from repro.index import FlatIndex
        from repro.core.schema import Metric

        mesh = make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        corpus = jnp.asarray(rng.standard_normal((4096, 32)).astype(np.float32))
        q = jnp.asarray(rng.standard_normal(32).astype(np.float32))
        mask = jnp.asarray(rng.random(4096) < 0.5)
        flat = FlatIndex(Metric.INNER_PRODUCT, corpus)
        gt_ids, gt_sims, _ = flat.topk(q, 10, mask)
        with mesh:
            sh_corpus, sh_ids = shard_corpus(mesh, corpus)
            sh_mask = jax.device_put(mask, sh_ids.sharding)
            fn = jax.jit(distributed_topk(mesh, Metric.INNER_PRODUCT, 10))
            ids, sims, valid = fn(sh_corpus, sh_ids, q, sh_mask)
        assert set(np.asarray(ids).tolist()) == set(np.asarray(gt_ids).tolist())
        np.testing.assert_allclose(np.sort(np.asarray(sims)),
                                   np.sort(np.asarray(gt_sims)), rtol=1e-5)
        print("DIST_TOPK_OK")
    """)
    assert "DIST_TOPK_OK" in out


def test_distributed_topk_multi_pod_hierarchical():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.dist.collectives import distributed_topk, shard_corpus
        from repro.index import FlatIndex
        from repro.core.schema import Metric

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rng = np.random.default_rng(1)
        corpus = jnp.asarray(rng.standard_normal((2048, 16)).astype(np.float32))
        q = jnp.asarray(rng.standard_normal(16).astype(np.float32))
        mask = jnp.ones(2048, bool)
        flat = FlatIndex(Metric.L2, corpus)
        gt_ids, _, _ = flat.topk(q, 8)
        with mesh:
            sh_corpus, sh_ids = shard_corpus(mesh, corpus, axes=("pod", "data"))
            sh_mask = jax.device_put(jnp.asarray(mask), sh_ids.sharding)
            fn = jax.jit(distributed_topk(mesh, Metric.L2, 8,
                                          axes=("pod", "data")))
            ids, sims, valid = fn(sh_corpus, sh_ids, q, sh_mask)
        assert set(np.asarray(ids).tolist()) == set(np.asarray(gt_ids).tolist())
        print("POD_TOPK_OK")
    """)
    assert "POD_TOPK_OK" in out


def test_compressed_dp_step_trains():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.configs import get_config
        from repro.data.pipeline import DataConfig, SyntheticLM
        from repro.models import init_params
        from repro.training import AdamWConfig, adamw_init
        from repro.training.step import build_compressed_dp_step

        mesh = make_mesh((8,), ("data",))
        cfg = get_config("qwen2-1.5b", smoke=True)
        opt_cfg = AdamWConfig(lr_peak=3e-3, warmup_steps=2, total_steps=30)
        params = init_params(jax.random.key(0), cfg)
        opt = adamw_init(opt_cfg, params)
        err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        data = SyntheticLM(DataConfig(global_batch=8, seq_len=32,
                                      vocab_size=cfg.vocab_size))
        step = build_compressed_dp_step(cfg, opt_cfg, mesh)
        losses = []
        with mesh:
            for i in range(30):
                params, opt, err, m = step(params, opt, err, data.batch_at(i))
                losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses
        print("COMPRESSED_DP_OK", round(losses[0], 3), round(losses[-1], 3))
    """)
    assert "COMPRESSED_DP_OK" in out


def test_moe_shard_map_matches_local():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.configs import get_config
        from repro.models.moe import moe_init, moe_apply, _moe_local
        from repro.dist.sharding import logical_axis_rules

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_config("moonshot-v1-16b-a3b", smoke=True)  # 8 experts % 4 == 0
        p = moe_init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model),
                              jnp.float32) * 0.3
        want, aux_w = _moe_local(p, cfg, x, 8.0)
        rules = {"batch": "data", "embed": None, "mlp_embed": None,
                 "ff": "model", "experts": "model", "expert_ff_in": None,
                 "moe_ff": None, "moe_cap": "data"}
        with mesh, logical_axis_rules(rules, mesh):
            got, aux_g = jax.jit(lambda p, x: moe_apply(p, cfg, x, 8.0))(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-3, atol=3e-3)
        np.testing.assert_allclose(float(aux_g), float(aux_w), rtol=1e-3)
        print("MOE_SHARDMAP_OK")
    """)
    assert "MOE_SHARDMAP_OK" in out


def test_elastic_reshard_restore():
    """Checkpoint under an 8-device mesh, restore under 4 devices."""
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        _run(f"""
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import make_mesh
            from repro.checkpoint import save
            mesh = make_mesh((4, 2), ("data", "model"))
            x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
            xs = jax.device_put(x, NamedSharding(mesh, P("data", "model")))
            save({tmp!r}, 1, {{"w": xs}})
            print("SAVED")
        """, devices=8)
        out = _run(f"""
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import make_mesh
            from repro.checkpoint import restore
            mesh = make_mesh((2, 2), ("data", "model"))   # smaller fleet
            target = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
            sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
            got = restore({tmp!r}, 1, target, sh)
            np.testing.assert_array_equal(
                np.asarray(got["w"]),
                np.arange(64, dtype=np.float32).reshape(8, 8))
            assert len(got["w"].sharding.device_set) == 4
            print("RESHARD_OK")
        """, devices=4)
        assert "RESHARD_OK" in out


def test_dryrun_tiny_mesh_smoke():
    """The dry-run entrypoint itself, on a tiny mesh (CI-scale)."""
    env = dict(os.environ)
    env["REPRO_DRYRUN_XLA_FLAGS"] = \
        "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "qwen2-1.5b,mamba2-370m", "--shape", "train_4k,decode_32k",
         "--mesh", "tiny", "--smoke-config"],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert r.stdout.count(" ok") >= 4, r.stdout
