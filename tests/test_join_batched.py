"""Batch-native join families (Q3-Q6): parity against the per-left-row
loop, execute_batch native lowering, and the lock-step straggler contract.

Contracts under test (DESIGN.md §7):
* with ``join_lowering='batch'`` every join family gathers its left rows
  into ONE query batch on the batched kernels/probes; with
  ``probe_batch=1`` (and the jnp flat path) results are bit-identical to
  the legacy ``join_lowering='perleft'`` loop at every L, including
  residual join predicates and ``max_pairs`` truncation;
* ordering policy: flat plans emit best-first (ascending order key) per
  left row; IVF plans emit probe-discovery order — identical across
  lowerings; the Pallas flat path may permute equal-key ties only;
* ``execute_batch`` on Q3-Q6 flattens (bind sets x left rows) into one
  kernel-level query batch — no vmap-of-scalar fallback;
* per-query counters report each query's OWN termination point (lock-step
  freezing), stay calibrated in cluster units for any probe_batch, and
  respect per-query probe budgets.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import EngineOptions, Metric, compile_query
from repro.core.semantics import QueryClass
from repro.core.physical import BATCH_BUILDERS
from repro.index import build_ivf
from repro.index.ivf import (ProbeConfig, ivf_range, ivf_range_batch,
                             ivf_range_category, ivf_range_category_batch,
                             ivf_topk, ivf_topk_batch)

PROBE = ProbeConfig(max_probes=16, capacity=256, termination="bound")

Q3 = """
SELECT queries.id AS qid, images.sample_id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${r}
AND images.capture_date > queries.capture_date
"""
Q4 = """
SELECT qid, tid FROM (
 SELECT users.id AS qid, movies.sample_id AS tid,
 RANK() OVER (PARTITION BY users.id
   ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
 FROM users JOIN movies ON users.preferred_rating = movies.rating
) AS ranked WHERE ranked.rank <= 5
"""
Q5 = """
SELECT qid, category FROM (
 SELECT sample_id AS qid, calorie_level AS category,
 RANK() OVER (PARTITION BY calorie_level
   ORDER BY DISTANCE(embedding, ${qv})) AS rank
 FROM recipes WHERE DISTANCE(embedding, ${qv}) <= ${r}
) AS ranked WHERE ranked.rank <= 4
"""
Q6 = """
SELECT qid, category, tid FROM (
 SELECT queries.id AS qid, recipes.sample_id AS tid,
 recipes.calorie_level AS category,
 RANK() OVER (PARTITION BY queries.id, recipes.calorie_level
   ORDER BY DISTANCE(queries.embedding, recipes.embedding)) AS rank
 FROM queries JOIN recipes
 ON DISTANCE(queries.embedding, recipes.embedding) <= ${r}
 AND queries.cuisine <> recipes.cuisine
) AS ranked WHERE ranked.rank <= 3
"""


def _make_catalog(n_queries: int):
    from repro.data import make_laion_catalog

    cat = make_laion_catalog(n_rows=1500, n_queries=n_queries, dim=24,
                             n_modes=12, num_categories=4, seed=0)
    idx = build_ivf(jax.random.key(0), cat.table("laion")["vec"], nlist=16,
                    metric=Metric.INNER_PRODUCT, iters=3)
    for name in ("laion", "products", "images", "recipes", "movies"):
        cat.register_index(name, "vec", idx)
        cat.register_index(name, "embedding", idx)
    sims = (np.asarray(cat.table("queries")["embedding"])
            @ np.asarray(cat.table("laion")["vec"]).T)
    radius = float(np.median(np.partition(sims, -40, axis=1)[:, -40]))
    return cat, radius


@pytest.fixture(scope="module")
def join_env():
    return _make_catalog(5)


@pytest.fixture(scope="module")
def join_env_l1():
    return _make_catalog(1)


def _both(sql, cat, binds, **opt_kw):
    outs = {}
    for low in ("batch", "perleft"):
        q = compile_query(sql, cat,
                          EngineOptions(join_lowering=low, **opt_kw))
        outs[low] = jax.tree.map(np.asarray, q(**binds))
    return outs["batch"], outs["perleft"]


def _assert_identical(b, p):
    """Bit-identical outputs; stats totals equal (perleft sums per row)."""
    for key in p:
        if key == "stats":
            for sk in p["stats"]:
                assert np.sum(b["stats"][sk]) == np.sum(p["stats"][sk]), sk
            continue
        if b[key].dtype.kind == "f":
            np.testing.assert_allclose(b[key], p[key], rtol=1e-5, atol=1e-5,
                                       err_msg=key)
        else:
            assert np.array_equal(b[key], p[key]), key


# ---------------------------------------------------------------------------
# lowering parity (bit-identical: probe_batch=1, jnp flat path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["chase", "vbase", "brute"])
def test_q3_batch_matches_perleft(join_env, engine):
    cat, radius = join_env
    b, p = _both(Q3, cat, {"r": radius}, engine=engine, probe=PROBE,
                 max_pairs=128)
    _assert_identical(b, p)


@pytest.mark.parametrize("engine", ["chase", "brute", "brute_sort"])
def test_q4_batch_matches_perleft(join_env, engine):
    cat, _ = join_env
    b, p = _both(Q4, cat, {}, engine=engine, probe=PROBE)
    _assert_identical(b, p)


@pytest.mark.parametrize("engine",
                         ["chase", "vbase", "brute", "chase_no_updatestate"])
def test_q6_batch_matches_perleft(join_env, engine):
    """chase exercises ivf_range_category_batch (batched Algorithm 2)."""
    cat, radius = join_env
    b, p = _both(Q6, cat, {"r": radius}, engine=engine, probe=PROBE)
    _assert_identical(b, p)


@pytest.mark.parametrize("sql,binds,engine", [
    (Q3, {"r": None}, "chase"), (Q4, {}, "brute"), (Q6, {"r": None}, "chase"),
])
def test_join_batch_matches_perleft_at_l1(join_env_l1, sql, binds, engine):
    """L=1: the degenerate batch is still bit-identical to the loop."""
    cat, radius = join_env_l1
    binds = {k: radius for k in binds}
    b, p = _both(sql, cat, binds, engine=engine, probe=PROBE, max_pairs=64)
    _assert_identical(b, p)


def test_q3_max_pairs_truncation_parity(join_env):
    """Tiny max_pairs forces buffer truncation; the clamped buffers and the
    pre-truncation counts must match across lowerings."""
    cat, radius = join_env
    b, p = _both(Q3, cat, {"r": radius}, engine="chase", probe=PROBE,
                 max_pairs=8)
    _assert_identical(b, p)
    assert b["tid"].shape[1] == 8
    assert (b["count"] >= np.sum(b["valid"], axis=1)).all()


def test_q3_pallas_flat_matches_jnp(join_env):
    """The query-tiled Pallas flat path: same rows as the exact scan up to
    equal-key ties (ordering policy: best-first per left row)."""
    cat, _ = join_env
    sims = (np.asarray(cat.table("queries")["embedding"])
            @ np.asarray(cat.table("laion")["vec"]).T)
    # tie-safe radius: the widest gap between adjacent similarity values
    # near the target selectivity, so kernel float error can't flip a hit
    allv = np.sort(sims, axis=None)
    lo = allv.size - 60 * sims.shape[0]
    window = allv[lo:lo + 120]
    j = int(np.argmax(np.diff(window)))
    radius = float((window[j] + window[j + 1]) / 2)
    mk = lambda pallas: compile_query(Q3, cat, EngineOptions(
        engine="brute", use_pallas=pallas, max_pairs=64))
    ob = jax.tree.map(np.asarray, mk(True)(r=radius))
    oj = jax.tree.map(np.asarray, mk(False)(r=radius))
    assert np.array_equal(ob["valid"], oj["valid"])
    assert np.array_equal(ob["count"], oj["count"])
    np.testing.assert_allclose(np.sort(ob["sim"], axis=1),
                               np.sort(oj["sim"], axis=1), rtol=1e-4,
                               atol=1e-5)
    for i in range(ob["tid"].shape[0]):
        assert (set(ob["tid"][i][ob["valid"][i]].tolist())
                == set(oj["tid"][i][oj["valid"][i]].tolist()))


def test_q3_batch_respects_residual_predicate(join_env):
    cat, radius = join_env
    q = compile_query(Q3, cat, EngineOptions(engine="chase", probe=PROBE,
                                             max_pairs=128))
    out = jax.tree.map(np.asarray, q(r=radius))
    qdate = np.asarray(cat.table("queries")["capture_date"])
    cdate = np.asarray(cat.table("laion")["capture_date"])
    sims = (np.asarray(cat.table("queries")["embedding"])
            @ np.asarray(cat.table("laion")["vec"]).T)
    for i in range(out["qid"].shape[0]):
        tids = out["tid"][i][out["valid"][i]]
        assert (cdate[tids] > qdate[i]).all()
        assert (sims[i][tids] >= radius - 1e-5).all()


# ---------------------------------------------------------------------------
# execute_batch: native join lowering (no vmap-of-scalar fallback)
# ---------------------------------------------------------------------------

def test_join_families_have_native_batch_builders():
    for qc in (QueryClass.DIST_JOIN, QueryClass.KNN_JOIN,
               QueryClass.CATEGORY_PARTITION, QueryClass.CATEGORY_JOIN):
        assert qc in BATCH_BUILDERS


@pytest.mark.parametrize("sql,engine", [(Q3, "chase"), (Q3, "brute"),
                                        (Q6, "chase")])
def test_execute_batch_join_matches_singles(join_env, sql, engine):
    cat, radius = join_env
    radii = np.asarray([radius, radius * 0.98], np.float32)
    q = compile_query(sql, cat, EngineOptions(engine=engine, probe=PROBE,
                                              max_pairs=64))
    assert q.batch_native
    out = jax.tree.map(np.asarray, q.execute_batch(r=radii))
    for i, r in enumerate(radii):
        single = jax.tree.map(np.asarray, q(r=float(r)))
        for key in single:
            if key == "stats":
                for sk in single["stats"]:
                    assert np.array_equal(out["stats"][sk][i],
                                          single["stats"][sk]), sk
                continue
            assert np.array_equal(out[key][i], single[key]), (key, i)


def test_execute_batch_q5_native(join_env):
    cat, radius = join_env
    qv = np.asarray(cat.table("queries")["embedding"][:3])
    q = compile_query(Q5, cat, EngineOptions(engine="chase", probe=PROBE))
    assert q.batch_native
    # Q5 has no per-left loop: join_lowering must not degrade its batching
    assert compile_query(Q5, cat, EngineOptions(
        engine="chase", probe=PROBE, join_lowering="perleft")).batch_native
    out = jax.tree.map(np.asarray, q.execute_batch(qv=qv, r=radius))
    assert out["ids"].shape[0] == 3
    for i in range(3):
        single = jax.tree.map(np.asarray, q(qv=qv[i], r=radius))
        assert np.array_equal(out["ids"][i], single["ids"])
        assert np.array_equal(out["stats"]["probes"][i],
                              single["stats"]["probes"])


def test_execute_batch_perleft_falls_back_and_agrees(join_env):
    """The perleft baseline's execute_batch (vmap fallback) must agree with
    the native flattened lowering — same results, different operator shape."""
    cat, radius = join_env
    radii = np.asarray([radius, radius * 0.98], np.float32)
    outs = {}
    for low in ("batch", "perleft"):
        q = compile_query(Q3, cat, EngineOptions(engine="chase", probe=PROBE,
                                                 max_pairs=64,
                                                 join_lowering=low))
        assert q.batch_native == (low == "batch")
        outs[low] = jax.tree.map(np.asarray, q.execute_batch(r=radii))
    assert np.array_equal(outs["batch"]["tid"], outs["perleft"]["tid"])
    assert np.array_equal(outs["batch"]["valid"], outs["perleft"]["valid"])
    assert np.sum(outs["batch"]["stats"]["probes"]) \
        == np.sum(outs["perleft"]["stats"]["probes"])


# ---------------------------------------------------------------------------
# explain(): batched lowering is visible in the plan text
# ---------------------------------------------------------------------------

def test_explain_reports_batch_lowering(join_env):
    cat, _ = join_env
    native = compile_query(Q3, cat, EngineOptions(engine="chase"))
    assert "batch:  native" in native.explain()
    assert "left rows flattened" in native.explain()
    fallback = compile_query(Q3, cat, EngineOptions(
        engine="chase", join_lowering="perleft"))
    assert "vmap-of-scalar fallback" in fallback.explain()
    vknn = compile_query(
        "SELECT sample_id FROM products "
        "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 5",
        cat, EngineOptions(engine="chase"))
    assert "batch:  native" in vknn.explain()
    assert "query-tiled" in vknn.explain()


# ---------------------------------------------------------------------------
# batched Algorithm 2 (ivf_range_category_batch) probe-level parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cat_probe_env(join_env):
    cat, radius = join_env
    corpus = cat.table("laion")["vec"]
    idx = cat.index_for("laion", "vec")
    cats = cat.table("laion")["calorie_level"]
    qs = cat.table("queries")["embedding"]
    return idx, corpus, cats, qs, radius


@pytest.mark.parametrize("termination", ["counter", "bound"])
def test_ivf_range_category_batch_parity(cat_probe_env, termination):
    idx, corpus, cats, qs, radius = cat_probe_env
    cfg = ProbeConfig(max_probes=16, capacity=256, termination=termination,
                      num_categories=4, k_per_category=3)
    ids, sims, valid, count, stats = ivf_range_category_batch(
        idx, corpus, cats, qs, radius, None, cfg)
    for qi in range(qs.shape[0]):
        si, ss, sv, sc, sst = ivf_range_category(idx, corpus, cats, qs[qi],
                                                 radius, None, cfg)
        assert np.array_equal(np.asarray(ids[qi]), np.asarray(si))
        np.testing.assert_allclose(np.asarray(sims[qi]), np.asarray(ss),
                                   rtol=1e-5, atol=1e-5)
        assert int(count[qi]) == int(sc)
        assert int(stats["probes"][qi]) == int(sst["probes"])
        assert int(stats["distance_evals"][qi]) == int(sst["distance_evals"])
        assert int(stats["categories_seen"][qi]) \
            == int(sst["categories_seen"])


def test_ivf_range_category_batch_multi_cluster_superset(cat_probe_env):
    """probe_batch>1 probes a superset prefix: found ids only grow."""
    idx, corpus, cats, qs, radius = cat_probe_env
    mk = lambda B: ProbeConfig(max_probes=16, capacity=512, probe_batch=B,
                               num_categories=4, k_per_category=3)
    i1, _, v1, c1, s1 = ivf_range_category_batch(idx, corpus, cats, qs,
                                                 radius, None, mk(1))
    i4, _, v4, c4, s4 = ivf_range_category_batch(idx, corpus, cats, qs,
                                                 radius, None, mk(4))
    for qi in range(qs.shape[0]):
        got1 = set(np.asarray(i1[qi])[np.asarray(v1[qi])].tolist())
        got4 = set(np.asarray(i4[qi])[np.asarray(v4[qi])].tolist())
        assert got1 <= got4
        assert int(s4["probes"][qi]) >= int(s1["probes"][qi])


# ---------------------------------------------------------------------------
# lock-step stragglers: counters stay calibrated, budgets cap heavy rows
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def hetero_env(join_env):
    """Heterogeneous left rows: one dense mask (light query, terminates
    fast), the rest highly selective (stragglers probing many clusters)."""
    cat, _ = join_env
    corpus = cat.table("laion")["vec"]
    idx = cat.index_for("laion", "vec")
    qs = cat.table("queries")["embedding"]
    rng = np.random.default_rng(7)
    n = corpus.shape[0]
    mask = np.asarray(rng.random((qs.shape[0], n)) < 0.02)
    mask[0] = rng.random(n) < 0.9
    return idx, corpus, qs, jnp.asarray(mask)


@pytest.mark.parametrize("probe_batch", [1, 4])
def test_straggler_counters_stay_calibrated(hetero_env, probe_batch):
    """Each query's counters report its OWN termination point: lock-step
    rounds never inflate a light query's probes beyond one round's rounding
    of its sequential count (cluster-unit calibration, 'bound' exact)."""
    idx, corpus, qs, mask = hetero_env
    k = 5
    cfg = ProbeConfig(max_probes=16, termination="bound",
                      probe_batch=probe_batch)
    cfg1 = ProbeConfig(max_probes=16, termination="bound")
    ids, sims, valid, stats = ivf_topk_batch(idx, corpus, qs, k, mask, cfg)
    seq_probes = []
    for qi in range(qs.shape[0]):
        _, _, _, sst = ivf_topk(idx, corpus, qs[qi], k, mask[qi], cfg1)
        seq_probes.append(int(sst["probes"]))
    batch_probes = np.asarray(stats["probes"])
    B = probe_batch
    for qi, sp in enumerate(seq_probes):
        assert sp <= int(batch_probes[qi]) <= -(-sp // B) * B, qi
    # heterogeneity is real: the dense-mask query terminates well before the
    # selective stragglers, and its counters froze there
    assert seq_probes[0] < max(seq_probes[1:])
    assert int(batch_probes[0]) < int(batch_probes[1:].max())


def test_batch_composition_does_not_change_results(hetero_env):
    """Freezing means stragglers can't contaminate a finished query: each
    query alone == the same query inside the heterogeneous batch."""
    idx, corpus, qs, mask = hetero_env
    cfg = ProbeConfig(max_probes=16, termination="bound", probe_batch=4)
    ids, sims, valid, stats = ivf_topk_batch(idx, corpus, qs, 5, mask, cfg)
    for qi in range(qs.shape[0]):
        si, ss, sv, sst = ivf_topk_batch(idx, corpus, qs[qi:qi + 1], 5,
                                         mask[qi:qi + 1], cfg)
        assert np.array_equal(np.asarray(ids[qi]), np.asarray(si[0]))
        assert int(stats["probes"][qi]) == int(sst["probes"][0])


@pytest.mark.parametrize("fn", ["topk", "range", "category"])
def test_per_query_probe_budget(hetero_env, join_env, fn):
    """probe_budget individually caps heavy queries (round-granular: at most
    one round of overshoot) while unbudgeted queries are untouched."""
    idx, corpus, qs, mask = hetero_env
    cats = join_env[0].table("laion")["calorie_level"]
    B = 2
    budget = np.full(qs.shape[0], 16, np.int32)
    budget[1] = 3                                  # cap one straggler
    budget = jnp.asarray(budget)
    if fn == "topk":
        cfg = ProbeConfig(max_probes=16, probe_batch=B)
        run = lambda pb: ivf_topk_batch(idx, corpus, qs, 5, mask, cfg,
                                        probe_budget=pb)[3]
    elif fn == "range":
        cfg = ProbeConfig(max_probes=16, capacity=256, probe_batch=B)
        run = lambda pb: ivf_range_batch(idx, corpus, qs, 0.9, mask, cfg,
                                         probe_budget=pb)[4]
    else:
        cfg = ProbeConfig(max_probes=16, capacity=256, probe_batch=B,
                          num_categories=4, k_per_category=3)
        run = lambda pb: ivf_range_category_batch(
            idx, corpus, cats, qs, 0.9, mask, cfg, probe_budget=pb)[4]
    free = np.asarray(run(None)["probes"])
    capped = np.asarray(run(budget)["probes"])
    assert int(capped[1]) <= 3 + (B - 1)           # round-granular cap
    keep = np.arange(qs.shape[0]) != 1
    assert np.array_equal(capped[keep], free[keep])
