"""Persistent AOT plan cache (DESIGN.md §15): warm restarts, hardened.

The contracts under test:

* **cross-process warm restart** — subprocess A prepares Q1-Q6 under
  ``aot_cache_path`` and persists; a FRESH subprocess B prepares the same
  statements and executes with ZERO retraces (``trace_counts`` asserted),
  returning results bit-identical to an in-process cold compile with no
  cache attached;
* **eviction to disk** — an LRU-evicted plan re-prepared later restores
  its bucket executable from disk instead of re-tracing;
* **invalidation** — a table re-registration (catalog structural drift)
  invalidates the PERSISTED entry, not just the memory entry: the stale
  counter bumps, the entry recompiles, and results reflect the new data;
* **poisoning** — a truncated entry, garbage bytes, a flipped jax-version
  header, and a stale catalog token each degrade to a clean cold miss
  with a typed :class:`~repro.api.AOTCacheWarning` and the matching
  ``corrupt`` / ``stale`` counter bump in ``cache_info()``; no exception
  escapes prepare/execute and results stay bit-identical;
* **unserializable plans** — an export failure restores the trace-count
  snapshot, warns, bumps ``errors``, and falls back to the plain jit path.

This file doubles as the subprocess child script (``__main__`` guard at
the bottom): children rebuild the SAME deterministic env (seeded catalog +
seeded IVF build + seeded binds), so bitwise comparison across processes
is meaningful.
"""
import json
import os
import struct
import subprocess
import sys

import jax
import jax.tree_util as jtu
import numpy as np
import pytest

from repro.api import AOTCacheWarning, ExecutionHints, connect
from repro.core import EngineOptions, Metric
from repro.core.aot import MAGIC, AOTPlanCache
from repro.data import make_laion_catalog
from repro.index import build_ivf
from repro.index.ivf import ProbeConfig

PROBE = ProbeConfig(max_probes=8, capacity=64, termination="bound",
                    probe_batch=2)
DIM = 16
QN = 5                                       # bucketed: pads 5 -> 8

Q1 = ("SELECT sample_id FROM products WHERE price < ${p} "
      "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")
Q2 = ("SELECT sample_id FROM images "
      "WHERE DISTANCE(embedding, ${qv}) <= ${r} AND capture_date > ${d}")
Q3 = """
SELECT queries.id AS qid, images.sample_id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${r}
AND images.capture_date > queries.capture_date
"""
Q4 = """
SELECT qid, tid FROM (
 SELECT users.id AS qid, movies.sample_id AS tid,
 RANK() OVER (PARTITION BY users.id
   ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
 FROM users JOIN movies ON users.preferred_rating = movies.rating
 AND movies.release_year >= ${y}
) AS ranked WHERE ranked.rank <= 4
"""
Q5 = """
SELECT qid, category FROM (
 SELECT sample_id AS qid, calorie_level AS category,
 RANK() OVER (PARTITION BY calorie_level
   ORDER BY DISTANCE(embedding, ${qv})) AS rank
 FROM recipes WHERE DISTANCE(embedding, ${qv}) <= ${r}
) AS ranked WHERE ranked.rank <= 3
"""
Q6 = """
SELECT qid, category, tid FROM (
 SELECT queries.id AS qid, recipes.sample_id AS tid,
 recipes.calorie_level AS category,
 RANK() OVER (PARTITION BY queries.id, recipes.calorie_level
   ORDER BY DISTANCE(queries.embedding, recipes.embedding)) AS rank
 FROM queries JOIN recipes
 ON DISTANCE(queries.embedding, recipes.embedding) <= ${r}
 AND queries.cuisine <> recipes.cuisine
) AS ranked WHERE ranked.rank <= 3
"""
ALL_SQL = {"q1": Q1, "q2": Q2, "q3": Q3, "q4": Q4, "q5": Q5, "q6": Q6}


# ---------------------------------------------------------------------------
# deterministic env + binds (identical in every process)
# ---------------------------------------------------------------------------

def build_env():
    """The cross-process-deterministic test env: seeded catalog, seeded IVF
    build, and the radius children and parent agree on bit-for-bit."""
    cat = make_laion_catalog(n_rows=500, n_queries=4, dim=DIM, n_modes=8,
                             num_categories=4, seed=0)
    idx = build_ivf(jax.random.key(0), cat.table("laion")["vec"], nlist=8,
                    metric=Metric.INNER_PRODUCT, iters=3)
    for name in ("laion", "products", "images", "recipes", "movies"):
        cat.register_index(name, "vec", idx)
        cat.register_index(name, "embedding", idx)
    sims = (np.asarray(cat.table("queries")["embedding"])
            @ np.asarray(cat.table("laion")["vec"]).T)
    radius = float(np.median(np.partition(sims, -30, axis=1)[:, -30]))
    return cat, radius


def _qvecs(cat, qn):
    base = np.asarray(cat.table("queries")["embedding"])
    rng = np.random.default_rng(3)
    reps = -(-qn // base.shape[0])
    qs = np.tile(base, (reps, 1))[:qn]
    return (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)


def binds_for(case, cat, radius, qn=QN):
    """Deterministic per-case bind sets (same in every process)."""
    rng = np.random.default_rng(7)
    price = np.asarray(cat.table("laion")["price"])
    dates = np.asarray(cat.table("laion")["capture_date"])
    years = np.asarray(cat.table("movies")["release_year"])
    qs = _qvecs(cat, qn)
    out = []
    for i in range(qn):
        if case == "q1":
            out.append({"qv": qs[i], "p": np.float32(np.quantile(
                price, rng.uniform(0.3, 1.0)))})
        elif case == "q2":
            out.append({"qv": qs[i],
                        "r": np.float32(radius * rng.uniform(0.95, 1.0)),
                        "d": np.int32(np.quantile(
                            dates, rng.uniform(0.2, 0.8)))})
        elif case in ("q3", "q6"):
            out.append({"r": np.float32(radius * rng.uniform(0.95, 1.0))})
        elif case == "q4":
            out.append({"y": np.int32(np.quantile(
                years, rng.uniform(0.1, 0.6)))})
        elif case == "q5":
            out.append({"qv": qs[i],
                        "r": np.float32(radius * rng.uniform(0.95, 1.0))})
    return out


def _options():
    return EngineOptions(engine="chase", probe=PROBE)


def ser_tree(data) -> dict:
    """Bit-exact, JSON-safe serialization of an output tree (dtype + shape
    + raw bytes hex per leaf) — equality of these dicts IS bit-parity."""
    out = {}
    for path, leaf in jtu.tree_leaves_with_path(dict(data)):
        arr = np.asarray(leaf)
        out[jtu.keystr(path)] = {"dtype": str(arr.dtype),
                                 "shape": list(arr.shape),
                                 "hex": np.ascontiguousarray(arr)
                                 .tobytes().hex()}
    return out


def _run_all(db, cat, radius, cases=None) -> dict:
    out = {}
    for case in sorted(cases or ALL_SQL):
        st = db.prepare(ALL_SQL[case])
        res = st.execute(binds_for(case, cat, radius))
        out[case] = {"data": ser_tree(res.data),
                     "trace_counts": {str(k): v for k, v
                                      in st.executor.trace_counts.items()},
                     "aot_loaded": {str(k): v for k, v
                                    in st.executor.aot_loaded.items()}}
    return out


def child_main(aot_dir: str, out_path: str) -> None:
    """Subprocess entry: build the deterministic env, prepare + execute
    Q1-Q6 under ``aot_cache_path``, dump results + executor state."""
    cat, radius = build_env()
    db = connect(cat, _options(), aot_cache_path=aot_dir)
    results = _run_all(db, cat, radius)
    with open(out_path, "w") as f:
        json.dump({"results": results, "aot": db.cache_info().aot}, f)


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def env():
    return build_env()


@pytest.fixture()
def aot_dir(tmp_path):
    return str(tmp_path / "aotcache")


def _spawn_child(aot_dir: str, out_path: str) -> None:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    child_env = dict(os.environ)
    child_env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                               + child_env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child",
         aot_dir, out_path],
        env=child_env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"child failed:\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")


# ---------------------------------------------------------------------------
# cross-process warm restart (the tentpole's acceptance test)
# ---------------------------------------------------------------------------

def test_cross_process_warm_restart(env, aot_dir, tmp_path):
    """Process A persists Q1-Q6; fresh process B loads every bucket with
    ZERO retraces and bit-identical results; the in-process no-cache cold
    compile agrees bit-for-bit with both."""
    out_a = str(tmp_path / "a.json")
    out_b = str(tmp_path / "b.json")
    _spawn_child(aot_dir, out_a)
    _spawn_child(aot_dir, out_b)
    with open(out_a) as f:
        a = json.load(f)
    with open(out_b) as f:
        b = json.load(f)

    # A compiled cold (one trace per case) and persisted every bucket
    for case, rep in a["results"].items():
        assert sum(rep["trace_counts"].values()) == 1, (case, rep)
        assert rep["aot_loaded"] == {}, case
    assert a["aot"]["saves"] == len(ALL_SQL)
    assert a["aot"]["hits"] == 0

    # B restored every bucket from disk: zero traces anywhere
    for case, rep in b["results"].items():
        assert all(v == 0 for v in rep["trace_counts"].values()), (case, rep)
        assert sum(rep["aot_loaded"].values()) == 1, (case, rep)
    assert b["aot"]["hits"] == len(ALL_SQL)
    assert b["aot"]["corrupt"] == b["aot"]["stale"] == 0

    # bit-identical across the restart
    for case in ALL_SQL:
        assert a["results"][case]["data"] == b["results"][case]["data"], case

    # ... and bit-identical to an in-process cold compile with NO cache
    cat, radius = env
    ref = _run_all(connect(cat, _options()), cat, radius)
    for case in ALL_SQL:
        assert ref[case]["data"] == a["results"][case]["data"], case


def test_in_process_restart_zero_traces(env, aot_dir):
    """Two sessions over one catalog: the second loads from disk (zero
    traces, bit-parity) — the cheap single-process restart proxy."""
    cat, radius = env
    cases = ("q1", "q5")
    first = _run_all(connect(cat, _options(), aot_cache_path=aot_dir),
                     cat, radius, cases)
    db2 = connect(cat, _options(), aot_cache_path=aot_dir)
    second = _run_all(db2, cat, radius, cases)
    for case in cases:
        assert first[case]["data"] == second[case]["data"]
        assert all(v == 0 for v in second[case]["trace_counts"].values())
        assert sum(second[case]["aot_loaded"].values()) == 1
    assert db2.cache_info().aot["hits"] == len(cases)


def test_eviction_to_disk_round_trip(env, aot_dir):
    """An LRU-evicted plan re-prepared later restores its bucket executable
    from disk: eviction evicts to disk, not to nothing."""
    cat, radius = env
    db = connect(cat, _options(), max_cached_plans=1,
                 aot_cache_path=aot_dir)
    st1 = db.prepare(Q1)
    want = ser_tree(st1.execute(binds_for("q1", cat, radius)).data)
    db.prepare(Q5).execute(binds_for("q5", cat, radius))   # evicts Q1
    assert db.cache_info().evictions >= 1

    st1b = db.prepare(Q1)                                  # re-prepare
    got = st1b.execute(binds_for("q1", cat, radius))
    assert ser_tree(got.data) == want
    assert all(v == 0 for v in st1b.executor.trace_counts.values()), (
        st1b.executor.trace_counts)
    assert sum(st1b.executor.aot_loaded.values()) == 1


# ---------------------------------------------------------------------------
# invalidation: catalog structural drift kills the DISK entry
# ---------------------------------------------------------------------------

def test_catalog_bump_invalidates_persisted_entry(aot_dir):
    """Re-registering a table after persisting invalidates the disk entry
    (stale counter, typed warning), and the recompiled plan sees the NEW
    data — never the frozen closure a poisoned hit would resurface."""
    from repro.core.schema import Table
    cat, radius = build_env()
    db = connect(cat, _options(), aot_cache_path=aot_dir)
    db.prepare(Q1).execute(binds_for("q1", cat, radius))
    assert db.cache_info().aot["saves"] == 1

    # re-register the plan's scan table with a shifted price column:
    # structural drift the catalog clock tracks (predicate columns are
    # baked into the trace, so the persisted executable is now wrong)
    tab = cat.table("products")
    cols = {n: tab[n] for n in tab.schema.names()}
    cols["price"] = cols["price"] + np.float32(1000.0)
    cat.register("products", Table(tab.schema, cols))

    db2 = connect(cat, _options(), aot_cache_path=aot_dir)
    st = db2.prepare(Q1)
    with pytest.warns(AOTCacheWarning, match="stale"):
        res = st.execute(binds_for("q1", cat, radius))
    assert db2.cache_info().aot["stale"] == 1
    # every price now exceeds the bind threshold: no rows can match
    assert not np.asarray(res["valid"]).any()
    # the recompile re-persisted a fresh entry for the new catalog state
    assert db2.cache_info().aot["saves"] == 1
    db3 = connect(cat, _options(), aot_cache_path=aot_dir)
    st3 = db3.prepare(Q1)
    res3 = st3.execute(binds_for("q1", cat, radius))
    assert all(v == 0 for v in st3.executor.trace_counts.values())
    assert ser_tree(res3.data) == ser_tree(res.data)


# ---------------------------------------------------------------------------
# cache poisoning: every corruption degrades to a clean cold miss
# ---------------------------------------------------------------------------

def _entry_files(aot_dir):
    return sorted(os.path.join(aot_dir, f) for f in os.listdir(aot_dir)
                  if f.endswith(".aot"))


def _rewrite_header(path: str, **fields) -> None:
    """Rewrite header fields of an entry file, keeping the framing and the
    payload checksums valid — isolates the identity/token checks."""
    with open(path, "rb") as f:
        blob = f.read()
    off = len(MAGIC)
    (hlen,) = struct.unpack(">I", blob[off:off + 4])
    header = json.loads(blob[off + 4:off + 4 + hlen].decode())
    header.update(fields)
    hj = json.dumps(header, sort_keys=True).encode()
    with open(path, "wb") as f:
        f.write(MAGIC + struct.pack(">I", len(hj)) + hj
                + blob[off + 4 + hlen:])


POISONS = {
    "truncated": ("corrupt",
                  lambda p: open(p, "r+b").truncate(
                      os.path.getsize(p) // 2)),
    "garbage": ("corrupt",
                lambda p: open(p, "wb").write(b"\x00garbage" * 64)),
    "jax_version_skew": ("stale",
                         lambda p: _rewrite_header(p, jax_version="0.0.0")),
    "catalog_token": ("stale",
                      lambda p: _rewrite_header(
                          p, catalog_token="deadbeef" * 8)),
}


@pytest.mark.parametrize("poison", sorted(POISONS))
def test_poisoned_entry_is_clean_cold_miss(env, aot_dir, poison):
    cat, radius = env
    counter, mutate = POISONS[poison]
    want = ser_tree(connect(cat, _options(), aot_cache_path=aot_dir)
                    .prepare(Q1).execute(binds_for("q1", cat, radius)).data)
    (path,) = _entry_files(aot_dir)
    mutate(path)

    db = connect(cat, _options(), aot_cache_path=aot_dir)
    st = db.prepare(Q1)
    with pytest.warns(AOTCacheWarning, match=counter):
        res = st.execute(binds_for("q1", cat, radius))
    info = db.cache_info()
    assert info.aot[counter] == 1, (poison, info.aot)
    # degraded to a cold compile: traced once, results bit-identical
    assert sum(st.executor.trace_counts.values()) == 1
    assert ser_tree(res.data) == want
    # the bad file was removed and a fresh entry re-persisted
    assert info.aot["saves"] == 1
    assert len(_entry_files(aot_dir)) == 1


def test_unserializable_plan_falls_back(env, aot_dir, monkeypatch):
    """An export failure restores the trace-count snapshot, warns, bumps
    ``errors``, and the plain jit path still returns correct results."""
    import repro.core.aot as aot_mod
    cat, radius = env
    want = ser_tree(connect(cat, _options())
                    .prepare(Q1).execute(binds_for("q1", cat, radius)).data)

    def boom(flat_fn, leaves):
        raise TypeError("synthetic: plan not exportable")

    monkeypatch.setattr(aot_mod, "export_flat", boom)
    db = connect(cat, _options(), aot_cache_path=aot_dir)
    st = db.prepare(Q1)
    with pytest.warns(AOTCacheWarning, match="not serializable"):
        res = st.execute(binds_for("q1", cat, radius))
    assert db.cache_info().aot["errors"] == 1
    assert db.cache_info().aot["saves"] == 0
    assert sum(st.executor.trace_counts.values()) == 1   # snapshot honest
    assert ser_tree(res.data) == want
    assert _entry_files(aot_dir) == []


def test_explain_reports_aot_line(env, aot_dir):
    cat, radius = env
    db = connect(cat, _options(), aot_cache_path=aot_dir)
    st = db.prepare(Q1)
    res = st.execute(binds_for("q1", cat, radius))
    rep = res.explain()
    assert rep.aot is not None and rep.aot["saves"] == 1
    assert any(line.startswith("-- aot:") for line
               in rep.render().splitlines())
    # no cache attached -> no line
    res2 = connect(cat, _options()).prepare(Q1).execute(
        binds_for("q1", cat, radius))
    assert res2.explain().aot is None


def test_cache_dir_is_created_and_shared(tmp_path):
    nested = str(tmp_path / "deep" / "aot")
    cache = AOTPlanCache(nested)
    assert os.path.isdir(nested)
    assert cache.stats() == {"hits": 0, "misses": 0, "corrupt": 0,
                             "stale": 0, "errors": 0, "saves": 0}


if __name__ == "__main__":
    if len(sys.argv) == 4 and sys.argv[1] == "--child":
        child_main(sys.argv[2], sys.argv[3])
    else:
        raise SystemExit("usage: test_aot_cache.py --child AOT_DIR OUT_JSON")
