"""Multi-device sharded batched scan (DESIGN.md §10).

Contracts under test:

* **shards=1 bit-parity**: an ``EngineOptions.dist`` plan on a one-device
  mesh is bit-identical to the single-device bucketed fused flat path for
  EVERY query class (Q1-Q6) — the hierarchical merge at one shard is an
  identity re-selection, so the shard × tile composition adds nothing.
* **pad-query inertness per shard**: the size-bucket ``qvalid`` lane
  threads through the shard_map — pad queries emit no candidates and zero
  counters (observable via ``BucketedExecutor.run_padded``).
* **range capacity truncation**: per-shard buffers concatenate and
  re-truncate best-first to ONE shard-count-independent ``capacity``-wide
  result; ``count`` stays exact past truncation.
* **mesh fingerprinting**: ``DistSpec`` folds into the plan-cache key — a
  same-mesh re-prepare compiles ZERO executables (trace_counts), a mesh
  change misses the cache and compiles fresh.
* **option validation**: dist composes only with engine chase/brute and
  join_lowering='batch'; malformed DistSpecs and missing devices fail loud.

Multi-shard exactness (shards ∈ {2, 4}, with a divisibility-padded corpus)
runs in subprocesses with fake CPU devices — marked ``slow`` like
tests/test_distributed.py; benchmarks/q10_sharded_qps.py asserts the same
invariants on every run.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import EngineOptions, Metric, compile_query
from repro.dist import DistSpec
from repro.dist.sharding import resolve_mesh
from repro.index.ivf import ProbeConfig

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SPEC1 = DistSpec(mesh_shape=(1,), axes=("data",))

Q1 = ("SELECT sample_id FROM products WHERE price < ${p} "
      "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")
Q2 = ("SELECT sample_id FROM images "
      "WHERE DISTANCE(embedding, ${qv}) <= ${r} AND capture_date > ${d}")
Q3 = """
SELECT queries.id AS qid, images.sample_id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${r}
AND images.capture_date > queries.capture_date
"""
Q4 = """
SELECT qid, tid FROM (
 SELECT users.id AS qid, movies.sample_id AS tid,
 RANK() OVER (PARTITION BY users.id
   ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
 FROM users JOIN movies ON users.preferred_rating = movies.rating
 AND movies.release_year >= ${y}
) AS ranked WHERE ranked.rank <= 4
"""
Q5 = """
SELECT qid, category FROM (
 SELECT sample_id AS qid, calorie_level AS category,
 RANK() OVER (PARTITION BY calorie_level
   ORDER BY DISTANCE(embedding, ${qv})) AS rank
 FROM recipes WHERE DISTANCE(embedding, ${qv}) <= ${r}
) AS ranked WHERE ranked.rank <= 3
"""
Q6 = """
SELECT qid, category, tid FROM (
 SELECT queries.id AS qid, recipes.sample_id AS tid,
 recipes.calorie_level AS category,
 RANK() OVER (PARTITION BY queries.id, recipes.calorie_level
   ORDER BY DISTANCE(queries.embedding, recipes.embedding)) AS rank
 FROM queries JOIN recipes
 ON DISTANCE(queries.embedding, recipes.embedding) <= ${r}
 AND queries.cuisine <> recipes.cuisine
) AS ranked WHERE ranked.rank <= 3
"""

# the single-device reference the sharded lowering is bit-identical to:
# the fused flat path (dist bypasses index probes — DESIGN.md §10)
FLAT = dict(engine="brute", use_pallas=True, max_pairs=64)

# predicate-free variants ride the SHARED (Npad,) mask path (no (Q, N)
# mask is materialized — collectives per_query_mask=False)
Q1_NOFILTER = ("SELECT sample_id FROM products "
               "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")
Q2_NOFILTER = ("SELECT sample_id FROM images "
               "WHERE DISTANCE(embedding, ${qv}) <= ${r}")

CASES = {"q1": Q1, "q2": Q2, "q3": Q3, "q4": Q4, "q5": Q5, "q6": Q6,
         "q1_nofilter": Q1_NOFILTER, "q2_nofilter": Q2_NOFILTER}


@pytest.fixture(scope="module")
def env():
    from repro.data import make_laion_catalog

    cat = make_laion_catalog(n_rows=1200, n_queries=4, dim=16, n_modes=8,
                             num_categories=4, seed=0)
    sims = (np.asarray(cat.table("queries")["embedding"])
            @ np.asarray(cat.table("laion")["vec"]).T)
    radius = float(np.median(np.partition(sims, -30, axis=1)[:, -30]))
    return cat, radius


def _qvecs(cat, qn: int) -> np.ndarray:
    base = np.asarray(cat.table("queries")["embedding"])
    rng = np.random.default_rng(3)
    reps = -(-qn // base.shape[0])
    qs = np.tile(base, (reps, 1))[:qn]
    return (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)


def _binds_for(case: str, cat, radius: float, qn: int) -> dict:
    rng = np.random.default_rng(7)
    price = np.asarray(cat.table("laion")["price"])
    dates = np.asarray(cat.table("laion")["capture_date"])
    if case == "q1_nofilter":
        return {"qv": _qvecs(cat, qn)}
    if case == "q2_nofilter":
        return {"qv": _qvecs(cat, qn),
                "r": (radius * rng.uniform(0.95, 1.0, qn)).astype(np.float32)}
    if case == "q1":
        return {"qv": _qvecs(cat, qn),
                "p": np.quantile(price, rng.uniform(0.3, 1.0, qn)).astype(
                    np.float32)}
    if case == "q2":
        return {"qv": _qvecs(cat, qn),
                "r": (radius * rng.uniform(0.95, 1.0, qn)).astype(np.float32),
                "d": np.quantile(dates, rng.uniform(0.2, 0.8, qn)).astype(
                    np.int32)}
    if case in ("q3", "q6"):
        return {"r": (radius * rng.uniform(0.95, 1.0, qn)).astype(np.float32)}
    if case == "q4":
        years = np.asarray(cat.table("movies")["release_year"])
        return {"y": np.quantile(years, rng.uniform(0.1, 0.6, qn)).astype(
            np.int32)}
    if case == "q5":
        return {"qv": _qvecs(cat, qn),
                "r": (radius * rng.uniform(0.95, 1.0, qn)).astype(np.float32)}
    raise ValueError(case)


def _assert_tree_equal(a, b, ctx=""):
    assert set(a) == set(b)
    for key in a:
        if key == "stats":
            for sk in a["stats"]:
                assert np.array_equal(np.asarray(a["stats"][sk]),
                                      np.asarray(b["stats"][sk])), \
                    f"{ctx}:stats.{sk}"
        else:
            assert np.array_equal(np.asarray(a[key]),
                                  np.asarray(b[key])), f"{ctx}:{key}"


# ---------------------------------------------------------------------------
# shards=1 bit-parity vs the single-device bucketed path, Q1-Q6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(CASES))
def test_shards1_bitparity_vs_bucketed(env, case):
    cat, radius = env
    ref = compile_query(CASES[case], cat, EngineOptions(**FLAT))
    dist = compile_query(CASES[case], cat,
                         EngineOptions(**FLAT, dist=SPEC1))
    binds = _binds_for(case, cat, radius, 3)
    _assert_tree_equal(ref.execute_bucketed(**binds),
                       dist.execute_bucketed(**binds), ctx=case)


def test_shards1_single_query_path_matches(env):
    cat, radius = env
    ref = compile_query(Q1, cat, EngineOptions(**FLAT))
    dist = compile_query(Q1, cat, EngineOptions(**FLAT, dist=SPEC1))
    binds = _binds_for("q1", cat, radius, 1)
    r = ref(qv=binds["qv"][0], p=float(binds["p"][0]))
    d = dist(qv=binds["qv"][0], p=float(binds["p"][0]))
    for key in ("ids", "sim", "valid"):
        assert np.array_equal(np.asarray(r[key]), np.asarray(d[key])), key


# ---------------------------------------------------------------------------
# pad queries are inert on the sharded path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(CASES))
def test_pad_queries_inert_on_sharded_path(env, case):
    cat, radius = env
    q = compile_query(CASES[case], cat, EngineOptions(**FLAT, dist=SPEC1))
    qn = 3
    binds = q._stack_binds(
        None, {k: jnp.asarray(v)
               for k, v in _binds_for(case, cat, radius, qn).items()})
    out, bucket, valid = q.executor.run_padded(binds, qn)
    assert bucket == 4 and not bool(np.asarray(valid)[qn:].any())
    for sk, v in out["stats"].items():
        assert (np.asarray(v)[qn:] == 0).all(), f"pad counters: {sk}"
    assert not np.asarray(out["valid"])[qn:].any()
    if "count" in out:
        assert (np.asarray(out["count"])[qn:] == 0).all()


# ---------------------------------------------------------------------------
# range capacity truncation across the (concatenated) per-shard buffers
# ---------------------------------------------------------------------------

def test_range_capacity_truncation_exact_counts(env):
    cat, radius = env
    cap = 16
    opts = dict(engine="brute", use_pallas=True,
                probe=ProbeConfig(capacity=cap))
    ref = compile_query(Q2, cat, EngineOptions(**opts))
    dist = compile_query(Q2, cat, EngineOptions(**opts, dist=SPEC1))
    qn = 3
    binds = _binds_for("q2", cat, radius, qn)
    # a wide-open radius (IP similarity: low threshold admits everything)
    # so every query overflows the capacity buffer
    binds["r"] = np.full((qn,), -1e6, np.float32)
    binds["d"] = np.full((qn,), int(np.min(np.asarray(
        cat.table("laion")["capture_date"]))) - 1, np.int32)
    r, d = ref.execute_bucketed(**binds), dist.execute_bucketed(**binds)
    _assert_tree_equal(r, d, ctx="q2-truncated")
    counts = np.asarray(d["count"])
    assert (counts > cap).all()                  # truncation actually bites
    assert np.asarray(d["ids"]).shape[1] == cap  # buffer is capacity-wide
    assert np.asarray(d["valid"]).sum(axis=1).tolist() == [cap] * qn


# ---------------------------------------------------------------------------
# mesh fingerprint: plan-cache behaviour (DESIGN.md §9 x §10)
# ---------------------------------------------------------------------------

def test_mesh_fingerprint_keys_plan_cache(env):
    from repro.api import connect

    cat, radius = env
    db = connect(cat, EngineOptions(**FLAT, dist=SPEC1))
    binds = _binds_for("q1", cat, radius, 3)

    s1 = db.prepare(Q1)
    s1.execute([{k: v[i] for k, v in binds.items()} for i in range(3)])
    assert s1.executor.trace_counts == {4: 1}

    # same-mesh re-prepare: cache hit, zero new executables
    s2 = db.prepare(Q1)
    assert s2.cache_hit and s2.executor is s1.executor
    s2.execute([{k: v[i] for k, v in binds.items()} for i in range(3)])
    assert s1.executor.trace_counts == {4: 1}
    assert db.cache_info().hits == 1

    # mesh change (different axis name -> different fingerprint): miss,
    # fresh compile in a fresh executor
    other = DistSpec(mesh_shape=(1,), axes=("shard",))
    s3 = db.prepare(Q1, options=EngineOptions(**FLAT, dist=other))
    assert not s3.cache_hit and s3.executor is not s1.executor
    assert s3.executor.trace_counts == {}
    res = s3.execute([{k: v[i] for k, v in binds.items()} for i in range(3)])
    assert s3.executor.trace_counts == {4: 1}
    assert s1.executor.trace_counts == {4: 1}    # untouched

    rep = res.explain()
    assert rep.shards == 1 and rep.merge_depth == 1
    assert "shards=1" in rep.render()


def test_sharded_corpus_registered_and_reused(env):
    cat, radius = env
    compile_query(Q1, cat, EngineOptions(**FLAT, dist=SPEC1))
    handle = cat.sharded_for("products", "embedding", SPEC1)
    assert handle is not None and handle.matches(SPEC1)
    assert handle.spec == SPEC1
    assert handle.num_rows == 1200
    q2 = compile_query(Q1, cat, EngineOptions(**FLAT, dist=SPEC1))
    assert q2._arrays["dcorpus"] is handle.corpus    # one device placement
    # the registry is keyed per mesh spec: a second mesh gets its OWN
    # cached handle and the first registration survives
    other = DistSpec(mesh_shape=(1,), axes=("shard",))
    compile_query(Q1, cat, EngineOptions(**FLAT, dist=other))
    assert cat.sharded_for("products", "embedding", SPEC1) is handle
    h2 = cat.sharded_for("products", "embedding", other)
    assert h2 is not None and h2 is not handle and h2.spec == other


# ---------------------------------------------------------------------------
# option / spec validation
# ---------------------------------------------------------------------------

def test_dist_option_validation(env):
    cat, _ = env
    with pytest.raises(ValueError, match="chase.*brute|brute.*chase"):
        compile_query(Q1, cat, EngineOptions(engine="pase", dist=SPEC1))
    with pytest.raises(ValueError, match="join_lowering='batch'"):
        compile_query(Q3, cat, EngineOptions(
            engine="brute", join_lowering="perleft", dist=SPEC1))


def test_dist_spec_validation():
    with pytest.raises(ValueError, match="same length"):
        DistSpec(mesh_shape=(2, 2), axes=("data",))
    with pytest.raises(ValueError, match="duplicate"):
        DistSpec(mesh_shape=(2, 2), axes=("data", "data"))
    with pytest.raises(ValueError, match=">= 1"):
        DistSpec(mesh_shape=(0,), axes=("data",))
    # normalized to tuples so the repr (the fingerprint) is stable
    assert repr(DistSpec(mesh_shape=[2], axes=["data"])) == \
        repr(DistSpec(mesh_shape=(2,), axes=("data",)))


def test_resolve_mesh_insufficient_devices(env):
    cat, _ = env
    need = len(jax.devices()) + 7
    with pytest.raises(RuntimeError,
                       match="xla_force_host_platform_device_count"):
        compile_query(Q1, cat, EngineOptions(
            **FLAT, dist=DistSpec(mesh_shape=(need,), axes=("data",))))


# ---------------------------------------------------------------------------
# multi-shard exactness (subprocess with fake CPU devices) — slow
# ---------------------------------------------------------------------------

def _run(code: str, devices: int = 4, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_multi_shard_topk_and_range_exact():
    # 1001 rows: NOT divisible by 2 or 4, so the divisibility padding and
    # its mask exclusion are exercised on every shard count
    out = _run("""
        import numpy as np
        from repro.core import EngineOptions, compile_query
        from repro.data import make_laion_catalog
        from repro.dist import DistSpec

        cat = make_laion_catalog(n_rows=1001, n_queries=4, dim=16,
                                 n_modes=8, num_categories=4, seed=0)
        sims = (np.asarray(cat.table("queries")["embedding"])
                @ np.asarray(cat.table("laion")["vec"]).T)
        radius = float(np.median(np.partition(sims, -30, axis=1)[:, -30]))
        FLAT = dict(engine="brute", use_pallas=True)
        Q1 = ("SELECT sample_id FROM products WHERE price < ${p} "
              "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")
        Q2 = ("SELECT sample_id FROM images "
              "WHERE DISTANCE(embedding, ${qv}) <= ${r}")
        qv = np.asarray(cat.table("queries")["embedding"])[:3]
        price = np.asarray(cat.table("laion")["price"])
        b1 = {"qv": qv.astype(np.float32),
              "p": np.quantile(price, [0.6, 0.8, 1.0]).astype(np.float32)}
        b2 = {"qv": qv.astype(np.float32),
              "r": np.full((3,), radius, np.float32)}
        ref1 = compile_query(Q1, cat, EngineOptions(**FLAT))
        ref2 = compile_query(Q2, cat, EngineOptions(**FLAT))
        r1 = ref1.execute_bucketed(**b1)
        r2 = ref2.execute_bucketed(**b2)
        for shards in (2, 4):
            opts = EngineOptions(**FLAT,
                                 dist=DistSpec(mesh_shape=(shards,)))
            d1 = compile_query(Q1, cat, opts).execute_bucketed(**b1)
            # exact top-k: same id set per query, same sims up to tie order
            for q in range(3):
                assert (set(np.asarray(d1["ids"])[q].tolist())
                        == set(np.asarray(r1["ids"])[q].tolist())), shards
            np.testing.assert_array_equal(
                np.sort(np.asarray(d1["sim"]), axis=1),
                np.sort(np.asarray(r1["sim"]), axis=1))
            # per-query counters exact at every shard count
            np.testing.assert_array_equal(
                np.asarray(d1["stats"]["distance_evals"]),
                np.asarray(r1["stats"]["distance_evals"]))
            d2 = compile_query(Q2, cat, opts).execute_bucketed(**b2)
            np.testing.assert_array_equal(np.asarray(d2["count"]),
                                          np.asarray(r2["count"]))
            for q in range(3):
                assert (set(np.asarray(d2["ids"])[q].tolist())
                        == set(np.asarray(r2["ids"])[q].tolist())), shards
        print("MULTI_SHARD_OK")
    """)
    assert "MULTI_SHARD_OK" in out


@pytest.mark.slow
def test_multi_shard_pad_queries_inert():
    out = _run("""
        import numpy as np, jax.numpy as jnp
        from repro.core import EngineOptions, compile_query
        from repro.data import make_laion_catalog
        from repro.dist import DistSpec

        cat = make_laion_catalog(n_rows=1000, n_queries=4, dim=16,
                                 n_modes=8, num_categories=4, seed=0)
        Q1 = ("SELECT sample_id FROM products "
              "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")
        q = compile_query(Q1, cat, EngineOptions(
            engine="brute", use_pallas=True,
            dist=DistSpec(mesh_shape=(4,))))
        qv = np.asarray(cat.table("queries")["embedding"])[:3]
        binds = q._stack_binds(None, {"qv": jnp.asarray(qv)})
        out, bucket, valid = q.executor.run_padded(binds, 3)
        assert bucket == 4 and not bool(np.asarray(valid)[3:].any())
        assert not np.asarray(out["valid"])[3:].any()
        for sk, v in out["stats"].items():
            assert (np.asarray(v)[3:] == 0).all(), sk
        print("PAD_INERT_OK")
    """)
    assert "PAD_INERT_OK" in out
