"""The adaptive optimizer's contracts (DESIGN.md §14).

Four promises, each asserted here:

* **Stats survive and invalidate.**  StatsStore round-trips through JSON
  byte-identically, and every aggregate is stamped with the catalog version
  token — a catalog bump makes lookups miss (stale stats never advise).
* **Decisions are deterministic.**  Two advisors fed the same executed
  sequence under a fixed seed emit identical decision streams.
* **Decisions never change results.**  Adaptive executions are bit-identical
  to the plain bucketed path across Q1–Q6, and ``ExecutionHints`` always
  win over the advisor.
* **Zero new retraces on the hot path.**  Once the (lock-step + budgeted)
  bucket variants are traced, adaptive executions with *changing* predicted
  budgets add no trace counts — budgets ride the runtime lane.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.api import ExecutionHints, connect
from repro.core import Metric
from repro.index import build_ivf
from repro.index.ivf import ProbeConfig
from repro.opt import CostModel, LoweringAdvisor, StatsStore, bucket_of
from repro.opt.stats import N_BUCKETS

PROBE = ProbeConfig(max_probes=16, capacity=128, termination="bound",
                    probe_batch=2)

Q1 = ("SELECT sample_id FROM products WHERE price < ${p} "
      "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")
Q2 = ("SELECT sample_id FROM images "
      "WHERE DISTANCE(embedding, ${qv}) <= ${r} AND capture_date > ${d}")
Q3 = """
SELECT queries.id AS qid, images.sample_id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${r}
AND images.capture_date > queries.capture_date
"""
Q4 = """
SELECT qid, tid FROM (
 SELECT users.id AS qid, movies.sample_id AS tid,
 RANK() OVER (PARTITION BY users.id
   ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
 FROM users JOIN movies ON users.preferred_rating = movies.rating
 AND movies.release_year >= ${y}
) AS ranked WHERE ranked.rank <= 4
"""
Q5 = """
SELECT qid, category FROM (
 SELECT sample_id AS qid, calorie_level AS category,
 RANK() OVER (PARTITION BY calorie_level
   ORDER BY DISTANCE(embedding, ${qv})) AS rank
 FROM recipes WHERE DISTANCE(embedding, ${qv}) <= ${r}
) AS ranked WHERE ranked.rank <= 3
"""
Q6 = """
SELECT qid, category, tid FROM (
 SELECT queries.id AS qid, recipes.sample_id AS tid,
 recipes.calorie_level AS category,
 RANK() OVER (PARTITION BY queries.id, recipes.calorie_level
   ORDER BY DISTANCE(queries.embedding, recipes.embedding)) AS rank
 FROM queries JOIN recipes
 ON DISTANCE(queries.embedding, recipes.embedding) <= ${r}
 AND queries.cuisine <> recipes.cuisine
) AS ranked WHERE ranked.rank <= 3
"""

CASES = {"q1": Q1, "q2": Q2, "q3": Q3, "q4": Q4, "q5": Q5, "q6": Q6}


@pytest.fixture(scope="module")
def env():
    from repro.data import make_laion_catalog

    cat = make_laion_catalog(n_rows=1200, n_queries=4, dim=16, n_modes=8,
                             num_categories=4, seed=0)
    idx = build_ivf(jax.random.key(0), cat.table("laion")["vec"], nlist=16,
                    metric=Metric.INNER_PRODUCT, iters=3)
    for name in ("laion", "products", "images", "recipes", "movies"):
        cat.register_index(name, "vec", idx)
        cat.register_index(name, "embedding", idx)
    sims = (np.asarray(cat.table("queries")["embedding"])
            @ np.asarray(cat.table("laion")["vec"]).T)
    radius = float(np.median(np.partition(sims, -30, axis=1)[:, -30]))
    return cat, radius


def _qvecs(cat, qn: int) -> np.ndarray:
    base = np.asarray(cat.table("queries")["embedding"])
    rng = np.random.default_rng(3)
    reps = -(-qn // base.shape[0])
    qs = np.tile(base, (reps, 1))[:qn]
    return (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)


def _binds_for(case: str, cat, radius: float, qn: int, seed: int = 7) -> dict:
    rng = np.random.default_rng(seed)
    price = np.asarray(cat.table("laion")["price"])
    dates = np.asarray(cat.table("laion")["capture_date"])
    if case == "q1":
        return {"qv": _qvecs(cat, qn),
                "p": np.quantile(price, rng.uniform(0.3, 1.0, qn)).astype(
                    np.float32)}
    if case == "q2":
        return {"qv": _qvecs(cat, qn),
                "r": (radius * rng.uniform(0.95, 1.0, qn)).astype(
                    np.float32),
                "d": np.quantile(dates, rng.uniform(0.2, 0.8, qn)).astype(
                    np.int32)}
    if case in ("q3", "q6"):
        return {"r": (radius * rng.uniform(0.95, 1.0, qn)).astype(
            np.float32)}
    if case == "q4":
        years = np.asarray(cat.table("movies")["release_year"])
        return {"y": np.quantile(years, rng.uniform(0.1, 0.6, qn)).astype(
            np.int32)}
    if case == "q5":
        return {"qv": _qvecs(cat, qn),
                "r": (radius * rng.uniform(0.95, 1.0, qn)).astype(
                    np.float32)}
    raise ValueError(case)


def _assert_tree_equal(a: dict, b: dict, ctx: str = ""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), ctx
    for i, (x, y) in enumerate(zip(la, lb)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), f"{ctx}[{i}]"


# ---------------------------------------------------------------------------
# StatsStore
# ---------------------------------------------------------------------------

def test_bucket_policy_edges():
    assert bucket_of(1.0) == 0
    assert bucket_of(0.6) == 0
    assert bucket_of(0.5) == 1          # (0.25, 0.5]
    assert bucket_of(0.25) == 2
    assert bucket_of(1e-6) == N_BUCKETS - 1
    assert bucket_of(0.0) == N_BUCKETS - 1
    # monotone: tighter never lands in a looser bucket
    sels = np.linspace(1e-6, 1.0, 200)
    buckets = [bucket_of(s) for s in sels]
    assert all(b1 >= b2 for b1, b2 in zip(buckets, buckets[1:]))


def test_stats_persistence_roundtrip(tmp_path):
    store = StatsStore()
    v = ((("table", "laion"), 3),)
    store.observe("plan-a", 2, v, selectivity=0.1,
                  probes=np.array([3, 5, 9]), rows=120.0, latency_ms=1.5)
    store.observe("plan-a", 2, v, selectivity=0.12,
                  probes=np.array([4, 4, 4]), rows=100.0, latency_ms=1.1)
    store.observe_left("plan-b", v, np.array([[2, 8], [3, 5]]))
    path = tmp_path / "stats.json"
    store.save(str(path))
    back = StatsStore.load(str(path))
    assert back.to_json() == store.to_json()        # byte-identical
    assert back.lookup("plan-a", 2, v) == store.lookup("plan-a", 2, v)
    np.testing.assert_array_equal(back.left_profile("plan-b", v),
                                  store.left_profile("plan-b", v))


def test_stats_version_invalidation():
    store = StatsStore()
    v1, v2 = (1,), (2,)
    store.observe("p", 0, v1, selectivity=1.0, probes=np.array([5]))
    assert store.lookup("p", 0, v1) is not None
    # a different catalog version token misses AND drops the stale entry
    assert store.lookup("p", 0, v2) is None
    assert store.lookup("p", 0, v1) is None
    store.observe_left("p", v1, np.array([[4, 6]]))
    assert store.left_profile("p", v1) is not None
    assert store.left_profile("p", v2) is None


def test_advisor_invalidates_on_catalog_bump(env):
    cat, radius = env
    db = connect(cat, adaptive=True, engine="chase", probe=PROBE)
    st = db.prepare(Q1)
    binds = _binds_for("q1", cat, radius, 4)
    st.execute(binds)                                   # cold: observes
    rep = st.execute(binds).explain()
    assert rep.opt["source"] in ("stats", "profile")    # warmed
    # re-register the index: the version token moves, stats must not advise
    idx = build_ivf(jax.random.key(1), cat.table("laion")["vec"], nlist=16,
                    metric=Metric.INNER_PRODUCT, iters=2)
    cat.register_index("products", "embedding", idx)
    rep = st.execute(binds).explain()
    assert rep.opt["source"] == "cold"


# ---------------------------------------------------------------------------
# CostModel
# ---------------------------------------------------------------------------

def test_cost_model_calibration_and_prediction():
    m1, m2 = CostModel.from_bench(), CostModel.from_bench()
    assert m1.describe() == m2.describe()               # deterministic
    scores = m1.score(n_rows=10_000, k=10, selectivity=0.05,
                      cluster_rows=100.0, quant_modes=("int8", "bf16"))
    assert set(scores) == {"flat", "ivf", "quant:int8", "quant:bf16"}
    assert scores["quant:int8"] < scores["flat"]
    assert m1.choose(scores) == min(scores, key=scores.get)
    # budget prediction: headroom above the EMA, clipped to the ceiling
    assert m1.probe_budget(8.0, floor=3, ceiling=16) == 11
    assert m1.probe_budget(100.0, floor=3, ceiling=16) == 16
    assert m1.probe_budget(0.5, floor=3, ceiling=16) == 3
    # tighter selectivity never predicts fewer cold-start probes
    e = [m1.expected_probes(s, min_probes=4, max_probes=64)
         for s in (1.0, 0.5, 0.1, 0.01)]
    assert e == sorted(e)


# ---------------------------------------------------------------------------
# Advisor decisions
# ---------------------------------------------------------------------------

def test_advisor_decisions_deterministic(env):
    cat, radius = env

    def run():
        db = connect(cat, adaptive=True, engine="chase", probe=PROBE)
        st = db.prepare(Q1)
        decisions = []
        for i in range(4):
            rep = st.execute(_binds_for("q1", cat, radius, 4,
                                        seed=i)).explain()
            decisions.append(rep.opt)
        return decisions

    assert run() == run()


@pytest.mark.parametrize("case", sorted(CASES))
def test_adaptive_bit_parity(env, case):
    """Advisor-chosen executions (cold AND warmed) must be bit-identical to
    the plain bucketed path and the hint-forced exact-shape path."""
    cat, radius = env
    opts = dict(engine="chase", probe=PROBE)
    if case in ("q3", "q6"):
        opts["max_pairs"] = 64
    adb = connect(cat, adaptive=True, **opts)
    pdb = connect(cat, **opts)
    ast, pst = adb.prepare(CASES[case]), pdb.prepare(CASES[case])
    binds = _binds_for(case, cat, radius, 4)
    for i in range(3):                  # cold -> stats/profile-warmed
        got = ast.execute(binds)
        want = pst.execute(binds)
        _assert_tree_equal(got.data, want.data, ctx=f"{case}/iter{i}")
    exact = pst.execute(binds, hints=ExecutionHints(exact_shape=True))
    _assert_tree_equal(got.data, exact.data, ctx=f"{case}/exact")


def test_hints_always_beat_advisor(env):
    cat, radius = env
    db = connect(cat, adaptive=True, engine="chase", probe=PROBE)
    st = db.prepare(Q1)
    binds = _binds_for("q1", cat, radius, 4)
    st.execute(binds)                                   # warm the stats
    for hints in (ExecutionHints(exact_shape=True),
                  ExecutionHints(pilot_budget=5),
                  ExecutionHints(probe_budget=6),
                  ExecutionHints(no_opt=True)):
        rep = st.execute(binds, hints=hints).explain()
        assert rep.path != "opt", hints
        assert rep.opt is None, hints


def test_zero_retraces_on_hot_path(env):
    """Changing predicted budgets ride the runtime probe_budget lane: after
    the first adaptive round has traced the (lock-step, budgeted) bucket
    variants, further adaptive executions add NO trace counts."""
    cat, radius = env
    db = connect(cat, adaptive=True, engine="chase", probe=PROBE)
    st = db.prepare(Q1)
    binds = _binds_for("q1", cat, radius, 4, seed=0)
    for _ in range(2):                  # cold lock-step + first budgeted run
        st.execute(binds)               # (same binds => same bucket warms)
    warm = dict(st.explain().trace_counts)
    for i in range(1, 5):               # new bind values => new predictions
        rep = st.execute(_binds_for("q1", cat, radius, 4, seed=i)).explain()
        assert rep.path == "opt"
    assert dict(st.explain().trace_counts) == warm


def test_effort_array_pilot_bit_parity(env):
    """run_effort_bucketed with per-query and per-left ARRAY pilots stays
    bit-identical to lock-step (the phase-2 safety net is unconditional)."""
    from repro.core import EngineOptions, compile_query
    from repro.serving.scheduler import run_effort_bucketed

    cat, radius = env

    def _sets(case, qn):
        batch = _binds_for(case, cat, radius, qn)
        return [{k: v[i] for k, v in batch.items()} for i in range(qn)]

    q = compile_query(Q2, cat, EngineOptions(engine="chase", probe=PROBE))
    binds = q._stack_binds(_sets("q2", 4), {})
    ref = q.executor(binds)
    for pilot in (np.array([2, 9, 3, 16], np.int32),
                  np.array([1, 1, 1, 1], np.int32)):
        out, info = run_effort_bucketed(q, binds, pilot)
        _assert_tree_equal(jax.tree.map(np.asarray, ref), out,
                           ctx=f"pilot={pilot}")
        assert info["n_light"] + info["n_heavy"] == 4
    j = compile_query(Q3, cat, EngineOptions(engine="chase", probe=PROBE,
                                             max_pairs=64))
    jbinds = j._stack_binds(_sets("q3", 2), {})
    jref = jax.tree.map(np.asarray, j.executor(jbinds))
    nleft = np.asarray(jref["stats"]["probes"]).shape[1]
    per_left = np.tile(np.arange(1, nleft + 1, dtype=np.int32) % 7 + 1,
                       (2, 1))
    out, info = run_effort_bucketed(j, jbinds, per_left)
    _assert_tree_equal(jref, out, ctx="per-left")


def test_advisor_stats_path_persists_through_db(env, tmp_path):
    cat, radius = env
    path = str(tmp_path / "opt_stats.json")
    db = connect(cat, adaptive=True, stats_path=path, engine="chase",
                 probe=PROBE)
    st = db.prepare(Q1)
    binds = _binds_for("q1", cat, radius, 4)
    st.execute(binds)
    db.advisor.save()
    db2 = connect(cat, adaptive=True, stats_path=path, engine="chase",
                  probe=PROBE)
    # restart skips the cold phase: first execution already advises effort
    rep = db2.prepare(Q1).execute(binds).explain()
    assert rep.opt["source"] in ("stats", "profile")


def test_advise_surface(env):
    cat, _radius = env
    db = connect(cat, engine="chase", probe=PROBE)
    advice = db.advise(Q1, selectivity=0.1)
    assert {"scores", "recommended", "n_rows", "cost_model"} <= set(advice)
    assert advice["recommended"] in advice["scores"]
    assert advice["n_rows"] == 1200
