"""Property tests on the system's invariants, plus the randomized
differential oracle over Q1-Q6 lowerings.

Two generator backends drive the same properties:

* **hypothesis**, when installed — shrinking, example databases, the works;
* a **seeded-rng fallback** otherwise — the differential oracle (the part
  this repo's CI must never silently skip) re-runs under parametrized
  ``numpy.random.default_rng`` seeds, so predicates/binds are still
  randomized per run of the suite's seed matrix.

The differential oracle (DESIGN.md §15) executes every randomly drawn
(case, batch size, bind set) through five lowerings and asserts bit-parity:
exact-shape flat (the reference), size-bucketed, int8-quantized, the
AOT-persisted-then-loaded executable, and the IVF engine (bucketed vs its
own exact-shape; result-set equality vs flat for the top-k class).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                        # pragma: no cover
    HAVE_HYPOTHESIS = False

    def settings(*_a, **_k):
        """No-op stand-in for hypothesis.settings."""
        return lambda fn: fn

    def given(*_a, **_k):
        """Stand-in for hypothesis.given: marks the test skipped (with the
        registered reason) instead of failing at import."""
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed in this container")(fn)

    class _StrategyShim:
        """Accepts any strategy-building expression at module scope."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyShim()

from repro.core.expr import distance_values, in_range, order_key
from repro.core.schema import Metric
from repro.core.sql import parse_sql
from repro.core.plan import Filter, walk_plan
from repro.index.flat import masked_topk
from repro.training.step import dequantize_int8, quantize_int8

FLOATS = st.floats(-1e3, 1e3, allow_nan=False, width=32)


@settings(max_examples=40, deadline=None)
@given(st.lists(FLOATS, min_size=1, max_size=64), st.data())
def test_masked_topk_invariants(keys, data):
    n = len(keys)
    mask = data.draw(st.lists(st.booleans(), min_size=n, max_size=n))
    k = data.draw(st.integers(1, n))
    keys_a = jnp.asarray(np.array(keys, np.float32))
    ids = jnp.arange(n, dtype=jnp.int32)
    mk, mi, mv = masked_topk(keys_a, ids, jnp.asarray(mask), k)
    mk, mi, mv = np.asarray(mk), np.asarray(mi), np.asarray(mv)
    masked_keys = np.array(keys, np.float32)[np.asarray(mask)]
    # 1) number of valid results = min(k, #masked)
    assert mv.sum() == min(k, len(masked_keys))
    # 2) valid ids are distinct and satisfy the mask
    got = mi[mv]
    assert len(set(got.tolist())) == len(got)
    assert all(mask[i] for i in got)
    # 3) ascending order and exactly the smallest masked keys
    assert (np.diff(mk[mv]) >= 0).all()
    want = np.sort(masked_keys)[:mv.sum()]
    np.testing.assert_allclose(np.sort(mk[mv]), want, rtol=1e-6, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(list(Metric)),
       st.lists(st.lists(FLOATS, min_size=4, max_size=4), min_size=1,
                max_size=32),
       st.lists(FLOATS, min_size=4, max_size=4), FLOATS)
def test_range_consistent_with_order_key(metric, xs, q, radius):
    """in_range(v, r) must equal order_key(v) <= order_key(r): the index's
    key-space reasoning and the predicate semantics cannot diverge."""
    x = jnp.asarray(np.array(xs, np.float32))
    qv = jnp.asarray(np.array(q, np.float32))
    raw = distance_values(metric, x, qv)
    lhs = np.asarray(in_range(metric, raw, radius))
    rhs = np.asarray(order_key(metric, raw)
                     <= order_key(metric, jnp.float32(radius)))
    assert (lhs == rhs).all()


@settings(max_examples=40, deadline=None)
@given(st.lists(FLOATS, min_size=1, max_size=256))
def test_int8_error_feedback_bound(vals):
    """Quantization error is bounded by scale/2 per element — the invariant
    the error-feedback compressor relies on."""
    x = jnp.asarray(np.array(vals, np.float32))
    q, scale = quantize_int8(x)
    err = np.asarray(x - dequantize_int8(q, scale))
    assert (np.abs(err) <= float(scale) * 0.5 + 1e-6).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 100), st.booleans())
def test_sql_roundtrip_predicates(thresh, limit, flip):
    op = "<" if flip else ">"
    sql = (f"SELECT sample_id FROM products WHERE price {op} {thresh} "
           f"ORDER BY DISTANCE(embedding, ${{qv}}) LIMIT {limit}")
    plan = parse_sql(sql)
    filt = next(n for n in walk_plan(plan) if isinstance(n, Filter))
    assert filt.predicate.op == op
    assert filt.predicate.rhs.value == thresh


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(1, 4))
def test_ivf_exactness_property(nlist, k):
    """IVF with 'bound' termination + unlimited probes is EXACT for any
    clustered corpus — the core soundness property of the adaptation."""
    rng = np.random.default_rng(nlist * 13 + k)
    x = rng.standard_normal((300, 8)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    from repro.index import FlatIndex, build_ivf
    from repro.index.ivf import ProbeConfig, ivf_topk
    corpus = jnp.asarray(x)
    idx = build_ivf(jax.random.key(0), corpus, nlist=nlist,
                    metric=Metric.L2, iters=3)
    flat = FlatIndex(Metric.L2, corpus)
    q = corpus[0] + 0.05
    gt, _, _ = flat.topk(q, k)
    ids, _, valid, _ = ivf_topk(
        idx, corpus, q, k,
        cfg=ProbeConfig(max_probes=nlist, termination="bound"))
    assert set(np.asarray(ids).tolist()) == set(np.asarray(gt).tolist())


# ---------------------------------------------------------------------------
# randomized differential oracle over Q1-Q6 lowerings (DESIGN.md §15)
# ---------------------------------------------------------------------------
# The four hand-written parity families (exact-shape vs bucketed, fp32 vs
# quant, in-memory vs AOT-loaded, flat vs IVF) become ONE oracle fed by a
# generator: draw (case, batch size, predicates, query vectors), execute
# through every lowering, require bit-parity with the exact-shape flat
# reference.  `test_aot_cache.build_env` supplies the deterministic corpus.

from test_aot_cache import ALL_SQL, PROBE, build_env, ser_tree  # noqa: E402

from repro.api import ExecutionHints, connect  # noqa: E402
from repro.core import EngineOptions  # noqa: E402

EXACT = ExecutionHints(exact_shape=True)
DIFF_QNS = (1, 5)           # exact-shape traces one executable per distinct Q


@pytest.fixture(scope="module")
def denv():
    return build_env()


@pytest.fixture(scope="module")
def ddbs(denv, tmp_path_factory):
    """Lane databases for the oracle, all over one catalog: flat, quant,
    IVF, and the AOT save/load pair (same disk dir, separate sessions, so
    the loaded lane actually restores executables the saving lane
    persisted)."""
    cat, _ = denv
    aot_dir = str(tmp_path_factory.mktemp("diff-aot"))
    def opts(**kw):
        return EngineOptions(engine="brute", probe=PROBE, use_pallas=True,
                             **kw)

    return {
        "flat": connect(cat, opts()),
        "quant": connect(cat, opts(quant="int8")),
        "ivf": connect(cat, EngineOptions(engine="chase", probe=PROBE,
                                          use_pallas=True)),
        "aot_save": connect(cat, opts(), aot_cache_path=aot_dir),
        "aot_load": connect(cat, opts(), aot_cache_path=aot_dir),
    }


def _draw_binds(case, cat, radius, qn, rng):
    """Randomized per-case binds: query vectors jittered off real queries,
    thresholds drawn over the live column quantiles, radii scaled around
    the calibrated match radius."""
    base = np.asarray(cat.table("queries")["embedding"])
    price = np.asarray(cat.table("laion")["price"])
    dates = np.asarray(cat.table("laion")["capture_date"])
    years = np.asarray(cat.table("movies")["release_year"])
    qs = (base[rng.integers(0, base.shape[0], qn)]
          + 0.05 * rng.standard_normal((qn, base.shape[1]))
          ).astype(np.float32)
    out = []
    for i in range(qn):
        r = np.float32(radius * rng.uniform(0.8, 1.05))
        if case == "q1":
            out.append({"qv": qs[i], "p": np.float32(
                np.quantile(price, rng.uniform(0.05, 1.0)))})
        elif case == "q2":
            out.append({"qv": qs[i], "r": r, "d": np.int32(
                np.quantile(dates, rng.uniform(0.0, 0.9)))})
        elif case in ("q3", "q6"):
            out.append({"r": r})
        elif case == "q4":
            out.append({"y": np.int32(
                np.quantile(years, rng.uniform(0.0, 0.8)))})
        elif case == "q5":
            out.append({"qv": qs[i], "r": r})
    return out


def _check_differential(ddbs, denv, case, qn, seed):
    cat, radius = denv
    binds = _draw_binds(case, cat, radius, qn, np.random.default_rng(seed))
    sql = ALL_SQL[case]
    ctx = f"{case}/qn={qn}/seed={seed}"

    ref = ser_tree(ddbs["flat"].prepare(sql)
                   .execute(binds, hints=EXACT).data)
    # bucketed flat: the pad-query lane must be inert
    assert ser_tree(ddbs["flat"].prepare(sql).execute(binds).data) == ref, (
        f"bucketed != exact-shape [{ctx}]")
    # int8 quantized scan with fused fp32 rescore: bytes change, bits don't
    assert ser_tree(ddbs["quant"].prepare(sql).execute(binds).data) == ref, (
        f"quant != flat [{ctx}]")
    # AOT: persist through one session, load through a fresh one
    assert ser_tree(ddbs["aot_save"].prepare(sql)
                    .execute(binds).data) == ref, f"aot-save != flat [{ctx}]"
    st_load = ddbs["aot_load"].prepare(sql)
    assert ser_tree(st_load.execute(binds).data) == ref, (
        f"aot-load != flat [{ctx}]")
    assert all(v == 0 for v in st_load.executor.trace_counts.values()), (
        f"aot-load lane traced [{ctx}]: {st_load.executor.trace_counts}")
    # IVF engine: bit-identical to its OWN exact-shape lowering; for the
    # top-k class the result id set equals flat's (ordering keys differ in
    # float-accumulation order, so cross-engine bitwise is not the contract)
    ivf_stmt = ddbs["ivf"].prepare(sql)
    assert (ser_tree(ivf_stmt.execute(binds).data)
            == ser_tree(ivf_stmt.execute(binds, hints=EXACT).data)), (
        f"ivf bucketed != ivf exact-shape [{ctx}]")
    if case == "q1":
        got = ivf_stmt.execute(binds).data
        want = ddbs["flat"].prepare(sql).execute(binds).data
        for q in range(qn):
            gv, wv = (np.asarray(got["valid"])[q], np.asarray(want["valid"])[q])
            assert (set(np.asarray(got["ids"])[q][gv].tolist())
                    == set(np.asarray(want["ids"])[q][wv].tolist())), (
                f"ivf != flat id set [{ctx}] query {q}")


_FALLBACK_EXAMPLES = [(case, qn, 1000 * i + j)
                      for i, case in enumerate(sorted(ALL_SQL))
                      for j, qn in enumerate(DIFF_QNS)]


@pytest.mark.parametrize("case,qn,seed", _FALLBACK_EXAMPLES)
def test_differential_oracle_seeded(ddbs, denv, case, qn, seed):
    """The seeded-rng leg: always runs, hypothesis installed or not."""
    _check_differential(ddbs, denv, case, qn, seed)


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_differential_oracle_hypothesis(ddbs, denv, data):
    """The hypothesis leg: free-form draws over the same oracle (skipped
    with a registered reason when hypothesis is absent)."""
    case = data.draw(st.sampled_from(sorted(ALL_SQL)))
    qn = data.draw(st.sampled_from(DIFF_QNS))
    seed = data.draw(st.integers(0, 2**31 - 1))
    _check_differential(ddbs, denv, case, qn, seed)
